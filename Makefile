# HEAPr build / verify entry points.
#
# `make verify` is the one-stop gate: advisory lints (fmt, clippy) followed
# by tier-1 (release build + full test suite). The lints are advisory —
# prefixed with `-` — because the offline build image pins no rustfmt or
# clippy; formatting drift must not mask tier-1 signal. Promote them to
# gating once CI pins a toolchain (see ROADMAP Open items).

PRESET ?= tiny
ARTIFACTS := artifacts/$(PRESET)

.PHONY: all build test tier1 fmt clippy verify artifacts bench clean

all: build

build:
	cargo build --release

test:
	cargo test -q

# Tier-1 gate (ROADMAP): release build + full test suite.
tier1: build test

fmt:
	-cargo fmt --check

clippy:
	-cargo clippy --all-targets

verify: fmt clippy tier1

# Export AOT HLO artifacts + manifest.json (requires the python/JAX
# toolchain). Optional: the rust host backend synthesizes the manifest for
# the built-in presets (tiny|small|base) when this has not been run.
artifacts:
	cd python && python -m compile.aot --preset $(PRESET) --out-dir ../$(ARTIFACTS)

bench:
	cargo bench --bench bench_runtime
	cargo bench --bench bench_serve

clean:
	cargo clean
