# HEAPr build / verify entry points.
#
# `make verify` is the one-stop gate: gating lints (fmt, clippy -D
# warnings), the documentation gate (rustdoc with warnings denied),
# then tier-1 (release build + full test suite). The toolchain —
# including rustfmt and clippy — is pinned by rust-toolchain.toml, so
# lint drift is a real signal, not toolchain skew. Use `make tier1`
# alone when iterating on a machine without the lint components.

PRESET ?= tiny
ARTIFACTS := artifacts/$(PRESET)

.PHONY: all build test tier1 fmt clippy docs verify artifacts bench bench-native clean

all: build

build:
	cargo build --release

test:
	cargo test -q

# Tier-1 gate (ROADMAP): release build + full test suite.
tier1: build test

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

# Documentation gate: rustdoc over the public API with warnings denied,
# so broken intra-doc links, links to private items, bad code fences and
# malformed HTML in doc comments fail the build instead of rotting.
# docs/ARCHITECTURE.md is the prose system map; this keeps the API
# reference honest next to it.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

verify: fmt clippy docs tier1

# Export AOT HLO artifacts + manifest.json (requires the python/JAX
# toolchain). Optional: the rust host backend synthesizes the manifest for
# the built-in presets (tiny|small|base) when this has not been run.
artifacts:
	cd python && python -m compile.aot --preset $(PRESET) --out-dir ../$(ARTIFACTS)

# Perf sweeps. bench_runtime sweeps the GEMM `kernel` axis (naive vs
# blocked vs simd — the simd leg only where runtime CPU detection finds
# avx2+fma) and refreshes the checked-in BENCH_kernels.json summary at
# the repo root so the kernel-perf trajectory is tracked across PRs;
# bench_serve adds the same axis to end-to-end decode throughput.
bench:
	cargo bench --bench bench_runtime
	cargo bench --bench bench_serve

# Same sweeps under -C target-cpu=native codegen. Opt-in and bench-only:
# the produced binaries are NOT portable (SIGILL on any older CPU — the
# exact trap the runtime-dispatched kernels removed from the default
# build). Useful to measure how close runtime dispatch comes to a
# native-tuned build on the same machine.
bench-native:
	RUSTFLAGS="-C target-cpu=native" cargo bench --bench bench_runtime
	RUSTFLAGS="-C target-cpu=native" cargo bench --bench bench_serve

clean:
	cargo clean
