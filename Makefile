# HEAPr build / verify entry points.
#
# `make verify` is the one-stop gate: gating lints (fmt, clippy -D
# warnings), the documentation gate (rustdoc with warnings denied),
# the repo linter (heapr-lint: SAFETY-comment audit + repo rules),
# then tier-1 (release build + full test suite). The toolchain —
# including rustfmt and clippy — is pinned by rust-toolchain.toml, so
# lint drift is a real signal, not toolchain skew. Use `make tier1`
# alone when iterating on a machine without the lint components.

PRESET ?= tiny
ARTIFACTS := artifacts/$(PRESET)

.PHONY: all build test tier1 fmt clippy docs lint miri verify artifacts bench bench-native clean

all: build

build:
	cargo build --release

test:
	cargo test -q

# Tier-1 gate (ROADMAP): release build + full test suite.
tier1: build test

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

# Documentation gate: rustdoc over the public API with warnings denied,
# so broken intra-doc links, links to private items, bad code fences and
# malformed HTML in doc comments fail the build instead of rotting.
# docs/ARCHITECTURE.md is the prose system map; this keeps the API
# reference honest next to it.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

# Repo linter (rust/src/lint): dependency-free static analysis, twelve
# rules — the SAFETY-comment convention on every unsafe site, the
# NaN-ordering ban (no partial_cmp().unwrap() outside util::cmp), the
# single-spawn-path policy (util::pool::spawn_named), the HEAPR_* env-var
# registry against README's table, rust/tests ⇄ Cargo.toml test
# registration, the ARCHITECTURE §2 layer map (layering, doc-driven),
# lock acquisition-order cycles (lock-order), the decode-hot-path panic
# ban (panic-free-serve), SendPtr/RowsPtr construction confinement
# (sendptr-confinement), heap allocations reachable from the decode
# entry set (hot-path-alloc — the allocation-free steady-state decode
# invariant), unpinned float reductions (float-accum-order), and
# discarded Results (swallowed-result). `--list-rules` / `--explain
# <rule>` document the catalogue from the binary itself. Exits nonzero
# with clickable file:line:col diagnostics; escape hatch is a
# span-anchored `// lint:allow(<rule>)` comment (see README). CI runs
# the same binary with --json under a 10s wall-clock budget and renders
# findings as PR annotations.
lint:
	cargo run -q --release --bin heapr-lint -- --root .

# Nightly-only: run the cfg(miri)-shrunk unsafe-substrate subset under
# Miri (pool fan-out, RowsPtr disjoint slicing, lane writes). Override
# MIRI_NIGHTLY to use the CI-pinned toolchain (see verify.yml); needs
# `rustup +$(MIRI_NIGHTLY) component add miri`. Mirrored by the gating
# CI job in .github/workflows/verify.yml.
MIRI_NIGHTLY ?= nightly
miri:
	cargo +$(MIRI_NIGHTLY) miri test --test miri_subset

verify: fmt clippy docs lint tier1

# Export AOT HLO artifacts + manifest.json (requires the python/JAX
# toolchain). Optional: the rust host backend synthesizes the manifest for
# the built-in presets (tiny|small|base) when this has not been run.
artifacts:
	cd python && python -m compile.aot --preset $(PRESET) --out-dir ../$(ARTIFACTS)

# Perf sweeps. bench_runtime sweeps the GEMM `kernel` axis (naive vs
# blocked vs simd — the simd leg only where runtime CPU detection finds
# avx2+fma) and refreshes the checked-in BENCH_kernels.json summary at
# the repo root so the kernel-perf trajectory is tracked across PRs;
# bench_serve adds the same axis to end-to-end decode throughput;
# bench_load replays open-loop Poisson arrivals against a live loopback
# HTTP server and refreshes BENCH_load.json (TTFT/completion
# percentiles, shed rate, saturation knee).
bench:
	cargo bench --bench bench_runtime
	cargo bench --bench bench_serve
	cargo bench --bench bench_load

# Same sweeps under -C target-cpu=native codegen. Opt-in and bench-only:
# the produced binaries are NOT portable (SIGILL on any older CPU — the
# exact trap the runtime-dispatched kernels removed from the default
# build). Useful to measure how close runtime dispatch comes to a
# native-tuned build on the same machine.
bench-native:
	RUSTFLAGS="-C target-cpu=native" cargo bench --bench bench_runtime
	RUSTFLAGS="-C target-cpu=native" cargo bench --bench bench_serve

clean:
	cargo clean
