//! Offline-image subset of the `anyhow` crate.
//!
//! The container this repo builds in has no crates.io registry, so the
//! handful of `anyhow` APIs the codebase uses are re-implemented here:
//! [`Error`] (a context chain of messages), [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait.
//!
//! Semantics intentionally mirror upstream where the codebase depends on
//! them: `{e}` prints the outermost message, `{e:#}` prints the whole
//! chain joined by `: `, `{e:?}` prints the chain as a `Caused by:` list,
//! and `?` converts any `std::error::Error + Send + Sync + 'static`.

use std::fmt;

/// Error as a chain of context messages; `chain[0]` is the outermost.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (becomes the new outermost).
    pub fn context(mut self, message: impl fmt::Display) -> Error {
        self.chain.insert(0, message.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// Outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`; that is what
// makes the blanket `From` below coherent (same trick as upstream anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to `Result`/`Option` errors.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, message: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, message: C) -> Result<T> {
        self.map_err(|e| e.into().context(message))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, message: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(message))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] from format-args.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an [`Error`] unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading x").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading x: gone");
        let o: Option<u32> = None;
        assert_eq!(format!("{}", o.context("missing").unwrap_err()), "missing");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x > 1, "too small: {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(0).unwrap_err()), "too small: 0");
        assert_eq!(format!("{}", f(11).unwrap_err()), "too big: 11");
        let e = anyhow!("v={}", 3);
        assert_eq!(format!("{e}"), "v=3");
    }
}
