//! Expert-kernel latency vs retained width — the mechanism behind Figure 2's
//! FLOPs-saving axis and Table 3's "real acceleration" claim: halving the
//! atomic-expert width should roughly halve expert dispatch time.

use heapr::bench::Bench;
use heapr::runtime::{Engine, Value};
use heapr::tensor::Tensor;
use heapr::util::rng::Pcg64;

fn main() {
    let engine = Engine::open("artifacts/tiny").expect("run `make artifacts`");
    let cfg = engine.config().clone();
    let d = cfg.d_model;
    let mut rng = Pcg64::new(2);
    let mut bench = Bench::default();

    let n = *cfg.token_buckets.last().unwrap();
    let x = Tensor::from_vec(&[n, d], (0..n * d).map(|_| rng.normal()).collect());
    for &w in &cfg.width_buckets {
        let name = format!("expert_n{n}_w{w}");
        engine.warmup(&[name.as_str()]).unwrap();
        let wg = Tensor::from_vec(&[w, d], (0..w * d).map(|_| rng.normal() * 0.2).collect());
        let wu = Tensor::from_vec(&[w, d], (0..w * d).map(|_| rng.normal() * 0.2).collect());
        let wdn = Tensor::from_vec(&[d, w], (0..w * d).map(|_| rng.normal() * 0.2).collect());
        bench.run(&format!("expert n={n} width={w}"), || {
            std::hint::black_box(engine.run(&name, &[
                Value::F32(x.clone()),
                Value::F32(wg.clone()),
                Value::F32(wu.clone()),
                Value::F32(wdn.clone()),
            ]).unwrap());
        }, Some((n as f64, "tok/s")));
    }

    // token-bucket scaling at full width
    let w = *cfg.width_buckets.last().unwrap();
    for &nb in &cfg.token_buckets {
        let name = format!("expert_n{nb}_w{w}");
        engine.warmup(&[name.as_str()]).unwrap();
        let xs = Tensor::from_vec(&[nb, d], (0..nb * d).map(|_| rng.normal()).collect());
        let wg = Tensor::from_vec(&[w, d], (0..w * d).map(|_| rng.normal() * 0.2).collect());
        let wu = wg.clone();
        let wdn = Tensor::from_vec(&[d, w], (0..w * d).map(|_| rng.normal() * 0.2).collect());
        bench.run(&format!("expert n={nb} width={w}"), || {
            std::hint::black_box(engine.run(&name, &[
                Value::F32(xs.clone()),
                Value::F32(wg.clone()),
                Value::F32(wu.clone()),
                Value::F32(wdn.clone()),
            ]).unwrap());
        }, Some((nb as f64, "tok/s")));
    }

    bench.save("runs/bench/expert.json").unwrap();
}
