//! End-to-end serving throughput, dense vs HEAPr-pruned (Appendix C shape):
//! the headline "pruning buys real latency" measurement.

use heapr::bench::Bench;
use heapr::coordinator::{Request, Server};
use heapr::data::corpus::Grammar;
use heapr::data::sampler::Split;
use heapr::data::tokenizer::ByteTokenizer;
use heapr::heapr::PrunePlan;
use heapr::heapr::Scope;
use heapr::model::store::ParamStore;
use heapr::runtime::Engine;
use heapr::tensor::Tensor;

fn main() {
    let engine = Engine::open("artifacts/tiny").expect("run `make artifacts`");
    let cfg = engine.config().clone();
    let grammar = Grammar::standard();
    let split = Split::from_docs(&grammar.corpus("wiki", 0, 100_000), cfg.seq_len);
    let params = ParamStore::init(&engine.manifest, 0);
    let mut bench = Bench::quick();

    // pseudo-scores: deterministic spread so plans are reproducible
    let n = cfg.n_atomic();
    let scores = Tensor::from_vec(
        &[cfg.n_layers, cfg.n_experts, cfg.d_inter],
        (0..n).map(|i| ((i * 2654435761) % 10_000) as f32).collect(),
    );

    let prompt = split.chunks[0][..32].to_vec();
    let new_tokens = 8;
    let bb = *cfg.serve_batches.last().unwrap();
    let mk_requests = || -> Vec<Request> {
        (0..bb).map(|i| Request::new(i as u64, prompt.clone(), new_tokens)).collect()
    };
    let tok_per_run = (bb * new_tokens) as f64;

    for ratio in [0.0, 0.25, 0.5, 0.75] {
        let plan = if ratio == 0.0 {
            None
        } else {
            Some(PrunePlan::from_scores(&scores, ratio, Scope::Global)
                .bucket_aligned(&scores, cfg.blk_i))
        };
        let mut server = Server::new(&engine, &params, plan.as_ref()).unwrap();
        // warm the executables once
        server.serve_batch(&mk_requests()).unwrap();
        bench.run(&format!("serve b{bb} gen{new_tokens} ratio={ratio:.2}"), || {
            let reqs = mk_requests();
            std::hint::black_box(server.serve_batch(&reqs).unwrap());
        }, Some((tok_per_run, "tok/s")));
        let _ = ByteTokenizer; // keep import for doc symmetry
    }

    bench.save("runs/bench/serve.json").unwrap();
}
