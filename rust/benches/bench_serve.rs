//! End-to-end serving throughput, dense vs HEAPr-pruned (Appendix C shape)
//! across the `HEAPR_THREADS` axis, the decode-residency axis and the
//! GEMM `kernel` axis: the headline "pruning buys real latency, threads
//! buy real throughput, engine-resident KV sessions stop paying the
//! marshalling tax, and the blocked kernels buy real decode steps/s"
//! measurement.
//!
//! Per (kernel, threads, ratio, residency) cell one server is built and
//! one batch is served to warm the executables, then `serve_batch` is
//! timed and the per-decode-step upload traffic is reported next to
//! tokens/s. Only the default kernel tier (simd where runtime detection
//! finds avx2+fma, else blocked) runs the full ratio grid — the other
//! tiers are before/after baselines and only measure the dense cells.
//! The final lines report the dense-serving speedups: widest thread
//! count over the serial pool, session over legacy, blocked over naive,
//! and (where detected) simd over blocked — the §Perf acceptance
//! numbers.
//!
//! A final admission-policy axis serves one mixed-extent request stream
//! batch-at-once vs continuously (lane scheduler, in-flight admission)
//! and reports per-request p50/p99 latency — submission to completion,
//! queue wait included — alongside tok/s for both.
//!
//! Last, the shared-prefix axis: N requests sharing a long system prompt
//! served continuously under paged residency across a page-size sweep
//! (plus a dense-resident baseline). Per-request p50/p99 admission
//! latency, the prefix-cache counters, and the analytic
//! max-concurrent-lanes-per-GB figure land in `BENCH_serve_paged.json`
//! at the repo root.

use heapr::bench::Bench;
use heapr::coordinator::{serve_continuous, Batcher, Request, Residency, SchedulerOpts, Server};
use heapr::data::corpus::Grammar;
use heapr::data::sampler::Split;
use heapr::data::tokenizer::ByteTokenizer;
use heapr::heapr::PrunePlan;
use heapr::heapr::Scope;
use heapr::model::flops::{kv_lane_bytes, kv_lanes_per_budget, kv_paged_lane_bytes};
use heapr::model::store::ParamStore;
use heapr::runtime::Engine;
use heapr::tensor::gemm;
use heapr::tensor::Tensor;
use heapr::util::json::Json;
use heapr::util::pool;
use heapr::util::stats::percentile;

const THREAD_AXIS: &[usize] = &[1, 2, 4];
const RATIOS: &[f64] = &[0.0, 0.25, 0.5, 0.75];
const RESIDENCY_AXIS: &[(Residency, &str)] = &[
    (Residency::Resident, "session"),
    (Residency::Paged, "paged"),
    (Residency::Legacy, "legacy"),
];
/// Page sizes swept by the shared-prefix axis (positions per KV page).
const PAGE_AXIS: &[usize] = &[8, 16, 32];

fn main() {
    let engine = Engine::open("artifacts/tiny").expect("open tiny preset");
    let cfg = engine.config().clone();
    let grammar = Grammar::standard();
    let split = Split::from_docs(&grammar.corpus("wiki", 0, 100_000), cfg.seq_len);
    let params = ParamStore::init(&engine.manifest, 0);
    let mut bench = Bench::quick();

    // pseudo-scores: deterministic spread so plans are reproducible
    let n = cfg.n_atomic();
    let scores = Tensor::from_vec(
        &[cfg.n_layers, cfg.n_experts, cfg.d_inter],
        (0..n).map(|i| ((i * 2654435761) % 10_000) as f32).collect(),
    );

    let prompt = split.chunks[0][..32].to_vec();
    let new_tokens = 8;
    let bb = *cfg.serve_batches.last().unwrap();
    let mk_requests = || -> Vec<Request> {
        (0..bb).map(|i| Request::new(i as u64, prompt.clone(), new_tokens)).collect()
    };
    let tok_per_run = (bb * new_tokens) as f64;

    // the default tier runs the full grid; the others are baselines and
    // only measure the dense cells. The simd leg only exists where the
    // CPU really has avx2+fma — elsewhere it would just re-measure the
    // blocked fallback under a misleading label.
    let default_kernel = gemm::default_kernel();
    let mut kernel_axis: Vec<(gemm::Kernel, &str)> = Vec::new();
    if gemm::simd_available() {
        kernel_axis.push((gemm::Kernel::Simd, "simd"));
    } else {
        println!("[kernel axis] avx2+fma not detected: simd leg skipped");
    }
    kernel_axis.push((gemm::Kernel::Blocked, "blocked"));
    kernel_axis.push((gemm::Kernel::Naive, "naive"));

    // (kernel, threads, tok/s) at ratio 0.0, per residency label
    let mut dense_tps: Vec<(&str, usize, &str, f64)> = Vec::new();
    for &(kernel, klabel) in &kernel_axis {
        gemm::set_kernel(kernel);
        for &threads in THREAD_AXIS {
            pool::set_threads(threads);
            for &ratio in RATIOS {
                // baseline tiers only run the dense cells
                if kernel != default_kernel && ratio != 0.0 {
                    continue;
                }
                let plan = if ratio == 0.0 {
                    None
                } else {
                    Some(PrunePlan::from_scores(&scores, ratio, Scope::Global)
                        .bucket_aligned(&scores, cfg.blk_i))
                };
                for &(residency, label) in RESIDENCY_AXIS {
                    let mut server = Server::new(&engine, &params, plan.as_ref()).unwrap();
                    server.set_residency(residency);
                    // warm the executables once
                    server.serve_batch(&mk_requests()).unwrap();
                    let r = bench.run(
                        &format!(
                            "serve b{bb} gen{new_tokens} ratio={ratio:.2} \
                             threads={threads} {label} kernel={klabel}"
                        ),
                        || {
                            let reqs = mk_requests();
                            std::hint::black_box(server.serve_batch(&reqs).unwrap());
                        },
                        Some((tok_per_run, "tok/s")),
                    );
                    println!(
                        "    upload {:>10.0} B/step over {} decode steps ({label})",
                        server.metrics.upload_bytes_per_step(),
                        server.metrics.decode_steps,
                    );
                    if ratio == 0.0 {
                        dense_tps.push((klabel, threads, label, r.throughput.unwrap().0));
                    }
                }
            }
            let _ = ByteTokenizer; // keep import for doc symmetry
        }
    }
    pool::set_threads(pool::default_threads());
    gemm::set_kernel(default_kernel); // back to the documented default

    let find = |kernel: &str, threads: usize, label: &str| {
        dense_tps
            .iter()
            .find(|(kl, t, l, _)| *kl == kernel && *t == threads && *l == label)
            .map(|(_, _, _, tps)| *tps)
    };
    let dk = default_kernel.name();
    let (t0, t1) = (THREAD_AXIS[0], *THREAD_AXIS.last().unwrap());
    if let (Some(a), Some(b)) = (find(dk, t0, "session"), find(dk, t1, "session")) {
        println!("serve speedup (dense, session): threads={t1} vs threads={t0} -> {:.2}x", b / a);
    }
    if let (Some(l), Some(s)) = (find(dk, t1, "legacy"), find(dk, t1, "session")) {
        println!("serve speedup (dense, threads={t1}): session vs legacy -> {:.2}x", s / l);
    }
    if let (Some(nv), Some(bl)) = (find("naive", t1, "session"), find("blocked", t1, "session")) {
        println!(
            "serve speedup (dense, session, threads={t1}): blocked vs naive -> {:.2}x",
            bl / nv
        );
    }
    if let (Some(bl), Some(sd)) = (find("blocked", t1, "session"), find("simd", t1, "session")) {
        println!(
            "serve speedup (dense, session, threads={t1}): simd vs blocked -> {:.2}x",
            sd / bl
        );
    }

    // ---- admission-policy axis: batch-at-once vs continuous ------------
    // A mixed-extent request stream (staggered prompts and budgets) is
    // queued up front and served to drain both ways. Per-request latency
    // is submission -> completion for both modes — queue wait included,
    // which is exactly what batch-at-once pays when a closed batch pins
    // its lanes to the slowest straggler and continuous admission does
    // not. Reported next to tok/s as p50/p99.
    let stream_reqs = || -> Vec<Request> {
        (0..4 * bb)
            .map(|i| {
                let plen = 12 + 8 * (i % 3); // 12/20/28-token prompts
                let budget = 4 + 8 * (i % 4); // 4..28 generated tokens
                Request::new(i as u64, split.chunks[0][..plen].to_vec(), budget)
            })
            .collect()
    };
    let mk_batcher = |reqs: Vec<Request>| {
        let (tx, rx) = std::sync::mpsc::channel();
        for r in reqs {
            tx.send(r).unwrap();
        }
        drop(tx); // pre-queued stream: the serve loop runs to drain
        Batcher::new(rx, cfg.serve_batches.clone(), std::time::Duration::from_millis(1))
    };
    let mut admission_tps: Vec<(&str, f64, f64, f64)> = Vec::new();
    for mode in ["batch-at-once", "continuous"] {
        let mut server = Server::new(&engine, &params, None).unwrap();
        server.serve_batch(&mk_requests()).unwrap(); // warm the executables
        let reqs = stream_reqs();
        let total_tokens: f64 = reqs.iter().map(|r| r.max_new_tokens as f64).sum();
        let mut batcher = mk_batcher(reqs);
        let t0 = std::time::Instant::now();
        let mut lats_ms: Vec<f64> = Vec::new();
        if mode == "continuous" {
            let responses =
                serve_continuous(&mut server, &mut batcher, SchedulerOpts::default()).unwrap();
            lats_ms.extend(responses.iter().map(|r| r.latency_ms));
        } else {
            while let Some(batch) = batcher.next_batch() {
                server.serve_batch(&batch).unwrap();
                // the whole batch completes together: each request's
                // latency runs from its submission to this instant
                lats_ms.extend(
                    batch.iter().map(|r| r.submitted.elapsed().as_secs_f64() * 1000.0),
                );
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let tps = total_tokens / wall;
        let (p50, p99) = (percentile(&lats_ms, 50.0), percentile(&lats_ms, 99.0));
        println!(
            "admission {mode:>13}: {tps:8.1} tok/s, per-request latency \
             p50 {p50:7.1} ms, p99 {p99:7.1} ms ({} requests)",
            lats_ms.len()
        );
        admission_tps.push((mode, tps, p50, p99));
    }
    if let [(_, _, _, p99_b), (_, _, _, p99_c)] = admission_tps[..] {
        println!(
            "admission p99 latency: batch-at-once vs continuous -> {:.2}x",
            p99_b / p99_c
        );
    }

    // ---- shared-prefix axis: paged residency, page-size sweep ----------
    // N requests share one long system prompt and differ only in a short
    // tail: with the prefix cache on, every admission after the first
    // maps the resident prefix pages (refcount++) and prefills only the
    // tail, so admission latency and prefill work both drop. Swept over
    // `PAGE_AXIS` page sizes plus a dense-resident baseline; each leg
    // reports per-request p50/p99, the prefix counters, and the analytic
    // lanes-per-GB figure from the observed workload extents.
    let shared = split.chunks[0][..32].to_vec();
    let prefix_reqs = || -> Vec<Request> {
        (0..4 * bb)
            .map(|i| {
                let mut p = shared.clone();
                p.extend((0..4 + 2 * (i % 3)).map(|j| ((i * 13 + j * 5) % 250 + 2) as i32));
                Request::new(i as u64, p, 4 + 4 * (i % 4))
            })
            .collect()
    };
    let probe = prefix_reqs();
    let max_extent = probe.iter().map(|r| r.extent()).max().unwrap();
    let mean_rows =
        probe.iter().map(|r| r.extent()).sum::<usize>() / probe.len();
    let prompt_rows: usize = probe.iter().map(|r| r.prompt.len()).sum();
    const GB: usize = 1 << 30;
    let dense_lane = kv_lane_bytes(&cfg, max_extent);

    let mut axis_rows: Vec<Json> = Vec::new();
    let mut legs: Vec<(String, Residency, usize)> = PAGE_AXIS
        .iter()
        .map(|&p| (format!("paged/{p}"), Residency::Paged, p))
        .collect();
    legs.push(("dense".to_string(), Residency::Resident, 0));
    for (label, residency, page) in legs {
        let mut server = Server::new(&engine, &params, None).unwrap();
        server.set_residency(residency);
        if page > 0 {
            server.set_kv_page(page);
        }
        server.serve_batch(&mk_requests()).unwrap(); // warm the executables
        let (pages0, reused0, skipped0) = (
            server.metrics.kv_pages_allocated,
            server.metrics.prefix_pages_reused,
            server.metrics.prefill_rows_skipped,
        );
        let reqs = prefix_reqs();
        let total_tokens: f64 = reqs.iter().map(|r| r.max_new_tokens as f64).sum();
        let mut batcher = mk_batcher(reqs);
        let t0 = std::time::Instant::now();
        let responses =
            serve_continuous(&mut server, &mut batcher, SchedulerOpts::default()).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let lats_ms: Vec<f64> = responses.iter().map(|r| r.latency_ms).collect();
        let (p50, p99) = (percentile(&lats_ms, 50.0), percentile(&lats_ms, 99.0));
        let tps = total_tokens / wall;
        let pages = server.metrics.kv_pages_allocated - pages0;
        let reused = server.metrics.prefix_pages_reused - reused0;
        let skipped = server.metrics.prefill_rows_skipped - skipped0;
        let hit_rate = skipped as f64 / prompt_rows as f64;
        let lane_bytes = if page > 0 {
            kv_paged_lane_bytes(&cfg, page, mean_rows)
        } else {
            dense_lane
        };
        let lanes_per_gb = kv_lanes_per_budget(GB, lane_bytes);
        println!(
            "shared-prefix {label:>9}: {tps:8.1} tok/s, p50 {p50:7.1} ms, p99 {p99:7.1} ms, \
             {reused} prefix pages reused, {skipped} prefill rows skipped \
             (hit rate {:.1}%), {lanes_per_gb} lanes/GB",
            100.0 * hit_rate
        );
        axis_rows.push(Json::obj(vec![
            ("leg", Json::s(label)),
            ("page", Json::n(page as f64)),
            ("tok_s", Json::n(tps)),
            ("latency_p50_ms", Json::n(p50)),
            ("latency_p99_ms", Json::n(p99)),
            ("kv_pages_allocated", Json::n(pages as f64)),
            ("kv_pages_peak", Json::n(server.metrics.kv_pages_peak as f64)),
            ("prefix_pages_reused", Json::n(reused as f64)),
            ("prefill_rows_skipped", Json::n(skipped as f64)),
            ("prefix_hit_rate", Json::n(hit_rate)),
            ("lane_bytes", Json::n(lane_bytes as f64)),
            ("max_concurrent_lanes_per_gb", Json::n(lanes_per_gb as f64)),
        ]));
    }
    let summary = Json::obj(vec![
        ("generated_by", Json::s("cargo bench --bench bench_serve")),
        (
            "note",
            Json::s(
                "pending first `make bench` run on a rust-enabled machine; the \
                 authoring container has no cargo, so no measured numbers are \
                 checked in yet — the bench sweeps page sizes over a shared-prefix \
                 request stream and writes tok/s, admission-latency p50/p99, the \
                 prefix-cache counters, and the analytic lanes-per-GB figure here",
            ),
        ),
        ("shared_prompt_tokens", Json::n(shared.len() as f64)),
        ("requests", Json::n(probe.len() as f64)),
        ("max_extent", Json::n(max_extent as f64)),
        ("dense_lane_bytes", Json::n(dense_lane as f64)),
        ("shared_prefix_axis", Json::Arr(axis_rows)),
    ]);
    std::fs::write("BENCH_serve_paged.json", summary.to_string()).unwrap();
    println!("wrote BENCH_serve_paged.json");

    bench.save("runs/bench/serve.json").unwrap();
}
