//! Runtime micro-benchmarks: dispatch overhead, literal marshalling, and
//! the quadform/gate artifacts across the `HEAPR_THREADS` axis. Establishes
//! the per-call floor the coordinator's costs sit on (EXPERIMENTS.md §Perf).

use heapr::bench::Bench;
use heapr::runtime::{Engine, Value};
use heapr::tensor::Tensor;
use heapr::util::pool;
use heapr::util::rng::Pcg64;

const THREAD_AXIS: &[usize] = &[1, 2, 4];

fn main() {
    let engine = Engine::open("artifacts/tiny").expect("open tiny preset");
    let cfg = engine.config().clone();
    let (d, di) = (cfg.d_model, cfg.d_inter);
    let mut rng = Pcg64::new(1);
    let mut bench = Bench::default();

    // literal marshalling round-trip cost (thread-independent)
    let big = Tensor::from_vec(&[256, 256], (0..256 * 256).map(|_| rng.normal()).collect());
    bench.run("literal/to_literal 256x256", || {
        let v = Value::F32(big.clone());
        std::hint::black_box(v.to_literal().unwrap());
    }, Some((256.0 * 256.0 * 4.0 / 1e6, "MB/s")));

    let wd = Tensor::from_vec(&[d, di], (0..d * di).map(|_| rng.normal()).collect());
    let a = Tensor::from_vec(&[d, d], (0..d * d).map(|_| rng.normal() * 0.1).collect());
    let g = heapr::tensor::matmul_tn(&a, &a);
    let router = Tensor::from_vec(&[cfg.n_experts, d],
                                  (0..cfg.n_experts * d).map(|_| rng.normal()).collect());
    let ln = Tensor::ones(&[d]);
    engine.warmup(&["quadform"]).unwrap();

    for &threads in THREAD_AXIS {
        pool::set_threads(threads);

        // smallest artifact: measures the dispatch floor
        bench.run(&format!("artifact/quadform (d={d}, di={di}) threads={threads}"), || {
            std::hint::black_box(
                engine.run("quadform", &[Value::F32(wd.clone()), Value::F32(g.clone())]).unwrap(),
            );
        }, None);

        // gate artifact at each token bucket: dispatch + small GEMM
        for &n in &cfg.token_buckets {
            let name = format!("moe_gate_n{n}");
            engine.warmup(&[name.as_str()]).unwrap();
            let x = Tensor::from_vec(&[n, d], (0..n * d).map(|_| rng.normal()).collect());
            bench.run(&format!("artifact/{name} threads={threads}"), || {
                std::hint::black_box(engine.run(&name, &[
                    Value::F32(x.clone()),
                    Value::F32(ln.clone()),
                    Value::F32(router.clone()),
                ]).unwrap());
            }, Some((n as f64, "tok/s")));
        }
    }
    pool::set_threads(pool::default_threads());

    bench.save("runs/bench/runtime.json").unwrap();
}
