//! Runtime micro-benchmarks: the GEMM `kernel` axis (naive vs blocked vs
//! simd — the last one only where runtime CPU detection finds avx2+fma)
//! on the large matmul shapes the host backend is bound by, plus dispatch
//! overhead, literal marshalling, and the quadform/gate artifacts across
//! the `HEAPR_THREADS` axis. Establishes the per-call floor the
//! coordinator's costs sit on (EXPERIMENTS.md §Perf) and writes the
//! cross-PR `BENCH_kernels.json` summary at the repo root.

use heapr::bench::Bench;
use heapr::runtime::{Engine, Value};
use heapr::tensor::gemm::{self, Layout};
use heapr::tensor::Tensor;
use heapr::util::json::Json;
use heapr::util::pool;
use heapr::util::rng::Pcg64;

const THREAD_AXIS: &[usize] = &[1, 2, 4];

/// Large GEMM shapes (label, layout, m, k, n) mirroring the host
/// backend's hot calls: the expert FFN up-projection, the attention
/// A·V product, and gradient accumulation.
const GEMM_SHAPES: &[(&str, Layout, usize, usize, usize)] = &[
    ("tn/expert-ffn", Layout::TN, 512, 256, 512),
    ("nn/attn-av", Layout::NN, 512, 512, 64),
    ("at/grad-accum", Layout::AT, 512, 256, 512),
];

type GemmFn = fn(Layout, &[f32], &[f32], &mut [f32], usize, usize, usize);

fn main() {
    let engine = Engine::open("artifacts/tiny").expect("open tiny preset");
    let cfg = engine.config().clone();
    let (d, di) = (cfg.d_model, cfg.d_inter);
    let mut rng = Pcg64::new(1);
    // default (not quick) floors: the kernel-axis means feed the
    // checked-in BENCH_kernels.json that later PRs diff against, so
    // run-to-run noise must stay below the deltas being tracked
    let mut bench = Bench::default();

    // ---------------------------------------------------- kernel axis --
    // the simd leg only runs (and is only recorded) where the CPU
    // actually has avx2+fma — on other hosts gemm::simd would silently
    // measure the blocked fallback and pollute the cross-PR JSON
    let mut kernels: Vec<(&str, GemmFn)> =
        vec![("naive", gemm::naive as GemmFn), ("blocked", gemm::blocked as GemmFn)];
    if gemm::simd_available() {
        kernels.push(("simd", gemm::simd as GemmFn));
    } else {
        println!("  [kernel axis] avx2+fma not detected: simd leg skipped");
    }
    let mut kernel_rows: Vec<Json> = Vec::new();
    for &(label, layout, m, k, n) in GEMM_SHAPES {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
        let gflop = 2.0 * (m * k * n) as f64 / 1e9;
        for &threads in THREAD_AXIS {
            pool::set_threads(threads);
            let mut mean_us = vec![0.0f64; kernels.len()];
            for (ki, &(kname, kfn)) in kernels.iter().enumerate() {
                let mut out = vec![0.0f32; m * n];
                let r = bench.run(
                    &format!("gemm/{label} {m}x{k}x{n} kernel={kname} threads={threads}"),
                    || {
                        kfn(layout, &a, &b, &mut out, m, k, n);
                        std::hint::black_box(&out);
                    },
                    Some((gflop, "GFLOP/s")),
                );
                mean_us[ki] = r.mean_us;
            }
            let speedup = mean_us[0] / mean_us[1];
            println!("    blocked vs naive ({label}, threads={threads}): {speedup:.2}x");
            let mut row = vec![
                ("shape", Json::s(format!("{label} {m}x{k}x{n}"))),
                ("threads", Json::n(threads as f64)),
                ("naive_us", Json::n(mean_us[0])),
                ("blocked_us", Json::n(mean_us[1])),
                ("speedup", Json::n(speedup)),
            ];
            if let Some(simd_us) = mean_us.get(2).copied() {
                println!(
                    "    simd vs blocked ({label}, threads={threads}): {:.2}x \
                     (vs naive: {:.2}x)",
                    mean_us[1] / simd_us,
                    mean_us[0] / simd_us,
                );
                row.push(("simd_us", Json::n(simd_us)));
                row.push(("simd_speedup", Json::n(mean_us[0] / simd_us)));
            }
            kernel_rows.push(Json::obj(row));
        }
    }
    pool::set_threads(pool::default_threads());

    // ---------------------------------------- dispatch + artifact floor --
    // literal marshalling round-trip cost (thread-independent)
    let big = Tensor::from_vec(&[256, 256], (0..256 * 256).map(|_| rng.normal()).collect());
    bench.run("literal/to_literal 256x256", || {
        let v = Value::F32(big.clone());
        std::hint::black_box(v.to_literal().unwrap());
    }, Some((256.0 * 256.0 * 4.0 / 1e6, "MB/s")));

    let wd = Tensor::from_vec(&[d, di], (0..d * di).map(|_| rng.normal()).collect());
    let a = Tensor::from_vec(&[d, d], (0..d * d).map(|_| rng.normal() * 0.1).collect());
    let g = heapr::tensor::matmul_tn(&a, &a);
    let router = Tensor::from_vec(&[cfg.n_experts, d],
                                  (0..cfg.n_experts * d).map(|_| rng.normal()).collect());
    let ln = Tensor::ones(&[d]);
    engine.warmup(&["quadform"]).unwrap();

    for &threads in THREAD_AXIS {
        pool::set_threads(threads);

        // smallest artifact: measures the dispatch floor
        bench.run(&format!("artifact/quadform (d={d}, di={di}) threads={threads}"), || {
            std::hint::black_box(
                engine.run("quadform", &[Value::F32(wd.clone()), Value::F32(g.clone())]).unwrap(),
            );
        }, None);

        // gate artifact at each token bucket: dispatch + small GEMM
        for &n in &cfg.token_buckets {
            let name = format!("moe_gate_n{n}");
            engine.warmup(&[name.as_str()]).unwrap();
            let x = Tensor::from_vec(&[n, d], (0..n * d).map(|_| rng.normal()).collect());
            bench.run(&format!("artifact/{name} threads={threads}"), || {
                std::hint::black_box(engine.run(&name, &[
                    Value::F32(x.clone()),
                    Value::F32(ln.clone()),
                    Value::F32(router.clone()),
                ]).unwrap());
            }, Some((n as f64, "tok/s")));
        }
    }
    pool::set_threads(pool::default_threads());

    bench.save("runs/bench/runtime.json").unwrap();

    // perf trajectory across PRs: the kernel-axis summary, checked in
    let summary = Json::obj(vec![
        ("generated_by", Json::s("cargo bench --bench bench_runtime")),
        ("bench_mode", Json::s("default (min 10 iters / 0.5s / 3 warmup)")),
        ("simd_available", Json::Bool(gemm::simd_available())),
        ("kernel_axis", Json::Arr(kernel_rows)),
    ]);
    std::fs::write("BENCH_kernels.json", summary.to_string()).unwrap();
    println!("wrote BENCH_kernels.json");
}
