//! Pruning-pipeline cost (Table 5's measured column): calibration pass 1,
//! pass 2, importance scoring and surgery, benchmarked separately so the
//! §Perf log can attribute regressions.

use heapr::bench::Bench;
use heapr::data::corpus::Grammar;
use heapr::data::sampler::{CalibSampler, Split};
use heapr::heapr::{importance_scores, surgery, Calibrator, PrunePlan, Scope};
use heapr::model::store::ParamStore;
use heapr::runtime::Engine;

fn main() {
    let engine = Engine::open("artifacts/tiny").expect("run `make artifacts`");
    let cfg = engine.config().clone();
    let grammar = Grammar::standard();
    let split = Split::from_docs(&grammar.corpus("wiki", 0, 200_000), cfg.seq_len);
    let params = ParamStore::init(&engine.manifest, 0);
    let calib = split.sample(cfg.batch * 2, 0);
    let batches = CalibSampler::batches(&calib, cfg.batch, cfg.seq_len);
    let mut bench = Bench::quick();

    engine.warmup(&["calib_pass1", "calib_pass2", "quadform"]).unwrap();
    let tokens_per_batch = (cfg.batch * cfg.seq_len) as f64;

    bench.run("calib/pass1 (fwd+bwd batch)", || {
        let mut cal = Calibrator::new(&cfg);
        let (t, g) = &batches[0];
        cal.accumulate_pass1(&engine, &params, t, g).unwrap();
    }, Some((tokens_per_batch, "tok/s")));

    bench.run("calib/pass2 (fwd batch)", || {
        let mut cal = Calibrator::new(&cfg);
        let (t, _) = &batches[0];
        cal.accumulate_pass2(&engine, &params, t).unwrap();
    }, Some((tokens_per_batch, "tok/s")));

    // full stats once, then scoring + surgery timings
    let mut cal = Calibrator::new(&cfg);
    for (t, g) in &batches {
        cal.accumulate_pass1(&engine, &params, t, g).unwrap();
        cal.accumulate_pass2(&engine, &params, t).unwrap();
    }
    let stats = cal.finish();
    let n_atomic = cfg.n_atomic() as f64;

    bench.run("score/importance (all experts)", || {
        std::hint::black_box(importance_scores(&engine, &params, &stats).unwrap());
    }, Some((n_atomic, "atomic/s")));

    let scores = importance_scores(&engine, &params, &stats).unwrap();
    bench.run("plan/global ranking", || {
        std::hint::black_box(PrunePlan::from_scores(&scores, 0.25, Scope::Global));
    }, Some((n_atomic, "atomic/s")));

    let plan = PrunePlan::from_scores(&scores, 0.25, Scope::Global)
        .bucket_aligned(&scores, cfg.blk_i);
    bench.run("surgery/slice weights", || {
        std::hint::black_box(surgery(&params, &plan).unwrap());
    }, None);

    bench.save("runs/bench/pipeline.json").unwrap();
}
