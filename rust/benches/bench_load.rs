//! Open-loop load harness against the live HTTP/1.1 wire layer.
//!
//! Per offered-QPS leg one loopback server is bound and a Poisson
//! arrival schedule ([`PoissonSchedule`], a pure function of the seed)
//! is replayed open-loop: every arrival gets its own connection and
//! fires at its scheduled instant whether or not earlier requests have
//! completed — the generator never waits on the system under test, so
//! saturation shows up as latency growth and shedding instead of a
//! silently throttled offered rate. Per leg the harness reports
//! p50/p99/p999 TTFT (first SSE event on the socket) and completion
//! latency, achieved tok/s, and the shed rate from the bounded
//! admission queue; the sweep's saturation knee — the first offered
//! rate whose achieved completion rate falls below 90% of offered —
//! lands with the legs in `BENCH_load.json` at the repo root.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use heapr::coordinator::{HttpOpts, HttpServer, PoissonSchedule, Server};
use heapr::data::corpus::Grammar;
use heapr::data::sampler::Split;
use heapr::model::store::ParamStore;
use heapr::runtime::Engine;
use heapr::util::json::Json;
use heapr::util::pool;
use heapr::util::stats::percentile;

const SEED: u64 = 0x4c4f_4144;
const QPS_AXIS: &[f64] = &[4.0, 8.0, 16.0, 32.0, 64.0];
const ARRIVALS_PER_LEG: usize = 48;
const BUDGET: usize = 16;
const MAX_QUEUE: usize = 8;
const KNEE_FRACTION: f64 = 0.9;

/// One request's open-loop observation.
struct Sample {
    ttft_ms: f64,
    completion_ms: f64,
    tokens: usize,
    shed: bool,
}

fn find(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Fire one request and watch the socket: TTFT is the instant the first
/// SSE `data:` event shows up past the response head; completion is the
/// terminal chunk (or, for non-200s, the framed error body).
fn fire(addr: SocketAddr, request: &[u8]) -> Sample {
    let mut conn = TcpStream::connect(addr).expect("connect load target");
    conn.set_nodelay(true).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let t0 = Instant::now();
    conn.write_all(request).expect("send load request");
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    let mut ttft = None;
    loop {
        match conn.read(&mut tmp) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => panic!("load read failed: {e}"),
        }
        let Some(head_end) = find(&buf, b"\r\n\r\n") else { continue };
        let body = &buf[head_end + 4..];
        if ttft.is_none() && find(body, b"data: ").is_some() {
            ttft = Some(t0.elapsed());
        }
        let status = std::str::from_utf8(&buf[..head_end])
            .ok()
            .and_then(|h| h.split(' ').nth(1))
            .and_then(|s| s.parse::<u16>().ok())
            .unwrap_or(0);
        if status != 200 {
            // shed (429) or refused (5xx): framed error body, no stream
            return Sample {
                ttft_ms: f64::NAN,
                completion_ms: t0.elapsed().as_secs_f64() * 1000.0,
                tokens: 0,
                shed: status == 429,
            };
        }
        if body.ends_with(b"0\r\n\r\n") {
            break;
        }
    }
    let done = t0.elapsed();
    let tokens = buf.windows(8).filter(|&w| w == b"\"token\":").count();
    Sample {
        ttft_ms: ttft.map(|d| d.as_secs_f64() * 1000.0).unwrap_or(f64::NAN),
        completion_ms: done.as_secs_f64() * 1000.0,
        tokens,
        shed: false,
    }
}

fn main() {
    let engine = Engine::open("artifacts/tiny").expect("open tiny preset");
    let seq_len = engine.config().seq_len;
    let grammar = Grammar::standard();
    let split = Split::from_docs(&grammar.corpus("wiki", 3, 100_000), seq_len);
    let params = ParamStore::init(&engine.manifest, 11);
    let prompt = split.chunks[0][..16].to_vec();

    let toks: Vec<f64> = prompt.iter().map(|&t| t as f64).collect();
    let body = Json::obj(vec![
        ("prompt", Json::arr_f64(&toks)),
        ("max_new_tokens", Json::n(BUDGET as f64)),
    ])
    .to_string();
    let mut request = format!(
        "POST /generate HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\n\r\n",
        body.len()
    )
    .into_bytes();
    request.extend_from_slice(body.as_bytes());
    let request = std::sync::Arc::new(request);

    let mut legs: Vec<Json> = Vec::new();
    let mut knee: Option<f64> = None;
    for &qps in QPS_AXIS {
        let mut server = Server::new(&engine, &params, None).unwrap();
        let http =
            HttpServer::bind(HttpOpts { max_queue: MAX_QUEUE, ..HttpOpts::default() }).unwrap();
        let addr = http.local_addr();
        let shutdown = http.shutdown_handle();
        // the generator runs off-thread: the scheduler owns this one
        let req = request.clone();
        let driver = pool::spawn_named("load-gen", move || {
            let arrivals: Vec<f64> =
                PoissonSchedule::new(SEED, qps).take(ARRIVALS_PER_LEG).collect();
            let t0 = Instant::now();
            let guns: Vec<_> = arrivals
                .into_iter()
                .map(|at| {
                    let req = req.clone();
                    pool::spawn_named("load-fire", move || {
                        let due = Duration::from_secs_f64(at);
                        if let Some(wait) = due.checked_sub(t0.elapsed()) {
                            std::thread::sleep(wait);
                        }
                        fire(addr, &req)
                    })
                })
                .collect();
            let samples: Vec<Sample> =
                guns.into_iter().map(|g| g.join().expect("load thread")).collect();
            let wall = t0.elapsed().as_secs_f64();
            shutdown.store(true, Ordering::Release);
            (samples, wall)
        });
        http.serve(&mut server).unwrap();
        let (samples, wall) = driver.join().expect("load driver");

        let served: Vec<&Sample> = samples.iter().filter(|s| !s.shed).collect();
        let shed = samples.len() - served.len();
        let ttft: Vec<f64> = served.iter().map(|s| s.ttft_ms).filter(|t| t.is_finite()).collect();
        let completion: Vec<f64> = served.iter().map(|s| s.completion_ms).collect();
        let tokens: usize = served.iter().map(|s| s.tokens).sum();
        let achieved = served.len() as f64 / wall;
        let shed_rate = shed as f64 / samples.len() as f64;
        let tok_s = tokens as f64 / wall;
        if knee.is_none() && achieved < KNEE_FRACTION * qps {
            knee = Some(qps);
        }
        println!(
            "offered {qps:6.1} qps: achieved {achieved:6.1} qps, {tok_s:8.1} tok/s, \
             ttft p50 {:7.1} p99 {:7.1} p999 {:7.1} ms, \
             completion p50 {:7.1} p99 {:7.1} p999 {:7.1} ms, shed {:.1}%",
            percentile(&ttft, 50.0),
            percentile(&ttft, 99.0),
            percentile(&ttft, 99.9),
            percentile(&completion, 50.0),
            percentile(&completion, 99.0),
            percentile(&completion, 99.9),
            100.0 * shed_rate,
        );
        legs.push(Json::obj(vec![
            ("offered_qps", Json::n(qps)),
            ("achieved_qps", Json::n(achieved)),
            ("tok_s", Json::n(tok_s)),
            ("ttft_p50_ms", Json::n(percentile(&ttft, 50.0))),
            ("ttft_p99_ms", Json::n(percentile(&ttft, 99.0))),
            ("ttft_p999_ms", Json::n(percentile(&ttft, 99.9))),
            ("completion_p50_ms", Json::n(percentile(&completion, 50.0))),
            ("completion_p99_ms", Json::n(percentile(&completion, 99.0))),
            ("completion_p999_ms", Json::n(percentile(&completion, 99.9))),
            ("shed_rate", Json::n(shed_rate)),
            ("arrivals", Json::n(samples.len() as f64)),
        ]));
    }

    match knee {
        Some(q) => println!("saturation knee: offered {q:.1} qps"),
        None => println!("saturation knee: not reached on this sweep"),
    }
    let summary = Json::obj(vec![
        ("generated_by", Json::s("cargo bench --bench bench_load")),
        (
            "note",
            Json::s(
                "the bench replays an open-loop Poisson arrival schedule against a \
                 live loopback HTTP server per offered-QPS leg and writes achieved \
                 qps, tok/s, TTFT and completion latency p50/p99/p999, the shed \
                 rate, and the saturation knee here",
            ),
        ),
        ("qps_axis", Json::arr_f64(QPS_AXIS)),
        ("seed", Json::n(SEED as f64)),
        ("arrivals_per_leg", Json::n(ARRIVALS_PER_LEG as f64)),
        ("max_new_tokens", Json::n(BUDGET as f64)),
        ("max_queue", Json::n(MAX_QUEUE as f64)),
        ("knee_fraction", Json::n(KNEE_FRACTION)),
        ("saturation_knee_qps", knee.map(Json::n).unwrap_or(Json::Null)),
        ("legs", Json::Arr(legs)),
    ]);
    std::fs::write("BENCH_load.json", summary.to_string()).unwrap();
    println!("wrote BENCH_load.json");
}
