//! Table 1: main results — methods × pruning ratios × {Wiki↓, PTB↓,
//! 7 zero-shot tasks, Avg}.
//!
//! Paper shape to reproduce: HEAPr ≥ every baseline at every ratio;
//! near-lossless at 20–25%; graceful at 40–50% while heuristics crater.

use anyhow::Result;

use crate::baselines;
use crate::experiments::common::*;
use crate::heapr::{self, PrunePlan, Scope};
use crate::info;

pub fn run(ctx: &Ctx, ratios: &[f64]) -> Result<()> {
    let cfg = ctx.engine.config().clone();
    let calib = ctx.calib_wiki(ctx.run.calib_samples, 0);
    info!("table1: calibrating on {} sequences", calib.len());
    let (scores, stats) = heapr::heapr_scores(&ctx.engine, &ctx.params, &calib)?;
    let camera = baselines::camera_scores(&ctx.params, &stats, 0.5)?;
    let magnitude =
        baselines::magnitude_scores(&ctx.params, cfg.n_layers, cfg.n_experts, cfg.d_inter)?;
    let random = baselines::random_scores(cfg.n_layers, cfg.n_experts, cfg.d_inter, 42);

    let mut rows = Vec::new();
    let original = eval_suite(ctx, &ctx.params, &ctx.ones())?;
    rows.push(("0% Original".to_string(), suite_row(&original)));

    // probe set for the NAEE-like expert-drop criterion (small, like NAEE)
    let probe = ctx.calib_wiki(cfg.batch * 2, 3);

    for &ratio in ratios {
        let pct = (ratio * 100.0).round() as usize;
        let mut methods: Vec<(String, PrunePlan)> = vec![
            (
                format!("{pct}% HEAPr"),
                PrunePlan::from_scores(&scores, ratio, Scope::Global),
            ),
            (
                format!("{pct}% CAMERA-P"),
                PrunePlan::from_scores(&camera, ratio, Scope::Layerwise),
            ),
            (
                format!("{pct}% Magnitude"),
                PrunePlan::from_scores(&magnitude, ratio, Scope::Layerwise),
            ),
            (
                format!("{pct}% Random"),
                PrunePlan::from_scores(&random, ratio, Scope::Global),
            ),
            (
                format!("{pct}% FreqDrop"),
                baselines::freq_drop_plan(&stats, ratio),
            ),
        ];
        methods.push((
            format!("{pct}% ExpertDrop"),
            baselines::expert_drop_plan(&ctx.engine, &ctx.params, &probe, ratio)?,
        ));
        for (name, plan) in methods {
            info!("table1: evaluating {name} (pruned {:.1}%)", plan.pruned_ratio() * 100.0);
            let suite = eval_suite(ctx, &ctx.params, &plan.mask())?;
            rows.push((name, suite_row(&suite)));
        }
    }

    let headers = suite_headers();
    print_table(
        &format!("Table 1 — main results ({} model)", cfg.name),
        &headers,
        &rows,
    );
    let body = rows
        .iter()
        .map(|(l, r)| format!("{l}: {}", r.join(" ")))
        .collect::<Vec<_>>()
        .join("\n");
    save_result(&ctx.out_dir, "table1", &body)?;
    Ok(())
}
