//! One module per paper table/figure (index in docs/ARCHITECTURE.md).

pub mod common;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table5;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig56;
