//! Table 3: pruning granularity — whole-expert vs atomic-expert — plus the
//! FLOPs-reduction column.
//!
//! Expert importance = Σ of its atomic importances (licensed by the
//! vanishing cross-atomic Hessian, paper eq. 7/8). Paper shape: atomic
//! granularity wins on quality *and* is the only one that reduces
//! activated FLOPs (expert-dropping keeps top-k compute unchanged).

use anyhow::Result;

use crate::experiments::common::*;
use crate::heapr::importance::expert_scores;
use crate::heapr::{self, PrunePlan, Scope};
use crate::info;
use crate::model::flops::{expert_flops_reduction, flops_reduction};

pub fn run(ctx: &Ctx, ratios: &[f64]) -> Result<()> {
    let cfg = ctx.engine.config().clone();
    let calib = ctx.calib_wiki(ctx.run.calib_samples, 0);
    let (scores, _stats) = heapr::heapr_scores(&ctx.engine, &ctx.params, &calib)?;
    let e_scores = expert_scores(&scores);

    let mut headers = vec!["FLOPsRR↑".to_string(), "ExpFLOPsRR↑".to_string()];
    headers.extend(suite_headers());
    let mut rows = Vec::new();
    for &ratio in ratios {
        let pct = (ratio * 100.0).round() as usize;
        for (name, plan) in [
            (
                format!("{pct}% Expert-level"),
                PrunePlan::expert_level(&e_scores, ratio, cfg.d_inter),
            ),
            (
                format!("{pct}% Atomic (HEAPr)"),
                PrunePlan::from_scores(&scores, ratio, Scope::Global),
            ),
        ] {
            info!("table3: {name}");
            // activated-FLOPs reduction: expert-level dropping leaves the
            // top-k activated width unchanged (the router re-normalises to
            // surviving experts), so its activated-FLOPs rr is ~0 — we
            // compute it from the width profile the same way for both.
            let (rr, err) = match name.contains("Expert-level") {
                true => (0.0, 0.0),
                false => (
                    flops_reduction(&cfg, &plan.widths()),
                    expert_flops_reduction(&cfg, &plan.widths()),
                ),
            };
            let suite = eval_suite(ctx, &ctx.params, &plan.mask())?;
            let mut row = vec![format!("{:.0}%", rr * 100.0),
                               format!("{:.0}%", err * 100.0)];
            row.extend(suite_row(&suite));
            rows.push((name, row));
        }
    }
    print_table("Table 3 — pruning granularity ablation", &headers, &rows);
    let body = rows
        .iter()
        .map(|(l, r)| format!("{l}: {}", r.join(" ")))
        .collect::<Vec<_>>()
        .join("\n");
    save_result(&ctx.out_dir, "table3", &body)?;
    Ok(())
}
