//! Table 2: global vs layer-wise ranking — CAMERA-P vs HEAPr-L vs HEAPr-G.
//!
//! Paper shape: HEAPr-L > CAMERA-P (better criterion at equal scope);
//! HEAPr-G ≥ HEAPr-L (loss-calibrated scores are globally comparable).

use anyhow::Result;

use crate::baselines;
use crate::experiments::common::*;
use crate::heapr::{self, PrunePlan, Scope};
use crate::info;

pub fn run(ctx: &Ctx, ratios: &[f64]) -> Result<()> {
    let calib = ctx.calib_wiki(ctx.run.calib_samples, 0);
    let (scores, stats) = heapr::heapr_scores(&ctx.engine, &ctx.params, &calib)?;
    let camera = baselines::camera_scores(&ctx.params, &stats, 0.5)?;

    let mut rows = Vec::new();
    for &ratio in ratios {
        let pct = (ratio * 100.0).round() as usize;
        for (name, plan) in [
            (
                format!("{pct}% CAMERA-P (layer)"),
                PrunePlan::from_scores(&camera, ratio, Scope::Layerwise),
            ),
            (
                format!("{pct}% HEAPr-L"),
                PrunePlan::from_scores(&scores, ratio, Scope::Layerwise),
            ),
            (
                format!("{pct}% HEAPr-G"),
                PrunePlan::from_scores(&scores, ratio, Scope::Global),
            ),
        ] {
            info!("table2: {name}");
            let suite = eval_suite(ctx, &ctx.params, &plan.mask())?;
            rows.push((name, suite_row(&suite)));
        }
    }
    print_table("Table 2 — layer-wise vs global pruning", &suite_headers(), &rows);
    let body = rows
        .iter()
        .map(|(l, r)| format!("{l}: {}", r.join(" ")))
        .collect::<Vec<_>>()
        .join("\n");
    save_result(&ctx.out_dir, "table2", &body)?;
    Ok(())
}
