//! Table 5 (Appendix C): pruning cost — calibration samples, analytic
//! TFLOPs, measured wallclock, peak memory — HEAPr vs an expert-drop
//! (NAEE-like) baseline vs a D²-MoE-like decomposition cost model.
//!
//! Paper shape: HEAPr sits between NAEE (cheapest, worst quality) and
//! D²-MoE (4× samples + SVD decomposition, far more expensive), while
//! matching/unlocking the best quality (Table 1).
//! Includes the paper's Table 4 calibration-size constants.

use anyhow::Result;

use crate::baselines;
use crate::experiments::common::*;
use crate::heapr;
use crate::info;
use crate::model::flops::calib_flops;
use crate::util::{peak_rss_mib, Timer};

pub fn run(ctx: &Ctx) -> Result<()> {
    let cfg = ctx.engine.config().clone();
    let n_tok = |samples: usize| samples * cfg.seq_len;

    // --- HEAPr: measured ---------------------------------------------------
    let calib = ctx.calib_wiki(ctx.run.calib_samples, 0);
    let t = Timer::start("heapr");
    let (_scores, _stats) = heapr::heapr_scores(&ctx.engine, &ctx.params, &calib)?;
    let heapr_s = t.secs();
    let heapr_rss = peak_rss_mib();
    // two forward passes + one backward pass on 128 samples
    let heapr_fl = calib_flops(&cfg, n_tok(ctx.run.calib_samples), 2.0, 1.0);

    // --- NAEE-like expert drop: measured ------------------------------------
    let probe = ctx.calib_wiki(cfg.batch * 2, 3);
    let t = Timer::start("expert-drop");
    let _ = baselines::expert_drop_plan(&ctx.engine, &ctx.params, &probe, 0.25)?;
    let naee_s = t.secs();
    let naee_rss = peak_rss_mib();
    // L·E masked forward evaluations over the probe set
    let naee_fl = calib_flops(&cfg, n_tok(probe.len()), (cfg.n_layers * cfg.n_experts) as f64, 0.0);

    // --- D²-MoE-like: cost model (paper used 512 samples + per-expert SVD) --
    let d2_samples = 512;
    let d2_fl = calib_flops(&cfg, n_tok(d2_samples), 2.0, 0.0)
        + svd_flops(&cfg) ;
    let d2_s = heapr_s * (d2_fl / heapr_fl); // scale measured rate
    let d2_rss = heapr_rss * 1.5; // decomposition workspaces (documented model)

    let headers: Vec<String> = ["Samples", "GFLOPs", "Time(s)", "PeakRSS(MiB)"]
        .iter().map(|s| s.to_string()).collect();
    let rows = vec![
        ("NAEE-like ExpertDrop".to_string(), vec![
            probe.len().to_string(),
            format!("{:.2}", naee_fl / 1e9),
            format!("{naee_s:.1}"),
            format!("{naee_rss:.0}"),
        ]),
        ("D2-MoE-like (cost model)".to_string(), vec![
            d2_samples.to_string(),
            format!("{:.2}", d2_fl / 1e9),
            format!("{d2_s:.1}"),
            format!("{d2_rss:.0}"),
        ]),
        ("HEAPr".to_string(), vec![
            ctx.run.calib_samples.to_string(),
            format!("{:.2}", heapr_fl / 1e9),
            format!("{heapr_s:.1}"),
            format!("{heapr_rss:.0}"),
        ]),
    ];
    print_table("Table 5 — pruning cost", &headers, &rows);
    info!(
        "table4 constants (calibration sizes, seq 2048 in paper): \
         NAEE=128, D2-MoE=512, Sub-MoE=128, HEAPr=128"
    );

    let body = rows
        .iter()
        .map(|(l, r)| format!("{l}: {}", r.join(" ")))
        .collect::<Vec<_>>()
        .join("\n");
    save_result(&ctx.out_dir, "table5", &body)?;
    Ok(())
}

/// FLOPs of one full-rank SVD per expert matrix (the D²-MoE-style cost):
/// ~ 4·m·n·min(m,n) per matrix, three matrices per expert.
fn svd_flops(cfg: &crate::config::ModelConfig) -> f64 {
    let (m, n) = (cfg.d_inter as f64, cfg.d_model as f64);
    let per = 4.0 * m * n * m.min(n);
    3.0 * per * (cfg.n_layers * cfg.n_experts) as f64
}
