//! Figure 4: robustness to the calibration corpus and its size.
//!
//! Calibrate on synth-wiki vs synth-c4, sizes {8, 32, full}, 3 seeds each;
//! report mean ± std of average task accuracy at a fixed pruning ratio.
//! Paper shape: corpus choice barely matters; more samples help modestly.

use anyhow::Result;

use crate::data::sampler::Split;
use crate::experiments::common::*;
use crate::heapr::{self, PrunePlan, Scope};
use crate::info;
use crate::util::stats::{mean, std};

pub fn run(ctx: &Ctx, ratio: f64, sizes: &[usize], seeds: &[u64]) -> Result<()> {
    let headers: Vec<String> = ["mean Avg↑", "std"].iter().map(|s| s.to_string()).collect();
    let mut rows = Vec::new();
    for (corpus_name, split) in [
        ("synth-wiki", &ctx.train_split),
        ("synth-c4", &ctx.calib_c4),
    ] as [(&str, &Split); 2]
    {
        for &size in sizes {
            let mut accs = Vec::new();
            for &seed in seeds {
                let calib = split.sample(size.min(split.n_chunks()), seed);
                let (scores, _stats) =
                    heapr::heapr_scores(&ctx.engine, &ctx.params, &calib)?;
                let plan = PrunePlan::from_scores(&scores, ratio, Scope::Global);
                let suite = eval_suite(ctx, &ctx.params, &plan.mask())?;
                info!(
                    "fig4 {corpus_name} size {size} seed {seed}: avg {:.3}",
                    suite.avg
                );
                accs.push(suite.avg);
            }
            rows.push((
                format!("{corpus_name} n={size}"),
                vec![format!("{:.3}", mean(&accs)), format!("{:.3}", std(&accs))],
            ));
        }
    }
    print_table(
        &format!("Figure 4 — calibration robustness at {:.0}% pruning", ratio * 100.0),
        &headers,
        &rows,
    );
    let body = rows
        .iter()
        .map(|(l, r)| format!("{l}: {}", r.join(" ± ")))
        .collect::<Vec<_>>()
        .join("\n");
    save_result(&ctx.out_dir, "fig4", &body)?;
    Ok(())
}
