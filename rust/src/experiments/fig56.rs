//! Figures 5/6: per-layer compression-rate profile under global pruning at
//! 25% and 50%.
//!
//! Paper shape: non-monotonic over depth — early layers pruned hardest,
//! middle layers preserved, deepest layers pruned again.

use anyhow::Result;

use crate::experiments::common::*;
use crate::heapr::{self, PrunePlan, Scope};

pub fn run(ctx: &Ctx, ratios: &[f64]) -> Result<()> {
    let cfg = ctx.engine.config().clone();
    let calib = ctx.calib_wiki(ctx.run.calib_samples, 0);
    let (scores, _stats) = heapr::heapr_scores(&ctx.engine, &ctx.params, &calib)?;

    let headers: Vec<String> =
        (0..cfg.n_layers).map(|l| format!("L{l}")).collect();
    let mut rows = Vec::new();
    let mut body = String::new();
    for &ratio in ratios {
        let plan = PrunePlan::from_scores(&scores, ratio, Scope::Global);
        let keep = plan.widths().per_layer_keep(cfg.d_inter);
        let pruned: Vec<f64> = keep.iter().map(|k| 1.0 - k).collect();
        rows.push((
            format!("{:.0}% global", ratio * 100.0),
            pruned.iter().map(|p| format!("{:.0}%", p * 100.0)).collect(),
        ));
        body += &format!(
            "{ratio:.2}: {}\n",
            pruned
                .iter()
                .map(|p| format!("{p:.3}"))
                .collect::<Vec<_>>()
                .join(" ")
        );
    }
    print_table(
        "Figures 5/6 — per-layer compression rate under global pruning",
        &headers,
        &rows,
    );
    save_result(&ctx.out_dir, "fig56 (per-layer pruned fraction)", &body)?;
    Ok(())
}
