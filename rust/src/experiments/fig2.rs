//! Figure 2: performance/FLOPs frontier under compression ratios 0 → 0.9.
//!
//! Paper shape: near-flat accuracy-retention to ~0.4 compression with ~20%
//! FLOPs saving, then graceful degradation; non-trivial retention even at
//! 0.9.

use anyhow::Result;

use crate::experiments::common::*;
use crate::heapr::{self, PrunePlan, Scope};
use crate::info;
use crate::model::flops::{expert_flops_reduction, flops_reduction};

pub fn run(ctx: &Ctx, ratios: &[f64]) -> Result<()> {
    let cfg = ctx.engine.config().clone();
    let calib = ctx.calib_wiki(ctx.run.calib_samples, 0);
    let (scores, _stats) = heapr::heapr_scores(&ctx.engine, &ctx.params, &calib)?;

    let base = eval_suite(ctx, &ctx.params, &ctx.ones())?;
    let headers: Vec<String> =
        ["Wiki↓", "Avg acc", "Retention", "FLOPsRR", "ExpFLOPsRR"]
            .iter().map(|s| s.to_string()).collect();
    let mut rows = vec![(
        "ratio 0.00".to_string(),
        vec![
            format!("{:.2}", base.ppl_wiki),
            format!("{:.3}", base.avg),
            "100%".to_string(),
            "0%".to_string(),
            "0%".to_string(),
        ],
    )];
    let mut series = vec![(0.0, 1.0, 0.0, 0.0)];
    for &ratio in ratios {
        let plan = PrunePlan::from_scores(&scores, ratio, Scope::Global);
        let suite = eval_suite(ctx, &ctx.params, &plan.mask())?;
        let rr = flops_reduction(&cfg, &plan.widths());
        let err = expert_flops_reduction(&cfg, &plan.widths());
        let retention = suite.avg / base.avg;
        info!(
            "fig2 ratio {ratio:.2}: ppl {:.2} avg {:.3} retention {:.2} rr {:.2}/{err:.2}",
            suite.ppl_wiki, suite.avg, retention, rr
        );
        rows.push((
            format!("ratio {ratio:.2}"),
            vec![
                format!("{:.2}", suite.ppl_wiki),
                format!("{:.3}", suite.avg),
                format!("{:.0}%", retention * 100.0),
                format!("{:.0}%", rr * 100.0),
                format!("{:.0}%", err * 100.0),
            ],
        ));
        series.push((ratio, retention, rr, err));
    }
    print_table("Figure 2 — accuracy & FLOPs vs compression ratio", &headers, &rows);
    let body = series
        .iter()
        .map(|(r, ret, rr, err)| format!("{r:.2} {ret:.4} {rr:.4} {err:.4}"))
        .collect::<Vec<_>>()
        .join("\n");
    save_result(&ctx.out_dir, "fig2 (ratio retention flops_rr expert_flops_rr)", &body)?;
    Ok(())
}
