//! Figure 3: empirical consistency between the importance score s_k and the
//! actual loss increase Δℓ.
//!
//! Atomic experts are sorted by score, grouped into 10% quantile bins; each
//! bin is masked alone and the calibration-loss increase measured. Paper
//! shape: Δℓ per bin tracks the bin's cumulative normalised importance —
//! we additionally report the Spearman rank correlation.

use anyhow::Result;

use crate::data::sampler::CalibSampler;
use crate::experiments::common::*;
use crate::heapr;
use crate::info;
use crate::runtime::Value;
use crate::tensor::{argsort, Tensor};
use crate::util::stats::spearman;

pub fn run(ctx: &Ctx, n_bins: usize) -> Result<()> {
    let cfg = ctx.engine.config().clone();
    let calib = ctx.calib_wiki(ctx.run.calib_samples.min(32), 0);
    let (scores, _stats) = heapr::heapr_scores(&ctx.engine, &ctx.params, &calib)?;

    let batches = CalibSampler::batches(&calib, cfg.batch, cfg.seq_len);
    let probe = &batches[..batches.len().min(4)];
    let loss_of = |mask: &Tensor| -> Result<f64> {
        let mut nll = 0.0;
        let mut cnt = 0.0;
        for (tokens, targets) in probe {
            let mut inputs = ctx.params.values();
            inputs.push(Value::F32(mask.clone()));
            inputs.push(Value::I32(tokens.clone()));
            inputs.push(Value::I32(targets.clone()));
            let out = ctx.engine.run("loss_masked", &inputs)?;
            // lint:allow(float-accum-order) f64 scalar total over probe batches, accumulated in the loop's one fixed order
            nll += out[0].clone().f32()?.item() as f64;
            // lint:allow(float-accum-order) same fixed-order f64 scalar total as `nll` above
            cnt += out[1].clone().f32()?.item() as f64;
        }
        Ok(nll / cnt.max(1.0))
    };
    let base_loss = loss_of(&ctx.ones())?;

    let order = argsort(scores.data());
    let n = order.len();
    let bin_sz = n.div_ceil(n_bins);
    let total_score: f64 = scores.data().iter().map(|&x| x as f64).sum();

    let mut bin_scores = Vec::new();
    let mut bin_dl = Vec::new();
    for b in 0..n_bins {
        let lo = b * bin_sz;
        let hi = ((b + 1) * bin_sz).min(n);
        if lo >= hi {
            break;
        }
        let mut mask = ctx.ones();
        let mut ssum = 0.0f64;
        for &flat in &order[lo..hi] {
            mask.data_mut()[flat] = 0.0;
            // lint:allow(float-accum-order) f64 reporting total of a bin's scores in ascending-importance order; not a kernel reduction
            ssum += scores.data()[flat] as f64;
        }
        let dl = loss_of(&mask)? - base_loss;
        info!(
            "fig3 bin {b}: norm score {:.4}, Δloss {:+.4}",
            ssum / total_score.max(1e-12),
            dl
        );
        bin_scores.push(ssum / total_score.max(1e-12));
        bin_dl.push(dl);
    }
    let rho = spearman(&bin_scores, &bin_dl);

    let headers: Vec<String> = ["norm s_k", "Δloss"].iter().map(|s| s.to_string()).collect();
    let rows: Vec<(String, Vec<String>)> = bin_scores
        .iter()
        .zip(&bin_dl)
        .enumerate()
        .map(|(b, (s, d))| {
            (
                format!("bin {b} ({}%..{}%)", b * 100 / n_bins, (b + 1) * 100 / n_bins),
                vec![format!("{s:.4}"), format!("{d:+.4}")],
            )
        })
        .collect();
    print_table(
        &format!("Figure 3 — score vs Δloss (Spearman ρ = {rho:.3})"),
        &headers,
        &rows,
    );
    let body = bin_scores
        .iter()
        .zip(&bin_dl)
        .map(|(s, d)| format!("{s:.5} {d:.5}"))
        .collect::<Vec<_>>()
        .join("\n")
        + &format!("\nspearman {rho:.4}");
    save_result(&ctx.out_dir, "fig3 (norm_score dloss)", &body)?;
    Ok(())
}
