//! Shared experiment plumbing: one trained model per preset (cached on
//! disk), corpus splits, the evaluation suite, and table formatting.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::config::RunConfig;
use crate::data::corpus::{Grammar, ALL_TASKS};
use crate::data::sampler::Split;
use crate::eval::tasks::{eval_tasks, mean_accuracy};
use crate::eval::{ones_mask, perplexity};
use crate::info;
use crate::model::checkpoint::Checkpoint;
use crate::model::store::ParamStore;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::train::Trainer;
use crate::util::json::Json;

pub struct Ctx {
    pub engine: Engine,
    pub run: RunConfig,
    pub grammar: Grammar,
    pub train_split: Split,
    /// held-out synth-wiki (perplexity column 1)
    pub eval_wiki: Split,
    /// held-out synth-ptb (perplexity column 2)
    pub eval_ptb: Split,
    /// synth-c4 calibration corpus (Figure 4)
    pub calib_c4: Split,
    pub params: ParamStore,
    pub out_dir: PathBuf,
}

impl Ctx {
    /// Open artifacts, build corpora, and train (or load the cached)
    /// model checkpoint at `<out>/model-<preset>.ckpt`.
    pub fn prepare(artifact_dir: &str, run: RunConfig, out: &str) -> Result<Ctx> {
        let engine = Engine::open(artifact_dir)?;
        let cfg = engine.config().clone();
        let grammar = Grammar::standard();

        let bytes = (run.corpus_mb * 1e6) as usize;
        let wiki = Split::from_docs(&grammar.corpus("wiki", run.seed, bytes), cfg.seq_len);
        let (train_split, eval_wiki) = wiki.train_eval(0.05);
        let eval_ptb = Split::from_docs(
            &grammar.corpus("ptb", run.seed, bytes / 8),
            cfg.seq_len,
        );
        let calib_c4 = Split::from_docs(
            &grammar.corpus("c4", run.seed, bytes / 2),
            cfg.seq_len,
        );

        let out_dir = PathBuf::from(out);
        std::fs::create_dir_all(&out_dir)?;
        let ckpt_path = out_dir.join(format!("model-{}.ckpt", cfg.name));
        let params = if ckpt_path.exists() {
            info!("loading cached checkpoint {ckpt_path:?}");
            Checkpoint::load(&ckpt_path)?.store
        } else {
            info!(
                "training {} ({} steps, lr {}) on synth-wiki…",
                cfg.name, run.train_steps, run.lr
            );
            let mut params = ParamStore::init(&engine.manifest, run.seed);
            let mut trainer = Trainer::new(&engine);
            let report = trainer.train(&mut params, &train_split, &run)?;
            info!(
                "trained: final loss {:.4} in {:.1}s",
                report.final_loss, report.wallclock_s
            );
            let curve = Json::Arr(
                report
                    .curve
                    .iter()
                    .map(|&(s, l, c)| {
                        Json::Arr(vec![
                            Json::n(s as f64),
                            Json::n(l as f64),
                            Json::n(c as f64),
                        ])
                    })
                    .collect(),
            );
            Checkpoint {
                store: params.clone(),
                widths: None,
                meta: Json::obj(vec![
                    ("steps", Json::n(run.train_steps as f64)),
                    ("final_loss", Json::n(report.final_loss as f64)),
                    ("curve", curve),
                ]),
            }
            .save(&ckpt_path)?;
            params
        };
        Ok(Ctx {
            engine,
            run,
            grammar,
            train_split,
            eval_wiki,
            eval_ptb,
            calib_c4,
            params,
            out_dir,
        })
    }

    /// Calibration sample per the paper's Appendix-B strategy, from the
    /// training-distribution corpus.
    pub fn calib_wiki(&self, n: usize, seed: u64) -> Vec<Vec<i32>> {
        self.train_split.sample(n.min(self.train_split.n_chunks()), seed)
    }

    pub fn ones(&self) -> Tensor {
        ones_mask(&self.engine)
    }
}

/// Full Table-1-style evaluation row under a mask.
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub ppl_wiki: f64,
    pub ppl_ptb: f64,
    pub task_acc: Vec<f64>, // per ALL_TASKS order
    pub avg: f64,
}

pub fn eval_suite(ctx: &Ctx, params: &ParamStore, mask: &Tensor) -> Result<SuiteResult> {
    let ppl_wiki = perplexity(&ctx.engine, params, mask, &ctx.eval_wiki, ctx.run.eval_batches)?;
    let ppl_ptb = perplexity(&ctx.engine, params, mask, &ctx.eval_ptb, ctx.run.eval_batches)?;
    let results = eval_tasks(&ctx.engine, params, mask, 32, 777)?;
    let task_acc: Vec<f64> = results.iter().map(|r| r.accuracy).collect();
    let avg = mean_accuracy(&results);
    Ok(SuiteResult { ppl_wiki, ppl_ptb, task_acc, avg })
}

pub fn suite_headers() -> Vec<String> {
    let mut h = vec!["Wiki↓".to_string(), "PTB↓".to_string()];
    h.extend(ALL_TASKS.iter().map(|t| t.name().to_string()));
    h.push("Avg↑".to_string());
    h
}

pub fn suite_row(s: &SuiteResult) -> Vec<String> {
    let mut r = vec![format!("{:.2}", s.ppl_wiki), format!("{:.2}", s.ppl_ptb)];
    r.extend(s.task_acc.iter().map(|a| format!("{a:.2}")));
    r.push(format!("{:.3}", s.avg));
    r
}

/// Monospace table printer (markdown-ish, matches EXPERIMENTS.md style).
pub fn print_table(title: &str, headers: &[String], rows: &[(String, Vec<String>)]) {
    println!("\n### {title}\n");
    let label_w = rows
        .iter()
        .map(|(l, _)| l.len())
        .chain(std::iter::once(6))
        .max()
        .unwrap();
    let col_ws: Vec<usize> = headers
        .iter()
        .enumerate()
        .map(|(i, h)| {
            rows.iter()
                .map(|(_, r)| r.get(i).map(|c| c.len()).unwrap_or(0))
                .chain(std::iter::once(h.len()))
                .max()
                .unwrap()
        })
        .collect();
    let mut line = format!("| {:label_w$} |", "Method");
    for (h, w) in headers.iter().zip(&col_ws) {
        line += &format!(" {h:>w$} |");
    }
    println!("{line}");
    let mut sep = format!("|{}|", "-".repeat(label_w + 2));
    for w in &col_ws {
        sep += &format!("{}|", "-".repeat(w + 2));
    }
    println!("{sep}");
    for (label, cells) in rows {
        let mut line = format!("| {label:label_w$} |");
        for (c, w) in cells.iter().zip(&col_ws) {
            line += &format!(" {c:>w$} |");
        }
        println!("{line}");
    }
}

/// Append a rendered experiment block to `<out>/results.md` (the raw
/// material EXPERIMENTS.md quotes).
pub fn save_result(out_dir: &Path, name: &str, body: &str) -> Result<()> {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(out_dir.join("results.md"))?;
    writeln!(f, "\n## {name}\n\n{body}")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_rendering_aligns() {
        let headers = vec!["A".to_string(), "Long↑".to_string()];
        let rows = vec![
            ("Original".to_string(), vec!["1.0".into(), "0.95".into()]),
            ("HEAPr".to_string(), vec!["12.34".into(), "0.5".into()]),
        ];
        // should not panic, covers width logic
        print_table("test", &headers, &rows);
    }

    #[test]
    fn suite_row_formats() {
        let s = SuiteResult {
            ppl_wiki: 3.14159,
            ppl_ptb: 2.0,
            task_acc: vec![0.5; 7],
            avg: 0.5,
        };
        let r = suite_row(&s);
        assert_eq!(r.len(), 10);
        assert_eq!(r[0], "3.14");
        assert_eq!(r[9], "0.500");
    }
}
