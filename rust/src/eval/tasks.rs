//! Zero-shot task evaluation (LM-Eval mechanics).
//!
//! Every `TaskItem` contributes two scored sequences (prefix+choice); the
//! model is correct when the *correct* choice has higher length-normalised
//! log-likelihood. Sequences are packed `batch` per `seq_nll` call; targets
//! are PAD everywhere except the choice span, so the artifact returns
//! exactly the choice log-likelihood.

use anyhow::Result;

use crate::data::corpus::{Grammar, TaskItem, TaskKind, ALL_TASKS};
use crate::data::tokenizer::{ByteTokenizer, BOS, PAD};
use crate::model::store::ParamStore;
use crate::runtime::{Engine, Value};
use crate::tensor::{ITensor, Tensor};

#[derive(Clone, Debug)]
pub struct TaskResult {
    pub kind: TaskKind,
    pub accuracy: f64,
    pub n_items: usize,
}

/// Encode one (prefix, choice) into a (tokens, targets) row pair.
/// Targets are PAD outside the choice span. Truncates the prefix from the
/// left if the sequence exceeds seq_len.
fn encode_row(prefix: &str, choice: &str, seq_len: usize) -> (Vec<i32>, Vec<i32>) {
    let tok = ByteTokenizer;
    let mut p = tok.encode(prefix);
    let c = tok.encode(choice);
    // need 1 (BOS) + len(p) + len(c) <= seq_len + 1 positions; inputs drop
    // the final token (it is only ever a target).
    let max_p = seq_len.saturating_sub(c.len());
    if p.len() > max_p {
        p = p[p.len() - max_p..].to_vec();
    }
    let full: Vec<i32> = p.iter().chain(c.iter()).copied().collect();
    let mut tokens = vec![PAD; seq_len];
    let mut targets = vec![PAD; seq_len];
    tokens[0] = BOS;
    for (i, &t) in full[..full.len() - 1].iter().enumerate() {
        tokens[i + 1] = t;
    }
    // target[t] = full[t]; mask to the choice span only
    for (i, &t) in full.iter().enumerate().skip(p.len()) {
        targets[i] = t;
    }
    (tokens, targets)
}

/// Batched per-row NLL of many (prefix, choice) rows.
fn score_rows(
    engine: &Engine,
    params: &ParamStore,
    mask: &Tensor,
    rows: &[(Vec<i32>, Vec<i32>)],
) -> Result<Vec<f64>> {
    let cfg = engine.config().clone();
    let (b, t) = (cfg.batch, cfg.seq_len);
    let mut out = Vec::with_capacity(rows.len());
    for group in rows.chunks(b) {
        let mut toks = vec![PAD; b * t];
        let mut tgts = vec![PAD; b * t];
        for (i, (tk, tg)) in group.iter().enumerate() {
            toks[i * t..(i + 1) * t].copy_from_slice(tk);
            tgts[i * t..(i + 1) * t].copy_from_slice(tg);
        }
        let mut inputs = params.values();
        inputs.push(Value::F32(mask.clone()));
        inputs.push(Value::I32(ITensor::from_vec(&[b, t], toks)));
        inputs.push(Value::I32(ITensor::from_vec(&[b, t], tgts)));
        let res = engine.run("seq_nll", &inputs)?;
        let nll = res[0].clone().f32()?;
        let cnt = res[1].clone().f32()?;
        for i in 0..group.len() {
            // length-normalised log-likelihood (higher = better)
            out.push(-(nll.data()[i] as f64) / (cnt.data()[i] as f64).max(1.0));
        }
    }
    Ok(out)
}

/// Accuracy of one task's items.
pub fn eval_task(
    engine: &Engine,
    params: &ParamStore,
    mask: &Tensor,
    items: &[TaskItem],
) -> Result<TaskResult> {
    let seq_len = engine.config().seq_len;
    let mut rows = Vec::with_capacity(items.len() * 2);
    for it in items {
        for ch in &it.choices {
            rows.push(encode_row(&it.prefix, ch, seq_len));
        }
    }
    let scores = score_rows(engine, params, mask, &rows)?;
    let mut correct = 0usize;
    for (i, it) in items.iter().enumerate() {
        let s = &scores[i * it.choices.len()..(i + 1) * it.choices.len()];
        // NaN-safe: a NaN likelihood never wins the argmax and never
        // panics the experiment process
        let best = s
            .iter()
            .enumerate()
            .max_by(|a, b| crate::util::cmp::f64_nan_first(*a.1, *b.1))
            .unwrap()
            .0;
        if best == it.correct {
            correct += 1;
        }
    }
    Ok(TaskResult {
        kind: items[0].kind,
        accuracy: correct as f64 / items.len() as f64,
        n_items: items.len(),
    })
}

/// Run all 7 tasks with `n_items` each.
pub fn eval_tasks(
    engine: &Engine,
    params: &ParamStore,
    mask: &Tensor,
    n_items: usize,
    seed: u64,
) -> Result<Vec<TaskResult>> {
    let grammar = Grammar::standard();
    ALL_TASKS
        .iter()
        .map(|&kind| {
            let items = grammar.task_items(kind, n_items, seed);
            eval_task(engine, params, mask, &items)
        })
        .collect()
}

/// Mean accuracy across task results (0.0 when empty).
pub fn mean_accuracy(results: &[TaskResult]) -> f64 {
    let accs: Vec<f64> = results.iter().map(|r| r.accuracy).collect();
    crate::util::stats::mean(&accs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_row_spans() {
        let (toks, tgts) = encode_row("ab", " cd", 16);
        // full = "ab cd" (5 bytes); prefix 2, choice 3
        assert_eq!(toks[0], BOS);
        assert_eq!(toks[1], 'a' as i32);
        assert_eq!(toks[2], 'b' as i32);
        assert_eq!(toks[3], ' ' as i32);
        assert_eq!(toks[4], 'c' as i32);
        assert_eq!(toks[5], PAD); // final 'd' never an input
        // targets only on choice span (positions 2..5 predict " cd")
        assert_eq!(tgts[0], PAD);
        assert_eq!(tgts[1], PAD);
        assert_eq!(tgts[2], ' ' as i32);
        assert_eq!(tgts[3], 'c' as i32);
        assert_eq!(tgts[4], 'd' as i32);
        assert_eq!(tgts[5], PAD);
    }

    #[test]
    fn encode_row_truncates_left() {
        let long_prefix = "x".repeat(100);
        let (toks, tgts) = encode_row(&long_prefix, " yz", 32);
        assert_eq!(toks.len(), 32);
        assert_eq!(tgts.len(), 32);
        // choice still present at the tail
        let n_tgt = tgts.iter().filter(|&&t| t != PAD).count();
        assert_eq!(n_tgt, 3);
    }

    #[test]
    fn choice_tokens_count_matches() {
        let (_, tgts) = encode_row("the brak", " slom", 64);
        assert_eq!(tgts.iter().filter(|&&t| t != PAD).count(), 5);
    }
}
