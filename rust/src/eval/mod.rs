//! Evaluation harness: held-out perplexity + the 7 synthetic zero-shot
//! tasks, scored LM-Eval style (length-normalised choice log-likelihood).

pub mod tasks;

pub use tasks::{eval_tasks, TaskResult};

use anyhow::Result;

use crate::data::sampler::{CalibSampler, Split};
use crate::model::store::ParamStore;
use crate::runtime::{Engine, Value};
use crate::tensor::Tensor;

/// exp(mean NLL) on up to `max_batches` of the split, under an atomic-expert
/// keep mask (all-ones = unpruned).
pub fn perplexity(
    engine: &Engine,
    params: &ParamStore,
    mask: &Tensor,
    split: &Split,
    max_batches: usize,
) -> Result<f64> {
    let cfg = engine.config().clone();
    let batches = CalibSampler::batches(&split.chunks, cfg.batch, cfg.seq_len);
    let mut nll = 0.0f64;
    let mut cnt = 0.0f64;
    for (tokens, targets) in batches.into_iter().take(max_batches) {
        let mut inputs = params.values();
        inputs.push(Value::F32(mask.clone()));
        inputs.push(Value::I32(tokens));
        inputs.push(Value::I32(targets));
        let out = engine.run("loss_masked", &inputs)?;
        // lint:allow(float-accum-order) f64 scalar total over eval batches, accumulated in the loop's one fixed order
        nll += out[0].clone().f32()?.item() as f64;
        // lint:allow(float-accum-order) same fixed-order f64 scalar total as `nll` above
        cnt += out[1].clone().f32()?.item() as f64;
    }
    Ok((nll / cnt.max(1.0)).exp())
}

/// Convenience: the all-ones mask for a config.
pub fn ones_mask(engine: &Engine) -> Tensor {
    let c = engine.config();
    Tensor::ones(&[c.n_layers, c.n_experts, c.d_inter])
}

#[cfg(test)]
mod tests {
    // artifact-backed perplexity is covered by rust/tests/integration.rs;
    // the pure logic here (mask shape) is trivial enough to assert inline.
    use crate::tensor::Tensor;

    #[test]
    fn ones_mask_shape_logic() {
        let m = Tensor::ones(&[2, 4, 32]);
        assert_eq!(m.len(), 256);
        assert!(m.data().iter().all(|&x| x == 1.0));
    }
}
