//! PCG64 pseudo-random generator (O'Neill 2014, PCG-XSL-RR 128/64).
//!
//! Deterministic across platforms — the corpus generator, calibration
//! sampler and all experiments are seeded so every table in EXPERIMENTS.md
//! regenerates bit-identically.

#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Sample `k` distinct indices from [0, n) (Fisher–Yates prefix).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Weighted index sample (weights need not be normalised).
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        assert!(total > 0.0, "weighted() needs positive mass");
        let mut x = self.f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg64::new(1);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Pcg64::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(3);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Pcg64::new(5);
        let picks = r.choose_distinct(100, 40);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40);
        assert!(picks.iter().all(|&i| i < 100));
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Pcg64::new(9);
        let w = [0.0f32, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((2.5..3.5).contains(&ratio), "{counts:?}");
    }
}
