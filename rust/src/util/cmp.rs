//! Total, panic-free float comparators for the ordering hot paths.
//!
//! The historical pattern `partial_cmp().unwrap()` panics the whole
//! serving / experiment process on a single NaN score. These helpers are
//! built on `total_cmp` with one shared policy — **NaN orders last**:
//!
//! * in an ascending or descending sort, every NaN lands at the end of
//!   the order (tie-broken by the caller's index, so sorts stay stable);
//! * in a max-selection (`max_by`), a NaN candidate never beats a number
//!   (use the `*_nan_first` variants, which rank NaN below everything).
//!
//! For non-NaN inputs `total_cmp` agrees with `partial_cmp` except that
//! `-0.0 < 0.0`, which only re-orders exact-zero ties.

use std::cmp::Ordering;

macro_rules! nan_cmp {
    ($nan_last:ident, $nan_last_desc:ident, $nan_first:ident, $t:ty) => {
        /// Ascending total order; every NaN after every non-NaN.
        pub fn $nan_last(a: $t, b: $t) -> Ordering {
            match (a.is_nan(), b.is_nan()) {
                (true, true) => Ordering::Equal,
                (true, false) => Ordering::Greater,
                (false, true) => Ordering::Less,
                (false, false) => a.total_cmp(&b),
            }
        }

        /// Descending total order; every NaN after every non-NaN.
        pub fn $nan_last_desc(a: $t, b: $t) -> Ordering {
            match (a.is_nan(), b.is_nan()) {
                (true, true) => Ordering::Equal,
                (true, false) => Ordering::Greater,
                (false, true) => Ordering::Less,
                (false, false) => b.total_cmp(&a),
            }
        }

        /// Ascending total order; every NaN *before* every non-NaN — the
        /// `max_by` comparator under which a NaN score never wins an
        /// argmax (and an all-NaN slice still yields a winner instead of
        /// a panic).
        pub fn $nan_first(a: $t, b: $t) -> Ordering {
            match (a.is_nan(), b.is_nan()) {
                (true, true) => Ordering::Equal,
                (true, false) => Ordering::Less,
                (false, true) => Ordering::Greater,
                (false, false) => a.total_cmp(&b),
            }
        }
    };
}

nan_cmp!(f32_nan_last, f32_nan_last_desc, f32_nan_first, f32);
nan_cmp!(f64_nan_last, f64_nan_last_desc, f64_nan_first, f64);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascending_sorts_nan_to_the_end() {
        let mut v = vec![2.0f32, f32::NAN, -1.0, f32::INFINITY, f32::NAN, 0.0];
        v.sort_by(|a, b| f32_nan_last(*a, *b));
        assert_eq!(&v[..4], &[-1.0, 0.0, 2.0, f32::INFINITY]);
        assert!(v[4].is_nan() && v[5].is_nan());

        let mut w = vec![f64::NAN, 1.0, 3.0];
        w.sort_by(|a, b| f64_nan_last(*a, *b));
        assert_eq!(&w[..2], &[1.0, 3.0]);
        assert!(w[2].is_nan());
    }

    #[test]
    fn descending_sorts_nan_to_the_end_too() {
        let mut v = vec![f32::NAN, 2.0, -1.0, 0.0];
        v.sort_by(|a, b| f32_nan_last_desc(*a, *b));
        assert_eq!(&v[..3], &[2.0, 0.0, -1.0]);
        assert!(v[3].is_nan());
    }

    #[test]
    fn max_by_with_nan_first_never_picks_nan_over_a_number() {
        let xs = [f32::NAN, 0.3, f32::NAN, 0.7, 0.1];
        let best = xs
            .iter()
            .enumerate()
            .max_by(|a, b| f32_nan_first(*a.1, *b.1))
            .unwrap()
            .0;
        assert_eq!(best, 3);
        // all-NaN still yields a winner instead of panicking
        let all = [f64::NAN, f64::NAN];
        let i = all
            .iter()
            .enumerate()
            .max_by(|a, b| f64_nan_first(*a.1, *b.1))
            .unwrap()
            .0;
        assert!(i < 2);
    }

    #[test]
    fn non_nan_agrees_with_partial_cmp() {
        for (a, b) in [(1.0f32, 2.0), (2.0, 1.0), (1.5, 1.5), (-3.0, 3.0)] {
            assert_eq!(f32_nan_last(a, b), a.partial_cmp(&b).unwrap());
            assert_eq!(f32_nan_first(a, b), a.partial_cmp(&b).unwrap());
            assert_eq!(f32_nan_last_desc(a, b), b.partial_cmp(&a).unwrap());
        }
    }
}
