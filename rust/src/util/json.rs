//! Minimal JSON: recursive-descent parser + writer.
//!
//! Handles the full JSON grammar minus exotic number forms; enough for
//! `artifacts/manifest.json`, run configs, and experiment reports. Numbers
//! are stored as f64 (manifest shapes are small integers, safe in f64).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a usize: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn s(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn n(v: f64) -> Json {
        Json::Num(v)
    }

    // -- serialisation -------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    // lint:allow(swallowed-result) fmt::Write into a String is infallible
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    // lint:allow(swallowed-result) fmt::Write into a String is infallible
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                // lint:allow(swallowed-result) fmt::Write into a String is infallible
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected eof"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // collect the full utf-8 sequence
                    let len = utf8_len(c);
                    out.push_str(std::str::from_utf8(
                        &self.b[self.i - 1..self.i - 1 + len])?);
                    self.i += len - 1;
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] found {:?}", c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "hi\nthere");
    }

    #[test]
    fn parses_manifest_like() {
        let src = r#"{"params":[{"name":"embed","shape":[260,64]}],"artifacts":{}}"#;
        let v = Json::parse(src).unwrap();
        let p = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("shape").unwrap().usize_vec().unwrap(), vec![260, 64]);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
        let s = Json::s("tab\there").to_string();
        assert_eq!(s, "\"tab\\there\"");
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::n(42.0).to_string(), "42");
        assert_eq!(Json::n(2.5).to_string(), "2.5");
    }
}
