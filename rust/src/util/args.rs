//! Tiny CLI argument parser (clap substitute).
//!
//! Grammar: `binary <subcommand> [--key value]... [--flag]...`.
//! Unknown keys are an error — catches typos in experiment invocations.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    known: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                a.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            let Some(key) = tok.strip_prefix("--") else {
                bail!("positional argument {tok:?} not allowed here");
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    a.kv.insert(key.to_string(), it.next().unwrap().clone());
                }
                _ => a.flags.push(key.to_string()),
            }
        }
        Ok(a)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn str(&mut self, key: &str, default: &str) -> String {
        self.known.push(key.to_string());
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&mut self, key: &str) -> Option<String> {
        self.known.push(key.to_string());
        self.kv.get(key).cloned()
    }

    /// String option constrained to an allowed set; a value outside it is
    /// an error listing the choices (typo-proofing for enum-like flags
    /// such as `--kernel`).
    pub fn choice(&mut self, key: &str, default: &str, allowed: &[&str]) -> Result<String> {
        debug_assert!(allowed.contains(&default));
        let v = self.str(key, default);
        if !allowed.contains(&v.as_str()) {
            bail!("--{key} must be one of {allowed:?}, got {v:?}");
        }
        Ok(v)
    }

    pub fn usize(&mut self, key: &str, default: usize) -> Result<usize> {
        self.known.push(key.to_string());
        match self.kv.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn f64(&mut self, key: &str, default: f64) -> Result<f64> {
        self.known.push(key.to_string());
        match self.kv.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn flag(&mut self, key: &str) -> bool {
        self.known.push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Call after all lookups: errors on unrecognised keys/flags.
    pub fn finish(&self) -> Result<()> {
        for k in self.kv.keys() {
            if !self.known.contains(k) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !self.known.contains(f) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_kv_flags() {
        let mut a = Args::parse(&sv(&["train", "--steps", "100", "--quiet"])).unwrap();
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.usize("steps", 0).unwrap(), 100);
        assert!(a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let mut a = Args::parse(&sv(&["x"])).unwrap();
        assert_eq!(a.str("preset", "small"), "small");
        assert_eq!(a.f64("ratio", 0.25).unwrap(), 0.25);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn choice_accepts_allowed_and_rejects_others() {
        let allowed = ["auto", "naive", "blocked", "simd"];
        let mut a = Args::parse(&sv(&["x", "--kernel", "simd"])).unwrap();
        assert_eq!(a.choice("kernel", "auto", &allowed).unwrap(), "simd");
        a.finish().unwrap();
        let mut b = Args::parse(&sv(&["x", "--kernel", "avx512"])).unwrap();
        assert!(b.choice("kernel", "auto", &allowed).is_err());
        let mut c = Args::parse(&sv(&["x"])).unwrap();
        assert_eq!(c.choice("kernel", "auto", &allowed).unwrap(), "auto");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut a = Args::parse(&sv(&["x", "--bogus", "1"])).unwrap();
        let _ = a.str("good", "");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let mut a = Args::parse(&sv(&["x", "--steps", "ten"])).unwrap();
        assert!(a.usize("steps", 0).is_err());
    }
}
