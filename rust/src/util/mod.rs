//! Substrate utilities. The offline image only vendors the `xla` crate's
//! dependency closure, so the usual ecosystem crates (rand, serde_json,
//! clap, proptest, log) are re-implemented here as small, tested modules.

pub mod rng;
pub mod json;
pub mod args;
pub mod cmp;
pub mod logging;
pub mod pool;
pub mod prop;
pub mod stats;

pub use rng::Pcg64;
pub use json::Json;

use std::time::Instant;

/// Wall-clock timer for coarse pipeline phases.
pub struct Timer {
    start: Instant,
    label: String,
}

impl Timer {
    pub fn start(label: &str) -> Self {
        Timer { start: Instant::now(), label: label.to_string() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn report(&self) -> String {
        format!("{}: {:.2}s", self.label, self.secs())
    }
}

/// Peak resident-set size of this process in MiB (Linux), for Table 5.
pub fn peak_rss_mib() -> f64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: f64 = rest
                    .trim()
                    .trim_end_matches("kB")
                    .trim()
                    .parse()
                    .unwrap_or(0.0);
                return kb / 1024.0;
            }
        }
    }
    0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_time() {
        let t = Timer::start("x");
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(t.secs() >= 0.009);
        assert!(t.report().starts_with("x:"));
    }

    #[test]
    fn peak_rss_positive_on_linux() {
        assert!(peak_rss_mib() > 0.0);
    }
}
