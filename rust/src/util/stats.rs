//! Small statistics helpers shared by eval, bench and experiments.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// p-th percentile via the nearest-rank method on a sorted copy: the
/// smallest value with at least p% of the sample at or below it —
/// `sorted[ceil(p/100 · n) - 1]`, rank clamped to [1, n]. Always returns
/// an element of `xs` (p=0 → minimum, p=100 → maximum); 0.0 when empty.
///
/// Out-of-domain `p` is clamped *before* the rank cast, explicitly:
/// negative `p` means the minimum, `p > 100` the maximum, and a NaN `p`
/// returns NaN (an undefined percentile is surfaced, not laundered into
/// some fabricated element). The old code leaned on the f64→usize `as`
/// cast saturating the wrapped rank — correct on today's rustc by the
/// saturating-cast rules, but an implicit contract this function has no
/// business depending on.
///
/// NaN *samples* sort last (high percentiles of a NaN-bearing sample may
/// be NaN, but the call never panics).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    if p.is_nan() {
        return f64::NAN;
    }
    let p = p.clamp(0.0, 100.0);
    let mut v = xs.to_vec();
    v.sort_by(|a, b| crate::util::cmp::f64_nan_last(*a, *b));
    let n = v.len();
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    v[rank.clamp(1, n) - 1]
}

/// Spearman rank correlation (ties broken by index; inputs same length).
/// NaN samples propagate: any NaN input yields NaN, never a finite
/// correlation fabricated from a rank the NaN does not deserve.
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.iter().chain(b).any(|v| v.is_nan()) {
        return f64::NAN;
    }
    let ra = ranks(a);
    let rb = ranks(b);
    pearson(&ra, &rb)
}

pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let (ma, mb) = (mean(a), mean(b));
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for i in 0..a.len() {
        let (xa, xb) = (a[i] - ma, b[i] - mb);
        num += xa * xb;
        da += xa * xa;
        db += xb * xb;
    }
    if da == 0.0 || db == 0.0 {
        return 0.0;
    }
    num / (da.sqrt() * db.sqrt())
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&i, &j| crate::util::cmp::f64_nan_last(xs[i], xs[j]).then(i.cmp(&j)));
    let mut r = vec![0.0; xs.len()];
    for (rank, &i) in idx.iter().enumerate() {
        r[i] = rank as f64;
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentile_ordering() {
        let xs = [5.0, 1.0, 9.0, 3.0, 7.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 9.0);
    }

    #[test]
    fn percentile_nearest_rank_odd_length() {
        // sorted: [1, 3, 5, 7, 9]; rank = ceil(p/100 * 5)
        let xs = [9.0, 7.0, 5.0, 3.0, 1.0];
        assert_eq!(percentile(&xs, 0.0), 1.0); // rank clamps to 1
        assert_eq!(percentile(&xs, 20.0), 1.0); // ceil(1.0) = 1
        assert_eq!(percentile(&xs, 50.0), 5.0); // ceil(2.5) = 3
        assert_eq!(percentile(&xs, 99.0), 9.0); // ceil(4.95) = 5
        assert_eq!(percentile(&xs, 100.0), 9.0);
    }

    #[test]
    fn percentile_nearest_rank_even_length() {
        // sorted: [1, 3, 5, 7]; rank = ceil(p/100 * 4)
        let xs = [7.0, 1.0, 5.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 25.0), 1.0); // ceil(1.0) = 1
        assert_eq!(percentile(&xs, 50.0), 3.0); // ceil(2.0) = 2
        assert_eq!(percentile(&xs, 75.0), 5.0); // ceil(3.0) = 3
        assert_eq!(percentile(&xs, 99.0), 7.0); // ceil(3.96) = 4
        assert_eq!(percentile(&xs, 100.0), 7.0);
    }

    #[test]
    fn percentile_singleton_and_empty() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[2.5], 0.0), 2.5);
        assert_eq!(percentile(&[2.5], 99.0), 2.5);
        assert_eq!(percentile(&[2.5], 100.0), 2.5);
    }

    #[test]
    fn percentile_out_of_domain_p_is_clamped() {
        let xs = [5.0, 1.0, 9.0, 3.0, 7.0];
        // negative p -> minimum, p > 100 -> maximum, never a wrapped or
        // saturated index
        assert_eq!(percentile(&xs, -0.001), 1.0);
        assert_eq!(percentile(&xs, -1e18), 1.0);
        assert_eq!(percentile(&xs, 100.001), 9.0);
        assert_eq!(percentile(&xs, 1e18), 9.0);
        assert_eq!(percentile(&xs, f64::NEG_INFINITY), 1.0);
        assert_eq!(percentile(&xs, f64::INFINITY), 9.0);
        // NaN p is undefined -> NaN out, not a fabricated element
        assert!(percentile(&xs, f64::NAN).is_nan());
        // empty input still wins over a NaN p (documented: 0.0 when empty)
        assert_eq!(percentile(&[], f64::NAN), 0.0);
    }

    #[test]
    fn nan_samples_do_not_panic_and_order_last() {
        // regression: partial_cmp().unwrap() used to panic here
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.0); // rank ceil(2.0)=2 of [1,2,3,NaN]
        assert!(percentile(&xs, 100.0).is_nan()); // NaN sorts last
        let r = ranks(&xs);
        assert_eq!(r[1], 3.0, "NaN must take the final rank");
        // spearman must surface the NaN, not a correlation computed from
        // a fabricated ranking
        assert!(spearman(&xs, &[1.0, 2.0, 3.0, 4.0]).is_nan());
        assert!(spearman(&[1.0, 2.0], &[3.0, f64::NAN]).is_nan());
    }

    #[test]
    fn spearman_monotone_is_one() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0, 200.0, 3000.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
        let c = [4.0, 3.0, 2.0, 1.0];
        assert!((spearman(&a, &c) + 1.0).abs() < 1e-12);
    }
}
