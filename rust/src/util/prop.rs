//! Property-testing helper (proptest substitute, offline image has none).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated inputs;
//! on failure it retries the failing seed with progressively "smaller"
//! regenerations (the generator receives a shrink factor in [0,1], 1 = full
//! size) and reports the smallest failing input's debug form.

use crate::util::rng::Pcg64;

/// Generator context handed to property generators.
pub struct Gen<'a> {
    pub rng: &'a mut Pcg64,
    /// Shrink factor in (0, 1]; generators should scale collection sizes
    /// and magnitudes by this to produce smaller counterexamples.
    pub size: f64,
}

impl<'a> Gen<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi_scaled = lo + (((hi - lo) as f64) * self.size).round() as usize;
        lo + self.rng.below(hi_scaled.max(lo) - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo) * self.size as f32
    }

    pub fn vec_f32(&mut self, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(1, max_len);
        (0..n).map(|_| lo + self.rng.f32() * (hi - lo)).collect()
    }
}

/// Run a property over `cases` random inputs. Panics with the seed and the
/// smallest regenerated failing input on failure.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> bool,
{
    let base_seed = 0x9e37_79b9_7f4a_7c15u64 ^ hash_name(name);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Pcg64::new(seed);
        let input = gen(&mut Gen { rng: &mut rng, size: 1.0 });
        if prop(&input) {
            continue;
        }
        // Shrink: regenerate from the same seed at smaller sizes, keep the
        // smallest input that still fails.
        let mut smallest = format!("{input:?}");
        for step in 1..=8 {
            let size = 1.0 - step as f64 * 0.115;
            let mut rng = Pcg64::new(seed);
            let candidate = gen(&mut Gen { rng: &mut rng, size: size.max(0.05) });
            if !prop(&candidate) {
                smallest = format!("{candidate:?}");
            }
        }
        panic!(
            "property {name:?} failed at case {case} (seed {seed:#x});\n\
             smallest failing input: {smallest}"
        );
    }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-comm", 50,
              |g| (g.usize_in(0, 100), g.usize_in(0, 100)),
              |&(a, b)| a + b == b + a);
    }

    #[test]
    #[should_panic(expected = "property \"always-false\"")]
    fn failing_property_reports() {
        check("always-false", 5, |g| g.usize_in(0, 10), |_| false);
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 100,
              |g| g.vec_f32(16, -2.0, 2.0),
              |v| !v.is_empty() && v.len() <= 16
                  && v.iter().all(|x| (-2.0..=2.0).contains(x)));
    }
}
