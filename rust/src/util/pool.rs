//! Home-grown fixed-worker thread pool with scoped `par_for` / `par_map`.
//! (System-level context: `docs/ARCHITECTURE.md` §5 — disjoint writes
//! only, caller-helps nesting.)
//!
//! The offline image has no rayon; this module supplies the minimal
//! data-parallel substrate the serving and calibration hot paths need:
//!
//! * a global pool sized by `HEAPR_THREADS` (default: available
//!   parallelism). `HEAPR_THREADS=1` makes every `par_for` run inline in
//!   the caller — byte-identical to the pre-pool serial code path, the
//!   before/after switch for §Perf measurements.
//! * [`par_for`]`(n, f)` — call `f(i)` for `i in 0..n`, work-stealing
//!   chunks across workers, caller participates. Panics in `f` propagate
//!   to the caller after every worker has finished (no detached unwinding).
//! * [`par_map`]`(n, f)` — same, collecting results in index order.
//!
//! Determinism: each index is processed exactly once and writes only its
//! own outputs, so results are bitwise identical for every thread count.
//!
//! Nesting (caller-helps): a `par_for` issued from inside a worker (a
//! thread-local marks worker context) queues helper jobs like any other
//! task, so idle lanes subdivide the nested index space — but instead of
//! blocking on completion, the nested caller *helps*: it drains queued
//! jobs (its own or other tasks') and yields until its helpers have all
//! run. A worker therefore never blocks on the pool it is part of, which
//! keeps the scheduler deadlock-free while recovering the parallelism
//! the old run-inline policy threw away (the attention fan-out nests
//! GEMM `par_for`s under pool workers).

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Shared job queue: FIFO + shutdown flag.
struct Queue {
    state: Mutex<(VecDeque<Job>, bool)>,
    cv: Condvar,
}

impl Queue {
    fn push(&self, job: Job) {
        let mut s = self.state.lock().unwrap();
        s.0.push_back(job);
        drop(s);
        self.cv.notify_one();
    }

    /// Non-blocking pop (the caller-helps drain loop).
    fn try_pop(&self) -> Option<Job> {
        self.state.lock().unwrap().0.pop_front()
    }

    /// Pop a job, blocking; None once shut down and drained.
    fn pop(&self) -> Option<Job> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(job) = s.0.pop_front() {
                return Some(job);
            }
            if s.1 {
                return None;
            }
            s = self.cv.wait(s).unwrap();
        }
    }

    fn shutdown(&self) {
        self.state.lock().unwrap().1 = true;
        self.cv.notify_all();
    }
}

thread_local! {
    static IN_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Fixed pool of `threads - 1` workers (the caller is the remaining lane).
pub struct ThreadPool {
    queue: Arc<Queue>,
    threads: usize,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Pool that runs `par_for` across `threads` lanes total.
    /// `threads <= 1` spawns nothing and runs everything inline.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let queue = Arc::new(Queue {
            state: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
        });
        let mut workers = Vec::new();
        for w in 0..threads.saturating_sub(1) {
            let q = Arc::clone(&queue);
            let h = thread::Builder::new()
                .name(format!("heapr-pool-{w}"))
                .spawn(move || {
                    IN_WORKER.with(|f| f.set(true));
                    while let Some(job) = q.pop() {
                        job();
                    }
                })
                .expect("spawn pool worker");
            workers.push(h);
        }
        ThreadPool { queue, threads, workers }
    }

    /// Total parallel lanes (workers + caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0..n)`, distributing chunks over the pool. Returns once every
    /// index is done; re-raises the first panic observed in `f`. From a
    /// non-worker thread the caller participates and then blocks; from
    /// inside a worker it participates and then *helps* (drains queued
    /// jobs) instead of blocking, so nested `par_for`s subdivide across
    /// idle lanes without ever deadlocking the pool.
    pub fn par_for<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        let helpers = self.threads.saturating_sub(1).min(n.saturating_sub(1));
        if helpers == 0 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let nested = IN_WORKER.with(|w| w.get());

        let chunk = (n / (self.threads * 4)).max(1);
        let ctx = TaskCtx {
            f: &f,
            n,
            chunk,
            next: AtomicUsize::new(0),
            panic: Mutex::new(None),
            remaining: Mutex::new(helpers),
            done_cv: Condvar::new(),
        };
        // SAFETY: helper jobs only dereference `ctx` before they release
        // the `remaining` lock after decrementing it; the caller below
        // (blocking or help-draining) returns only after observing
        // `remaining == 0` under that same lock, so `ctx` (and the borrow
        // of `f`) strictly outlives every access.
        let ptr = SendPtr(&ctx as *const TaskCtx as *const ());
        for _ in 0..helpers {
            let p = ptr;
            // lint:allow(hot-path-alloc) the job queue's unit IS `Box<dyn FnOnce>`: one box per helper lane per parallel region (<= threads-1), not per element
            self.queue.push(Box::new(move || {
                // SAFETY: `p` came from `&ctx` above and the caller only
                // returns after `remaining == 0`, which this job signals
                // as its very last `ctx` access — so the reference is
                // valid for this job's whole lifetime (argument above).
                let ctx = unsafe { &*(p.0 as *const TaskCtx) };
                ctx.run_lane();
                let mut rem = ctx.remaining.lock().unwrap();
                *rem -= 1;
                ctx.done_cv.notify_all();
                // last ctx access is releasing this lock
            }));
        }
        ctx.run_lane(); // caller participates
        if nested {
            // Caller-helps: a worker must never block on the pool — it IS
            // a pool lane. Drain whatever is queued (this task's helpers
            // or another task's jobs; either way progress) while the last
            // helper jobs finish elsewhere. Helper jobs never unwind
            // (run_lane parks panics), so `job()` is safe to run on this
            // lane. Empty polls back off from yield to a short timed
            // done_cv wait so idle spinners stop hammering the shared
            // queue mutex; the timeout keeps the drain loop live for jobs
            // pushed while parked, preserving deadlock-freedom.
            let mut idle_polls = 0u32;
            loop {
                if *ctx.remaining.lock().unwrap() == 0 {
                    break;
                }
                match self.queue.try_pop() {
                    Some(job) => {
                        idle_polls = 0;
                        job();
                    }
                    None if idle_polls < 64 => {
                        idle_polls += 1;
                        thread::yield_now();
                    }
                    None => {
                        let rem = ctx.remaining.lock().unwrap();
                        if *rem > 0 {
                            let _ = ctx
                                .done_cv
                                .wait_timeout(rem, std::time::Duration::from_micros(100))
                                .unwrap();
                        }
                    }
                }
            }
        } else {
            let mut rem = ctx.remaining.lock().unwrap();
            while *rem > 0 {
                rem = ctx.done_cv.wait(rem).unwrap();
            }
            drop(rem);
        }
        if let Some(payload) = ctx.panic.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }

    /// `par_for` collecting `f(i)` into index order.
    pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(&self, n: usize, f: F) -> Vec<T> {
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        self.par_for(n, |i| {
            *slots[i].lock().unwrap() = Some(f(i));
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("par_map slot filled"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Workers drain queued jobs, then exit on the shutdown flag.
        self.queue.shutdown();
        // Join them so a dropped pool leaves no stray threads (what the
        // Miri tier checks) — except from a thread that is itself one of
        // these workers: a nested `pool()` clone can make a worker the
        // last Arc holder during a `set_threads` swap, and joining
        // yourself deadlocks. An unjoined worker exits on its own right
        // after the drain.
        let me = thread::current().id();
        for h in self.workers.drain(..) {
            if h.thread().id() != me {
                // lint:allow(swallowed-result) Drop cannot propagate; a worker's Err means it panicked, and the process is already tearing the pool down
                let _ = h.join();
            }
        }
    }
}

#[derive(Clone, Copy)]
struct SendPtr(*const ());
// SAFETY: only ever wraps a `TaskCtx` that outlives the helper jobs it
// is sent to (see the lifetime argument in `par_for`); `TaskCtx` itself
// is `Sync` (its `f` is `Sync`, the rest is atomics/locks), so sharing
// the pointee across worker threads is sound.
unsafe impl Send for SendPtr {}

struct TaskCtx<'a> {
    f: &'a (dyn Fn(usize) + Sync),
    n: usize,
    chunk: usize,
    next: AtomicUsize,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    remaining: Mutex<usize>,
    done_cv: Condvar,
}

impl TaskCtx<'_> {
    /// Claim chunks until the index space is exhausted. Never unwinds: a
    /// panic in `f` is parked in `self.panic` for the caller to re-raise.
    fn run_lane(&self) {
        loop {
            let start = self.next.fetch_add(self.chunk, Ordering::Relaxed);
            if start >= self.n {
                return;
            }
            let end = (start + self.chunk).min(self.n);
            let r = catch_unwind(AssertUnwindSafe(|| {
                for i in start..end {
                    (self.f)(i);
                }
            }));
            if let Err(payload) = r {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
                // Lane keeps claiming chunks so the index space drains and
                // the caller never deadlocks. Note: the rest of THIS chunk
                // is skipped (the panic aborted it mid-loop), so coverage
                // is not complete under panics — fine, because the parked
                // payload is re-raised and the results are discarded.
            }
        }
    }
}

/// Write handle for `par_for` lanes that fill disjoint row ranges of one
/// f32 buffer (the shared unsafe substrate for row-blocked tensor ops and
/// the serving gather/scatter paths).
///
/// Create a fresh `RowsPtr` per parallel fan-out: in debug builds each
/// handle starts a new disjointness *generation* for its buffer — every
/// [`RowsPtr::slice`] is recorded in a claim ledger and checked against
/// the generation's other claims, so an overlapping lane panics at the
/// claim (before any aliasing slice exists, which also makes the check
/// Miri-clean) instead of silently racing. Release builds compile the
/// ledger out; the comment-and-review contract is all that remains, so
/// keep the per-call `// SAFETY:` arguments honest.
#[derive(Clone, Copy)]
pub struct RowsPtr {
    ptr: *mut f32,
    len: usize,
}
// SAFETY: lanes write only the ranges they own (callers guarantee
// disjointness; debug builds enforce it dynamically) and the buffer
// outlives the par_for call.
unsafe impl Send for RowsPtr {}
// SAFETY: same argument as Send — a shared `RowsPtr` only hands out
// caller-disjoint ranges, so concurrent `slice` calls never alias.
unsafe impl Sync for RowsPtr {}

impl RowsPtr {
    /// Wrap `buf` for one parallel fan-out (debug builds reset the
    /// buffer's claim ledger here — see the type docs).
    pub fn new(buf: &mut [f32]) -> RowsPtr {
        #[cfg(debug_assertions)]
        claims::reset(buf.as_mut_ptr() as usize);
        RowsPtr { ptr: buf.as_mut_ptr(), len: buf.len() }
    }

    /// The `len`-element range starting at `offset`.
    ///
    /// # Safety
    /// `offset + len` must be in bounds of the wrapped buffer, and ranges
    /// handed to concurrent lanes must not overlap. Debug builds turn a
    /// violation of either clause into an immediate panic (bounds here,
    /// overlap against this handle's other claims in the ledger).
    pub unsafe fn slice(&self, offset: usize, len: usize) -> &mut [f32] {
        debug_assert!(
            offset <= self.len && len <= self.len - offset,
            "RowsPtr::slice out of bounds: [{offset}, {offset}+{len}) vs buffer len {}",
            self.len
        );
        #[cfg(debug_assertions)]
        claims::claim(self.ptr as usize, offset, len);
        std::slice::from_raw_parts_mut(self.ptr.add(offset), len)
    }
}

/// Debug-build claim ledger behind [`RowsPtr`]: a map from buffer base
/// address to the ranges sliced out of it since its last `RowsPtr::new`.
/// Exists only under `cfg(debug_assertions)` — release builds carry no
/// ledger, no lock, no overhead.
#[cfg(debug_assertions)]
mod claims {
    use std::collections::BTreeMap;
    use std::sync::{Mutex, PoisonError};

    static CLAIMS: Mutex<BTreeMap<usize, Vec<(usize, usize)>>> = Mutex::new(BTreeMap::new());

    fn ledger() -> std::sync::MutexGuard<'static, BTreeMap<usize, Vec<(usize, usize)>>> {
        // Poison-tolerant: a panicked test (e.g. the should_panic overlap
        // test itself) must not cascade into every later claimant.
        CLAIMS.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Forget all claims on `base`: a fresh `RowsPtr::new` starts a new
    /// fan-out generation over the buffer (allocator address reuse is
    /// handled the same way — the new owner resets the entry).
    pub(super) fn reset(base: usize) {
        ledger().remove(&base);
    }

    /// Record `[offset, offset+len)` against `base`, panicking if it
    /// overlaps any other claim of the current generation.
    pub(super) fn claim(base: usize, offset: usize, len: usize) {
        if len == 0 {
            return;
        }
        let mut map = ledger();
        let ranges = map.entry(base).or_default();
        for &(o, l) in ranges.iter() {
            assert!(
                offset + len <= o || o + l <= offset,
                "RowsPtr::slice overlap: [{offset}, {}) vs existing claim [{o}, {}) \
                 on the same buffer generation",
                offset + len,
                o + l
            );
        }
        ranges.push((offset, len));
    }
}

// ---------------------------------------------------------------- global --

static GLOBAL: OnceLock<RwLock<Arc<ThreadPool>>> = OnceLock::new();

fn global() -> &'static RwLock<Arc<ThreadPool>> {
    GLOBAL.get_or_init(|| RwLock::new(Arc::new(ThreadPool::new(default_threads()))))
}

/// `HEAPR_THREADS` if set to a positive integer, else available
/// parallelism. A malformed value falls back to available parallelism too
/// (with a warning) — never to a silently serial pool.
pub fn default_threads() -> usize {
    let hw = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    match std::env::var("HEAPR_THREADS") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                crate::warn!(
                    "HEAPR_THREADS={v:?} is not a positive integer; \
                     using available parallelism ({hw})"
                );
                hw
            }
        },
        Err(_) => hw,
    }
}

/// Handle to the process-wide pool.
pub fn pool() -> Arc<ThreadPool> {
    // lint:allow(hot-path-alloc) Arc handle clone: a refcount bump on the process-wide pool, no buffer is copied
    global().read().unwrap().clone()
}

/// Current global lane count.
pub fn threads() -> usize {
    pool().threads()
}

/// Row-block height for row-partitioned parallel kernels (the GEMM
/// drivers): at most `cap` rows per work item, shrinking — down to
/// single rows — until there are about four blocks per lane, so
/// small-`m` work (decode-shaped GEMMs, `m` = batch) still fans out.
/// `threads` is passed in (not re-read) so one kernel invocation sees
/// one consistent lane count. Row blocking sits outside the GEMM
/// accumulation contract: any block height yields bitwise-identical
/// results.
pub fn row_block(m: usize, cap: usize, threads: usize) -> usize {
    cap.min(m.div_ceil(threads.max(1) * 4)).max(1)
}

/// Swap the global pool for one with `n` lanes (benchmark threads axis;
/// library code never calls this). In-flight `par_for`s on the old pool
/// finish normally — its workers drain and exit once unreferenced.
pub fn set_threads(n: usize) {
    *global().write().unwrap() = Arc::new(ThreadPool::new(n));
}

/// Serializes tests that reconfigure process-global execution state —
/// the global pool via [`set_threads`], the GEMM kernel selection via
/// `tensor::gemm::set_kernel`. `cargo test` runs tests on parallel
/// threads, and swapping the pool while another test is mid-`par_for`
/// (or flipping the kernel under a bitwise-equality assertion) makes
/// such tests flaky. Poison is ignored: one panicked test must not
/// cascade into every later lock holder.
#[doc(hidden)]
pub fn test_serial_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// `f(i)` for `i in 0..n` on the global pool.
pub fn par_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    pool().par_for(n, f)
}

/// Collect `f(i)` for `i in 0..n` on the global pool, in index order.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    pool().par_map(n, f)
}

/// Spawn a free-standing OS thread named `heapr-<name>`. This is the one
/// sanctioned spawn path outside this module — the `no-raw-thread-spawn`
/// lint rule rejects raw `std::thread::spawn` everywhere else — so every
/// thread in the process is attributable in debuggers, profilers and
/// panic messages. Long-lived service threads (the serve-loop feeder,
/// the CLI stream printer) go through here; data-parallel work belongs
/// on [`par_for`]/[`par_map`] instead.
pub fn spawn_named<T, F>(name: &str, f: F) -> thread::JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    thread::Builder::new()
        .name(format!("heapr-{name}"))
        .spawn(f)
        .expect("spawn named thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn single_thread_runs_inline() {
        let p = ThreadPool::new(1);
        let caller = thread::current().id();
        let ids = Mutex::new(Vec::new());
        p.par_for(8, |_| ids.lock().unwrap().push(thread::current().id()));
        let ids = ids.into_inner().unwrap();
        assert_eq!(ids.len(), 8);
        assert!(ids.iter().all(|&id| id == caller), "threads=1 must be inline");
    }

    #[test]
    fn every_index_exactly_once() {
        let p = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        p.par_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_in_index_order() {
        let p = ThreadPool::new(3);
        let v = p.par_map(100, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn uses_multiple_threads_when_sized_up() {
        let p = ThreadPool::new(4);
        let ids = Mutex::new(std::collections::HashSet::new());
        p.par_for(64, |_| {
            ids.lock().unwrap().insert(thread::current().id());
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert!(ids.into_inner().unwrap().len() > 1, "expected >1 worker thread");
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let p = ThreadPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            p.par_for(50, |i| {
                if i == 17 {
                    panic!("boom at {i}");
                }
            });
        }));
        assert!(r.is_err(), "panic in par_for body must propagate");
        // pool remains usable after a propagated panic
        let sum = AtomicU64::new(0);
        p.par_for(10, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn nested_par_for_completes_without_deadlock() {
        // every lane of a 2-thread pool is busy with an outer chunk; the
        // nested par_fors must still drain via caller-helps
        let p = Arc::new(ThreadPool::new(2));
        let q = Arc::clone(&p);
        let total = AtomicUsize::new(0);
        p.par_for(4, |_| {
            q.par_for(4, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn nested_par_for_subdivides_across_idle_lanes() {
        // outer uses 2 of 4 lanes; the nested loops' helper jobs must be
        // picked up by the idle ones instead of running inline
        let p = Arc::new(ThreadPool::new(4));
        let q = Arc::clone(&p);
        let ids = Mutex::new(std::collections::HashSet::new());
        p.par_for(2, |_| {
            q.par_for(8, |_| {
                ids.lock().unwrap().insert(thread::current().id());
                std::thread::sleep(std::time::Duration::from_millis(2));
            });
        });
        let ids = ids.into_inner().unwrap();
        assert!(ids.len() >= 3, "nested work stayed on {} lane(s)", ids.len());
    }

    #[test]
    fn nested_indices_run_exactly_once() {
        let p = Arc::new(ThreadPool::new(4));
        let q = Arc::clone(&p);
        let hits: Vec<AtomicUsize> = (0..4 * 64).map(|_| AtomicUsize::new(0)).collect();
        p.par_for(4, |o| {
            q.par_for(64, |i| {
                hits[o * 64 + i].fetch_add(1, Ordering::Relaxed);
            });
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn panic_in_nested_par_for_propagates() {
        let p = Arc::new(ThreadPool::new(4));
        let q = Arc::clone(&p);
        let r = catch_unwind(AssertUnwindSafe(|| {
            p.par_for(4, |o| {
                q.par_for(8, |i| {
                    if o == 1 && i == 5 {
                        panic!("inner boom");
                    }
                });
            });
        }));
        assert!(r.is_err(), "nested panic must reach the outer caller");
        // pool remains usable afterwards
        let sum = AtomicU64::new(0);
        p.par_for(10, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn row_block_shrinks_for_small_m_and_caps_at_cap() {
        // plenty of rows: capped at `cap`
        assert_eq!(row_block(1000, 64, 4), 63); // ceil(1000/16)=63 < 64
        assert_eq!(row_block(4096, 64, 4), 64);
        // small m: single-row blocks so every lane gets work
        assert_eq!(row_block(4, 64, 4), 1);
        assert_eq!(row_block(1, 64, 8), 1);
        // serial pool: still sized, never zero
        assert_eq!(row_block(10, 64, 1), 3);
        assert!(row_block(1, 64, 0) >= 1);
    }

    #[test]
    fn spawn_named_names_the_thread() {
        let h = spawn_named("test-worker", || thread::current().name().map(String::from));
        assert_eq!(h.join().unwrap().as_deref(), Some("heapr-test-worker"));
    }

    #[test]
    fn rows_ptr_disjoint_lanes_fill_their_own_rows() {
        let p = ThreadPool::new(4);
        let mut buf = vec![0.0f32; 64 * 8];
        let rows = RowsPtr::new(&mut buf);
        p.par_for(64, |i| {
            // SAFETY: lane i writes only its own row i (disjoint, in bounds).
            let row = unsafe { rows.slice(i * 8, 8) };
            for v in row {
                *v = i as f32;
            }
        });
        for (i, c) in buf.chunks(8).enumerate() {
            assert!(c.iter().all(|&v| v == i as f32), "row {i} corrupted");
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "out of bounds")]
    fn rows_ptr_bounds_check_fires_in_debug() {
        let mut buf = vec![0.0f32; 8];
        let rows = RowsPtr::new(&mut buf);
        // SAFETY: violated on purpose — the debug bounds assert must
        // abort before the raw slice is materialized.
        let _ = unsafe { rows.slice(4, 8) };
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "overlap")]
    fn rows_ptr_overlap_check_fires_in_debug() {
        let mut buf = vec![0.0f32; 16];
        let rows = RowsPtr::new(&mut buf);
        // SAFETY: in bounds; first claim of this generation.
        let _a = unsafe { rows.slice(0, 8) };
        // SAFETY: in bounds; overlaps the first claim on purpose — must
        // panic at the ledger check before any aliasing slice exists.
        let _b = unsafe { rows.slice(4, 8) };
    }

    #[cfg(debug_assertions)]
    #[test]
    fn rows_ptr_new_resets_the_claim_ledger() {
        let mut buf = vec![0.0f32; 8];
        for _ in 0..3 {
            // same base address every pass: without the reset in `new`,
            // the second pass would trip the overlap assert
            let rows = RowsPtr::new(&mut buf);
            // SAFETY: one in-bounds claim per generation, no overlap.
            let _ = unsafe { rows.slice(0, 8) };
        }
    }

    #[test]
    fn sum_matches_serial() {
        let p = ThreadPool::new(8);
        let par = Mutex::new(0u64);
        p.par_for(5000, |i| {
            *par.lock().unwrap() += (i as u64).wrapping_mul(2654435761);
        });
        let want: u64 = (0..5000u64).map(|i| i.wrapping_mul(2654435761)).sum();
        assert_eq!(*par.lock().unwrap(), want);
    }
}
