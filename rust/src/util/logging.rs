//! Leveled stderr logger with elapsed-time prefixes.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
static START: OnceLock<Instant> = OnceLock::new();

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match l {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
    };
    eprintln!("[{t:8.2}s {tag}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info,
                                   &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug,
                                   &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn,
                                   &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
