//! Configuration. The *model* configuration is read from
//! `artifacts/<preset>/manifest.json` — the python exporter is the single
//! source of truth, so rust can never disagree with the compiled HLO about
//! shapes. Run-level knobs (steps, lr, corpus size, pruning ratio...) are
//! rust-side with CLI overrides.

use anyhow::Result;

use crate::util::json::Json;

/// Mirror of `python/compile/configs.py::ModelConfig`, parsed from the
/// manifest's `preset` object.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub d_inter: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub blk_n: usize,
    pub blk_i: usize,
    pub serve_batches: Vec<usize>,
    pub token_buckets: Vec<usize>,
    pub width_buckets: Vec<usize>,
    pub max_decode_len: usize,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.get("name")?.as_str()?.to_string(),
            vocab: j.get("vocab")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            d_head: j.get("d_head")?.as_usize()?,
            n_experts: j.get("n_experts")?.as_usize()?,
            top_k: j.get("top_k")?.as_usize()?,
            d_inter: j.get("d_inter")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            batch: j.get("batch")?.as_usize()?,
            blk_n: j.get("blk_n")?.as_usize()?,
            blk_i: j.get("blk_i")?.as_usize()?,
            serve_batches: j.get("serve_batches")?.usize_vec()?,
            token_buckets: j.get("token_buckets")?.usize_vec()?,
            width_buckets: j.get("width_buckets")?.usize_vec()?,
            max_decode_len: j.get("max_decode_len")?.as_usize()?,
        })
    }

    /// Total atomic experts in the model (the pruning universe).
    pub fn n_atomic(&self) -> usize {
        self.n_layers * self.n_experts * self.d_inter
    }

    /// Tokens per training / calibration batch.
    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.seq_len
    }
}

/// Run-level knobs with sensible defaults; every experiment binds these
/// from CLI flags.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub seed: u64,
    pub train_steps: usize,
    pub lr: f64,
    pub corpus_mb: f64,
    /// Calibration samples (sequences), paper default 128.
    pub calib_samples: usize,
    pub eval_batches: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            seed: 0,
            train_steps: 300,
            lr: 3e-3,
            corpus_mb: 2.0,
            calib_samples: 128,
            eval_batches: 16,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(
            r#"{"name":"tiny","vocab":260,"d_model":64,"n_layers":2,
                "n_heads":2,"d_head":32,"n_experts":4,"top_k":2,
                "d_inter":32,"seq_len":64,"batch":4,"blk_n":16,"blk_i":8,
                "aux_coef":0.01,
                "serve_batches":[1,4],"token_buckets":[8,32],
                "width_buckets":[8,16,24,32],"max_decode_len":96}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_preset() {
        let c = ModelConfig::from_json(&sample_json()).unwrap();
        assert_eq!(c.d_model, 64);
        assert_eq!(c.n_atomic(), 2 * 4 * 32);
        assert_eq!(c.tokens_per_batch(), 256);
        assert_eq!(c.width_buckets, vec![8, 16, 24, 32]);
    }

    #[test]
    fn missing_key_is_error() {
        let j = Json::parse(r#"{"name":"x"}"#).unwrap();
        assert!(ModelConfig::from_json(&j).is_err());
    }
}
