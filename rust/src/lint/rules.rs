//! The repo-specific lint rules, driven by the token stream of
//! [`super::lexer`]. Each rule is a pure function from a parsed
//! [`SourceFile`] (or the registry inputs: `Cargo.toml`, `README.md`,
//! the `rust/tests/` listing) to diagnostics; [`super::lint_repo`] wires
//! them over the repo and applies `lint:allow` escapes afterwards.
//!
//! See `docs/ARCHITECTURE.md` §7 for the rule catalogue and the
//! procedure for adding a rule.

use super::Diagnostic;
use crate::lint::lexer::{lex, Tok, TokKind};
use crate::lint::tree::{self, Tree};

/// Every `unsafe` block/fn/impl must be immediately preceded by a
/// `// SAFETY:` comment (or a `# Safety` doc section).
pub const UNSAFE_SAFETY: &str = "unsafe-needs-safety-comment";
/// `partial_cmp(..).unwrap()/.expect(..)` is banned outside `util::cmp`
/// (NaN ordering must go through `total_cmp`-based helpers).
pub const PARTIAL_CMP: &str = "no-partial-cmp-unwrap";
/// `std::thread::spawn` is allowed only inside `util::pool`.
pub const THREAD_SPAWN: &str = "no-raw-thread-spawn";
/// Every `HEAPR_*` env read must have a row in README's env table, and
/// every row must correspond to a read.
pub const ENV_REGISTRY: &str = "env-var-registry";
/// Every file under `rust/tests/` must be a `Cargo.toml` test target.
pub const TEST_REG: &str = "test-registration";
/// The `use crate::…` graph must satisfy the ARCHITECTURE.md layer map
/// and be cycle-free (cross-file; see [`super::graph::layering`]).
pub const LAYERING: &str = "layering";
/// The may-hold-while-acquiring lock graph must be cycle-free
/// (cross-file; see [`super::graph::lock_order`]).
pub const LOCK_ORDER: &str = "lock-order";
/// No `unwrap()`/`expect()`/`panic!`/`unreachable!`/`todo!` in the
/// decode hot path (host, kv, scheduler, serve, gemm).
pub const PANIC_FREE: &str = "panic-free-serve";
/// `RowsPtr`/`SendPtr` construction only in the registered raw-pointer
/// modules (`util/pool`, `tensor/gemm`, `runtime/host`).
pub const SENDPTR: &str = "sendptr-confinement";
/// No heap-allocation site in any function reachable from the
/// decode-step entry set (cross-file; see [`super::calls`]).
pub const HOT_ALLOC: &str = "hot-path-alloc";
/// No bare `+=` / `.sum::<f32|f64>()` float reduction outside the
/// kernel layer and the sanctioned `util` reducers.
pub const FLOAT_ACCUM: &str = "float-accum-order";
/// No `let _ = <fallible call>` / bare `.ok();` Result discards
/// outside `#[cfg(test)]`.
pub const SWALLOWED: &str = "swallowed-result";
/// Meta-diagnostic: a `lint:allow` naming a rule that does not exist.
pub const UNKNOWN_RULE: &str = "unknown-rule";
/// Meta-diagnostic: a `lint:allow` for a rule in [`JUSTIFIED_RULES`]
/// with no justification text after the closing paren.
pub const ALLOW_JUSTIFY: &str = "allow-needs-justification";

/// The enforced rule set (the valid names for `lint:allow`).
pub const RULES: [&str; 12] = [
    UNSAFE_SAFETY,
    PARTIAL_CMP,
    THREAD_SPAWN,
    ENV_REGISTRY,
    TEST_REG,
    LAYERING,
    LOCK_ORDER,
    PANIC_FREE,
    SENDPTR,
    HOT_ALLOC,
    FLOAT_ACCUM,
    SWALLOWED,
];

/// Rules whose `lint:allow` escapes must carry a written justification:
/// `// lint:allow(panic-free-serve) <why this site is sound>`. An empty
/// suffix surfaces as [`ALLOW_JUSTIFY`] (the allow still applies, so the
/// meta-finding is the only diagnostic — CI stays red either way).
pub const JUSTIFIED_RULES: [&str; 7] =
    [LAYERING, LOCK_ORDER, PANIC_FREE, SENDPTR, HOT_ALLOC, FLOAT_ACCUM, SWALLOWED];

/// One paragraph of normative documentation per rule (and per
/// meta-diagnostic) — the `--explain <rule>` text, and the source of
/// truth the README rule table summarizes.
pub const RULE_DOCS: &[(&str, &str)] = &[
    (
        UNSAFE_SAFETY,
        "Every `unsafe` block, fn, or impl must sit directly under a `// SAFETY:` \
         comment (or a `# Safety` doc section) stating the soundness argument. \
         Attribute lines between the comment and the item are transparent; a blank \
         or code line breaks adjacency.",
    ),
    (
        PARTIAL_CMP,
        "`partial_cmp(..).unwrap()/.expect(..)` is banned outside `util::cmp`: a NaN \
         comparand panics at the ordering site. Orderings over floats go through the \
         `total_cmp`-based helpers, which are total by construction.",
    ),
    (
        THREAD_SPAWN,
        "`std::thread::spawn` is allowed only inside `util::pool`. One spawn path \
         means thread naming, panic parking, and shutdown are audited in one place \
         instead of leaking per call site.",
    ),
    (
        ENV_REGISTRY,
        "Every `HEAPR_*` environment read must have a row in README's env table, and \
         every row must correspond to a live read — both directions, so the table \
         can be trusted as the complete runtime-knob inventory.",
    ),
    (
        TEST_REG,
        "Every file under `rust/tests/` must be declared as a `[[test]]` target in \
         Cargo.toml, and every declared target must exist on disk. An orphaned test \
         file silently never runs; this keeps the suite closed under addition.",
    ),
    (
        LAYERING,
        "The `use crate::…` graph must satisfy the layer map and stay cycle-free. \
         The map is parsed at lint time from the machine-parsed table in \
         ARCHITECTURE.md §2 (the doc is the normative source; a missing or \
         unparseable table is itself a finding), with the built-in map as the \
         fallback when the doc is absent (fixture trees).",
    ),
    (
        LOCK_ORDER,
        "The conservative may-hold-while-acquiring lock graph over the \
         lock-discipline scope (`util/pool`, `runtime/kv`, `coordinator/`) must be \
         cycle-free. Lock identity is the receiver name before `.lock()`; call \
         edges come from the `lint::calls` graph, restricted to the scope.",
    ),
    (
        PANIC_FREE,
        "No `unwrap()` / `expect()` / `panic!` / `unreachable!` / `todo!` in the \
         decode hot path (host, kv, scheduler, serve, gemm). A bad request must \
         fail with an error response, not take the serve loop down.",
    ),
    (
        SENDPTR,
        "`RowsPtr::new` / `SendPtr` construction is confined to the registered \
         raw-pointer modules (`util/pool`, `tensor/gemm`, `runtime/host`), so \
         raw-pointer parallelism cannot spread unaudited. Fires in test code too.",
    ),
    (
        HOT_ALLOC,
        "No heap-allocation site (`vec![..]`, `format!`, `Box::new`, \
         `String::from`, `::with_capacity`, `.to_vec()`, `.to_string()`, \
         `.to_owned()`, `.clone()`, `.collect()`) in any function reachable from \
         the decode-step entry set in the `lint::calls` graph. `Vec::new` / \
         `String::new` are exempt (const, no allocation until growth), and growing \
         a reused state-owned scratch buffer is by design not a finding — it \
         amortizes to zero steady-state allocations. Entry points, cold \
         boundaries, and sanctioned value-ABI sinks are listed in \
         ARCHITECTURE.md §7; predictable per-token latency is the contract.",
    ),
    (
        FLOAT_ACCUM,
        "No bare `acc += x` over a float local and no `.sum::<f32|f64>()` outside \
         the kernel layer (`tensor/`, `runtime/host.rs`) and the sanctioned \
         reducers (`util/stats.rs`, `util/rng.rs`). Every bitwise-equivalence \
         claim rests on a pinned accumulation order; ad-hoc reductions reorder \
         under refactors and break it silently. `#[cfg(test)]` code is exempt.",
    ),
    (
        SWALLOWED,
        "No `let _ = <fallible call>` and no bare `.ok();` statement outside \
         `#[cfg(test)]`: both discard a `Result` without a decision. Handle it, \
         propagate with `?`, or justify the discard with a written allow. \
         Expressions that already decide (`unwrap`/`expect`/trailing `?`) are \
         not findings.",
    ),
    (
        UNKNOWN_RULE,
        "Meta-diagnostic: a `lint:allow(..)` escape names a rule that does not \
         exist, so it would silently suppress nothing. Typos stay loud.",
    ),
    (
        ALLOW_JUSTIFY,
        "Meta-diagnostic: a `lint:allow` for a justified-class rule carries no \
         written justification after the closing paren. The allow still applies, \
         so this finding is what keeps CI red until the why is written down.",
    ),
];

/// One lexed source file plus a line → covering-tokens index (multi-line
/// comments and strings cover every line they span).
pub struct SourceFile {
    /// Repo-relative path with `/` separators (used for rule exemptions
    /// and diagnostics).
    pub path: String,
    pub toks: Vec<Tok>,
    cover: Vec<Vec<usize>>,
    /// Line ranges governed by `#[cfg(test)]` items (see
    /// [`tree::Tree::test_lines`]); hot-path rules skip these.
    test_lines: Vec<(u32, u32)>,
}

/// Classification of one source line, for the SAFETY-adjacency walk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum LineKind {
    /// No tokens at all (or only whitespace).
    Blank,
    /// Only comment tokens.
    Comment,
    /// First code token is `#` — an attribute between the comment and
    /// the item it documents.
    Attr,
    Code,
}

impl SourceFile {
    pub fn parse(path: &str, src: &str) -> SourceFile {
        let toks = lex(src);
        let nlines = toks.iter().map(|t| t.end_line).max().unwrap_or(0) as usize;
        let mut cover: Vec<Vec<usize>> = vec![Vec::new(); nlines];
        for (i, t) in toks.iter().enumerate() {
            for ln in t.line..=t.end_line {
                cover[ln as usize - 1].push(i);
            }
        }
        let test_lines = Tree::new(&toks).test_lines();
        SourceFile { path: path.to_string(), toks, cover, test_lines }
    }

    /// Is 1-based `line` inside a `#[cfg(test)]` item?
    pub fn is_test_line(&self, line: u32) -> bool {
        tree::in_ranges(&self.test_lines, line)
    }

    /// Tokens whose span covers line `ln` (1-based).
    fn line_toks(&self, ln: u32) -> impl Iterator<Item = &Tok> {
        let idx: &[usize] = self.cover.get(ln as usize - 1).map_or(&[], |v| v.as_slice());
        idx.iter().map(move |&i| &self.toks[i])
    }

    fn line_kind(&self, ln: u32) -> LineKind {
        let mut any = false;
        let mut all_comments = true;
        let mut first_code: Option<&Tok> = None;
        for t in self.line_toks(ln) {
            any = true;
            if t.kind.is_comment() {
                continue;
            }
            all_comments = false;
            if t.line < ln {
                return LineKind::Code; // continuation of a multi-line literal
            }
            match first_code {
                Some(f) if f.col <= t.col => {}
                _ => first_code = Some(t),
            }
        }
        if !any {
            return LineKind::Blank;
        }
        if all_comments {
            return LineKind::Comment;
        }
        match first_code {
            Some(t) if t.kind == TokKind::Punct && t.text == "#" => LineKind::Attr,
            _ => LineKind::Code,
        }
    }

    /// The non-comment token stream, for sequence matching.
    fn code(&self) -> Vec<&Tok> {
        self.toks.iter().filter(|t| !t.kind.is_comment()).collect()
    }
}

fn diag(rule: &'static str, file: &str, t: &Tok, message: String) -> Diagnostic {
    Diagnostic { rule, file: file.to_string(), line: t.line, col: t.col, message }
}

// ------------------------------------------------ unsafe-needs-safety --

/// True when a comment with the given marker sits next to the token:
/// on the same line, or on the contiguous comment block directly above
/// it (attribute lines like `#[target_feature(..)]` may sit in between;
/// a blank or code line breaks adjacency).
fn has_adjacent_marker(f: &SourceFile, t: &Tok, markers: &[&str]) -> bool {
    let hit = |text: &str| markers.iter().any(|m| text.contains(m));
    if f.line_toks(t.line).any(|c| c.kind.is_comment() && hit(&c.text)) {
        return true;
    }
    let mut ln = t.line;
    while ln > 1 {
        ln -= 1;
        match f.line_kind(ln) {
            LineKind::Comment => {
                if f.line_toks(ln).any(|c| c.kind.is_comment() && hit(&c.text)) {
                    return true;
                }
            }
            LineKind::Attr => {}
            LineKind::Blank | LineKind::Code => return false,
        }
    }
    false
}

/// Rule `unsafe-needs-safety-comment`: every `unsafe` token must carry
/// an adjacent `// SAFETY:` comment or `# Safety` doc section.
pub fn unsafe_needs_safety(f: &SourceFile) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for t in &f.toks {
        if t.kind != TokKind::Ident || t.text != "unsafe" {
            continue;
        }
        if has_adjacent_marker(f, t, &["SAFETY:", "# Safety"]) {
            continue;
        }
        out.push(diag(
            UNSAFE_SAFETY,
            &f.path,
            t,
            "`unsafe` without an immediately preceding `// SAFETY:` comment \
             (or `# Safety` doc section)"
                .to_string(),
        ));
    }
    out
}

// ------------------------------------------------ no-partial-cmp-unwrap --

/// Rule `no-partial-cmp-unwrap`: ban `partial_cmp(..).unwrap()` and
/// `partial_cmp(..).expect(..)` outside `util::cmp` — a NaN anywhere in
/// the compared data panics the process; ordering goes through the
/// `total_cmp`-based helpers instead (PR 3's NaN sweep, kept enforced).
pub fn no_partial_cmp_unwrap(f: &SourceFile) -> Vec<Diagnostic> {
    if f.path.ends_with("util/cmp.rs") {
        return Vec::new();
    }
    let code = f.code();
    let mut out = Vec::new();
    for i in 0..code.len() {
        if !(code[i].kind == TokKind::Ident && code[i].text == "partial_cmp") {
            continue;
        }
        let Some(open) = code.get(i + 1) else { continue };
        if !(open.kind == TokKind::Punct && open.text == "(") {
            continue;
        }
        // find the matching close paren
        let mut depth = 0usize;
        let mut j = i + 1;
        let close = loop {
            let Some(t) = code.get(j) else { break None };
            if t.kind == TokKind::Punct && t.text == "(" {
                depth += 1;
            } else if t.kind == TokKind::Punct && t.text == ")" {
                depth -= 1;
                if depth == 0 {
                    break Some(j);
                }
            }
            j += 1;
        };
        let Some(close) = close else { continue };
        let dot = code.get(close + 1);
        let method = code.get(close + 2);
        let unwraps = matches!(
            (dot, method),
            (Some(d), Some(m))
                if d.kind == TokKind::Punct && d.text == "."
                    && m.kind == TokKind::Ident
                    && (m.text == "unwrap" || m.text == "expect")
        );
        if unwraps {
            out.push(diag(
                PARTIAL_CMP,
                &f.path,
                code[i],
                "`partial_cmp(..).unwrap()/.expect(..)` panics on NaN; use the \
                 `util::cmp` total-order helpers"
                    .to_string(),
            ));
        }
    }
    out
}

// ------------------------------------------------- no-raw-thread-spawn --

/// Rule `no-raw-thread-spawn`: `std::thread::spawn` only inside
/// `util::pool` — everything else goes through `util::pool::spawn_named`
/// so every OS thread in the process carries a `heapr-` name.
pub fn no_raw_thread_spawn(f: &SourceFile) -> Vec<Diagnostic> {
    if f.path.ends_with("util/pool.rs") {
        return Vec::new();
    }
    let code = f.code();
    let mut out = Vec::new();
    for w in code.windows(4) {
        let [a, b, c, d] = w else { continue };
        if a.kind == TokKind::Ident
            && a.text == "thread"
            && b.text == ":"
            && c.text == ":"
            && d.kind == TokKind::Ident
            && d.text == "spawn"
        {
            out.push(diag(
                THREAD_SPAWN,
                &f.path,
                a,
                "raw `std::thread::spawn` outside `util::pool`; use \
                 `util::pool::spawn_named` (named threads, one spawn path)"
                    .to_string(),
            ));
        }
    }
    out
}

// --------------------------------------------------- env-var-registry --

fn is_env_name(s: &str) -> bool {
    s.strip_prefix("HEAPR_").is_some_and(|rest| {
        !rest.is_empty()
            && rest.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
    })
}

/// `HEAPR_*` env reads in this file: `var("HEAPR_X")` / `var_os(..)`
/// call sites, returned as `(name, line, col)`.
pub fn env_reads(f: &SourceFile) -> Vec<(String, u32, u32)> {
    let code = f.code();
    let mut out = Vec::new();
    for w in code.windows(3) {
        let [call, open, arg] = w else { continue };
        if call.kind == TokKind::Ident
            && (call.text == "var" || call.text == "var_os")
            && open.text == "("
            && arg.kind == TokKind::Str
            && is_env_name(arg.str_content())
        {
            out.push((arg.str_content().to_string(), arg.line, arg.col));
        }
    }
    out
}

/// `HEAPR_*` rows of README's env table: table lines (`| \`HEAPR_X\` |…`)
/// whose first backtick span is exactly an env name.
pub fn readme_env_rows(readme: &str) -> Vec<(String, u32)> {
    let mut out = Vec::new();
    for (i, line) in readme.lines().enumerate() {
        if !line.trim_start().starts_with('|') {
            continue;
        }
        let Some(start) = line.find('`') else { continue };
        let rest = &line[start + 1..];
        let Some(end) = rest.find('`') else { continue };
        let name = &rest[..end];
        if is_env_name(name) {
            out.push((name.to_string(), i as u32 + 1));
        }
    }
    out
}

/// Rule `env-var-registry`: every read has a README row, every README
/// row has a read. `reads` is `(file, name, line, col)` over the whole
/// scan; `readme_path` is the display path for README-side diagnostics.
pub fn env_registry(
    reads: &[(String, String, u32, u32)],
    readme: &str,
    readme_path: &str,
) -> Vec<Diagnostic> {
    let rows = readme_env_rows(readme);
    let mut out = Vec::new();
    for (file, name, line, col) in reads {
        if !rows.iter().any(|(n, _)| n == name) {
            out.push(Diagnostic {
                rule: ENV_REGISTRY,
                file: file.clone(),
                line: *line,
                col: *col,
                message: format!(
                    "env var `{name}` is read here but has no row in \
                     {readme_path} §Runtime switches"
                ),
            });
        }
    }
    for (name, line) in &rows {
        if !reads.iter().any(|(_, n, _, _)| n == name) {
            out.push(Diagnostic {
                rule: ENV_REGISTRY,
                file: readme_path.to_string(),
                line: *line,
                col: 1,
                message: format!(
                    "documented env var `{name}` is never read in rust/src or rust/tests"
                ),
            });
        }
    }
    out
}

// -------------------------------------------------- test-registration --

/// Rule `test-registration`: every top-level `rust/tests/*.rs` file must
/// be declared as a test target in `Cargo.toml` (this workspace disables
/// target auto-discovery by living outside `src/`), and every declared
/// `rust/tests/` path must exist. `test_files` are bare file names.
pub fn test_registration(test_files: &[String], cargo: &str) -> Vec<Diagnostic> {
    let mut registered: Vec<(String, u32)> = Vec::new();
    for (i, line) in cargo.lines().enumerate() {
        let t = line.trim();
        let Some(rest) = t.strip_prefix("path = \"") else { continue };
        let Some(path) = rest.strip_suffix('"') else { continue };
        if let Some(name) = path.strip_prefix("rust/tests/") {
            registered.push((name.to_string(), i as u32 + 1));
        }
    }
    let mut out = Vec::new();
    for f in test_files {
        if !registered.iter().any(|(n, _)| n == f) {
            out.push(Diagnostic {
                rule: TEST_REG,
                file: format!("rust/tests/{f}"),
                line: 1,
                col: 1,
                message: format!(
                    "rust/tests/{f} is not declared as a test target in Cargo.toml \
                     (it would silently never run)"
                ),
            });
        }
    }
    for (name, line) in &registered {
        if !test_files.iter().any(|f| f == name) {
            out.push(Diagnostic {
                rule: TEST_REG,
                file: "Cargo.toml".to_string(),
                line: *line,
                col: 1,
                message: format!("Cargo.toml declares rust/tests/{name}, which does not exist"),
            });
        }
    }
    out
}

// -------------------------------------------------- panic-free-serve --

/// Is this file part of the decode hot path?
fn in_panic_free_scope(path: &str) -> bool {
    path.ends_with("runtime/host.rs")
        || path.ends_with("runtime/kv.rs")
        || path.ends_with("coordinator/scheduler.rs")
        || path.ends_with("coordinator/serve.rs")
        || path.ends_with("coordinator/http.rs")
        || path.contains("tensor/gemm")
}

/// Rule `panic-free-serve`: no `unwrap()`/`expect()`/`panic!`/
/// `unreachable!`/`todo!` in the decode hot path. A request must fail
/// with an error `Response`, not take the whole serve loop down.
/// `#[cfg(test)]` code is exempt; everything else needs a
/// `lint:allow(panic-free-serve) <justification>` escape.
pub fn panic_free_serve(f: &SourceFile) -> Vec<Diagnostic> {
    if !in_panic_free_scope(&f.path) {
        return Vec::new();
    }
    let code = f.code();
    let mut out = Vec::new();
    for i in 0..code.len() {
        let t = code[i];
        if t.kind != TokKind::Ident || f.is_test_line(t.line) {
            continue;
        }
        let what = match t.text.as_str() {
            // `.unwrap(` / `.expect(` method calls only — `unwrap_or`
            // and friends are the non-panicking fixes, not findings
            "unwrap" | "expect"
                if i > 0
                    && code[i - 1].text == "."
                    && code.get(i + 1).is_some_and(|n| n.text == "(") =>
            {
                format!(".{}()", t.text)
            }
            "panic" | "unreachable" | "todo"
                if code.get(i + 1).is_some_and(|n| n.text == "!") =>
            {
                format!("{}!", t.text)
            }
            _ => continue,
        };
        out.push(diag(
            PANIC_FREE,
            &f.path,
            t,
            format!(
                "`{what}` in the decode hot path; return an error \
                 (`.context(..)?` / `bail!`) or justify with \
                 `lint:allow(panic-free-serve) <why>`"
            ),
        ));
    }
    out
}

// ---------------------------------------------- sendptr-confinement --

/// Modules registered for raw-pointer parallelism (audited `RowsPtr` /
/// `SendPtr` construction).
fn in_sendptr_scope(path: &str) -> bool {
    path.ends_with("util/pool.rs")
        || path.contains("tensor/gemm")
        || path.ends_with("runtime/host.rs")
}

/// Rule `sendptr-confinement`: `RowsPtr::new(..)` and `SendPtr(..)`
/// construction sites are allowed only in the registered modules, so
/// raw-pointer parallelism cannot leak into new code unaudited. Fires
/// in test code too — tests run the same aliasing risks.
pub fn sendptr_confinement(f: &SourceFile) -> Vec<Diagnostic> {
    if in_sendptr_scope(&f.path) {
        return Vec::new();
    }
    let code = f.code();
    let mut out = Vec::new();
    for i in 0..code.len() {
        let t = code[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        let constructed = match t.text.as_str() {
            "RowsPtr" => {
                code.get(i + 1).is_some_and(|a| a.text == ":")
                    && code.get(i + 2).is_some_and(|a| a.text == ":")
                    && code.get(i + 3).is_some_and(|a| a.kind == TokKind::Ident && a.text == "new")
            }
            "SendPtr" => code.get(i + 1).is_some_and(|a| a.text == "(" || a.text == "{"),
            _ => false,
        };
        if constructed {
            out.push(diag(
                SENDPTR,
                &f.path,
                t,
                format!(
                    "`{}` constructed outside the registered raw-pointer modules \
                     (util/pool, tensor/gemm, runtime/host); move the construction \
                     or justify with `lint:allow(sendptr-confinement) <why>`",
                    t.text
                ),
            ));
        }
    }
    out
}

// --------------------------------------------------- float-accum-order --

/// Files whose reductions *are* the pinned-order contract: the kernel
/// layer (`tensor/`, plus `runtime/host.rs` — the decode attention /
/// softmax family pins its own order next to the GEMM driver) and the
/// sanctioned `util` reducers (`util/stats.rs`, `util/rng.rs`).
fn in_float_accum_scope(path: &str) -> bool {
    path.contains("tensor/")
        || path.ends_with("runtime/host.rs")
        || path.ends_with("util/stats.rs")
        || path.ends_with("util/rng.rs")
}

/// A numeric literal that denotes a float (`1.0`, `2.5f32`, `3f64`).
fn is_float_literal(text: &str) -> bool {
    text.contains('.') || text.ends_with("f32") || text.ends_with("f64")
}

/// Rule `float-accum-order`: bare `+=` accumulation into a float local
/// and `.sum::<f32|f64>()` reductions outside the sanctioned scope.
/// Every bitwise-equivalence claim in the repo rests on a pinned
/// accumulation order; an ad-hoc reduction reorders under innocent
/// refactors. Indexed (`dst[j] += …`) and field (`self.m.x += …`)
/// accumulations are deliberately out of pattern — the rule targets
/// scalar reduction loops, the shape that silently becomes a kernel.
/// `#[cfg(test)]` code and `rust/tests/` integration files are exempt —
/// test reference computations decide by assertion, not by contract.
pub fn float_accum_order(f: &SourceFile) -> Vec<Diagnostic> {
    if in_float_accum_scope(&f.path) || f.path.starts_with("rust/tests/") {
        return Vec::new();
    }
    let t = Tree::new(&f.toks);
    let code = &t.code;
    let mut out = Vec::new();

    // Pass 1: float-typed `let` locals — an explicit `: f32/f64`
    // annotation, or an initializer containing a float literal or an
    // `f32`/`f64` cast/path before its terminating `;`.
    let mut float_vars: Vec<&str> = Vec::new();
    for i in 0..code.len() {
        if code[i].kind != TokKind::Ident || code[i].text != "let" {
            continue;
        }
        let mut j = i + 1;
        if code.get(j).is_some_and(|x| x.text == "mut") {
            j += 1;
        }
        let Some(var) = code.get(j).filter(|x| x.kind == TokKind::Ident) else { continue };
        let mut is_float = false;
        let mut k = j + 1;
        let mut depth = 0usize;
        while k < code.len() {
            let c = code[k];
            if c.kind == TokKind::Punct {
                match c.text.as_str() {
                    "(" | "[" | "{" => depth += 1,
                    ")" | "]" | "}" => depth = depth.saturating_sub(1),
                    ";" if depth == 0 => break,
                    _ => {}
                }
            }
            is_float |= c.kind == TokKind::Num && is_float_literal(&c.text);
            is_float |= c.kind == TokKind::Ident && (c.text == "f32" || c.text == "f64");
            k += 1;
        }
        if is_float {
            float_vars.push(var.text.as_str());
        }
    }

    for i in 0..code.len() {
        let c = code[i];
        if c.kind != TokKind::Ident || f.is_test_line(c.line) {
            continue;
        }
        // Pass 2: `x += …` where `x` is a float local (not `recv.x`).
        if float_vars.contains(&c.text.as_str())
            && (i == 0 || code[i - 1].text != ".")
            && code.get(i + 1).is_some_and(|n| n.text == "+")
            && code.get(i + 2).is_some_and(|n| n.text == "=")
        {
            out.push(diag(
                FLOAT_ACCUM,
                &f.path,
                c,
                format!(
                    "bare `{} += ..` float accumulation outside the pinned kernels; \
                     route the reduction through `tensor::gemm` / `util::stats`, or \
                     justify with `lint:allow(float-accum-order) <why the order is \
                     free here>`",
                    c.text
                ),
            ));
        }
        // Pass 3: `.sum::<f32|f64>()` turbofish reductions.
        if c.text == "sum"
            && i > 0
            && code[i - 1].text == "."
            && code.get(i + 1).is_some_and(|n| n.text == ":")
            && code.get(i + 2).is_some_and(|n| n.text == ":")
            && code.get(i + 3).is_some_and(|n| n.text == "<")
            && code.get(i + 4).is_some_and(|n| n.text == "f32" || n.text == "f64")
        {
            out.push(diag(
                FLOAT_ACCUM,
                &f.path,
                c,
                "`.sum::<f32|f64>()` reduction outside the pinned kernels; \
                 iterator reduction order is unpinned — use `util::stats` or \
                 justify with `lint:allow(float-accum-order) <why>`"
                    .to_string(),
            ));
        }
    }
    out
}

// ---------------------------------------------------- swallowed-result --

/// True when the `.ok();` chain ending at the `.` token at index `dot`
/// is the tail of a binding or assignment (`let x = …ok();`,
/// `x = …ok();`): the Option is kept for use, not discarded. Walks
/// backwards to the statement start, hopping closed groups whole via
/// the partner table; reaching a `;` or an unmatched `{` first means
/// the chain stands bare.
fn ok_chain_is_bound(t: &Tree, dot: usize) -> bool {
    let code = &t.code;
    let mut j = dot;
    while j > 0 {
        j -= 1;
        let u = code[j];
        if u.kind != TokKind::Punct {
            if u.kind == TokKind::Ident && u.text == "let" {
                return true;
            }
            continue;
        }
        match u.text.as_str() {
            ")" | "]" | "}" => match t.partner(j) {
                Some(open) => j = open,
                None => return false, // unmatched closer: malformed, stay conservative
            },
            ";" | "{" => return false,
            "=" => {
                let prev = if j > 0 { code[j - 1].text.as_str() } else { "" };
                let next = code.get(j + 1).map_or("", |n| n.text.as_str());
                // a plain assignment `=` — not `==`/`!=`/`<=`/`>=`,
                // compound `+=`-family, or a match arm's `=>`
                if next != "="
                    && next != ">"
                    && !matches!(
                        prev,
                        "=" | "!" | "<" | ">" | "+" | "-" | "*" | "/" | "%" | "&" | "|" | "^"
                    )
                {
                    return true;
                }
            }
            _ => {}
        }
    }
    false
}

/// Rule `swallowed-result`: `let _ = <expr with a call>` and bare
/// `.ok();` statements discard a `Result` without a decision. The
/// pattern skips expressions that already decide — a contained
/// `unwrap`/`expect`, a trailing `?` before the `;`, or a binding
/// (`let x = …ok();` / `x = …ok();` convert Result→Option for use, they
/// do not discard it). `#[cfg(test)]` code and `rust/tests/`
/// integration files are exempt.
pub fn swallowed_result(f: &SourceFile) -> Vec<Diagnostic> {
    if f.path.starts_with("rust/tests/") {
        return Vec::new();
    }
    let t = Tree::new(&f.toks);
    let code = &t.code;
    let mut out = Vec::new();
    for i in 0..code.len() {
        let c = code[i];
        if c.kind != TokKind::Ident || f.is_test_line(c.line) {
            continue;
        }
        // (a) `let _ = …;`
        if c.text == "let"
            && code.get(i + 1).is_some_and(|n| n.text == "_")
            && code.get(i + 2).is_some_and(|n| n.text == "=")
        {
            let mut k = i + 3;
            let mut depth = 0usize;
            let (mut has_call, mut decided) = (false, false);
            while k < code.len() {
                let e = code[k];
                if e.kind == TokKind::Punct {
                    match e.text.as_str() {
                        "(" | "[" | "{" => depth += 1,
                        ")" | "]" | "}" => depth = depth.saturating_sub(1),
                        ";" if depth == 0 => {
                            decided |= code[k - 1].text == "?";
                            break;
                        }
                        _ => {}
                    }
                } else if e.kind == TokKind::Ident {
                    match e.text.as_str() {
                        "unwrap" | "expect" => decided = true,
                        name if !super::calls::is_keywordish(name) => {
                            // `name(` call or `name!(` macro call
                            has_call |= code.get(k + 1).is_some_and(|n| n.text == "(");
                            has_call |= code.get(k + 1).is_some_and(|n| n.text == "!")
                                && code.get(k + 2).is_some_and(|n| n.text == "(");
                        }
                        _ => {}
                    }
                }
                k += 1;
            }
            if has_call && !decided {
                out.push(diag(
                    SWALLOWED,
                    &f.path,
                    c,
                    "`let _ = <fallible call>` swallows the Result; handle it, \
                     propagate with `?`, or justify with \
                     `lint:allow(swallowed-result) <why the outcome is irrelevant>`"
                        .to_string(),
                ));
            }
        }
        // (b) a bare `.ok();` statement (chained `.ok().…` is fine, and a
        // bound `let x = …ok();` / `x = …ok();` converts the Result for
        // use rather than discarding it — walk back to the statement
        // start, hopping closed groups via the partner table).
        if c.text == "ok"
            && i > 0
            && code[i - 1].text == "."
            && code.get(i + 1).is_some_and(|n| n.text == "(")
            && code.get(i + 2).is_some_and(|n| n.text == ")")
            && code.get(i + 3).is_some_and(|n| n.text == ";")
            && !ok_chain_is_bound(&t, i - 1)
        {
            out.push(diag(
                SWALLOWED,
                &f.path,
                c,
                "bare `.ok();` discards the Result; handle it, propagate with \
                 `?`, or justify with `lint:allow(swallowed-result) <why>`"
                    .to_string(),
            ));
        }
    }
    out
}

// ------------------------------------------------------- lint:allow --

/// A span-anchored rule suppression parsed from an allow directive
/// (a comment whose body starts with `lint:allow` plus a parenthesized
/// rule list): it silences diagnostics of `rule` anchored on the
/// comment's own lines or the line directly below it.
pub struct Allow {
    pub rule: &'static str,
    pub from: u32,
    pub to: u32,
}

/// Parse every allow directive in the file — a comment whose body
/// *starts* with `lint:allow(` (after the `//`/`/*` leader), so prose
/// that merely mentions the syntax is not a directive. Unknown rule
/// names come back as diagnostics (a typoed allow must not silently
/// suppress nothing), and allows for [`JUSTIFIED_RULES`] with no
/// justification text after the closing paren come back as
/// [`ALLOW_JUSTIFY`] findings.
pub fn allows(f: &SourceFile) -> (Vec<Allow>, Vec<Diagnostic>) {
    let mut out = Vec::new();
    let mut meta = Vec::new();
    for t in &f.toks {
        if !t.kind.is_comment() {
            continue;
        }
        let body = t.text.trim_start_matches(['/', '*', '!']).trim_start();
        let Some(args) = body.strip_prefix("lint:allow(") else { continue };
        let Some(end) = args.find(')') else { continue };
        let justification =
            args[end + 1..].trim_end_matches("*/").trim();
        for name in args[..end].split(',') {
            let name = name.trim();
            match RULES.iter().find(|r| **r == name) {
                Some(rule) => {
                    if JUSTIFIED_RULES.contains(rule) && justification.is_empty() {
                        meta.push(diag(
                            ALLOW_JUSTIFY,
                            &f.path,
                            t,
                            format!(
                                "lint:allow({name}) requires a justification after the \
                                 closing paren: why is this site sound?"
                            ),
                        ));
                    }
                    out.push(Allow { rule, from: t.line, to: t.end_line + 1 });
                }
                None => meta.push(diag(
                    UNKNOWN_RULE,
                    &f.path,
                    t,
                    format!("lint:allow names unknown rule `{name}` (known: {RULES:?})"),
                )),
            }
        }
    }
    (out, meta)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sf(path: &str, src: &str) -> SourceFile {
        SourceFile::parse(path, src)
    }

    fn rules_fired(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.rule).collect()
    }

    // ---------------------------------------- unsafe-needs-safety-comment

    #[test]
    fn unsafe_without_comment_fires() {
        let f = sf("rust/src/x.rs", "fn f() {\n    unsafe { g(); }\n}\n");
        let d = unsafe_needs_safety(&f);
        assert_eq!(rules_fired(&d), vec![UNSAFE_SAFETY]);
        assert_eq!((d[0].line, d[0].file.as_str()), (2, "rust/src/x.rs"));
    }

    #[test]
    fn safety_comment_directly_above_clears() {
        let src = "fn f() {\n    // SAFETY: g has no preconditions here\n    unsafe { g(); }\n}\n";
        assert!(unsafe_needs_safety(&sf("rust/src/x.rs", src)).is_empty());
    }

    #[test]
    fn safety_doc_section_clears_unsafe_fn() {
        let src = "/// Does a thing.\n///\n/// # Safety\n/// Caller upholds X.\n\
                   #[inline]\npub unsafe fn f() {}\n";
        assert!(unsafe_needs_safety(&sf("rust/src/x.rs", src)).is_empty());
    }

    #[test]
    fn attribute_between_comment_and_unsafe_is_transparent() {
        let src = "// SAFETY: checked at runtime\n#[cfg(target_arch = \"x86_64\")]\n\
                   unsafe fn f() {}\n";
        assert!(unsafe_needs_safety(&sf("rust/src/x.rs", src)).is_empty());
    }

    #[test]
    fn blank_line_breaks_safety_adjacency() {
        let src = "// SAFETY: too far away\n\nunsafe fn f() {}\n";
        let d = unsafe_needs_safety(&sf("rust/src/x.rs", src));
        assert_eq!(rules_fired(&d), vec![UNSAFE_SAFETY]);
    }

    #[test]
    fn trailing_same_line_safety_clears() {
        let src = "let x = unsafe { y() }; // SAFETY: y is infallible here\n";
        assert!(unsafe_needs_safety(&sf("rust/src/x.rs", src)).is_empty());
    }

    #[test]
    fn unsafe_in_string_or_comment_is_ignored() {
        let src = "let s = r#\"unsafe { nope }\"#;\n// an unsafe-sounding comment\n\
                   let t = \"unsafe\";\n";
        assert!(unsafe_needs_safety(&sf("rust/src/x.rs", src)).is_empty());
    }

    #[test]
    fn two_adjacent_unsafe_impls_each_need_a_comment() {
        let src = "// SAFETY: A is fine\nunsafe impl Send for A {}\nunsafe impl Sync for A {}\n";
        let d = unsafe_needs_safety(&sf("rust/src/x.rs", src));
        assert_eq!(d.len(), 1, "the Sync impl lacks its own comment: {d:?}");
        assert_eq!(d[0].line, 3);
    }

    // ------------------------------------------------ no-partial-cmp-unwrap

    #[test]
    fn partial_cmp_unwrap_fires() {
        let f = sf("rust/src/x.rs", "v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n");
        assert_eq!(rules_fired(&no_partial_cmp_unwrap(&f)), vec![PARTIAL_CMP]);
    }

    #[test]
    fn partial_cmp_expect_fires_across_lines() {
        let src = "let o = a\n    .partial_cmp(&b)\n    .expect(\"ordered\");\n";
        let d = no_partial_cmp_unwrap(&sf("rust/src/x.rs", src));
        assert_eq!(rules_fired(&d), vec![PARTIAL_CMP]);
        assert_eq!(d[0].line, 2, "anchored at the partial_cmp call");
    }

    #[test]
    fn partial_cmp_with_fallback_or_total_cmp_clears() {
        let src = "let o = a.partial_cmp(&b).unwrap_or(Ordering::Equal);\n\
                   v.sort_by(|a, b| a.total_cmp(b));\n";
        assert!(no_partial_cmp_unwrap(&sf("rust/src/x.rs", src)).is_empty());
    }

    #[test]
    fn util_cmp_is_exempt() {
        let src = "assert_eq!(f(a, b), a.partial_cmp(&b).unwrap());\n";
        assert!(no_partial_cmp_unwrap(&sf("rust/src/util/cmp.rs", src)).is_empty());
        assert!(!no_partial_cmp_unwrap(&sf("rust/src/util/stats.rs", src)).is_empty());
    }

    #[test]
    fn partial_cmp_mention_in_comment_is_ignored() {
        let src = "// regression: partial_cmp().unwrap() used to panic here\nlet x = 1;\n";
        assert!(no_partial_cmp_unwrap(&sf("rust/src/x.rs", src)).is_empty());
    }

    // -------------------------------------------------- no-raw-thread-spawn

    #[test]
    fn raw_thread_spawn_fires() {
        for src in [
            "let h = std::thread::spawn(move || work());\n",
            "use std::thread;\nlet h = thread::spawn(f);\n",
        ] {
            let d = no_raw_thread_spawn(&sf("rust/src/x.rs", src));
            assert_eq!(rules_fired(&d), vec![THREAD_SPAWN], "{src}");
        }
    }

    #[test]
    fn pool_spawn_named_and_builder_clear() {
        let src = "let h = pool::spawn_named(\"producer\", move || work());\n\
                   let b = thread::Builder::new().name(n).spawn(f);\n";
        assert!(no_raw_thread_spawn(&sf("rust/src/x.rs", src)).is_empty());
    }

    #[test]
    fn util_pool_is_exempt_from_spawn_rule() {
        let src = "let h = std::thread::spawn(f);\n";
        assert!(no_raw_thread_spawn(&sf("rust/src/util/pool.rs", src)).is_empty());
    }

    // ---------------------------------------------------- env-var-registry

    const README_OK: &str = "## Runtime switches\n\n| Variable | Default | Effect |\n\
        |---|---|---|\n| `HEAPR_THREADS` | auto | pool lanes (`HEAPR_THREADS=1` inline) |\n";

    #[test]
    fn env_read_detection_finds_var_calls_only() {
        let src = "let a = std::env::var(\"HEAPR_THREADS\");\n\
                   crate::warn!(\"HEAPR_THREADS={v} bad\");\nlet s = \"HEAPR_THREADS\";\n";
        let reads = env_reads(&sf("rust/src/x.rs", src));
        assert_eq!(reads, vec![("HEAPR_THREADS".to_string(), 1, 23)]);
    }

    #[test]
    fn undocumented_env_read_fires() {
        let reads = vec![("rust/src/x.rs".to_string(), "HEAPR_NEW_KNOB".to_string(), 3, 5)];
        let d = env_registry(&reads, README_OK, "README.md");
        assert_eq!(rules_fired(&d), vec![ENV_REGISTRY]);
        assert_eq!(d[0].file, "rust/src/x.rs");
    }

    #[test]
    fn stale_readme_row_fires_on_readme_side() {
        let d = env_registry(&[], README_OK, "README.md");
        assert_eq!(rules_fired(&d), vec![ENV_REGISTRY]);
        assert_eq!((d[0].file.as_str(), d[0].line), ("README.md", 5));
    }

    #[test]
    fn matching_read_and_row_clears() {
        let reads = vec![("rust/src/x.rs".to_string(), "HEAPR_THREADS".to_string(), 1, 1)];
        assert!(env_registry(&reads, README_OK, "README.md").is_empty());
    }

    #[test]
    fn readme_rows_ignore_non_table_mentions_and_assignments() {
        let readme = "`HEAPR_KERNEL=naive` is the escape hatch (prose, not a row)\n\
            | `--continuous` | off | not an env var |\n\
            | `HEAPR_KERNEL` | auto | the real row |\n";
        assert_eq!(readme_env_rows(readme), vec![("HEAPR_KERNEL".to_string(), 3)]);
    }

    // --------------------------------------------------- test-registration

    const CARGO_FIXTURE: &str = "[package]\nname = \"heapr\"\n\n[[test]]\n\
        name = \"integration\"\npath = \"rust/tests/integration.rs\"\n";

    #[test]
    fn unregistered_test_file_fires() {
        let files = vec!["integration.rs".to_string(), "orphan.rs".to_string()];
        let d = test_registration(&files, CARGO_FIXTURE);
        assert_eq!(rules_fired(&d), vec![TEST_REG]);
        assert_eq!(d[0].file, "rust/tests/orphan.rs");
    }

    #[test]
    fn registered_but_missing_file_fires_on_cargo_side() {
        let d = test_registration(&[], CARGO_FIXTURE);
        assert_eq!(rules_fired(&d), vec![TEST_REG]);
        assert_eq!((d[0].file.as_str(), d[0].line), ("Cargo.toml", 6));
    }

    #[test]
    fn registered_files_clear() {
        let files = vec!["integration.rs".to_string()];
        assert!(test_registration(&files, CARGO_FIXTURE).is_empty());
    }

    // ---------------------------------------------------------- lint:allow

    #[test]
    fn allow_parses_and_flags_unknown_rules() {
        let src = "// lint:allow(no-raw-thread-spawn, not-a-rule)\nlet x = 1;\n";
        let (a, unknown) = allows(&sf("rust/src/x.rs", src));
        assert_eq!(a.len(), 1);
        assert_eq!((a[0].rule, a[0].from, a[0].to), (THREAD_SPAWN, 1, 2));
        assert_eq!(rules_fired(&unknown), vec![UNKNOWN_RULE]);
    }

    #[test]
    fn allow_inside_a_string_is_not_an_allow() {
        let src = "let s = \"lint:allow(no-raw-thread-spawn)\";\n";
        let (a, unknown) = allows(&sf("rust/src/x.rs", src));
        assert!(a.is_empty());
        assert!(unknown.is_empty());
    }

    #[test]
    fn justified_rules_require_a_justification() {
        // bare allow on a justified rule → meta finding, allow still parsed
        let src = "// lint:allow(panic-free-serve)\nx.unwrap();\n";
        let (a, meta) = allows(&sf("rust/src/runtime/host.rs", src));
        assert_eq!(a.len(), 1);
        assert_eq!(rules_fired(&meta), vec![ALLOW_JUSTIFY]);
        // with a justification → clean
        let src = "// lint:allow(panic-free-serve) shape checked two lines up\nx.unwrap();\n";
        let (a, meta) = allows(&sf("rust/src/runtime/host.rs", src));
        assert_eq!((a.len(), meta.len()), (1, 0));
        // legacy rules stay justification-free
        let src = "// lint:allow(no-raw-thread-spawn)\nstd::thread::spawn(f);\n";
        let (a, meta) = allows(&sf("rust/src/x.rs", src));
        assert_eq!((a.len(), meta.len()), (1, 0));
        // a block comment's trailing */ is not a justification
        let src = "/* lint:allow(sendptr-confinement) */\nlet p = RowsPtr::new(&mut v);\n";
        let (_a, meta) = allows(&sf("rust/src/x.rs", src));
        assert_eq!(rules_fired(&meta), vec![ALLOW_JUSTIFY]);
    }

    // ----------------------------------------------------- panic-free-serve

    #[test]
    fn hot_path_panics_fire() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   \x20   let a = x.unwrap();\n\
                   \x20   let b = x.expect(\"b\");\n\
                   \x20   if a == 0 { panic!(\"zero\"); }\n\
                   \x20   match b { 0 => unreachable!(), _ => todo!() }\n\
                   }\n";
        let d = panic_free_serve(&sf("rust/src/coordinator/serve.rs", src));
        let fired: Vec<(u32, &str)> = d
            .iter()
            .map(|x| (x.line, x.message.split('`').nth(1).unwrap_or("")))
            .collect();
        assert_eq!(
            fired,
            vec![
                (2, ".unwrap()"),
                (3, ".expect()"),
                (4, "panic!"),
                (5, "unreachable!"),
                (5, "todo!"),
            ],
            "{d:#?}"
        );
    }

    #[test]
    fn non_hot_path_files_and_test_code_are_exempt() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert!(panic_free_serve(&sf("rust/src/train/mod.rs", src)).is_empty());
        let src = "fn ok() -> u32 { 0 }\n#[cfg(test)]\nmod tests {\n\
                   \x20   fn t() { x.unwrap(); panic!(\"fine in tests\"); }\n}\n";
        assert!(panic_free_serve(&sf("rust/src/runtime/kv.rs", src)).is_empty());
    }

    #[test]
    fn non_panicking_variants_clear() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   \x20   x.unwrap_or(0) + x.unwrap_or_else(|| 1) + x.unwrap_or_default()\n\
                   }\n// a comment saying unwrap() is fine\n";
        assert!(panic_free_serve(&sf("rust/src/runtime/host.rs", src)).is_empty());
    }

    // -------------------------------------------------- sendptr-confinement

    #[test]
    fn stray_rowsptr_and_sendptr_fire() {
        let src = "let p = RowsPtr::new(&mut buf);\nlet q = SendPtr(raw);\n";
        let d = sendptr_confinement(&sf("rust/src/coordinator/serve.rs", src));
        let fired: Vec<(u32, &str)> = d.iter().map(|x| (x.line, x.rule)).collect();
        assert_eq!(fired, vec![(1, SENDPTR), (2, SENDPTR)], "{d:#?}");
    }

    #[test]
    fn registered_modules_are_exempt() {
        let src = "let p = RowsPtr::new(&mut buf);\nlet q = SendPtr(raw);\n";
        for path in
            ["rust/src/util/pool.rs", "rust/src/tensor/gemm.rs", "rust/src/runtime/host.rs"]
        {
            assert!(sendptr_confinement(&sf(path, src)).is_empty(), "{path}");
        }
    }

    #[test]
    fn mentions_that_are_not_constructions_clear() {
        let src = "use crate::util::pool::{RowsPtr, SendPtr};\n\
                   fn f(p: RowsPtr, s: &SendPtr) -> RowsPtr { g(p, s) }\n\
                   // RowsPtr::new in prose\nlet s = \"SendPtr(fake)\";\n";
        assert!(sendptr_confinement(&sf("rust/src/coordinator/serve.rs", src)).is_empty());
    }

    // --------------------------------------------------- float-accum-order

    #[test]
    fn bare_float_accumulation_fires() {
        let src = "fn f(xs: &[f32]) -> f32 {\n\
                   \x20   let mut acc = 0.0;\n\
                   \x20   for x in xs { acc += x; }\n\
                   \x20   acc\n}\n";
        let d = float_accum_order(&sf("rust/src/eval/mod.rs", src));
        let fired: Vec<(u32, &str)> = d.iter().map(|x| (x.line, x.rule)).collect();
        assert_eq!(fired, vec![(3, FLOAT_ACCUM)], "{d:#?}");
    }

    #[test]
    fn annotated_float_and_sum_turbofish_fire() {
        let src = "fn f(xs: &[f32]) {\n\
                   \x20   let mut s: f32 = 0.0;\n    s += xs[0];\n\
                   \x20   let t = xs.iter().sum::<f32>();\n}\n";
        let d = float_accum_order(&sf("rust/src/model/flops.rs", src));
        let fired: Vec<u32> = d.iter().map(|x| x.line).collect();
        assert_eq!(fired, vec![3, 4], "{d:#?}");
    }

    #[test]
    fn integer_field_and_indexed_accumulation_clear() {
        let src = "fn f(dst: &mut [f32], m: &mut M) {\n\
                   \x20   let mut n = 0usize;\n    n += 1;\n\
                   \x20   dst[0] += 1.0;\n    m.hits += 2;\n\
                   \x20   self.metrics.steps += 1;\n}\n";
        assert!(float_accum_order(&sf("rust/src/eval/mod.rs", src)).is_empty());
    }

    #[test]
    fn kernel_scope_and_test_code_are_exempt() {
        let src = "fn f(xs: &[f32]) -> f32 {\n\
                   \x20   let mut acc = 0.0;\n\
                   \x20   for x in xs { acc += x; }\n    acc\n}\n";
        assert!(float_accum_order(&sf("rust/src/tensor/gemm.rs", src)).is_empty());
        assert!(float_accum_order(&sf("rust/src/runtime/host.rs", src)).is_empty());
        assert!(float_accum_order(&sf("rust/src/util/stats.rs", src)).is_empty());
        let test_src = format!("#[cfg(test)]\nmod tests {{\n{src}}}\n");
        assert!(float_accum_order(&sf("rust/src/eval/mod.rs", &test_src)).is_empty());
    }

    // ---------------------------------------------------- swallowed-result

    #[test]
    fn let_underscore_call_and_bare_ok_fire() {
        let src = "fn f(tx: &Sender<u32>, file: &mut W) {\n\
                   \x20   let _ = tx.send(1);\n\
                   \x20   let _ = write!(file, \"x\");\n\
                   \x20   file.flush().ok();\n}\n";
        let d = swallowed_result(&sf("rust/src/coordinator/scheduler.rs", src));
        let fired: Vec<(u32, &str)> = d.iter().map(|x| (x.line, x.rule)).collect();
        assert_eq!(fired, vec![(2, SWALLOWED), (3, SWALLOWED), (4, SWALLOWED)], "{d:#?}");
    }

    #[test]
    fn decided_discards_and_non_calls_clear() {
        let src = "fn f(h: Handle, x: u32) -> Result<()> {\n\
                   \x20   let _ = h.join().unwrap();\n\
                   \x20   let _ = maybe()?;\n\
                   \x20   let _ = x;\n\
                   \x20   let y = h.ok().map(|v| v + 1);\n\
                   \x20   Ok(())\n}\n";
        assert!(swallowed_result(&sf("rust/src/util/pool.rs", src)).is_empty());
    }

    #[test]
    fn swallowed_result_is_test_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n\
                   \x20   fn t(tx: &Sender<u32>) { let _ = tx.send(1); tx.flush().ok(); }\n}\n";
        assert!(swallowed_result(&sf("rust/src/coordinator/scheduler.rs", src)).is_empty());
    }

    #[test]
    fn bound_ok_conversions_clear_but_bare_still_fires() {
        // `let x = …ok();` and `x = …ok();` keep the Option; only the
        // statement-position discard is a finding.
        let src = "fn f(path: &str, slot: &mut Option<String>, tx: &Sender<u32>) {\n\
                   \x20   let arch = std::fs::read_to_string(path).ok();\n\
                   \x20   *slot = std::fs::read_to_string(path).ok();\n\
                   \x20   let picked = (if arch.is_some() { tx.probe() } else { tx.poll() }).ok();\n\
                   \x20   tx.send(1).ok();\n}\n";
        let d = swallowed_result(&sf("rust/src/coordinator/scheduler.rs", src));
        let fired: Vec<u32> = d.iter().map(|x| x.line).collect();
        assert_eq!(fired, vec![5], "{d:#?}");
    }

    #[test]
    fn integration_test_paths_are_exempt() {
        let src = "fn f(tx: &Sender<u32>) { tx.send(1).ok(); let mut a = 0.0; a += 1.0; }\n";
        assert!(swallowed_result(&sf("rust/tests/integration.rs", src)).is_empty());
        assert!(float_accum_order(&sf("rust/tests/integration.rs", src)).is_empty());
    }
}
