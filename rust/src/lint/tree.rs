//! Token-tree layer over [`super::lexer`]: delimiter matching and item
//! extraction, the structure the cross-file rules in [`super::graph`]
//! (and the scoped rules in [`super::rules`]) are built on.
//!
//! This is still not a Rust parser — it is the minimal tree view a
//! repo linter needs, and it **never panics on unbalanced input** (an
//! unmatched opener simply has no partner; an unmatched closer is
//! ignored). Three services:
//!
//! * [`Tree::new`] — match every `(`/`)`, `[`/`]`, `{`/`}` pair in the
//!   non-comment token stream (strings and comments were already opaque
//!   single tokens at the lexer level, so a brace inside a string can
//!   never desynchronize the tree);
//! * [`Tree::items`] — extract `use` declarations (with `crate::{a, b}`
//!   group expansion), `fn` items with their body ranges, `mod` items,
//!   and `impl` blocks, each tagged with whether a `#[cfg(test)]`
//!   attribute governs it;
//! * [`Tree::test_lines`] — the line ranges covered by `#[cfg(test)]`
//!   items, so rules that audit *shipped* code (panic-freedom, raw
//!   pointer confinement, layering) can skip test scaffolding.

use super::lexer::{Tok, TokKind};

/// Matched-delimiter view over a lexed file. Indices refer to the
/// `code` vector (comments filtered out), not the raw token stream.
pub struct Tree<'a> {
    /// Non-comment tokens in source order.
    pub code: Vec<&'a Tok>,
    /// `partner[i]`: for an opening delimiter, the index of its closer;
    /// for a closer, its opener; `None` for everything else and for
    /// unbalanced delimiters.
    partner: Vec<Option<usize>>,
}

/// One extracted item. Line/col anchor at the introducing keyword.
#[derive(Debug)]
pub enum Item {
    /// `use a::b::{c, d::e};` — one entry per expanded leaf path.
    Use { path: Vec<String>, line: u32, col: u32, cfg_test: bool },
    /// `fn name(..) { .. }` — `body` is the `(open, close)` code-index
    /// pair of the body braces (`None` for bodyless trait methods or
    /// unterminated input).
    Fn { name: String, line: u32, body: Option<(usize, usize)>, cfg_test: bool },
    /// `mod name { .. }` or `mod name;`.
    Mod { name: String, line: u32, body: Option<(usize, usize)>, cfg_test: bool },
    /// `impl .. { .. }`.
    Impl { line: u32, body: Option<(usize, usize)>, cfg_test: bool },
}

impl<'a> Tree<'a> {
    /// Build the matched-delimiter view. Unbalanced input degrades to
    /// `None` partners — no panic, ever (fuzz-shaped inputs reach this
    /// through `lint_repo` on arbitrary `.rs` files).
    pub fn new(toks: &'a [Tok]) -> Tree<'a> {
        let code: Vec<&Tok> = toks.iter().filter(|t| !t.kind.is_comment()).collect();
        let mut partner = vec![None; code.len()];
        // One stack per delimiter class: a stray `)` must not steal the
        // partner of an outer `{`.
        let mut stacks: [Vec<usize>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        let class = |t: &Tok| match t.text.as_str() {
            "(" | ")" => Some(0usize),
            "[" | "]" => Some(1),
            "{" | "}" => Some(2),
            _ => None,
        };
        for (i, t) in code.iter().enumerate() {
            if t.kind != TokKind::Punct {
                continue;
            }
            let Some(c) = class(t) else { continue };
            if matches!(t.text.as_str(), "(" | "[" | "{") {
                stacks[c].push(i);
            } else if let Some(open) = stacks[c].pop() {
                partner[open] = Some(i);
                partner[i] = Some(open);
            } // unmatched closer: ignored
        }
        Tree { code, partner }
    }

    /// The matching delimiter of code index `i`, if balanced.
    pub fn partner(&self, i: usize) -> Option<usize> {
        self.partner.get(i).copied().flatten()
    }

    /// The code index of the innermost `{` enclosing code index `i`
    /// (`None` at top level). Linear scan backwards, skipping balanced
    /// sibling blocks via the partner table.
    pub fn enclosing_brace(&self, i: usize) -> Option<usize> {
        let mut j = i;
        while j > 0 {
            j -= 1;
            let t = self.code[j];
            if t.kind == TokKind::Punct && t.text == "}" {
                match self.partner(j) {
                    Some(open) => j = open, // skip the sibling block
                    None => return None,    // unbalanced: give up, no panic
                }
            } else if t.kind == TokKind::Punct && t.text == "{" {
                return Some(j);
            }
        }
        None
    }

    /// Extract `use` / `fn` / `mod` / `impl` items at every nesting
    /// level. `cfg_test` is true when the item itself carries a
    /// `#[cfg(test)]` attribute or sits inside an item that does.
    pub fn items(&self) -> Vec<Item> {
        let mut out = Vec::new();
        // (close-index, _) stack of enclosing cfg(test) bodies.
        let mut test_until: Vec<usize> = Vec::new();
        let mut i = 0;
        while i < self.code.len() {
            while test_until.last().is_some_and(|&c| i > c) {
                test_until.pop();
            }
            let in_test = !test_until.is_empty();
            let t = self.code[i];
            if t.kind != TokKind::Ident {
                i += 1;
                continue;
            }
            match t.text.as_str() {
                "use" => {
                    let (paths, next) = self.parse_use(i + 1);
                    for p in paths {
                        out.push(Item::Use {
                            path: p,
                            line: t.line,
                            col: t.col,
                            cfg_test: in_test || self.has_cfg_test_attr(i),
                        });
                    }
                    i = next;
                }
                "fn" => {
                    let name = self
                        .code
                        .get(i + 1)
                        .filter(|n| n.kind == TokKind::Ident)
                        .map(|n| n.text.clone())
                        .unwrap_or_default();
                    let body = self.find_body(i + 1);
                    let cfg_test = in_test || self.has_cfg_test_attr(i);
                    if let (Some((_, close)), true) = (body, cfg_test) {
                        test_until.push(close);
                    }
                    out.push(Item::Fn { name, line: t.line, body, cfg_test });
                    i += 1;
                }
                "mod" => {
                    let name = self
                        .code
                        .get(i + 1)
                        .filter(|n| n.kind == TokKind::Ident)
                        .map(|n| n.text.clone())
                        .unwrap_or_default();
                    let body = self.find_body(i + 1);
                    let cfg_test = in_test || self.has_cfg_test_attr(i);
                    if let (Some((_, close)), true) = (body, cfg_test) {
                        test_until.push(close);
                    }
                    out.push(Item::Mod { name, line: t.line, body, cfg_test });
                    i += 1;
                }
                "impl" => {
                    let body = self.find_body(i + 1);
                    let cfg_test = in_test || self.has_cfg_test_attr(i);
                    if let (Some((_, close)), true) = (body, cfg_test) {
                        test_until.push(close);
                    }
                    out.push(Item::Impl { line: t.line, body, cfg_test });
                    i += 1;
                }
                _ => i += 1,
            }
        }
        out
    }

    /// 1-based inclusive line ranges governed by `#[cfg(test)]` items.
    pub fn test_lines(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        for item in self.items() {
            let (body, cfg_test) = match &item {
                Item::Fn { body, cfg_test, .. }
                | Item::Mod { body, cfg_test, .. }
                | Item::Impl { body, cfg_test, .. } => (*body, *cfg_test),
                Item::Use { line, cfg_test, .. } => {
                    if *cfg_test {
                        out.push((*line, *line));
                    }
                    continue;
                }
            };
            if let (Some((open, close)), true) = (body, cfg_test) {
                // from the item keyword's line is not recorded in body,
                // so anchor at the opening brace; attributes above are
                // harmless to leave un-covered.
                out.push((self.code[open].line, self.code[close].end_line));
            }
        }
        merge_ranges(out)
    }

    /// Scan forward from code index `i` for the item's body: the first
    /// `{` before any `;` at the current nesting (skipping balanced
    /// `(..)` / `[..]` / `<..>`-free groups via the partner table).
    /// Returns the `(open, close)` pair.
    fn find_body(&self, mut i: usize) -> Option<(usize, usize)> {
        while i < self.code.len() {
            let t = self.code[i];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "{" => return self.partner(i).map(|c| (i, c)),
                    ";" => return None,
                    "(" | "[" => {
                        i = self.partner(i).map(|c| c + 1)?;
                        continue;
                    }
                    // a stray closer means we ran out of this item's
                    // scope (e.g. `fn` as the last token of a block)
                    ")" | "]" | "}" => return None,
                    _ => {}
                }
            }
            i += 1;
        }
        None
    }

    /// Is code index `i` (an item keyword) preceded by attribute groups
    /// among which one is `#[cfg(test)]` (or `#[cfg(.. test ..)]`)?
    /// Walks consecutive `#[..]` / visibility / qualifier tokens upward.
    fn has_cfg_test_attr(&self, i: usize) -> bool {
        let mut j = i;
        loop {
            if j == 0 {
                return false;
            }
            let prev = self.code[j - 1];
            // transparent qualifiers between attributes and the keyword
            if prev.kind == TokKind::Ident
                && matches!(prev.text.as_str(), "pub" | "unsafe" | "const" | "async" | "extern")
            {
                j -= 1;
                continue;
            }
            if prev.kind == TokKind::Punct && prev.text == ")" {
                // `pub(crate)` etc: skip the group and the ident before
                match self.partner(j - 1) {
                    Some(open) => {
                        j = open;
                        continue;
                    }
                    None => return false,
                }
            }
            if prev.kind == TokKind::Punct && prev.text == "]" {
                let Some(open) = self.partner(j - 1) else { return false };
                // open points at `[`; the token before must be `#`
                if open == 0 || self.code[open - 1].text != "#" {
                    return false;
                }
                if self.attr_is_cfg_test(open, j - 1) {
                    return true;
                }
                j = open - 1; // keep walking: more attributes above?
                continue;
            }
            return false;
        }
    }

    /// Does the attribute body between `[` (exclusive) and `]`
    /// (exclusive) spell `cfg ( .. test .. )`?
    fn attr_is_cfg_test(&self, open: usize, close: usize) -> bool {
        let body = &self.code[open + 1..close];
        body.first().is_some_and(|t| t.kind == TokKind::Ident && t.text == "cfg")
            && body.iter().any(|t| t.kind == TokKind::Ident && t.text == "test")
    }

    /// Parse one `use` declaration starting after the `use` keyword.
    /// Returns the expanded leaf paths and the code index just past the
    /// terminating `;` (or wherever parsing gave up — always progress).
    fn parse_use(&self, start: usize) -> (Vec<Vec<String>>, usize) {
        let mut paths = Vec::new();
        let end = self.use_end(start);
        self.parse_use_group(start, end, &Vec::new(), &mut paths, 0);
        (paths, end)
    }

    /// Find the code index just past the `;` that ends a use starting
    /// at `start` (or the end of input for unterminated declarations).
    fn use_end(&self, start: usize) -> usize {
        let mut i = start;
        while i < self.code.len() {
            let t = self.code[i];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    ";" => return i + 1,
                    "{" | "(" | "[" => match self.partner(i) {
                        Some(c) => {
                            i = c + 1;
                            continue;
                        }
                        None => return self.code.len(),
                    },
                    "}" | ")" | "]" => return i, // stray closer: stop
                    _ => {}
                }
            }
            i += 1;
        }
        self.code.len()
    }

    /// Recursive expansion of a use segment list over `[start, end)`:
    /// `prefix::{a, b::c}` yields `prefix::a` and `prefix::b::c`.
    /// `depth` bounds pathological nesting (never panics, just stops).
    fn parse_use_group(
        &self,
        start: usize,
        end: usize,
        prefix: &[String],
        out: &mut Vec<Vec<String>>,
        depth: usize,
    ) {
        if depth > 16 {
            return;
        }
        let mut segs: Vec<String> = prefix.to_vec();
        let mut emitted = false;
        let mut i = start;
        while i < end {
            let t = self.code[i];
            match (t.kind, t.text.as_str()) {
                (TokKind::Ident, "as") => {
                    // rename: skip the alias ident
                    i += 2;
                }
                (TokKind::Ident, _) => {
                    segs.push(t.text.clone());
                    i += 1;
                }
                (TokKind::Punct, ":") => i += 1,
                (TokKind::Punct, "*") => {
                    segs.push("*".to_string());
                    i += 1;
                }
                (TokKind::Punct, "{") => {
                    let close = self.partner(i).unwrap_or(end.min(self.code.len()));
                    // split the group body on top-level commas
                    let mut item_start = i + 1;
                    let mut j = i + 1;
                    while j < close {
                        let u = self.code[j];
                        if u.kind == TokKind::Punct {
                            match u.text.as_str() {
                                "," => {
                                    self.parse_use_group(item_start, j, &segs, out, depth + 1);
                                    item_start = j + 1;
                                }
                                "{" | "(" | "[" => {
                                    j = self.partner(j).unwrap_or(close);
                                }
                                _ => {}
                            }
                        }
                        j += 1;
                    }
                    if item_start < close {
                        self.parse_use_group(item_start, close, &segs, out, depth + 1);
                    }
                    emitted = true;
                    i = close + 1;
                }
                (TokKind::Punct, ";") => break,
                _ => i += 1,
            }
        }
        if !emitted && segs.len() > prefix.len() {
            out.push(segs);
        }
    }
}

/// Merge overlapping/adjacent 1-based inclusive line ranges.
fn merge_ranges(mut v: Vec<(u32, u32)>) -> Vec<(u32, u32)> {
    v.sort_unstable();
    let mut out: Vec<(u32, u32)> = Vec::new();
    for (a, b) in v {
        match out.last_mut() {
            Some((_, pb)) if a <= *pb + 1 => *pb = (*pb).max(b),
            _ => out.push((a, b)),
        }
    }
    out
}

/// Is 1-based `line` inside any of the (merged, sorted) ranges?
pub fn in_ranges(ranges: &[(u32, u32)], line: u32) -> bool {
    ranges.iter().any(|&(a, b)| line >= a && line <= b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn tree(src: &str) -> (Vec<Tok>, Vec<Item>) {
        let toks = lex(src);
        let items = Tree::new(&toks).items();
        (toks, items)
    }

    fn use_paths(src: &str) -> Vec<String> {
        let (_t, items) = tree(src);
        items
            .iter()
            .filter_map(|i| match i {
                Item::Use { path, .. } => Some(path.join("::")),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn matches_nested_delimiters() {
        let toks = lex("fn f(a: [u8; 3]) { g((1), [2]); }");
        let t = Tree::new(&toks);
        // every opener has a partner and round-trips
        for i in 0..t.code.len() {
            if matches!(t.code[i].text.as_str(), "(" | "[" | "{") {
                let c = t.partner(i).expect("balanced");
                assert_eq!(t.partner(c), Some(i));
            }
        }
    }

    #[test]
    fn unbalanced_input_never_panics() {
        for src in ["fn f( {", "}}} )))", "{ ( } )", "fn f() { loop {", "use a::{b, ;"] {
            let toks = lex(src);
            let t = Tree::new(&toks);
            let _ = t.items();
            let _ = t.test_lines();
            for i in 0..t.code.len() {
                let _ = t.partner(i);
                let _ = t.enclosing_brace(i);
            }
        }
    }

    #[test]
    fn simple_use_path() {
        assert_eq!(use_paths("use crate::tensor::Tensor;"), vec!["crate::tensor::Tensor"]);
    }

    #[test]
    fn grouped_use_expands() {
        let p = use_paths("use crate::{util::pool, runtime::{Engine, kv::PagedKv}};");
        assert_eq!(
            p,
            vec!["crate::util::pool", "crate::runtime::Engine", "crate::runtime::kv::PagedKv"]
        );
    }

    #[test]
    fn use_rename_and_glob() {
        let p = use_paths("use crate::tensor::ops as tops;\nuse crate::util::*;");
        assert_eq!(p, vec!["crate::tensor::ops", "crate::util::*"]);
    }

    #[test]
    fn fn_bodies_extracted() {
        let (_t, items) = tree("fn a() { x(); }\nfn b(v: Vec<u8>) -> usize { v.len() }\nfn c();");
        let fns: Vec<(&str, bool)> = items
            .iter()
            .filter_map(|i| match i {
                Item::Fn { name, body, .. } => Some((name.as_str(), body.is_some())),
                _ => None,
            })
            .collect();
        assert_eq!(fns, vec![("a", true), ("b", true), ("c", false)]);
    }

    #[test]
    fn cfg_test_mod_marks_ranges() {
        let src = "fn shipped() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        let toks = lex(src);
        let ranges = Tree::new(&toks).test_lines();
        assert!(in_ranges(&ranges, 4), "{ranges:?}");
        assert!(!in_ranges(&ranges, 1), "{ranges:?}");
    }

    #[test]
    fn cfg_test_fn_with_other_attrs_marks_ranges() {
        let src = "#[cfg(test)]\n#[allow(dead_code)]\npub fn helper() {\n    boom();\n}\nfn live() {}\n";
        let toks = lex(src);
        let ranges = Tree::new(&toks).test_lines();
        assert!(in_ranges(&ranges, 4), "{ranges:?}");
        assert!(!in_ranges(&ranges, 6), "{ranges:?}");
    }

    #[test]
    fn cfg_not_test_is_not_a_test_range() {
        let src = "#[cfg(debug_assertions)]\nmod claims {\n    fn f() {}\n}\n";
        let toks = lex(src);
        assert!(Tree::new(&toks).test_lines().is_empty());
    }

    #[test]
    fn nested_items_inside_cfg_test_inherit() {
        let src = "#[cfg(test)]\nmod tests {\n    use crate::runtime::Engine;\n}\n";
        let (_t, items) = tree(src);
        let u = items
            .iter()
            .find_map(|i| match i {
                Item::Use { cfg_test, .. } => Some(*cfg_test),
                _ => None,
            })
            .unwrap();
        assert!(u, "use inside #[cfg(test)] mod must be tagged cfg_test");
    }

    #[test]
    fn enclosing_brace_walks_out_of_sibling_blocks() {
        let toks = lex("fn f() { { inner(); } outer(); }");
        let t = Tree::new(&toks);
        let outer_idx = t.code.iter().position(|x| x.text == "outer").unwrap();
        let open = t.enclosing_brace(outer_idx).unwrap();
        // the fn body brace (code index 4: `fn f ( ) {`), not the inner block's
        assert_eq!(open, 4);
    }
}
