//! `heapr-lint` — dependency-free static analysis for this repo.
//!
//! The offline build image has no crates.io access, so the linter is
//! hand-rolled like the vendored `anyhow`. The engine has four layers:
//! [`lexer`] is a small but correct Rust *surface* lexer (line and
//! nested block comments, strings, raw/byte/C strings, shebang/BOM,
//! char-vs-lifetime disambiguation, spans); [`tree`] matches delimiters
//! and extracts `use`/`fn`/`mod`/`impl` items (never panicking on
//! unbalanced input); [`rules`] holds the per-file rules;
//! [`graph`] the cross-file passes that see the whole repo at once; and
//! [`calls`] the whole-repo call graph (free fns + inherent methods
//! resolved by name) with forward reachability from the declared
//! decode-step entry points. The twelve rules:
//!
//! | rule | enforces |
//! |---|---|
//! | `unsafe-needs-safety-comment` | every `unsafe` carries an adjacent `// SAFETY:` argument |
//! | `no-partial-cmp-unwrap` | NaN-safe ordering (PR 3) outside `util::cmp` |
//! | `no-raw-thread-spawn` | one spawn path: `util::pool::spawn_named` |
//! | `env-var-registry` | `HEAPR_*` reads ⇄ README env table, both directions |
//! | `test-registration` | `rust/tests/*.rs` ⇄ `Cargo.toml` test targets |
//! | `layering` | the ARCHITECTURE §2 layer table over `use crate::…`, cycle-free |
//! | `lock-order` | cycle-free may-hold-while-acquiring lock graph |
//! | `panic-free-serve` | no `unwrap`/`expect`/`panic!`/… in the decode hot path |
//! | `sendptr-confinement` | `RowsPtr`/`SendPtr` built only in registered modules |
//! | `hot-path-alloc` | zero heap-allocation sites reachable from the decode step |
//! | `float-accum-order` | f32/f64 reductions only in kernels and sanctioned reducers |
//! | `swallowed-result` | no `let _ = fallible(…)` / bare `.ok()` discards outside tests |
//!
//! [`lint_repo`] walks `rust/src` + `rust/tests` (sorted, so output is
//! deterministic), applies `// lint:allow(<rule>)` escapes (the graph,
//! hot-path, float, and result rules require a written justification in
//! the escape — see [`rules::JUSTIFIED_RULES`]), and
//! returns sorted diagnostics; the `heapr-lint` binary
//! (`rust/src/bin/lint.rs`) prints them as clickable `file:line:col`
//! lines — or one JSON object per line under `--json`, filtered by
//! `--rule <name>` — and exits nonzero on any finding. Run it via
//! `make lint` (part of `make verify`).
//!
//! `docs/ARCHITECTURE.md` §7 documents the SAFETY-comment convention,
//! the layer map and lock model the graph rules encode, the
//! escape-hatch policy, and how to add a rule.

pub mod calls;
pub mod graph;
pub mod lexer;
pub mod rules;
pub mod tree;

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One lint finding, anchored to a repo-relative `file:line:col`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Rule name (one of [`rules::RULES`], or `unknown-rule`).
    pub rule: &'static str,
    /// Repo-relative path with `/` separators.
    pub file: String,
    /// 1-based line of the offending token.
    pub line: u32,
    /// 1-based column (bytes) of the offending token.
    pub col: u32,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}:{}: [{}] {}", self.file, self.line, self.col, self.rule, self.message)
    }
}

impl Diagnostic {
    /// One machine-readable JSON object (no trailing newline), the
    /// `--json` line format: `{"file":…,"line":…,"col":…,"rule":…,"msg":…}`.
    /// Key order is fixed so the CI awk annotation step can stay trivial.
    pub fn to_json(&self) -> String {
        format!(
            r#"{{"file":"{}","line":{},"col":{},"rule":"{}","msg":"{}"}}"#,
            json_escape(&self.file),
            self.line,
            self.col,
            self.rule,
            json_escape(&self.message)
        )
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control bytes).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Lint the repo rooted at `root`: every `.rs` file under `rust/src`
/// and `rust/tests`, plus the `README.md` env table and the `Cargo.toml`
/// test-target registry. Returns diagnostics sorted by
/// `(file, line, col, rule)` after `lint:allow` suppression; empty
/// means clean. Errors only on I/O problems (unreadable tree), never on
/// findings.
pub fn lint_repo(root: &Path) -> Result<Vec<Diagnostic>> {
    let src_dir = root.join("rust").join("src");
    let tests_dir = root.join("rust").join("tests");
    if !src_dir.is_dir() {
        bail!("{} is not a repo root (no rust/src)", root.display());
    }
    let mut files = Vec::new();
    collect_rs(&src_dir, &mut files)?;
    if tests_dir.is_dir() {
        collect_rs(&tests_dir, &mut files)?;
    }
    files.sort();

    let readme = fs::read_to_string(root.join("README.md")).context("reading README.md")?;
    let cargo = fs::read_to_string(root.join("Cargo.toml")).context("reading Cargo.toml")?;
    // The layering rule parses the §2 layer table out of the
    // architecture doc when it exists (the real repo); fixture trees
    // without the doc fall back to the built-in map.
    let arch = fs::read_to_string(root.join("docs").join("ARCHITECTURE.md")).ok();

    let mut diags = Vec::new();
    let mut env_reads: Vec<(String, String, u32, u32)> = Vec::new();
    let mut allows: Vec<(String, rules::Allow)> = Vec::new();

    // Parse everything first: the graph passes need the whole repo.
    let mut parsed: Vec<rules::SourceFile> = Vec::new();
    for path in &files {
        let src = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        parsed.push(rules::SourceFile::parse(&rel_path(root, path), &src));
    }

    for f in &parsed {
        let (file_allows, meta) = rules::allows(f);
        allows.extend(file_allows.into_iter().map(|a| (f.path.clone(), a)));
        diags.extend(meta);
        diags.extend(rules::unsafe_needs_safety(f));
        diags.extend(rules::no_partial_cmp_unwrap(f));
        diags.extend(rules::no_raw_thread_spawn(f));
        diags.extend(rules::panic_free_serve(f));
        diags.extend(rules::sendptr_confinement(f));
        diags.extend(rules::float_accum_order(f));
        diags.extend(rules::swallowed_result(f));
        for (name, line, col) in rules::env_reads(f) {
            env_reads.push((f.path.clone(), name, line, col));
        }
    }
    diags.extend(rules::env_registry(&env_reads, &readme, "README.md"));
    diags.extend(graph::layering(&parsed, arch.as_deref()));
    // One call graph serves both cross-fn passes: lock-order edge
    // propagation and decode-step allocation reachability.
    let cg = calls::CallGraph::build(&parsed);
    diags.extend(graph::lock_order(&cg));
    diags.extend(calls::hot_path_alloc(&cg));

    let mut test_files: Vec<String> = Vec::new();
    if tests_dir.is_dir() {
        for entry in fs::read_dir(&tests_dir).context("listing rust/tests")? {
            let p = entry.context("listing rust/tests")?.path();
            if p.is_file() && p.extension().is_some_and(|e| e == "rs") {
                if let Some(name) = p.file_name() {
                    test_files.push(name.to_string_lossy().into_owned());
                }
            }
        }
    }
    test_files.sort();
    diags.extend(rules::test_registration(&test_files, &cargo));

    // A `lint:allow(rule)` silences that rule on the comment's own lines
    // and the line directly below it, in the same file only.
    diags.retain(|d| {
        !allows.iter().any(|(file, a)| {
            *file == d.file && a.rule == d.rule && d.line >= a.from && d.line <= a.to
        })
    });
    diags.sort_by(|x, y| {
        (x.file.as_str(), x.line, x.col, x.rule).cmp(&(y.file.as_str(), y.line, y.col, y.rule))
    });
    Ok(diags)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = Vec::new();
    for e in fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
        entries.push(e.with_context(|| format!("listing {}", dir.display()))?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    let parts: Vec<_> = rel.components().map(|c| c.as_os_str().to_string_lossy()).collect();
    parts.join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A throwaway on-disk repo skeleton under the system temp dir, so
    /// the fixture tree exercises the real walker (sorted recursion,
    /// README/Cargo registry reads) and not just in-memory parsing.
    struct FixtureRepo {
        root: PathBuf,
    }

    impl FixtureRepo {
        fn new(tag: &str) -> FixtureRepo {
            let name = format!("heapr-lint-{tag}-{}", std::process::id());
            let root = std::env::temp_dir().join(name);
            let _ = fs::remove_dir_all(&root);
            fs::create_dir_all(root.join("rust").join("src")).unwrap();
            fs::create_dir_all(root.join("rust").join("tests")).unwrap();
            FixtureRepo { root }
        }

        fn write(&self, rel: &str, contents: &str) {
            let path = self.root.join(rel);
            if let Some(parent) = path.parent() {
                fs::create_dir_all(parent).unwrap();
            }
            fs::write(path, contents).unwrap();
        }

        fn lint(&self) -> Vec<Diagnostic> {
            lint_repo(&self.root).unwrap()
        }
    }

    impl Drop for FixtureRepo {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.root);
        }
    }

    const README_FIXTURE: &str = "# fixture\n\n| Variable | Default | Effect |\n|---|---|---|\n\
        | `HEAPR_DOCUMENTED` | off | a documented switch |\n";

    const CARGO_FIXTURE: &str = "[package]\nname = \"fixture\"\n\n[[test]]\nname = \"missing\"\n\
        path = \"rust/tests/missing.rs\"\n";

    /// Every rule fires on its seeded violation, with diagnostics
    /// anchored where the violation lives.
    #[test]
    fn seeded_violations_fire_every_rule() {
        let repo = FixtureRepo::new("bad");
        repo.write("README.md", README_FIXTURE);
        repo.write("Cargo.toml", CARGO_FIXTURE);
        repo.write(
            "rust/src/bad.rs",
            "pub fn f(a: f32, b: f32) {\n\
             \x20   let x = unsafe { g() };\n\
             \x20   let o = a.partial_cmp(&b).unwrap();\n\
             \x20   let h = std::thread::spawn(work);\n\
             \x20   let t = std::env::var(\"HEAPR_MYSTERY\");\n\
             }\n",
        );
        repo.write("rust/tests/orphan.rs", "#[test]\nfn t() {}\n");

        let diags = repo.lint();
        let fired: Vec<(&str, &str, u32)> =
            diags.iter().map(|d| (d.rule, d.file.as_str(), d.line)).collect();
        assert_eq!(
            fired,
            vec![
                (rules::TEST_REG, "Cargo.toml", 6),
                (rules::ENV_REGISTRY, "README.md", 5),
                (rules::UNSAFE_SAFETY, "rust/src/bad.rs", 2),
                (rules::PARTIAL_CMP, "rust/src/bad.rs", 3),
                (rules::THREAD_SPAWN, "rust/src/bad.rs", 4),
                (rules::ENV_REGISTRY, "rust/src/bad.rs", 5),
                (rules::TEST_REG, "rust/tests/orphan.rs", 1),
            ],
            "{diags:#?}"
        );
    }

    /// The fixed forms of the same tree lint clean.
    #[test]
    fn fixed_tree_is_clean() {
        let repo = FixtureRepo::new("good");
        repo.write("README.md", README_FIXTURE);
        repo.write(
            "Cargo.toml",
            "[package]\nname = \"fixture\"\n\n[[test]]\nname = \"orphan\"\n\
             path = \"rust/tests/orphan.rs\"\n",
        );
        repo.write(
            "rust/src/good.rs",
            "pub fn f(a: f32, b: f32) {\n\
             \x20   // SAFETY: g has no preconditions in this fixture\n\
             \x20   let x = unsafe { g() };\n\
             \x20   let o = a.partial_cmp(&b).unwrap_or(std::cmp::Ordering::Equal);\n\
             \x20   let h = pool::spawn_named(\"worker\", work);\n\
             \x20   let t = std::env::var(\"HEAPR_DOCUMENTED\");\n\
             }\n",
        );
        repo.write("rust/tests/orphan.rs", "#[test]\nfn t() {}\n");
        assert_eq!(repo.lint(), Vec::new(), "expected a clean fixture tree");
    }

    /// `lint:allow` suppresses exactly its own span (the comment's lines
    /// plus the next line) for exactly the named rule; a typoed rule
    /// name surfaces as `unknown-rule` instead of silently allowing.
    #[test]
    fn allow_escape_is_span_and_rule_scoped() {
        let repo = FixtureRepo::new("allow");
        repo.write("README.md", "# fixture\n");
        repo.write("Cargo.toml", "[package]\nname = \"fixture\"\n");
        repo.write(
            "rust/src/a.rs",
            "// lint:allow(no-raw-thread-spawn) fixture needs a raw thread\n\
             let h = std::thread::spawn(work);\n\
             let j = std::thread::spawn(work);\n\
             // lint:allow(no-partial-cmp-unwrap) wrong rule for the next line\n\
             let k = std::thread::spawn(work);\n\
             // lint:allow(not-a-rule)\n",
        );
        let diags = repo.lint();
        let fired: Vec<(&str, u32)> = diags.iter().map(|d| (d.rule, d.line)).collect();
        assert_eq!(
            fired,
            vec![
                (rules::THREAD_SPAWN, 3),
                (rules::THREAD_SPAWN, 5),
                (rules::UNKNOWN_RULE, 6),
            ],
            "{diags:#?}"
        );
    }

    /// One fixture tree seeding all four v2 rules at once: a layering
    /// violation that is also half of a module cycle, a lock-order
    /// inversion, a hot-path `unwrap()`, and a stray `RowsPtr`
    /// construction. The exact diagnostic list is asserted.
    #[test]
    fn seeded_new_rule_violations_fire_exactly() {
        let repo = FixtureRepo::new("v2-bad");
        repo.write("README.md", "# fixture\n");
        repo.write("Cargo.toml", "[package]\nname = \"fixture\"\n");
        repo.write("rust/src/model/store.rs", "use crate::runtime::Engine;\n");
        repo.write("rust/src/runtime/mod.rs", "use crate::model::Store;\npub struct Engine;\n");
        repo.write(
            "rust/src/runtime/kv.rs",
            "pub fn get(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
        );
        repo.write(
            "rust/src/util/pool.rs",
            "pub struct Q;\nimpl Q {\n\
             fn ab(&self) { let a = self.a.lock().unwrap(); let _x = self.b.lock().unwrap(); }\n\
             fn ba(&self) { let b = self.b.lock().unwrap(); let _y = self.a.lock().unwrap(); }\n\
             }\n",
        );
        repo.write(
            "rust/src/coordinator/serve.rs",
            "pub fn gather(buf: &mut [f32]) {\n    let p = RowsPtr::new(buf);\n}\n",
        );

        let diags = repo.lint();
        let fired: Vec<(&str, &str, u32)> =
            diags.iter().map(|d| (d.rule, d.file.as_str(), d.line)).collect();
        assert_eq!(
            fired,
            vec![
                (rules::SENDPTR, "rust/src/coordinator/serve.rs", 2),
                (rules::LAYERING, "rust/src/model/store.rs", 1),
                (rules::LAYERING, "rust/src/model/store.rs", 1),
                (rules::PANIC_FREE, "rust/src/runtime/kv.rs", 2),
                (rules::LOCK_ORDER, "rust/src/util/pool.rs", 3),
            ],
            "{diags:#?}"
        );
        // the two layering findings: the violation, then the cycle path
        assert!(diags[1].message.contains("layer violation"), "{}", diags[1].message);
        assert!(
            diags[2].message.contains("`model` → `runtime` → `model`"),
            "{}",
            diags[2].message
        );
        assert!(diags[4].message.contains("potential deadlock"), "{}", diags[4].message);
    }

    /// The repaired variant of the same tree: the cycle import removed,
    /// the unwrap made total, the lock order made consistent, and the
    /// `RowsPtr` construction justified with a written allow.
    #[test]
    fn fixed_new_rule_tree_is_clean() {
        let repo = FixtureRepo::new("v2-good");
        repo.write("README.md", "# fixture\n");
        repo.write("Cargo.toml", "[package]\nname = \"fixture\"\n");
        repo.write("rust/src/model/store.rs", "pub struct Store;\n");
        repo.write("rust/src/runtime/mod.rs", "use crate::model::Store;\npub struct Engine;\n");
        repo.write(
            "rust/src/runtime/kv.rs",
            "pub fn get(x: Option<u32>) -> u32 {\n    x.unwrap_or(0)\n}\n",
        );
        repo.write(
            "rust/src/util/pool.rs",
            "pub struct Q;\nimpl Q {\n\
             fn ab(&self) { let a = self.a.lock().unwrap(); let _x = self.b.lock().unwrap(); }\n\
             fn ab2(&self) { let a = self.a.lock().unwrap(); let _y = self.b.lock().unwrap(); }\n\
             }\n",
        );
        repo.write(
            "rust/src/coordinator/serve.rs",
            "pub fn gather(buf: &mut [f32]) {\n    \
             // lint:allow(sendptr-confinement) audited: fixture rows stay disjoint\n    \
             let p = RowsPtr::new(buf);\n}\n",
        );
        assert_eq!(repo.lint(), Vec::new(), "expected a clean v2 fixture tree");
    }

    /// A justified-rule allow with no justification keeps CI red via the
    /// `allow-needs-justification` meta finding.
    #[test]
    fn bare_allow_on_justified_rule_stays_red() {
        let repo = FixtureRepo::new("v2-bare-allow");
        repo.write("README.md", "# fixture\n");
        repo.write("Cargo.toml", "[package]\nname = \"fixture\"\n");
        repo.write(
            "rust/src/coordinator/serve.rs",
            "pub fn gather(buf: &mut [f32]) {\n    // lint:allow(sendptr-confinement)\n    \
             let p = RowsPtr::new(buf);\n}\n",
        );
        let diags = repo.lint();
        let fired: Vec<(&str, u32)> = diags.iter().map(|d| (d.rule, d.line)).collect();
        assert_eq!(fired, vec![(rules::ALLOW_JUSTIFY, 2)], "{diags:#?}");
    }

    /// One fixture tree seeding all three v3 rules at once: a decode-hot
    /// allocation in the scheduler entry itself, one in a helper it
    /// calls (the cold `retire` twin stays silent), a bare float
    /// accumulation plus a `.sum::<f32>()` turbofish, and both
    /// swallowed-result shapes. The exact diagnostic list is asserted.
    #[test]
    fn seeded_v3_rule_violations_fire_exactly() {
        let repo = FixtureRepo::new("v3-bad");
        repo.write("README.md", "# fixture\n");
        repo.write("Cargo.toml", "[package]\nname = \"fixture\"\n");
        repo.write(
            "rust/src/coordinator/scheduler.rs",
            "pub struct S;\n\
             impl S {\n\
             \x20   pub fn run(&mut self) {\n\
             \x20       let snap = input.to_vec();\n\
             \x20       helper(&snap);\n\
             \x20   }\n\
             }\n\
             fn helper(xs: &[f32]) {\n\
             \x20   let tmp = vec![0.0; xs.len()];\n\
             }\n\
             pub fn retire() {\n\
             \x20   let cold = vec![1.0; 4];\n\
             }\n",
        );
        repo.write(
            "rust/src/eval/mod.rs",
            "pub fn mean(xs: &[f32]) -> f32 {\n\
             \x20   let mut acc = 0.0;\n\
             \x20   for x in xs {\n\
             \x20       acc += *x;\n\
             \x20   }\n\
             \x20   acc / xs.len() as f32\n\
             }\n\
             pub fn total(xs: &[f32]) -> f32 {\n\
             \x20   xs.iter().sum::<f32>()\n\
             }\n\
             pub fn flush(tx: &Sender<u32>) {\n\
             \x20   let _ = tx.send(1);\n\
             \x20   tx.flush().ok();\n\
             }\n",
        );

        let diags = repo.lint();
        let fired: Vec<(&str, &str, u32)> =
            diags.iter().map(|d| (d.rule, d.file.as_str(), d.line)).collect();
        assert_eq!(
            fired,
            vec![
                (rules::HOT_ALLOC, "rust/src/coordinator/scheduler.rs", 4),
                (rules::HOT_ALLOC, "rust/src/coordinator/scheduler.rs", 9),
                (rules::FLOAT_ACCUM, "rust/src/eval/mod.rs", 4),
                (rules::FLOAT_ACCUM, "rust/src/eval/mod.rs", 9),
                (rules::SWALLOWED, "rust/src/eval/mod.rs", 12),
                (rules::SWALLOWED, "rust/src/eval/mod.rs", 13),
            ],
            "{diags:#?}"
        );
        // the alloc inside the entry fn itself carries no witness chain;
        // the helper names the entry it is reachable from
        assert!(!diags[0].message.contains("reachable from"), "{}", diags[0].message);
        assert!(
            diags[1].message.contains("reachable from entry `run`"),
            "{}",
            diags[1].message
        );
    }

    /// The repaired variant: the scheduler reuses state-owned scratch
    /// (clear + extend, no per-step allocation), the reduction routes
    /// through the sanctioned reducer, and the Result is propagated.
    #[test]
    fn fixed_v3_rule_tree_is_clean() {
        let repo = FixtureRepo::new("v3-good");
        repo.write("README.md", "# fixture\n");
        repo.write("Cargo.toml", "[package]\nname = \"fixture\"\n");
        repo.write(
            "rust/src/coordinator/scheduler.rs",
            "pub struct S { scratch: Vec<f32> }\n\
             impl S {\n\
             \x20   pub fn run(&mut self) {\n\
             \x20       self.scratch.clear();\n\
             \x20       self.scratch.extend_from_slice(input);\n\
             \x20       helper(&mut self.scratch);\n\
             \x20   }\n\
             }\n\
             fn helper(xs: &mut [f32]) {\n\
             \x20   for x in xs.iter_mut() { *x = 0.0; }\n\
             }\n",
        );
        repo.write(
            "rust/src/eval/mod.rs",
            "pub fn mean(xs: &[f64]) -> f64 {\n\
             \x20   crate::util::stats::mean(xs)\n\
             }\n\
             pub fn flush(tx: &Sender<u32>) -> Result<()> {\n\
             \x20   tx.send(1)?;\n\
             \x20   Ok(())\n\
             }\n",
        );
        assert_eq!(repo.lint(), Vec::new(), "expected a clean v3 fixture tree");
    }

    /// v3 allows are span- and justification-scoped like the v2 ones: a
    /// justified allow silences exactly one line, and a bare allow on
    /// any of the three new rules keeps CI red via
    /// `allow-needs-justification` (while still suppressing, so the
    /// meta finding is the only signal).
    #[test]
    fn v3_allow_escapes_are_span_scoped_and_need_justification() {
        let repo = FixtureRepo::new("v3-allow");
        repo.write("README.md", "# fixture\n");
        repo.write("Cargo.toml", "[package]\nname = \"fixture\"\n");
        repo.write(
            "rust/src/coordinator/scheduler.rs",
            "impl S {\n\
             \x20   pub fn run(&mut self) {\n\
             \x20       // lint:allow(hot-path-alloc) one-time warmup copy, audited\n\
             \x20       let snap = input.to_vec();\n\
             \x20       let again = input.to_vec();\n\
             \x20   }\n\
             }\n",
        );
        repo.write(
            "rust/src/eval/mod.rs",
            "pub fn total(xs: &[f32]) -> f32 {\n\
             \x20   // lint:allow(float-accum-order) order-free: inputs are pre-sorted\n\
             \x20   xs.iter().sum::<f32>()\n\
             }\n\
             pub fn flush(tx: &Sender<u32>) {\n\
             \x20   // lint:allow(swallowed-result)\n\
             \x20   let _ = tx.send(1);\n\
             }\n",
        );
        let diags = repo.lint();
        let fired: Vec<(&str, &str, u32)> =
            diags.iter().map(|d| (d.rule, d.file.as_str(), d.line)).collect();
        assert_eq!(
            fired,
            vec![
                (rules::HOT_ALLOC, "rust/src/coordinator/scheduler.rs", 5),
                (rules::ALLOW_JUSTIFY, "rust/src/eval/mod.rs", 6),
            ],
            "{diags:#?}"
        );
    }

    #[test]
    fn diagnostics_render_json_lines() {
        let d = Diagnostic {
            rule: rules::PANIC_FREE,
            file: "rust/src/coordinator/serve.rs".to_string(),
            line: 530,
            col: 22,
            message: "`.unwrap()` on a \"bucket\"\nlist".to_string(),
        };
        assert_eq!(
            d.to_json(),
            r#"{"file":"rust/src/coordinator/serve.rs","line":530,"col":22,"rule":"panic-free-serve","msg":"`.unwrap()` on a \"bucket\"\nlist"}"#
        );
    }

    #[test]
    fn diagnostics_render_clickable_file_line_col() {
        let d = Diagnostic {
            rule: rules::THREAD_SPAWN,
            file: "rust/src/main.rs".to_string(),
            line: 285,
            col: 13,
            message: "raw spawn".to_string(),
        };
        assert_eq!(d.to_string(), "rust/src/main.rs:285:13: [no-raw-thread-spawn] raw spawn");
    }

    /// The linter holds on the real repo across all twelve rules:
    /// `cargo test` fails if an undocumented `unsafe`, a raw spawn, an
    /// unregistered test file, a stale env row, a layer-table or module
    /// cycle violation (or §2 doc drift), a lock-order inversion, a
    /// hot-path panic site, a stray `RowsPtr`/`SendPtr` construction, a
    /// heap allocation reachable from the decode step, an unpinned
    /// float reduction, or a swallowed `Result` lands. Same check as
    /// `make lint`, kept in the tier-1 suite so it cannot be skipped.
    #[test]
    fn real_repo_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let diags = lint_repo(root).unwrap();
        assert!(
            diags.is_empty(),
            "repo has lint findings (run `make lint` for the same list):\n{}",
            diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
