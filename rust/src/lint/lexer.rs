//! Surface lexer for the lint engine.
//!
//! This is *not* a Rust parser: it is a token scanner whose one job is to
//! classify every byte of a source file as comment, string/char literal,
//! identifier, number or punctuation — with file positions — so the lint
//! rules in [`super::rules`] can match token sequences without ever being
//! fooled by the word `unsafe` inside a string, a `//` inside a string,
//! or a quote inside a comment. The hard cases it gets right:
//!
//! * line comments (`//`, `///`, `//!`) and **nested** block comments
//!   (`/* a /* b */ c */` is one token — Rust block comments nest);
//! * string literals with escapes (`"\\"`, `"\""`), byte strings
//!   (`b"…"`), C strings (`c"…"`, Rust 1.77), and raw strings with any
//!   hash depth (`r"…"`, `r#"…"#`, `br##"…"##`, `cr#"…"#`) — a raw
//!   string containing `unsafe` or `*/` stays one [`TokKind::Str`]
//!   token;
//! * a leading UTF-8 BOM and/or `#!…` shebang line is skipped before
//!   lexing starts (`#![inner_attr]` is *not* a shebang and still lexes
//!   as `#` `!` `[` …), so neither can shift the classification of the
//!   rest of the file;
//! * raw identifiers: `r#match` is an identifier, not the start of a raw
//!   string;
//! * char literals vs lifetimes: `'a'` is a char, `'a` in `&'a str` is a
//!   lifetime, `'\''` and `'\u{1F600}'` are chars.
//!
//! The lexer never panics on malformed input: an unterminated literal or
//! comment simply extends to end of file. Positions are 1-based; `col`
//! is a byte offset into the line (all delimiters are ASCII, so slicing
//! at token boundaries is always UTF-8 safe).

/// Token classification — just enough structure for the lint rules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (including raw identifiers like `r#match`).
    Ident,
    /// `// …` to end of line (including `///` and `//!` doc comments).
    LineComment,
    /// `/* … */`, nesting honoured (including `/** … */` doc comments).
    BlockComment,
    /// String literal: plain, byte, or raw with any hash depth.
    Str,
    /// Char or byte-char literal (`'x'`, `b'x'` yields `b` + `'x'`).
    Char,
    /// Lifetime (`'a`, `'static`) — distinct from [`TokKind::Char`].
    Lifetime,
    /// Numeric literal (integer or float, any base; suffix included).
    Num,
    /// Single punctuation byte (`::` is two `:` tokens).
    Punct,
}

impl TokKind {
    /// True for both comment kinds.
    pub fn is_comment(self) -> bool {
        matches!(self, TokKind::LineComment | TokKind::BlockComment)
    }
}

/// One lexed token with its source span.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    /// The exact source text, delimiters included.
    pub text: String,
    /// 1-based line of the token's first byte.
    pub line: u32,
    /// 1-based line of the token's last byte (multi-line comments and
    /// strings span lines; everything else has `end_line == line`).
    pub end_line: u32,
    /// 1-based byte column of the token's first byte within its line.
    pub col: u32,
}

impl Tok {
    /// For [`TokKind::Str`] tokens: the content between the quotes, with
    /// any `b`/`r`/`c` prefix and raw-string hashes stripped (escapes
    /// are *not* decoded). Returns the raw text unchanged for other
    /// kinds.
    pub fn str_content(&self) -> &str {
        if self.kind != TokKind::Str {
            return &self.text;
        }
        let s = self.text.trim_start_matches(['b', 'r', 'c']).trim_matches('#');
        s.strip_prefix('"').and_then(|s| s.strip_suffix('"')).unwrap_or(s)
    }
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Lexer state: a byte cursor plus line/column bookkeeping.
struct Cursor<'a> {
    src: &'a [u8],
    i: usize,
    line: u32,
    line_start: usize,
}

impl<'a> Cursor<'a> {
    fn new(src: &'a str) -> Cursor<'a> {
        Cursor { src: src.as_bytes(), i: 0, line: 1, line_start: 0 }
    }

    fn peek(&self, ahead: usize) -> Option<u8> {
        self.src.get(self.i + ahead).copied()
    }

    /// Advance one byte, tracking newlines.
    fn bump(&mut self) {
        if self.src.get(self.i) == Some(&b'\n') {
            self.line += 1;
            self.line_start = self.i + 1;
        }
        self.i += 1;
    }

    fn bump_n(&mut self, n: usize) {
        for _ in 0..n {
            self.bump();
        }
    }

    fn col(&self, at: usize) -> u32 {
        (at - self.line_start + 1) as u32
    }
}

/// Lex `src` into a token stream (whitespace dropped, everything else —
/// comments included — kept in source order).
pub fn lex(src: &str) -> Vec<Tok> {
    let mut toks = Vec::new();
    let mut cur = Cursor::new(src);
    // Leading BOM, then a shebang line (`#!…` at byte 0 that is not the
    // start of an inner attribute `#![…]`) — both skipped silently so
    // they can't shift how the rest of the file lexes. Resetting
    // `line_start` keeps column numbers 1-based past the BOM.
    if cur.src.starts_with(&[0xEF, 0xBB, 0xBF]) {
        cur.i = 3;
        cur.line_start = 3;
    }
    if cur.peek(0) == Some(b'#') && cur.peek(1) == Some(b'!') && cur.peek(2) != Some(b'[') {
        while cur.peek(0).is_some_and(|c| c != b'\n') {
            cur.bump();
        }
    }
    while let Some(b) = cur.peek(0) {
        if b.is_ascii_whitespace() {
            cur.bump();
            continue;
        }
        let start = cur.i;
        let (line, col) = (cur.line, cur.col(start));
        let kind = scan_token(&mut cur, b);
        let text = String::from_utf8_lossy(&cur.src[start..cur.i]).into_owned();
        toks.push(Tok { kind, text, line, end_line: cur.line, col });
    }
    toks
}

/// Scan one token starting at byte `b`; advances the cursor past it and
/// returns its kind.
fn scan_token(cur: &mut Cursor, b: u8) -> TokKind {
    // comments
    if b == b'/' && cur.peek(1) == Some(b'/') {
        while cur.peek(0).is_some_and(|c| c != b'\n') {
            cur.bump();
        }
        return TokKind::LineComment;
    }
    if b == b'/' && cur.peek(1) == Some(b'*') {
        cur.bump_n(2);
        let mut depth = 1usize;
        while depth > 0 {
            match (cur.peek(0), cur.peek(1)) {
                (Some(b'/'), Some(b'*')) => {
                    depth += 1;
                    cur.bump_n(2);
                }
                (Some(b'*'), Some(b'/')) => {
                    depth -= 1;
                    cur.bump_n(2);
                }
                (Some(_), _) => cur.bump(),
                (None, _) => break, // unterminated: extend to EOF
            }
        }
        return TokKind::BlockComment;
    }
    // string-ish prefixes: r"…", r#"…"#, b"…", br#"…"#, c"…", cr#"…"#
    // (Rust 1.77 C strings), and the raw *identifier* escape r#ident
    // (which is NOT a string)
    if b == b'r' || b == b'b' || b == b'c' {
        let after_b =
            if (b == b'b' || b == b'c') && cur.peek(1) == Some(b'r') { 2 } else { 1 };
        let mut hashes = 0usize;
        while cur.peek(after_b + hashes) == Some(b'#') {
            hashes += 1;
        }
        let raw_marker = b == b'r' || after_b == 2;
        if raw_marker && cur.peek(after_b + hashes) == Some(b'"') {
            cur.bump_n(after_b + hashes + 1);
            scan_raw_string_body(cur, hashes);
            return TokKind::Str;
        }
        if b == b'r' && hashes >= 1 && cur.peek(2).is_some_and(is_ident_start) {
            // raw identifier r#ident
            cur.bump_n(2);
            while cur.peek(0).is_some_and(is_ident_cont) {
                cur.bump();
            }
            return TokKind::Ident;
        }
        if hashes == 0 && cur.peek(after_b) == Some(b'"') {
            // b"…" / c"…" (after_b == 1 only: br/cr were handled above)
            cur.bump_n(after_b);
            return scan_quoted(cur, b'"');
        }
        if b == b'b' && cur.peek(1) == Some(b'\'') {
            cur.bump(); // the `b`; the char literal lexes next round
            return TokKind::Ident;
        }
        // plain identifier starting with r/b
        while cur.peek(0).is_some_and(is_ident_cont) {
            cur.bump();
        }
        return TokKind::Ident;
    }
    if b == b'"' {
        return scan_quoted(cur, b'"');
    }
    if b == b'\'' {
        return scan_quote_or_lifetime(cur);
    }
    if is_ident_start(b) {
        while cur.peek(0).is_some_and(is_ident_cont) {
            cur.bump();
        }
        return TokKind::Ident;
    }
    if b.is_ascii_digit() {
        scan_number(cur);
        return TokKind::Num;
    }
    cur.bump();
    TokKind::Punct
}

/// Scan a plain (escaped) quoted literal; the cursor sits on the opening
/// quote. Consumes through the closing quote (or EOF).
fn scan_quoted(cur: &mut Cursor, quote: u8) -> TokKind {
    cur.bump(); // opening quote
    while let Some(c) = cur.peek(0) {
        if c == b'\\' {
            cur.bump_n(2);
            continue;
        }
        cur.bump();
        if c == quote {
            break;
        }
    }
    if quote == b'"' {
        TokKind::Str
    } else {
        TokKind::Char
    }
}

/// Raw-string body after the opening quote: runs to `"` followed by
/// `hashes` `#` bytes (no escapes exist in raw strings).
fn scan_raw_string_body(cur: &mut Cursor, hashes: usize) {
    while let Some(c) = cur.peek(0) {
        if c == b'"' && (0..hashes).all(|h| cur.peek(1 + h) == Some(b'#')) {
            cur.bump_n(1 + hashes);
            return;
        }
        cur.bump();
    }
}

/// Disambiguate `'a'` (char) from `'a` (lifetime); the cursor sits on
/// the opening quote.
fn scan_quote_or_lifetime(cur: &mut Cursor) -> TokKind {
    match cur.peek(1) {
        Some(b'\\') => {
            // escaped char literal: consume to the closing quote
            cur.bump_n(2); // ' and backslash
            cur.bump(); // the escaped byte itself (n, ', u, x, …)
            while cur.peek(0).is_some_and(|c| c != b'\'') {
                cur.bump();
            }
            cur.bump(); // closing quote (no-op at EOF)
            TokKind::Char
        }
        Some(c) if is_ident_start(c) => {
            // 'a' is a char, 'a / 'static are lifetimes: scan the ident
            // run, then look for an immediate closing quote
            let mut n = 1;
            while cur.peek(1 + n).is_some_and(is_ident_cont) {
                n += 1;
            }
            if cur.peek(1 + n) == Some(b'\'') {
                cur.bump_n(n + 2);
                TokKind::Char
            } else {
                cur.bump_n(n + 1);
                TokKind::Lifetime
            }
        }
        Some(_) => {
            // non-alphabetic single char: '0', '%', ' ' …
            cur.bump_n(2);
            if cur.peek(0) == Some(b'\'') {
                cur.bump();
            }
            TokKind::Char
        }
        None => {
            cur.bump();
            TokKind::Punct // stray quote at EOF
        }
    }
}

/// Numeric literal: digits, `_`, alphanumeric suffixes/bases, and a `.`
/// only when a digit follows (so `0..n` lexes as `0` `.` `.` `n`).
fn scan_number(cur: &mut Cursor) {
    while let Some(c) = cur.peek(0) {
        if c.is_ascii_alphanumeric() || c == b'_' {
            cur.bump();
        } else if c == b'.' && cur.peek(1).is_some_and(|d| d.is_ascii_digit()) {
            cur.bump();
        } else {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_numbers() {
        let t = kinds("let x = foo(1, 2.5);");
        assert_eq!(t[0], (TokKind::Ident, "let".into()));
        assert_eq!(t[1], (TokKind::Ident, "x".into()));
        assert_eq!(t[2], (TokKind::Punct, "=".into()));
        assert_eq!(t[3], (TokKind::Ident, "foo".into()));
        assert_eq!(t[5], (TokKind::Num, "1".into()));
        assert_eq!(t[7], (TokKind::Num, "2.5".into()));
    }

    #[test]
    fn range_does_not_eat_dots() {
        let t = kinds("0..n");
        assert_eq!(t[0], (TokKind::Num, "0".into()));
        assert_eq!(t[1], (TokKind::Punct, ".".into()));
        assert_eq!(t[2], (TokKind::Punct, ".".into()));
        assert_eq!(t[3], (TokKind::Ident, "n".into()));
    }

    #[test]
    fn unsafe_in_plain_string_is_not_an_ident() {
        let t = lex(r#"let s = "unsafe { boom() }";"#);
        assert!(t.iter().all(|t| !(t.kind == TokKind::Ident && t.text == "unsafe")));
        assert!(t.iter().any(|t| t.kind == TokKind::Str));
    }

    #[test]
    fn unsafe_in_raw_string_is_one_token() {
        // the fixture case from the issue: a raw string containing the
        // word unsafe (and a fake comment-closer) must stay one Str token
        let src = "let s = r##\"unsafe */ \"# still \"## ; unsafe";
        let t = lex(src);
        let strs: Vec<&Tok> = t.iter().filter(|t| t.kind == TokKind::Str).collect();
        assert_eq!(strs.len(), 1);
        assert!(strs[0].text.contains("unsafe"));
        assert!(strs[0].str_content().starts_with("unsafe"));
        // the trailing real `unsafe` ident survives
        let last = t.last().unwrap();
        assert_eq!((last.kind, last.text.as_str()), (TokKind::Ident, "unsafe"));
    }

    #[test]
    fn byte_and_raw_byte_strings() {
        let t = kinds(r##"(b"ab", br#"c"d"#)"##);
        let strs: Vec<_> = t.iter().filter(|(k, _)| *k == TokKind::Str).collect();
        assert_eq!(strs.len(), 2);
        assert_eq!(strs[0].1, "b\"ab\"");
        assert_eq!(strs[1].1, "br#\"c\"d\"#");
    }

    #[test]
    fn raw_identifier_is_not_a_string() {
        let t = kinds("let r#match = r#fn;");
        assert_eq!(t[1], (TokKind::Ident, "r#match".into()));
        assert_eq!(t[3], (TokKind::Ident, "r#fn".into()));
    }

    #[test]
    fn nested_block_comment_is_one_token() {
        let src = "a /* outer /* inner */ still outer */ b";
        let t = kinds(src);
        assert_eq!(t.len(), 3);
        assert_eq!(t[0], (TokKind::Ident, "a".into()));
        assert_eq!(t[1].0, TokKind::BlockComment);
        assert!(t[1].1.contains("inner"));
        assert!(t[1].1.ends_with("*/"));
        assert_eq!(t[2], (TokKind::Ident, "b".into()));
    }

    #[test]
    fn line_comment_stops_at_newline() {
        let t = kinds("x // unsafe here\ny");
        assert_eq!(t[0], (TokKind::Ident, "x".into()));
        assert_eq!(t[1].0, TokKind::LineComment);
        assert_eq!(t[2], (TokKind::Ident, "y".into()));
    }

    #[test]
    fn quote_in_comment_does_not_open_a_string() {
        let t = kinds("// it's fine\nx");
        assert_eq!(t.len(), 2);
        assert_eq!(t[1], (TokKind::Ident, "x".into()));
    }

    #[test]
    fn comment_markers_inside_strings_are_inert() {
        let t = kinds(r#"let s = "// not a comment /* nope"; y"#);
        assert!(t.iter().all(|(k, _)| !k.is_comment()));
        assert_eq!(t.last().unwrap(), &(TokKind::Ident, "y".into()));
    }

    #[test]
    fn char_vs_lifetime() {
        let t = kinds("fn f<'a>(x: &'a str) { let c = 'a'; let n = '\\n'; let q = '\\''; }");
        let lifes: Vec<_> = t.iter().filter(|(k, _)| *k == TokKind::Lifetime).collect();
        let chars: Vec<_> = t.iter().filter(|(k, _)| *k == TokKind::Char).collect();
        assert_eq!(lifes.len(), 2, "two 'a lifetimes: {t:?}");
        assert_eq!(chars.len(), 3, "'a', newline and quote chars: {t:?}");
        assert_eq!(chars[0].1, "'a'");
        assert_eq!(chars[2].1, "'\\''");
    }

    #[test]
    fn static_lifetime_and_unicode_escape() {
        let t = kinds("&'static str; '\\u{1F600}'");
        assert!(t.iter().any(|(k, s)| *k == TokKind::Lifetime && s == "'static"));
        assert!(t.iter().any(|(k, s)| *k == TokKind::Char && s == "'\\u{1F600}'"));
    }

    #[test]
    fn spans_track_lines_and_cols() {
        let t = lex("ab\n  cd /* x\ny */ ef");
        assert_eq!((t[0].line, t[0].col), (1, 1));
        assert_eq!((t[1].line, t[1].col), (2, 3)); // cd
        assert_eq!((t[2].line, t[2].end_line), (2, 3)); // multi-line comment
        assert_eq!((t[3].line, t[3].col), (3, 6)); // ef
    }

    #[test]
    fn unterminated_literals_extend_to_eof_without_panic() {
        for src in ["\"never closed", "/* never closed", "r#\"never closed", "'"] {
            let t = lex(src);
            assert!(!t.is_empty(), "{src:?} must still lex");
        }
    }

    #[test]
    fn c_string_literals() {
        let t = kinds(r###"(c"lib\0", cr#"raw " c"#)"###);
        let strs: Vec<_> = t.iter().filter(|(k, _)| *k == TokKind::Str).collect();
        assert_eq!(strs.len(), 2, "{t:?}");
        assert_eq!(strs[0].1, "c\"lib\\0\"");
        assert_eq!(strs[1].1, "cr#\"raw \" c\"#");
    }

    #[test]
    fn c_prefixed_idents_are_not_c_strings() {
        // `crate` starts with `cr`, `c` alone is an ident, `cr8` too
        let t = kinds("crate c cr8 c\"s\"");
        assert_eq!(t[0], (TokKind::Ident, "crate".into()));
        assert_eq!(t[1], (TokKind::Ident, "c".into()));
        assert_eq!(t[2], (TokKind::Ident, "cr8".into()));
        assert_eq!(t[3], (TokKind::Str, "c\"s\"".into()));
    }

    #[test]
    fn unterminated_c_string_extends_to_eof() {
        for src in ["c\"never", "cr#\"never"] {
            let t = lex(src);
            assert_eq!(t.len(), 1, "{src:?} -> {t:?}");
            assert_eq!(t[0].kind, TokKind::Str);
        }
    }

    #[test]
    fn shebang_line_is_skipped() {
        let t = lex("#!/usr/bin/env run-cargo-script\nfn main() {}\n");
        assert_eq!((t[0].kind, t[0].text.as_str(), t[0].line), (TokKind::Ident, "fn", 2));
        assert!(t.iter().all(|x| !x.text.contains("usr")));
    }

    #[test]
    fn inner_attribute_is_not_a_shebang() {
        let t = kinds("#![allow(dead_code)]\nfn main() {}\n");
        assert_eq!(t[0], (TokKind::Punct, "#".into()));
        assert_eq!(t[1], (TokKind::Punct, "!".into()));
        assert_eq!(t[2], (TokKind::Punct, "[".into()));
    }

    #[test]
    fn bom_then_shebang_is_skipped_with_sane_columns() {
        let t = lex("\u{feff}#!/bin/sh\nlet x = 1;\n");
        assert_eq!((t[0].kind, t[0].text.as_str()), (TokKind::Ident, "let"));
        assert_eq!((t[0].line, t[0].col), (2, 1));
        // BOM alone, no shebang
        let t = lex("\u{feff}fn f() {}");
        assert_eq!((t[0].text.as_str(), t[0].line, t[0].col), ("fn", 1, 1));
    }

    #[test]
    fn str_content_strips_prefixes() {
        let t = lex(r###"("HEAPR_X", r#"raw"#, b"by")"###);
        let c: Vec<&str> = t
            .iter()
            .filter(|t| t.kind == TokKind::Str)
            .map(|t| t.str_content())
            .collect();
        assert_eq!(c, vec!["HEAPR_X", "raw", "by"]);
    }
}
