//! `lint::calls` — the whole-repo call graph, forward reachability
//! from the decode-step entry set, and the `hot-path-alloc` rule that
//! rides on it.
//!
//! The graph is name-resolved within the crate, from the token tree
//! alone (no type information): `recv.name(..)` resolves to inherent
//! methods named `name` (free functions as a fallback when no method
//! exists), `path::name(..)` to both sets, and a bare `name(..)` to
//! free functions first. Macros never produce edges — a call site
//! requires `(` directly after the name, and a macro name is followed
//! by `!`. A call may therefore resolve to several same-named
//! functions; reachability takes them all. That conservatism is the
//! point: a function is declared *cold* only by name, in
//! [`COLD_BOUNDARIES`], with the rationale documented in
//! ARCHITECTURE.md §7 — never by accident of resolution.
//!
//! Traversal starts at [`ENTRY_POINTS`] — the per-token decode step:
//! the scheduler's step/commit/admission loop, the serve layer's
//! `decode_step`/`decode_lane_step`, the session and host `run_s`
//! decode family, the `runtime/kv` page walk, and the GEMM kernels —
//! and stops at cold boundaries (constructors, admission/retirement
//! machinery, legacy dispatch helpers) and at [`SANCTIONED_SINKS`]
//! (the owned-tensor value ABI: allocations there are the engine
//! contract, not per-token jitter). Entry functions are always
//! scanned, even when their name also appears in a stop list (e.g.
//! `Scheduler::run` is an entry while `run` — the `HostBackend` name
//! dispatcher — is a boundary). `#[cfg(test)]` code is never entered.
//!
//! [`hot_path_alloc`] then scans every reachable body for
//! heap-allocation sites (`vec![..]`, `format!`, `Box::new`,
//! `String::from`, `..::with_capacity`, `.to_vec()`, `.to_string()`,
//! `.to_owned()`, `.clone()`, `.collect()`). `Vec::new`/`String::new`
//! are exempt (const constructors, no allocation until growth), and
//! growth of a *reused* scratch buffer (`.push`/`.extend`/`.resize`
//! onto state-owned storage) is by design not a finding — it
//! amortizes to zero steady-state allocations, which is exactly the
//! pattern the rule pushes hot code toward.

use std::collections::{BTreeMap, VecDeque};

use super::lexer::TokKind;
use super::rules::{SourceFile, HOT_ALLOC};
use super::tree::{Item, Tree};
use super::Diagnostic;

/// The decode-step entry set, as (file suffix, fn name) pairs. This
/// list is normative (mirrored in ARCHITECTURE.md §7); the
/// `real_repo_entry_points_resolve` test keeps it honest against the
/// actual tree.
pub const ENTRY_POINTS: &[(&str, &str)] = &[
    // per-token scheduler loop: step body, token commit, mid-flight
    // admission (runs between decode steps on the scheduler thread)
    ("coordinator/scheduler.rs", "run"),
    ("coordinator/scheduler.rs", "commit"),
    ("coordinator/scheduler.rs", "admit"),
    ("coordinator/scheduler.rs", "try_admit_prefix"),
    // wire layer: the per-token SSE serialization + chunk write
    ("coordinator/http.rs", "write_event"),
    ("coordinator/http.rs", "write_chunk"),
    // serve layer: the per-step forward pass
    ("coordinator/serve.rs", "decode_step"),
    ("coordinator/serve.rs", "decode_lane_step"),
    // session + host backend decode family
    ("runtime/mod.rs", "run_s"),
    ("runtime/host.rs", "run_s"),
    ("runtime/host.rs", "decode_attend"),
    ("runtime/host.rs", "attn_decode"),
    ("runtime/host.rs", "attn_decode_inplace"),
    ("runtime/host.rs", "attn_decode_paged"),
    ("runtime/host.rs", "attend_softmax_v"),
    // paged-KV per-step page walk (append one row, read one row)
    ("runtime/kv.rs", "append_row"),
    ("runtime/kv.rs", "row"),
    // GEMM kernels (every decode matmul lands here)
    ("tensor/gemm.rs", "gemm"),
    ("tensor/gemm.rs", "blocked"),
    ("tensor/gemm.rs", "simd"),
    ("tensor/gemm.rs", "naive"),
    ("tensor/gemm.rs", "dot"),
    ("tensor/gemm.rs", "dot8"),
    ("tensor/gemm.rs", "dot_k"),
    ("tensor/gemm.rs", "dot_simd"),
];

/// Functions reachability does not descend into, by name. These are
/// per-sequence or per-run machinery that sits *next to* the decode
/// loop, not inside its steady state; each group's rationale is the
/// ARCHITECTURE.md §7 text. Name-only matching is deliberate: the
/// same boundary name may resolve across several types
/// (`write_lane` exists on `DecodeState`, `Session` and `PagedKv`),
/// and all of them are cold for the same reason.
pub const COLD_BOUNDARIES: &[&str] = &[
    // constructors and defaults: run once per object, not per token
    "new",
    "default",
    // per-sequence admission / retirement / drain machinery: paid per
    // request, amortized over its whole generation
    "retire",
    "compact",
    "prefill",
    "prefill_with_capacity",
    "empty_state",
    "serve_batch",
    "admit_lane",
    "write_lane",
    "zero_lane",
    "release",
    "map_prefix",
    "share_prefix",
    "alloc_resident",
    "alloc_paged",
    "alloc_paged_resident",
    "free_resident",
    "register",
    "evict",
    "lookup",
    "clear",
    "session",
    "download",
    "dense",
    "absorb_kv_stats",
    // first-touch page allocation: the pool hands back recycled pages
    // in steady state; a fresh allocation is a capacity event
    "alloc",
    // legacy / non-decode dispatch: `HostBackend::run` is a name
    // dispatcher (the decode artifact family is declared as entries
    // directly); `run` on `Engine` is the stateless upload-per-call
    // path that `run_s` exists to replace
    "run",
    "dispatch",
    "fit_cache",
    "lane_rows",
    "kv_cache",
    "legacy_decode_attn",
    "run_moe_gate_legacy",
    "run_expert_legacy",
    "run_lm_head_legacy",
    // std-method name shadowing: `.parse()` on `str` and `.load()` on
    // atomics resolve by name to the config/manifest/checkpoint
    // loaders — all once-per-process startup machinery. The local fns
    // that share these names (`Json::parse`, `Kernel::parse`,
    // `Checkpoint::load`, …) are themselves cold for the same reason.
    "parse",
    "load",
    // blocking request intake: the scheduler parks here between
    // batches; work done behind these names is paid per admitted
    // request, not per decoded token
    "wait_ready",
    "take_ready",
];

/// Value-ABI sinks: calls whose *callee* is not scanned because its
/// allocations are the engine's owned-tensor contract (every kernel
/// and artifact returns freshly owned tensors by construction).
/// Removing those allocations means engine-level buffer donation (the
/// PJRT follow-up), not scratch hoisting — so the audit's scope is
/// the orchestration layer plus the in-place decode-append family,
/// and these names stop traversal exactly like a cold boundary.
/// Kept as a separate list so `--explain hot-path-alloc` and the docs
/// can state the two rationales apart.
pub const SANCTIONED_SINKS: &[&str] = &[
    "from_vec", "zeros", "reshape", "slice0", "f32", "as_f32", "as_f32_mut", "as_i32",
    "upload", "run_b", "matmul_tn", "matmul_nn", "matmul_at", "rmsnorm", "softmax",
    "gather0",
];

/// One function (free fn or method) with a body, as a call-graph node.
pub struct FnInfo {
    /// Index into the [`SourceFile`] slice the graph was built over.
    pub file: usize,
    pub name: String,
    /// Declared inside an `impl` block (span containment) vs at
    /// module level.
    pub is_method: bool,
    pub line: u32,
    /// Code-token indices of the body's `{` / `}` in the file's tree.
    pub body: (usize, usize),
    pub cfg_test: bool,
}

/// One `name(` call site inside a function body.
pub struct CallSite {
    pub name: String,
    /// Code-token index of the name token in the file's tree.
    pub at: usize,
    /// Candidate callees (indices into [`CallGraph::fns`]), deduped.
    pub callees: Vec<usize>,
}

/// The crate call graph: every bodied function, its call sites, and
/// the per-file token trees the sites index into.
pub struct CallGraph<'a> {
    pub files: &'a [SourceFile],
    pub trees: Vec<Tree<'a>>,
    pub fns: Vec<FnInfo>,
    /// `calls[i]` — the call sites inside `fns[i]`'s body (tokens of
    /// functions nested inside it are skipped; they are their own
    /// nodes).
    pub calls: Vec<Vec<CallSite>>,
}

/// Rust keywords that can look like `name(` call sites but are not.
pub(crate) fn is_keywordish(s: &str) -> bool {
    matches!(
        s,
        "if" | "while" | "for" | "match" | "return" | "loop" | "fn" | "as" | "in"
            | "let" | "move" | "ref" | "mut" | "else" | "break" | "continue"
    )
}

impl<'a> CallGraph<'a> {
    pub fn build(files: &'a [SourceFile]) -> CallGraph<'a> {
        let trees: Vec<Tree<'a>> = files.iter().map(|f| Tree::new(&f.toks)).collect();
        let mut fns: Vec<FnInfo> = Vec::new();
        for (fi, tree) in trees.iter().enumerate() {
            let mut impls: Vec<(usize, usize)> = Vec::new();
            let mut decls: Vec<(String, u32, (usize, usize), bool)> = Vec::new();
            for item in tree.items() {
                match item {
                    Item::Impl { body: Some((o, c)), .. } => impls.push((o, c)),
                    Item::Fn { name, line, body: Some((o, c)), cfg_test } => {
                        if !name.is_empty() {
                            decls.push((name, line, (o, c), cfg_test));
                        }
                    }
                    _ => {}
                }
            }
            for (name, line, (o, c), cfg_test) in decls {
                let is_method = impls.iter().any(|&(io, ic)| io < o && c < ic);
                fns.push(FnInfo { file: fi, name, is_method, line, body: (o, c), cfg_test });
            }
        }

        // Name → candidate node indices, split by declaration kind.
        let mut free: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        let mut meth: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for (i, f) in fns.iter().enumerate() {
            let map = if f.is_method { &mut meth } else { &mut free };
            map.entry(f.name.as_str()).or_default().push(i);
        }

        let mut calls = Vec::with_capacity(fns.len());
        for (i, f) in fns.iter().enumerate() {
            let nested = nested_bodies(&fns, i);
            calls.push(scan_calls(&trees[f.file], f.body, &nested, &free, &meth));
        }
        CallGraph { files, trees, fns, calls }
    }

    /// The node whose file path ends with `suffix` and whose name is
    /// `name` (first match in build order, test code excluded).
    pub fn fn_index(&self, suffix: &str, name: &str) -> Option<usize> {
        self.fns.iter().position(|f| {
            !f.cfg_test && f.name == name && self.files[f.file].path.ends_with(suffix)
        })
    }

    /// Forward BFS from `entries`. Traversal never enters
    /// `#[cfg(test)]` functions and does not descend into callees
    /// whose *name* is in `stop`; entry functions themselves are
    /// always scanned, even when stop-named. Returns node → the entry
    /// node it was first reached from (the finding witness).
    pub fn reachable_from(&self, entries: &[usize], stop: &[&str]) -> BTreeMap<usize, usize> {
        let mut hot: BTreeMap<usize, usize> = BTreeMap::new();
        let mut queue: VecDeque<usize> = VecDeque::new();
        for &e in entries {
            if !self.fns[e].cfg_test && !hot.contains_key(&e) {
                hot.insert(e, e);
                queue.push_back(e);
            }
        }
        while let Some(i) = queue.pop_front() {
            let witness = hot[&i];
            for site in &self.calls[i] {
                for &j in &site.callees {
                    let f = &self.fns[j];
                    if f.cfg_test || stop.contains(&f.name.as_str()) {
                        continue;
                    }
                    if !hot.contains_key(&j) {
                        hot.insert(j, witness);
                        queue.push_back(j);
                    }
                }
            }
        }
        hot
    }
}

/// Body spans of every *other* function strictly nested inside
/// `fns[i]`'s body (same file) — skipped when scanning `i`, so a
/// nested `fn` is attributed to its own node, not its enclosure.
fn nested_bodies(fns: &[FnInfo], i: usize) -> Vec<(usize, usize)> {
    let me = &fns[i];
    let mut out: Vec<(usize, usize)> = fns
        .iter()
        .enumerate()
        .filter(|&(j, f)| {
            j != i && f.file == me.file && f.body.0 > me.body.0 && f.body.1 < me.body.1
        })
        .map(|(_, f)| f.body)
        .collect();
    out.sort_unstable();
    out
}

/// Extract and resolve the call sites in one body.
fn scan_calls(
    tree: &Tree<'_>,
    (open, close): (usize, usize),
    nested: &[(usize, usize)],
    free: &BTreeMap<&str, Vec<usize>>,
    meth: &BTreeMap<&str, Vec<usize>>,
) -> Vec<CallSite> {
    let code = &tree.code;
    let mut out: Vec<CallSite> = Vec::new();
    let mut i = open + 1;
    while i < close && i < code.len() {
        if let Some(&(_, nc)) = nested.iter().find(|&&(no, _)| no == i) {
            i = nc + 1;
            continue;
        }
        let t = code[i];
        if t.kind != TokKind::Ident
            || is_keywordish(&t.text)
            || !code.get(i + 1).is_some_and(|n| n.kind == TokKind::Punct && n.text == "(")
        {
            i += 1;
            continue;
        }
        // `fn name(` is a declaration, not a call
        if i > 0 && code[i - 1].kind == TokKind::Ident && code[i - 1].text == "fn" {
            i += 1;
            continue;
        }
        let name = t.text.as_str();
        let dotted = i > 0 && code[i - 1].kind == TokKind::Punct && code[i - 1].text == ".";
        let pathed = i >= 2 && code[i - 1].text == ":" && code[i - 2].text == ":";
        let callees = if dotted {
            prefer(meth.get(name), free.get(name))
        } else if pathed {
            merge(meth.get(name), free.get(name))
        } else {
            prefer(free.get(name), meth.get(name))
        };
        out.push(CallSite { name: t.text.clone(), at: i, callees });
        i += 1;
    }
    out
}

/// `a` when non-empty, else `b` (the resolution fallback).
fn prefer(a: Option<&Vec<usize>>, b: Option<&Vec<usize>>) -> Vec<usize> {
    match a {
        Some(v) if !v.is_empty() => v.clone(),
        _ => b.cloned().unwrap_or_default(),
    }
}

/// Sorted union of both candidate sets (path calls reach either kind).
fn merge(a: Option<&Vec<usize>>, b: Option<&Vec<usize>>) -> Vec<usize> {
    let mut v: Vec<usize> =
        a.into_iter().chain(b).flat_map(|v| v.iter().copied()).collect();
    v.sort_unstable();
    v.dedup();
    v
}

// ------------------------------------------------------ hot-path-alloc --

/// Rule `hot-path-alloc`: heap-allocation sites in any function
/// reachable from the decode-step entry set. See the module docs for
/// the detector inventory and the exemptions.
pub fn hot_path_alloc(cg: &CallGraph<'_>) -> Vec<Diagnostic> {
    let mut entries: Vec<usize> = Vec::new();
    for &(suffix, name) in ENTRY_POINTS {
        for (i, f) in cg.fns.iter().enumerate() {
            if !f.cfg_test && f.name == name && cg.files[f.file].path.ends_with(suffix) {
                entries.push(i);
            }
        }
    }
    let stop: Vec<&str> =
        COLD_BOUNDARIES.iter().chain(SANCTIONED_SINKS).copied().collect();
    let hot = cg.reachable_from(&entries, &stop);

    let mut out = Vec::new();
    for (&i, &w) in &hot {
        let f = &cg.fns[i];
        let path = &cg.files[f.file].path;
        let entry = &cg.fns[w];
        let via = if w == i {
            String::new()
        } else {
            format!(" (reachable from entry `{}`)", entry.name)
        };
        let nested = nested_bodies(&cg.fns, i);
        for (t, what) in alloc_sites(&cg.trees[f.file], f.body, &nested) {
            out.push(Diagnostic {
                rule: HOT_ALLOC,
                file: path.clone(),
                line: t.0,
                col: t.1,
                message: format!(
                    "{what} in decode-hot fn `{}`{via}; the steady-state decode loop \
                     must not heap-allocate — reuse state-owned scratch or justify \
                     with `lint:allow(hot-path-alloc) <why>`",
                    f.name
                ),
            });
        }
    }
    out
}

/// Allocation sites in one body: ((line, col), description).
fn alloc_sites(
    tree: &Tree<'_>,
    (open, close): (usize, usize),
    nested: &[(usize, usize)],
) -> Vec<((u32, u32), String)> {
    let code = &tree.code;
    let mut out = Vec::new();
    let mut i = open + 1;
    while i < close && i < code.len() {
        if let Some(&(_, nc)) = nested.iter().find(|&&(no, _)| no == i) {
            i = nc + 1;
            continue;
        }
        let t = code[i];
        if t.kind != TokKind::Ident {
            i += 1;
            continue;
        }
        let next = |k: usize| code.get(i + k).map(|n| n.text.as_str()).unwrap_or("");
        let prev = |k: usize| {
            i.checked_sub(k).and_then(|p| code.get(p)).map(|n| n.text.as_str()).unwrap_or("")
        };
        // a `(` directly after the name, or a `::<..>(` turbofish
        let called = next(1) == "(" || (next(1) == ":" && next(2) == ":" && next(3) == "<");
        let hit = match t.text.as_str() {
            "vec" if next(1) == "!" => Some("`vec![..]` heap-allocates".to_string()),
            "format" if next(1) == "!" => Some("`format!` allocates a String".to_string()),
            "with_capacity" if next(1) == "(" && prev(1) == ":" => {
                Some("`::with_capacity` heap-allocates".to_string())
            }
            "new" if next(1) == "(" && prev(1) == ":" && prev(2) == ":" && prev(3) == "Box" => {
                Some("`Box::new` heap-allocates".to_string())
            }
            "from" if next(1) == "(" && prev(1) == ":" && prev(2) == ":" && prev(3) == "String" => {
                Some("`String::from` allocates".to_string())
            }
            m @ ("to_vec" | "to_string" | "to_owned" | "clone" | "collect")
                if called && prev(1) == "." =>
            {
                Some(format!("`.{m}()` allocates a fresh owned value"))
            }
            _ => None,
        };
        if let Some(what) = hit {
            out.push(((t.line, t.col), what));
        }
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sf(path: &str, src: &str) -> SourceFile {
        SourceFile::parse(path, src)
    }

    fn names(cg: &CallGraph<'_>, set: &BTreeMap<usize, usize>) -> Vec<String> {
        set.keys().map(|&i| cg.fns[i].name.clone()).collect()
    }

    #[test]
    fn direct_and_transitive_edges_resolve() {
        let files = vec![sf(
            "rust/src/a.rs",
            "fn a() { b(); }\nfn b() { c(); }\nfn c() {}\nfn d() {}\n",
        )];
        let cg = CallGraph::build(&files);
        let e = cg.fn_index("a.rs", "a").unwrap();
        let hot = cg.reachable_from(&[e], &[]);
        assert_eq!(names(&cg, &hot), vec!["a", "b", "c"]);
        // every reached node's witness is the single entry
        assert!(hot.values().all(|&w| w == e));
    }

    #[test]
    fn method_vs_free_fn_shadowing() {
        let files = vec![sf(
            "rust/src/a.rs",
            "struct S;\nimpl S {\n    fn step(&self) { inner_m(); }\n}\n\
             fn step() { inner_f(); }\n\
             fn inner_m() {}\nfn inner_f() {}\n\
             fn via_method(s: &S) { s.step(); }\n\
             fn via_free() { step(); }\n",
        )];
        let cg = CallGraph::build(&files);
        let m = cg.fn_index("a.rs", "via_method").unwrap();
        let f = cg.fn_index("a.rs", "via_free").unwrap();
        let hot_m = cg.reachable_from(&[m], &[]);
        let hot_f = cg.reachable_from(&[f], &[]);
        let nm = names(&cg, &hot_m);
        let nf = names(&cg, &hot_f);
        assert!(nm.contains(&"inner_m".to_string()) && !nm.contains(&"inner_f".to_string()), "{nm:?}");
        assert!(nf.contains(&"inner_f".to_string()) && !nf.contains(&"inner_m".to_string()), "{nf:?}");
    }

    #[test]
    fn dotted_call_falls_back_to_free_fn_when_no_method_exists() {
        let files = vec![sf(
            "rust/src/a.rs",
            "fn f(&self) { self.g(); }\nfn g(&self) { h(); }\nfn h() {}\n",
        )];
        let cg = CallGraph::build(&files);
        let e = cg.fn_index("a.rs", "f").unwrap();
        assert_eq!(names(&cg, &cg.reachable_from(&[e], &[])), vec!["f", "g", "h"]);
    }

    #[test]
    fn recursion_terminates_and_macros_make_no_edges() {
        let files = vec![sf(
            "rust/src/a.rs",
            "fn a() { a(); b(); }\nfn b() { a(); println!(\"x\"); }\nfn println() {}\n",
        )];
        let cg = CallGraph::build(&files);
        let e = cg.fn_index("a.rs", "a").unwrap();
        // `println!` is a macro (name followed by `!`), so the free fn
        // named `println` must not be reached through it
        assert_eq!(names(&cg, &cg.reachable_from(&[e], &[])), vec!["a", "b"]);
    }

    #[test]
    fn boundary_names_stop_traversal_but_entries_are_always_scanned() {
        let files = vec![sf(
            "rust/src/a.rs",
            "fn run() { helper(); }\nfn helper() { deep(); }\nfn deep() {}\n",
        )];
        let cg = CallGraph::build(&files);
        let e = cg.fn_index("a.rs", "run").unwrap();
        // `run` as entry is scanned even though `run` is also a stop
        // name; `helper` is stopped by name, so `deep` is never seen
        let hot = cg.reachable_from(&[e], &["run", "helper"]);
        assert_eq!(names(&cg, &hot), vec!["run"]);
    }

    #[test]
    fn cfg_test_fns_are_never_entered() {
        let files = vec![sf(
            "rust/src/a.rs",
            "fn a() { t(); }\n#[cfg(test)]\nmod tests {\n    fn t() { super::a(); }\n}\n",
        )];
        let cg = CallGraph::build(&files);
        let e = cg.fn_index("a.rs", "a").unwrap();
        assert_eq!(names(&cg, &cg.reachable_from(&[e], &[])), vec!["a"]);
    }

    #[test]
    fn nested_fn_tokens_belong_to_the_nested_node() {
        let files = vec![sf(
            "rust/src/a.rs",
            "fn outer() {\n    fn inner() { leaf(); }\n    other();\n}\n\
             fn leaf() {}\nfn other() {}\n",
        )];
        let cg = CallGraph::build(&files);
        let e = cg.fn_index("a.rs", "outer").unwrap();
        // outer reaches other() but NOT leaf(): the inner body's call
        // belongs to `inner`, which nothing calls
        assert_eq!(names(&cg, &cg.reachable_from(&[e], &[])), vec!["outer", "other"]);
    }

    #[test]
    fn hot_path_alloc_fires_only_on_reachable_bodies() {
        let files = vec![sf(
            "rust/src/coordinator/scheduler.rs",
            "impl Scheduler {\n\
             \x20   fn run(&mut self) { let xs = data.to_vec(); self.helper(); }\n\
             \x20   fn helper(&self) { let v = vec![0; 8]; }\n\
             \x20   fn retire(&mut self) { let cold = vec![1; 8]; }\n\
             }\n",
        )];
        let cg = CallGraph::build(&files);
        let d = hot_path_alloc(&cg);
        let fired: Vec<(u32, &str)> = d.iter().map(|x| (x.line, x.rule)).collect();
        // run's .to_vec() and helper's vec![..]; retire is a cold
        // boundary by name and stays silent
        assert_eq!(fired, vec![(2, HOT_ALLOC), (3, HOT_ALLOC)], "{d:#?}");
        assert!(d[1].message.contains("reachable from entry `run`"), "{}", d[1].message);
    }

    #[test]
    fn const_constructors_and_scratch_growth_are_exempt() {
        let files = vec![sf(
            "rust/src/coordinator/scheduler.rs",
            "impl Scheduler {\n\
             \x20   fn run(&mut self) {\n\
             \x20       let mut v: Vec<i32> = Vec::new();\n\
             \x20       let s = String::new();\n\
             \x20       self.scratch.clear();\n\
             \x20       self.scratch.resize(8, 0);\n\
             \x20       self.scratch.push(1);\n\
             \x20   }\n\
             }\n",
        )];
        let cg = CallGraph::build(&files);
        assert_eq!(hot_path_alloc(&cg), Vec::new());
    }

    /// The entry-point table stays honest against the real tree: every
    /// declared (file, fn) pair must resolve to a node. A rename that
    /// silently empties the hot set fails here, not in production.
    #[test]
    fn real_repo_entry_points_resolve() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
        let mut files = Vec::new();
        for sub in ["coordinator", "runtime", "tensor"] {
            let dir = root.join("rust").join("src").join(sub);
            for e in std::fs::read_dir(dir).unwrap() {
                let p = e.unwrap().path();
                if p.extension().is_some_and(|x| x == "rs") {
                    let rel = format!(
                        "rust/src/{sub}/{}",
                        p.file_name().unwrap().to_string_lossy()
                    );
                    files.push(sf(&rel, &std::fs::read_to_string(&p).unwrap()));
                }
            }
        }
        let cg = CallGraph::build(&files);
        let missing: Vec<String> = ENTRY_POINTS
            .iter()
            .filter(|(suffix, name)| cg.fn_index(suffix, name).is_none())
            .map(|(suffix, name)| format!("{suffix}::{name}"))
            .collect();
        assert!(missing.is_empty(), "stale ENTRY_POINTS entries: {missing:?}");
    }
}
