//! Cross-file passes: rules that need to see the whole repo at once
//! instead of one file at a time. [`super::lint_repo`] parses every
//! source file into a [`SourceFile`], then hands the full slice here
//! (plus the crate call graph from [`super::calls`]).
//!
//! Two rules live at this layer:
//!
//! * [`layering`] — extracts the intra-crate `use crate::…` graph and
//!   asserts the layer map, plus whole-graph dependency cycle
//!   detection with the full path in the message. The map itself is
//!   parsed at lint time from the machine-parsed table in
//!   ARCHITECTURE.md §2 when the doc is present — the doc is the
//!   normative source, and a missing/unparseable table or a row
//!   naming a nonexistent module is itself a finding. The built-in
//!   map (util/tensor are the foundation; runtime may not import the
//!   coordinator; model/heapr may not import runtime or coordinator)
//!   is the fallback for doc-less trees (fixtures);
//! * [`lock_order`] — collects `Mutex`/`Condvar` acquisition sites per
//!   function in the lock-discipline scope (`util/pool.rs`,
//!   `runtime/kv.rs`, `coordinator/`), builds the conservative
//!   may-hold-while-acquiring graph — call edges come from the
//!   [`super::calls`] graph, restricted to the scope — and flags
//!   cycles as potential deadlocks.
//!
//! The lock model is intentionally static and conservative; see
//! ARCHITECTURE.md §7 for the normative statement the rule encodes:
//! a lock's identity is the final field/variable name before `.lock()`,
//! a `let`-bound guard is held to the end of its enclosing block (or an
//! explicit `drop(guard)`), an unbound temporary is held to the end of
//! its statement, and `Condvar::wait*` counts as a point acquisition of
//! the condvar's node (the wait releases its mutex, so it is never
//! *held*).

use std::collections::{BTreeMap, BTreeSet};

use super::calls::{is_keywordish, CallGraph};
use super::lexer::TokKind;
use super::rules::{SourceFile, LAYERING, LOCK_ORDER};
use super::tree::{Item, Tree};
use super::Diagnostic;

/// The crate module a repo-relative path belongs to, for layering:
/// `rust/src/util/pool.rs` → `util`, `rust/src/config.rs` → `config`,
/// `rust/src/bin/lint.rs` → `bin`. Files outside `rust/src` (tests,
/// vendored code) are not part of the crate layer map.
pub fn module_of(path: &str) -> Option<&str> {
    let rest = path.strip_prefix("rust/src/")?;
    let first = rest.split('/').next().unwrap_or(rest);
    Some(first.strip_suffix(".rs").unwrap_or(first))
}

/// Why an import from `from` into `to` is forbidden, if it is — the
/// built-in fallback map, used when no ARCHITECTURE.md is present
/// (fixture trees). With the doc present, the §2 table is normative
/// and this map must agree with it (the table is written to encode
/// exactly these constraints; drift is a finding).
fn layer_reason(from: &str, to: &str) -> Option<&'static str> {
    match from {
        // Foundation: util imports nothing internal; tensor may import
        // only util (gemm legitimately drives the thread pool).
        "util" => Some("`util` is the foundation and imports nothing internal"),
        "tensor" => (to != "util")
            .then_some("`tensor` may import only `util` (foundation layer)"),
        "runtime" => (to == "coordinator")
            .then_some("`runtime` (L2) may not import the `coordinator` (L3)"),
        "model" | "heapr" => matches!(to, "runtime" | "coordinator").then_some(
            "`model`/`heapr` may not import `runtime` or `coordinator` \
             (engine access is the caller's job)",
        ),
        _ => None,
    }
}

/// One parsed row of the ARCHITECTURE §2 layer table.
enum Constraint {
    /// "imports nothing internal"
    Nothing,
    /// "imports only `a`, `b`"
    Only(Vec<String>),
    /// "never imports `a` or `b`"
    Not(Vec<String>),
}

/// The repo-relative path layer-table findings anchor to.
const ARCH_DOC: &str = "docs/ARCHITECTURE.md";

/// Parse the machine-parsed layer table out of ARCHITECTURE.md §2:
/// the first `| module | constraint |` table after the
/// "machine-parsed by heapr-lint" marker line. Returns the rows
/// (module, constraint, 1-based doc line) and any drift findings
/// (marker/table missing, unparseable constraint text).
fn parse_layer_table(doc: &str) -> (Vec<(String, Constraint, u32)>, Vec<Diagnostic>) {
    let drift = |line: u32, message: String| Diagnostic {
        rule: LAYERING,
        file: ARCH_DOC.to_string(),
        line,
        col: 1,
        message,
    };
    let mut rows = Vec::new();
    let mut out = Vec::new();
    let mut after_marker = false;
    let mut in_table = false;
    for (i, raw) in doc.lines().enumerate() {
        let ln = i as u32 + 1;
        let line = raw.trim();
        if !after_marker {
            after_marker = line.contains("machine-parsed by heapr-lint");
            continue;
        }
        if !line.starts_with('|') {
            if in_table {
                break; // the marked table ended
            }
            continue;
        }
        in_table = true;
        let cells: Vec<&str> = line.trim_matches('|').split('|').map(str::trim).collect();
        if cells.len() < 2 || cells[0].starts_with("---") || cells[0] == "module" {
            continue; // header / separator row
        }
        let ticked = |s: &str| -> Vec<String> {
            s.split('`')
                .skip(1)
                .step_by(2)
                .map(str::to_string)
                .collect()
        };
        let modules = ticked(cells[0]);
        let [module] = modules.as_slice() else {
            out.push(drift(
                ln,
                format!("layer-table row has no single backticked module name: `{line}`"),
            ));
            continue;
        };
        let text = cells[1];
        let deps = ticked(text);
        let constraint = if text.contains("nothing internal") {
            Constraint::Nothing
        } else if text.contains("only") && !deps.is_empty() {
            Constraint::Only(deps)
        } else if text.contains("never import") && !deps.is_empty() {
            Constraint::Not(deps)
        } else {
            out.push(drift(
                ln,
                format!(
                    "unparseable layer constraint for `{module}`: `{text}` (say \
                     \"imports nothing internal\", \"imports only `a`\", or \
                     \"never imports `a` or `b`\")"
                ),
            ));
            continue;
        };
        rows.push((module.clone(), constraint, ln));
    }
    if rows.is_empty() && out.is_empty() {
        out.push(drift(
            1,
            "no machine-parsed layer table found (marker \"machine-parsed by \
             heapr-lint\" followed by a `| module | constraint |` table in §2); \
             the layering rule has lost its normative source"
                .to_string(),
        ));
    }
    (rows, out)
}

/// Rule `layering`: assert the layer map over the `use crate::…` graph
/// and report any dependency cycle with its full module path. `arch`
/// is the ARCHITECTURE.md contents when the doc exists — its §2 table
/// is then the normative map (drift findings anchored to the doc);
/// `None` falls back to the built-in [`layer_reason`] map.
pub fn layering(files: &[SourceFile], arch: Option<&str>) -> Vec<Diagnostic> {
    let known: BTreeSet<&str> = files.iter().filter_map(|f| module_of(&f.path)).collect();
    // (from, to) → use sites, in walk order (files arrive sorted).
    let mut edges: BTreeMap<(String, String), Vec<(&str, u32, u32)>> = BTreeMap::new();
    for f in files {
        let Some(m) = module_of(&f.path) else { continue };
        let toks = &f.toks;
        for item in Tree::new(toks).items() {
            let Item::Use { path, line, col, cfg_test } = item else { continue };
            if cfg_test || path.first().map(String::as_str) != Some("crate") {
                continue;
            }
            let Some(dep) = path.get(1) else { continue };
            if dep == m || !known.contains(dep.as_str()) {
                continue;
            }
            edges
                .entry((m.to_string(), dep.clone()))
                .or_default()
                .push((f.path.as_str(), line, col));
        }
    }

    let mut out = Vec::new();
    let table = arch.map(parse_layer_table);
    if let Some((rows, drift)) = &table {
        out.extend(drift.iter().cloned());
        for (module, _, ln) in rows {
            if !known.contains(module.as_str()) {
                out.push(Diagnostic {
                    rule: LAYERING,
                    file: ARCH_DOC.to_string(),
                    line: *ln,
                    col: 1,
                    message: format!(
                        "layer table names module `{module}` which does not exist \
                         under rust/src (doc drift: update the §2 table)"
                    ),
                });
            }
        }
    }
    // The verdict for one import edge: the §2 table when present
    // (normative), the built-in map otherwise.
    let reason = |from: &str, to: &str| -> Option<String> {
        match &table {
            Some((rows, _)) => {
                let (_, c, _) = rows.iter().find(|(m, _, _)| m == from)?;
                let hit = match c {
                    Constraint::Nothing => true,
                    Constraint::Only(deps) => !deps.iter().any(|d| d == to),
                    Constraint::Not(deps) => deps.iter().any(|d| d == to),
                };
                hit.then(|| {
                    let what = match c {
                        Constraint::Nothing => "imports nothing internal".to_string(),
                        Constraint::Only(deps) => format!("may import only `{}`", deps.join("`, `")),
                        Constraint::Not(deps) => format!("may never import `{}`", deps.join("`/`")),
                    };
                    format!("`{from}` {what} (ARCHITECTURE §2)")
                })
            }
            None => layer_reason(from, to).map(str::to_string),
        }
    };
    for ((from, to), sites) in &edges {
        if let Some(reason) = reason(from, to) {
            for (file, line, col) in sites {
                out.push(Diagnostic {
                    rule: LAYERING,
                    file: file.to_string(),
                    line: *line,
                    col: *col,
                    message: format!("layer violation: `{from}` imports `{to}`; {reason}"),
                });
            }
        }
    }

    // Whole-graph cycle detection, independent of the layer table: any
    // module cycle is a finding, anchored at the first use site of the
    // cycle's first edge.
    let adj: BTreeMap<&str, BTreeSet<&str>> = edges.keys().fold(
        BTreeMap::new(),
        |mut m, (from, to)| {
            m.entry(from.as_str()).or_default().insert(to.as_str());
            m
        },
    );
    for cycle in find_cycles(&adj) {
        let path = cycle.join("` → `");
        let (file, line, col) =
            edges[&(cycle[0].to_string(), cycle[1].to_string())][0];
        out.push(Diagnostic {
            rule: LAYERING,
            file: file.to_string(),
            line,
            col,
            message: format!(
                "dependency cycle between modules: `{path}` → `{}` \
                 (break one of these imports)",
                cycle[0]
            ),
        });
    }
    out
}

/// Find cycles in a directed graph; returns one representative cycle per
/// strongly-connected component, as a node path (first node repeated
/// implicitly at the end), canonically rotated and deduplicated.
/// Deterministic: nodes and successors iterate in sorted order.
fn find_cycles<'a>(adj: &BTreeMap<&'a str, BTreeSet<&'a str>>) -> Vec<Vec<&'a str>> {
    let mut found: BTreeSet<Vec<&str>> = BTreeSet::new();
    for &start in adj.keys() {
        // DFS with an explicit stack of (node, successor iterator index).
        let mut stack: Vec<(&str, Vec<&str>)> = vec![(
            start,
            adj.get(start).map(|s| s.iter().copied().collect()).unwrap_or_default(),
        )];
        let mut on_path: Vec<&str> = vec![start];
        let mut visited: BTreeSet<&str> = BTreeSet::new();
        visited.insert(start);
        while let Some((_, succs)) = stack.last_mut() {
            let Some(next) = succs.pop() else {
                stack.pop();
                on_path.pop();
                continue;
            };
            if let Some(pos) = on_path.iter().position(|&n| n == next) {
                let mut cycle: Vec<&str> = on_path[pos..].to_vec();
                // canonical rotation: start at the lexicographically
                // smallest node so each cycle is reported once
                let min = cycle.iter().enumerate().min_by_key(|(_, n)| **n).map(|(i, _)| i);
                if let Some(i) = min {
                    cycle.rotate_left(i);
                }
                found.insert(cycle);
                continue;
            }
            if visited.insert(next) {
                on_path.push(next);
                stack.push((
                    next,
                    adj.get(next).map(|s| s.iter().copied().collect()).unwrap_or_default(),
                ));
            }
        }
    }
    found.into_iter().collect()
}

// ----------------------------------------------------------- lock-order --

/// Files inside the lock-discipline scope.
fn in_lock_scope(path: &str) -> bool {
    path.ends_with("util/pool.rs")
        || path.ends_with("runtime/kv.rs")
        || path.contains("coordinator/")
}

/// One acquisition event inside a function body.
struct Acq {
    /// Lock identity: the final field/variable name before `.lock()` /
    /// `.wait*()`.
    name: String,
    /// Code-token index of the event (the receiver name token).
    at: usize,
    /// Half-open code-index range during which the guard is held;
    /// `None` for point events (`Condvar::wait*` releases its mutex and
    /// holds nothing).
    held: Option<(usize, usize)>,
    line: u32,
    col: u32,
}

/// Rule `lock-order`: build the may-hold-while-acquiring graph over the
/// lock-discipline scope and flag cycles as potential deadlocks.
/// Call edges come from the crate [`CallGraph`], restricted to in-scope
/// non-test functions (an out-of-scope callee holds no locks by scope
/// definition, so traversal through it adds nothing). Same-name edges
/// are suppressed (an indexed receiver like `slots[i].lock()` names one
/// identity but guards many mutexes), so re-entrant acquisition is out
/// of scope for this rule.
pub fn lock_order(cg: &CallGraph<'_>) -> Vec<Diagnostic> {
    // In-scope nodes and their acquisition events.
    let scoped: Vec<usize> = (0..cg.fns.len())
        .filter(|&i| {
            let f = &cg.fns[i];
            !f.cfg_test && in_lock_scope(&cg.files[f.file].path)
        })
        .collect();
    let in_scope: BTreeSet<usize> = scoped.iter().copied().collect();
    let acqs: BTreeMap<usize, Vec<Acq>> = scoped
        .iter()
        .map(|&i| {
            let f = &cg.fns[i];
            (i, scan_acqs(&cg.trees[f.file], f.body.0, f.body.1))
        })
        .collect();

    // Direct lock sets per node, then the transitive closure through
    // the call-graph edges (callees restricted to the scope).
    let mut reach: BTreeMap<usize, BTreeSet<String>> = acqs
        .iter()
        .map(|(&i, a)| (i, a.iter().map(|x| x.name.clone()).collect()))
        .collect();
    loop {
        let mut changed = false;
        for &i in &scoped {
            let mut add: BTreeSet<String> = BTreeSet::new();
            for site in &cg.calls[i] {
                for j in site.callees.iter().filter(|j| in_scope.contains(j)) {
                    add.extend(reach[j].iter().cloned());
                }
            }
            let mine = reach.get_mut(&i).expect("every scoped fn has a reach entry");
            let before = mine.len();
            mine.extend(add);
            changed |= mine.len() != before;
        }
        if !changed {
            break;
        }
    }

    // Held-while-acquiring edges: (held, acquired) → first witness site.
    let mut edge_site: BTreeMap<(String, String), (String, u32, u32, String)> = BTreeMap::new();
    let mut record =
        |from: &str, to: &str, file: &str, line: u32, col: u32, how: String| {
            edge_site
                .entry((from.to_string(), to.to_string()))
                .or_insert_with(|| (file.to_string(), line, col, how));
        };
    for &i in &scoped {
        let file = &cg.files[cg.fns[i].file].path;
        for a in &acqs[&i] {
            let Some((h0, h1)) = a.held else { continue };
            for other in &acqs[&i] {
                if other.at > h0 && other.at < h1 && other.name != a.name {
                    record(
                        &a.name,
                        &other.name,
                        file,
                        other.line,
                        other.col,
                        format!("`{}` acquired while `{}` is held", other.name, a.name),
                    );
                }
            }
            for site in &cg.calls[i] {
                if site.at <= h0 || site.at >= h1 {
                    continue;
                }
                for j in site.callees.iter().filter(|j| in_scope.contains(j)) {
                    for l in &reach[j] {
                        if *l != a.name {
                            record(
                                &a.name,
                                l,
                                file,
                                a.line,
                                a.col,
                                format!(
                                    "call to `{}` (which may lock `{l}`) \
                                     while `{}` is held",
                                    site.name, a.name
                                ),
                            );
                        }
                    }
                }
            }
        }
    }

    let adj: BTreeMap<&str, BTreeSet<&str>> = edge_site.keys().fold(
        BTreeMap::new(),
        |mut m, (from, to)| {
            m.entry(from.as_str()).or_default().insert(to.as_str());
            m
        },
    );
    let mut out = Vec::new();
    for cycle in find_cycles(&adj) {
        let next = cycle.get(1).copied().unwrap_or(cycle[0]);
        let (file, line, col, how) =
            &edge_site[&(cycle[0].to_string(), next.to_string())];
        let path = cycle.join("` → `");
        out.push(Diagnostic {
            rule: LOCK_ORDER,
            file: file.clone(),
            line: *line,
            col: *col,
            message: format!(
                "potential deadlock: lock-order cycle `{path}` → `{}` \
                 (each arrow = acquired while the previous is held; witness: {how})",
                cycle[0]
            ),
        });
    }
    out
}

/// Scan one function body (code indices `open..=close`) for lock
/// acquisition events. Call sites are no longer collected here — the
/// [`CallGraph`] owns call extraction and resolution.
fn scan_acqs(tree: &Tree, open: usize, close: usize) -> Vec<Acq> {
    let code = &tree.code;
    let mut acqs = Vec::new();
    let mut i = open + 1;
    while i < close {
        let t = code[i];
        if t.kind == TokKind::Ident
            && !is_keywordish(&t.text)
            && code.get(i + 1).is_some_and(|n| n.text == "(")
            && matches!(t.text.as_str(), "lock" | "wait" | "wait_timeout" | "wait_while")
            && i > open + 1
            && code[i - 1].text == "."
        {
            if let Some(a) = acquisition(tree, open, close, i) {
                acqs.push(a);
            }
        }
        i += 1;
    }
    acqs
}

/// Build the acquisition event for a `.lock(` / `.wait*(` at code index
/// `m` (the method name). Returns `None` when the receiver cannot be
/// named (conservative skip).
fn acquisition(tree: &Tree, body_open: usize, body_close: usize, m: usize) -> Option<Acq> {
    let code = &tree.code;
    // receiver: the token before the `.`; step through a `]`/`)` group
    let mut r = m - 1; // the `.`
    if r == 0 {
        return None;
    }
    r -= 1;
    let recv = loop {
        let t = code[r];
        if t.kind == TokKind::Ident {
            break t;
        }
        if (t.text == "]" || t.text == ")") && tree.partner(r).is_some() {
            let open = tree.partner(r).expect("checked");
            if open == 0 {
                return None;
            }
            r = open - 1;
            continue;
        }
        return None;
    };
    let name = recv.text.clone();
    let is_wait = code[m].text.starts_with("wait");
    if is_wait {
        // Condvar::wait* releases its mutex; point event, nothing held.
        return Some(Acq { name, at: r, held: None, line: recv.line, col: recv.col });
    }
    // Statement start: walk back to the nearest `;` / `{` / `}`,
    // stepping over balanced `)`/`]` groups.
    let mut s = r;
    while s > body_open {
        let prev = code[s - 1];
        if matches!(prev.text.as_str(), ";" | "{" | "}") && prev.kind == TokKind::Punct {
            break;
        }
        if (prev.text == ")" || prev.text == "]") && prev.kind == TokKind::Punct {
            match tree.partner(s - 1) {
                Some(o) => s = o,
                None => break,
            }
            continue;
        }
        s -= 1;
    }
    let bound = code.get(s).is_some_and(|t| t.kind == TokKind::Ident && t.text == "let");
    let end = if bound {
        // held to the end of the enclosing block, or an explicit
        // `drop(binding)` inside it
        let close = tree
            .enclosing_brace(m)
            .and_then(|b| tree.partner(b))
            .unwrap_or(body_close);
        let mut bind = code.get(s + 1).filter(|t| t.kind == TokKind::Ident);
        if bind.is_some_and(|t| t.text == "mut") {
            bind = code.get(s + 2).filter(|t| t.kind == TokKind::Ident);
        }
        let mut end = close;
        if let Some(b) = bind {
            let mut k = m;
            while k + 3 < close.min(code.len()) {
                if code[k].text == "drop"
                    && code[k + 1].text == "("
                    && code[k + 2].text == b.text
                    && code[k + 3].text == ")"
                {
                    end = k;
                    break;
                }
                k += 1;
            }
        }
        end
    } else {
        // temporary: held to the end of the statement — the next `;`, or
        // the `{` that opens a block (an if/while condition temporary
        // drops before the block runs)
        let mut k = m + 1;
        loop {
            if k >= body_close || k >= code.len() {
                break body_close;
            }
            let t = code[k];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "(" | "[" => {
                        k = tree.partner(k).map(|c| c + 1).unwrap_or(body_close);
                        continue;
                    }
                    ";" | "{" | "}" => break k,
                    _ => {}
                }
            }
            k += 1;
        }
    };
    Some(Acq { name, at: r, held: Some((r, end)), line: recv.line, col: recv.col })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sf(path: &str, src: &str) -> SourceFile {
        SourceFile::parse(path, src)
    }

    // ------------------------------------------------------------ layering

    #[test]
    fn module_of_paths() {
        assert_eq!(module_of("rust/src/util/pool.rs"), Some("util"));
        assert_eq!(module_of("rust/src/config.rs"), Some("config"));
        assert_eq!(module_of("rust/src/bin/lint.rs"), Some("bin"));
        assert_eq!(module_of("rust/tests/integration.rs"), None);
    }

    #[test]
    fn forbidden_imports_fire() {
        let files = vec![
            sf("rust/src/runtime/mod.rs", "use crate::coordinator::Scheduler;\n"),
            sf("rust/src/coordinator/mod.rs", "pub struct Scheduler;\n"),
            sf("rust/src/model/mod.rs", "use crate::runtime::Engine;\n"),
            sf("rust/src/util/mod.rs", "use crate::runtime::Engine;\n"),
            sf("rust/src/tensor/mod.rs", "use crate::util::pool;\n"),
        ];
        let d = layering(&files, None);
        let fired: Vec<(&str, u32)> = d.iter().map(|x| (x.file.as_str(), x.line)).collect();
        assert_eq!(
            fired,
            vec![
                ("rust/src/model/mod.rs", 1),
                ("rust/src/runtime/mod.rs", 1),
                ("rust/src/util/mod.rs", 1),
            ],
            "{d:#?}"
        );
        assert!(d.iter().all(|x| x.rule == LAYERING));
    }

    #[test]
    fn tensor_to_util_is_allowed() {
        let files = vec![
            sf("rust/src/tensor/gemm.rs", "use crate::util::pool::ThreadPool;\n"),
            sf("rust/src/util/pool.rs", "pub struct ThreadPool;\n"),
        ];
        assert!(layering(&files, None).is_empty());
    }

    #[test]
    fn cycle_is_reported_with_full_path() {
        let files = vec![
            sf("rust/src/alpha.rs", "use crate::beta::B;\n"),
            sf("rust/src/beta.rs", "use crate::gamma::G;\n"),
            sf("rust/src/gamma.rs", "use crate::alpha::A;\n"),
        ];
        let d = layering(&files, None);
        assert_eq!(d.len(), 1, "{d:#?}");
        assert!(d[0].message.contains("`alpha` → `beta` → `gamma` → `alpha`"), "{}", d[0].message);
        assert_eq!(d[0].file, "rust/src/alpha.rs");
    }

    #[test]
    fn cfg_test_imports_do_not_count() {
        let files = vec![
            sf(
                "rust/src/model/mod.rs",
                "#[cfg(test)]\nmod tests {\n    use crate::runtime::Engine;\n}\n",
            ),
            sf("rust/src/runtime/mod.rs", "pub struct Engine;\n"),
        ];
        assert!(layering(&files, None).is_empty());
    }

    #[test]
    fn non_module_second_segment_is_ignored() {
        // `use crate::debug;` imports a macro, not a module
        let files = vec![sf("rust/src/runtime/mod.rs", "use crate::{debug, info};\n")];
        assert!(layering(&files, None).is_empty());
    }

    // ------------------------------------------- layering (doc-driven map)

    const ARCH_FIXTURE: &str = "# doc\n\n## 2. Layers\n\n\
        The table below is machine-parsed by heapr-lint.\n\n\
        | module | constraint |\n|---|---|\n\
        | `util` | imports nothing internal |\n\
        | `tensor` | imports only `util` |\n\
        | `runtime` | never imports `coordinator` |\n";

    #[test]
    fn doc_table_drives_the_verdicts() {
        let files = vec![
            sf("rust/src/tensor/mod.rs", "use crate::util::pool;\nuse crate::runtime::E;\n"),
            sf("rust/src/util/mod.rs", "pub struct P;\n"),
            sf("rust/src/runtime/mod.rs", "pub struct E;\n"),
        ];
        let d = layering(&files, Some(ARCH_FIXTURE));
        assert_eq!(d.len(), 1, "{d:#?}");
        assert_eq!((d[0].file.as_str(), d[0].line), ("rust/src/tensor/mod.rs", 2));
        assert!(d[0].message.contains("ARCHITECTURE §2"), "{}", d[0].message);
    }

    #[test]
    fn missing_marker_or_table_is_a_drift_finding() {
        let files = vec![sf("rust/src/util/mod.rs", "pub struct P;\n")];
        let d = layering(&files, Some("# doc with no marked table\n"));
        assert_eq!(d.len(), 1, "{d:#?}");
        assert_eq!(d[0].file, "docs/ARCHITECTURE.md");
        assert!(d[0].message.contains("no machine-parsed layer table"), "{}", d[0].message);
    }

    #[test]
    fn unparseable_row_and_unknown_module_are_drift_findings() {
        let arch = "machine-parsed by heapr-lint\n\
            | module | constraint |\n|---|---|\n\
            | `util` | does whatever it wants |\n\
            | `phantom` | never imports `util` |\n";
        let files = vec![sf("rust/src/util/mod.rs", "pub struct P;\n")];
        let d = layering(&files, Some(arch));
        let msgs: Vec<&str> = d.iter().map(|x| x.message.as_str()).collect();
        assert_eq!(d.len(), 2, "{d:#?}");
        assert!(msgs[0].contains("unparseable layer constraint for `util`"), "{msgs:?}");
        assert!(msgs[1].contains("names module `phantom`"), "{msgs:?}");
        assert!(d.iter().all(|x| x.file == "docs/ARCHITECTURE.md"));
    }

    #[test]
    fn doc_and_builtin_maps_agree_on_the_builtin_cases() {
        // The §2 fixture rows encode the same constraints as
        // `layer_reason`; both map forms must produce identical
        // verdicts over the same import edges.
        let files = vec![
            sf("rust/src/runtime/mod.rs", "use crate::coordinator::S;\n"),
            sf("rust/src/coordinator/mod.rs", "pub struct S;\n"),
            sf("rust/src/util/mod.rs", "use crate::runtime::R;\n"),
            sf("rust/src/tensor/mod.rs", "use crate::util::pool;\npub struct T;\n"),
        ];
        let with_doc: Vec<(String, u32)> = layering(&files, Some(ARCH_FIXTURE))
            .into_iter()
            .map(|x| (x.file, x.line))
            .collect();
        let builtin: Vec<(String, u32)> =
            layering(&files, None).into_iter().map(|x| (x.file, x.line)).collect();
        assert_eq!(with_doc, builtin, "doc-driven and built-in verdicts diverge");
        assert_eq!(with_doc.len(), 2, "{with_doc:?}"); // runtime→coordinator, util→runtime
    }

    // ---------------------------------------------------------- lock-order

    fn pool(src: &str) -> Vec<SourceFile> {
        vec![sf("rust/src/util/pool.rs", src)]
    }

    /// Run lock-order through the call graph, as `lint_repo` does.
    fn lo(files: &[SourceFile]) -> Vec<Diagnostic> {
        lock_order(&CallGraph::build(files))
    }

    #[test]
    fn inverted_orders_cycle() {
        let src = "impl Q {\n\
            fn ab(&self) {\n    let a = self.a.lock().unwrap();\n    self.b.lock().unwrap();\n}\n\
            fn ba(&self) {\n    let b = self.b.lock().unwrap();\n    self.a.lock().unwrap();\n}\n\
            }\n";
        let d = lo(&pool(src));
        assert_eq!(d.len(), 1, "{d:#?}");
        assert_eq!(d[0].rule, LOCK_ORDER);
        assert!(d[0].message.contains("`a` → `b` → `a`"), "{}", d[0].message);
    }

    #[test]
    fn consistent_order_is_clean() {
        let src = "impl Q {\n\
            fn ab(&self) {\n    let a = self.a.lock().unwrap();\n    self.b.lock().unwrap();\n}\n\
            fn ab2(&self) {\n    let a = self.a.lock().unwrap();\n    let b = self.b.lock().unwrap();\n}\n\
            }\n";
        assert!(lo(&pool(src)).is_empty());
    }

    #[test]
    fn drop_releases_before_second_lock() {
        let src = "fn f(&self) {\n    let a = self.a.lock().unwrap();\n    drop(a);\n\
                   \x20   let b = self.b.lock().unwrap();\n}\n\
                   fn g(&self) {\n    let b = self.b.lock().unwrap();\n    drop(b);\n\
                   \x20   let a = self.a.lock().unwrap();\n}\n";
        assert!(lo(&pool(src)).is_empty());
    }

    #[test]
    fn condition_temporary_does_not_hold_into_block() {
        // `if *x.lock()… { y.lock() }` + elsewhere `y` then `x` must NOT
        // cycle: the condition temporary drops before the block runs
        let src = "fn f(&self) {\n    if *self.x.lock().unwrap() == 0 {\n        \
                   self.y.lock().unwrap();\n    }\n}\n\
                   fn g(&self) {\n    let y = self.y.lock().unwrap();\n    \
                   self.x.lock().unwrap();\n}\n";
        assert!(lo(&pool(src)).is_empty());
    }

    #[test]
    fn statement_temporary_does_hold_within_statement() {
        let src = "fn f(&self) {\n    g(self.a.lock().unwrap(), self.b.lock().unwrap());\n}\n\
                   fn h(&self) {\n    let b = self.b.lock().unwrap();\n    \
                   self.a.lock().unwrap();\n}\n";
        let d = lo(&pool(src));
        assert_eq!(d.len(), 1, "{d:#?}");
    }

    #[test]
    fn call_edges_are_transitive() {
        // f: holds a, calls g; g locks b. h: holds b, locks a → cycle.
        let src = "fn f(&self) {\n    let a = self.a.lock().unwrap();\n    self.g();\n}\n\
                   fn g(&self) {\n    self.b.lock().unwrap();\n}\n\
                   fn h(&self) {\n    let b = self.b.lock().unwrap();\n    \
                   self.a.lock().unwrap();\n}\n";
        let d = lo(&pool(src));
        assert_eq!(d.len(), 1, "{d:#?}");
        assert!(d[0].message.contains("potential deadlock"), "{}", d[0].message);
    }

    #[test]
    fn same_lock_name_reacquisition_is_not_flagged() {
        // `slots[i].lock()` / `slots[j].lock()` share a receiver name
        // but guard *different* mutexes — same-name edges are suppressed
        // (direct and through calls) to avoid aliasing false positives;
        // documented limitation of the name-based lock identity.
        let src = "fn f(&self) {\n    let a = slots[i].lock().unwrap();\n    \
                   let b = slots[j].lock().unwrap();\n}\n";
        assert!(lo(&pool(src)).is_empty());
        let src2 = "fn f(&self) {\n    let a = self.a.lock().unwrap();\n    self.g();\n}\n\
                    fn g(&self) {\n    self.a.lock().unwrap();\n}\n";
        assert!(lo(&pool(src2)).is_empty());
    }

    #[test]
    fn wait_is_an_acquisition_but_holds_nothing() {
        // pool.rs shape: hold `remaining`, wait on `done_cv` → edge
        // remaining→done_cv; the reverse never exists because a wait
        // holds nothing. No cycle.
        let src = "fn f(&self) {\n    let mut rem = self.remaining.lock().unwrap();\n    \
                   while *rem > 0 {\n        rem = self.done_cv.wait(rem).unwrap();\n    }\n}\n";
        assert!(lo(&pool(src)).is_empty());
    }

    #[test]
    fn cfg_test_fns_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n\
                   fn ab(&self) {\n    let a = self.a.lock().unwrap();\n    self.b.lock().unwrap();\n}\n\
                   fn ba(&self) {\n    let b = self.b.lock().unwrap();\n    self.a.lock().unwrap();\n}\n\
                   }\n";
        assert!(lo(&pool(src)).is_empty());
    }

    #[test]
    fn out_of_scope_files_are_ignored() {
        let src = "fn ab(&self) {\n    let a = self.a.lock().unwrap();\n    self.b.lock().unwrap();\n}\n\
                   fn ba(&self) {\n    let b = self.b.lock().unwrap();\n    self.a.lock().unwrap();\n}\n";
        let files = vec![sf("rust/src/train/mod.rs", src)];
        assert!(lo(&files).is_empty());
    }
}
