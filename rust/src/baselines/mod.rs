//! Baseline pruning criteria spanning the design space the paper compares
//! against (docs/ARCHITECTURE.md maps each to its published counterpart):
//!
//! * [`random_scores`] — random atomic pruning (sanity floor).
//! * [`magnitude_scores`] — calibration-free weight-norm criterion.
//! * [`camera_scores`] — CAMERA-P (Xu et al. 2025), the paper's §4.2
//!   comparison: ε_{i,j} = (‖Φ‖₂ + α‖Φ‖_∞)·‖w_down‖₂ with Φ the atomic
//!   activations over the calibration set; layerwise by construction.
//! * [`freq_drop_plan`] — frequency-based whole-expert dropping.
//! * [`expert_drop_plan`] — NAEE-like whole-expert dropping by measured
//!   calibration-loss damage.
//! * expert-level HEAPr lives in `heapr::importance::expert_scores`
//!   (Table 3 ablation).

use anyhow::Result;

use crate::data::sampler::CalibSampler;
use crate::heapr::calibrate::CalibStats;
use crate::heapr::plan::PrunePlan;
use crate::model::store::ParamStore;
use crate::runtime::{Engine, Value};
use crate::tensor::{argsort, Tensor};
use crate::util::rng::Pcg64;

/// Uniform-random atomic scores.
pub fn random_scores(l: usize, e: usize, di: usize, seed: u64) -> Tensor {
    let mut rng = Pcg64::with_stream(seed, 0xbad5e);
    Tensor::from_vec(&[l, e, di], (0..l * e * di).map(|_| rng.f32()).collect())
}

/// ‖w_gate_k‖·‖w_up_k‖·‖w_down_k‖ — no calibration data at all.
pub fn magnitude_scores(params: &ParamStore, l: usize, e: usize, di: usize) -> Result<Tensor> {
    let mut s = Tensor::zeros(&[l, e, di]);
    for li in 0..l {
        let wg = params.get(&format!("l{li}.wg"))?; // [E, di, d]
        let wu = params.get(&format!("l{li}.wu"))?;
        let wd = params.get(&format!("l{li}.wd"))?; // [E, d, di]
        let d = wd.shape()[1];
        for ei in 0..e {
            for k in 0..di {
                let row_norm = |t: &Tensor| -> f32 {
                    let dlen = t.shape()[2];
                    let base = (ei * di + k) * dlen;
                    t.data()[base..base + dlen]
                        .iter()
                        .map(|x| x * x)
                        // lint:allow(float-accum-order) row-norm for a magnitude ranking; the baseline has no bitwise contract and any fixed order serves it
                        .sum::<f32>()
                        .sqrt()
                };
                let g = row_norm(wg);
                let u = row_norm(wu);
                let mut dn = 0.0f32;
                for r in 0..d {
                    let v = wd.at(&[ei, r, k]);
                    // lint:allow(float-accum-order) column-norm sum of squares for the same magnitude ranking; order-free by construction
                    dn += v * v;
                }
                s.set(&[li, ei, k], g * u * dn.sqrt());
            }
        }
    }
    Ok(s)
}

/// CAMERA-P decoding-time energy. `alpha` weighs the ∞-norm term (the
/// paper does not publish α; 0.5 is our documented choice). Uses the same
/// pass-2 statistics HEAPr collects, so the comparison is compute-matched.
pub fn camera_scores(
    params: &ParamStore,
    stats: &CalibStats,
    alpha: f32,
) -> Result<Tensor> {
    let (l, e, _d, di) = stats.cfg_dims;
    let mut s = Tensor::zeros(&[l, e, di]);
    for li in 0..l {
        let wd = params.get(&format!("l{li}.wd"))?; // [E, d, di]
        let d = wd.shape()[1];
        for ei in 0..e {
            let cnt = stats.counts.at(&[li, ei]).max(1.0);
            for k in 0..di {
                // ‖Φ‖₂ over routed tokens = sqrt(Σ h²) = sqrt(mean·cnt)
                let l2 = (stats.hsq_mean.at(&[li, ei, k]) * cnt).sqrt();
                let linf = stats.hmax.at(&[li, ei, k]);
                let mut dn = 0.0f32;
                for r in 0..d {
                    let v = wd.at(&[ei, r, k]);
                    // lint:allow(float-accum-order) column-norm sum of squares for the CAMERA-P energy ranking; order-free by construction
                    dn += v * v;
                }
                s.set(&[li, ei, k], (l2 + alpha * linf) * dn.sqrt());
            }
        }
    }
    Ok(s)
}

/// Drop whole experts with the lowest routed-token counts until `ratio` of
/// atomic experts are gone.
pub fn freq_drop_plan(stats: &CalibStats, ratio: f64) -> PrunePlan {
    let (_l, _e, _d, di) = stats.cfg_dims;
    PrunePlan::expert_level(&stats.counts, ratio, di)
}

/// NAEE-like expert dropping: measure each expert's calibration-loss damage
/// when fully masked (one `loss_masked` call per expert over a small probe
/// set), then drop the least-damaging experts.
pub fn expert_drop_plan(
    engine: &Engine,
    params: &ParamStore,
    probe: &[Vec<i32>],
    ratio: f64,
) -> Result<PrunePlan> {
    let cfg = engine.config().clone();
    let (l, e, di) = (cfg.n_layers, cfg.n_experts, cfg.d_inter);
    let batches = CalibSampler::batches(probe, cfg.batch, cfg.seq_len);
    let mut damage = Tensor::zeros(&[l, e]);
    for li in 0..l {
        for ei in 0..e {
            let mut mask = Tensor::ones(&[l, e, di]);
            for k in 0..di {
                mask.set(&[li, ei, k], 0.0);
            }
            let mut nll = 0.0f64;
            let mut cnt = 0.0f64;
            for (tokens, targets) in &batches {
                let mut inputs = params.values();
                inputs.push(Value::F32(mask.clone()));
                inputs.push(Value::I32(tokens.clone()));
                inputs.push(Value::I32(targets.clone()));
                let out = engine.run("loss_masked", &inputs)?;
                // lint:allow(float-accum-order) f64 scalar total over probe batches, accumulated in the loop's one fixed order
                nll += out[0].clone().f32()?.item() as f64;
                // lint:allow(float-accum-order) same fixed-order f64 scalar total as `nll` above
                cnt += out[1].clone().f32()?.item() as f64;
            }
            damage.set(&[li, ei], (nll / cnt.max(1.0)) as f32);
        }
    }
    Ok(PrunePlan::expert_level(&damage, ratio, di))
}

/// Rank-agreement diagnostic between two criteria (used by experiments to
/// report how close a heuristic gets to HEAPr's ordering).
pub fn rank_overlap(a: &Tensor, b: &Tensor, frac: f64) -> f64 {
    assert_eq!(a.shape(), b.shape());
    let n = a.len();
    let k = ((n as f64) * frac).round() as usize;
    let oa: std::collections::HashSet<usize> =
        argsort(a.data()).into_iter().take(k).collect();
    let ob: std::collections::HashSet<usize> =
        argsort(b.data()).into_iter().take(k).collect();
    if k == 0 {
        return 1.0;
    }
    oa.intersection(&ob).count() as f64 / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_scores_deterministic() {
        let a = random_scores(2, 2, 4, 1);
        let b = random_scores(2, 2, 4, 1);
        assert_eq!(a, b);
        assert_ne!(a, random_scores(2, 2, 4, 2));
    }

    #[test]
    fn magnitude_scores_scale_with_weights() {
        let names = vec!["l0.wg".into(), "l0.wu".into(), "l0.wd".into()];
        let mut wg = Tensor::ones(&[1, 2, 3]);
        // make atomic expert 1's gate row twice as large
        for i in 0..3 {
            wg.set(&[0, 1, i], 2.0);
        }
        let tensors = vec![wg, Tensor::ones(&[1, 2, 3]), Tensor::ones(&[1, 3, 2])];
        let store = ParamStore::from_tensors(names, tensors);
        let s = magnitude_scores(&store, 1, 1, 2).unwrap();
        assert!((s.at(&[0, 0, 1]) / s.at(&[0, 0, 0]) - 2.0).abs() < 1e-5);
    }

    #[test]
    fn camera_uses_stats() {
        let names = vec!["l0.wd".into()];
        let tensors = vec![Tensor::ones(&[1, 2, 2])];
        let store = ParamStore::from_tensors(names, tensors);
        let stats = CalibStats {
            cfg_dims: (1, 1, 2, 2),
            gbar: Tensor::zeros(&[1, 1, 2, 2]),
            hsq_mean: Tensor::from_vec(&[1, 1, 2], vec![4.0, 1.0]),
            hmax: Tensor::from_vec(&[1, 1, 2], vec![2.0, 1.0]),
            counts: Tensor::from_vec(&[1, 1], vec![4.0]),
            calib_ce: 0.0,
            n_sequences: 4,
        };
        let s = camera_scores(&store, &stats, 0.5).unwrap();
        // k=0: (sqrt(16) + 0.5*2) * sqrt(2) = 5*sqrt2; k=1: (2+0.5)*sqrt2
        assert!((s.at(&[0, 0, 0]) - 5.0 * 2f32.sqrt()).abs() < 1e-4);
        assert!((s.at(&[0, 0, 1]) - 2.5 * 2f32.sqrt()).abs() < 1e-4);
    }

    #[test]
    fn rank_overlap_bounds() {
        let a = Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(rank_overlap(&a, &b, 0.5), 1.0);
        let c = Tensor::from_vec(&[4], vec![4.0, 3.0, 2.0, 1.0]);
        assert_eq!(rank_overlap(&a, &c, 0.5), 0.0);
    }
}
