//! Training-loop driver.
//!
//! Rust owns the loop (data order, schedule, logging, checkpoints); the
//! `train_step` artifact owns one Adam step. The loop feeds (params, m, v,
//! step, lr, tokens, targets) and swaps the returned states back in —
//! python never runs.

use anyhow::{bail, Result};

use crate::config::RunConfig;
use crate::data::sampler::{CalibSampler, Split};
use crate::info;
use crate::model::store::ParamStore;
use crate::runtime::{Engine, Value};
use crate::util::rng::Pcg64;
use crate::util::Timer;

#[derive(Clone, Debug)]
pub struct TrainReport {
    /// (step, total loss, ce loss) at every logged step.
    pub curve: Vec<(usize, f32, f32)>,
    pub final_loss: f32,
    pub wallclock_s: f64,
}

pub struct Trainer<'e> {
    engine: &'e Engine,
    m: ParamStore,
    v: ParamStore,
    step: usize,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine) -> Trainer<'e> {
        Trainer {
            engine,
            m: ParamStore::zeros(&engine.manifest),
            v: ParamStore::zeros(&engine.manifest),
            step: 0,
        }
    }

    /// One optimisation step on a packed batch; updates `params` in place.
    pub fn step(
        &mut self,
        params: &mut ParamStore,
        tokens: &crate::tensor::ITensor,
        targets: &crate::tensor::ITensor,
        lr: f32,
    ) -> Result<(f32, f32)> {
        let mut inputs = params.values();
        inputs.extend(self.m.values());
        inputs.extend(self.v.values());
        inputs.push(Value::scalar_i32(self.step as i32));
        inputs.push(Value::scalar_f32(lr));
        inputs.push(Value::I32(tokens.clone()));
        inputs.push(Value::I32(targets.clone()));

        let mut out = self.engine.run("train_step", &inputs)?;
        let n = params.len();
        if out.len() != 2 + 3 * n {
            bail!("train_step returned {} outputs, expected {}", out.len(), 2 + 3 * n);
        }
        let rest = out.split_off(2);
        let loss = out[0].clone().f32()?.item();
        let ce = out[1].clone().f32()?.item();
        let mut rest = rest;
        let vs = rest.split_off(2 * n);
        let ms = rest.split_off(n);
        params.set_all(rest)?;
        self.m.set_all(ms)?;
        self.v.set_all(vs)?;
        self.step += 1;
        if !loss.is_finite() {
            bail!("training diverged at step {}: loss={loss}", self.step);
        }
        Ok((loss, ce))
    }

    /// Full training run on a corpus split; returns the loss curve.
    pub fn train(
        &mut self,
        params: &mut ParamStore,
        split: &Split,
        run: &RunConfig,
    ) -> Result<TrainReport> {
        let cfg = self.engine.config().clone();
        let mut rng = Pcg64::with_stream(run.seed, 0x7247);
        let timer = Timer::start("train");
        let mut curve = Vec::new();
        let log_every = (run.train_steps / 20).max(1);
        let mut last = (0.0, 0.0);
        for s in 0..run.train_steps {
            // simple warmup then constant lr
            let warm = ((s + 1) as f64 / 20.0).min(1.0);
            let lr = (run.lr * warm) as f32;
            let (tokens, targets) = CalibSampler::train_batch(split, cfg.batch, &mut rng);
            last = self.step(params, &tokens, &targets, lr)?;
            if s % log_every == 0 || s + 1 == run.train_steps {
                curve.push((s, last.0, last.1));
                info!("step {s:>5}  loss {:.4}  ce {:.4}", last.0, last.1);
            }
        }
        Ok(TrainReport {
            curve,
            final_loss: last.0,
            wallclock_s: timer.secs(),
        })
    }
}
