//! `heapr-lint` — the repo's dependency-free static-analysis gate.
//!
//! Usage: `heapr-lint [--root <repo-root>] [--json] [--rule <name>]…`
//! (default root: the current directory). Prints one clickable
//! `file:line:col: [rule] message` per finding — or, under `--json`,
//! one JSON object per line (`{"file":…,"line":…,"col":…,"rule":…,
//! "msg":…}`) for machine consumption (CI turns these into GitHub
//! annotations) — and exits nonzero when anything fires. `--rule`
//! restricts output to the named rule(s) (repeatable) so a developer
//! can iterate on one rule; the name must be a known rule or
//! meta-diagnostic. `make lint` runs the binary as part of
//! `make verify`; the engine and rule catalogue live in `heapr::lint`
//! (see `docs/ARCHITECTURE.md` §7).

use std::path::PathBuf;
use std::process::ExitCode;

use heapr::lint::{self, rules};

fn usage() {
    println!("usage: heapr-lint [--root <repo-root>] [--json] [--rule <name>]...");
}

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut json = false;
    let mut only: Vec<String> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("heapr-lint: --root needs a path");
                    return ExitCode::from(2);
                }
            },
            "--json" => json = true,
            "--rule" => match args.next() {
                Some(name) => {
                    let known = rules::RULES.contains(&name.as_str())
                        || name == rules::UNKNOWN_RULE
                        || name == rules::ALLOW_JUSTIFY;
                    if !known {
                        eprintln!(
                            "heapr-lint: unknown rule `{name}` (known: {:?})",
                            rules::RULES
                        );
                        return ExitCode::from(2);
                    }
                    only.push(name);
                }
                None => {
                    eprintln!("heapr-lint: --rule needs a rule name");
                    return ExitCode::from(2);
                }
            },
            "-h" | "--help" => {
                usage();
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("heapr-lint: unknown argument `{other}` (try --help)");
                return ExitCode::from(2);
            }
        }
    }
    match lint::lint_repo(&root) {
        Ok(mut diags) => {
            if !only.is_empty() {
                diags.retain(|d| only.iter().any(|r| r == d.rule));
            }
            if diags.is_empty() {
                if !json {
                    println!("heapr-lint: clean");
                }
                return ExitCode::SUCCESS;
            }
            for d in &diags {
                if json {
                    println!("{}", d.to_json());
                } else {
                    println!("{d}");
                }
            }
            eprintln!("heapr-lint: {} finding(s)", diags.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("heapr-lint: {e}");
            ExitCode::from(2)
        }
    }
}
