//! `heapr-lint` — the repo's dependency-free static-analysis gate.
//!
//! Usage: `heapr-lint [--root <repo-root>]` (default: the current
//! directory). Prints one clickable `file:line:col: [rule] message` per
//! finding and exits nonzero when anything fires. `make lint` runs it
//! as part of `make verify`; the engine and rule catalogue live in
//! `heapr::lint` (see `docs/ARCHITECTURE.md` §7).

use std::path::PathBuf;
use std::process::ExitCode;

use heapr::lint;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("heapr-lint: --root needs a path");
                    return ExitCode::from(2);
                }
            },
            "-h" | "--help" => {
                println!("usage: heapr-lint [--root <repo-root>]");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("heapr-lint: unknown argument `{other}` (try --help)");
                return ExitCode::from(2);
            }
        }
    }
    match lint::lint_repo(&root) {
        Ok(diags) if diags.is_empty() => {
            println!("heapr-lint: clean");
            ExitCode::SUCCESS
        }
        Ok(diags) => {
            for d in &diags {
                println!("{d}");
            }
            eprintln!("heapr-lint: {} finding(s)", diags.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("heapr-lint: {e}");
            ExitCode::from(2)
        }
    }
}
