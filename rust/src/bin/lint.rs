//! `heapr-lint` — the repo's dependency-free static-analysis gate.
//!
//! Usage: `heapr-lint [--root <repo-root>] [--json] [--rule <name>]…`,
//! or `heapr-lint --list-rules` / `heapr-lint --explain <rule>`
//! (default root: the current directory). Prints one clickable
//! `file:line:col: [rule] message` per finding — or, under `--json`,
//! one JSON object per line (`{"file":…,"line":…,"col":…,"rule":…,
//! "msg":…}`) for machine consumption (CI turns these into GitHub
//! annotations) — and exits nonzero when anything fires. `--rule`
//! restricts output to the named rule(s) (repeatable) so a developer
//! can iterate on one rule; the name must be a known rule or
//! meta-diagnostic, else exit 2 with the known list. `--list-rules`
//! prints the enabled rule names one per line (CI records the count so
//! a silently-disabled rule is visible); `--explain <rule>` prints the
//! one-paragraph doc for a rule from the same catalogue the README
//! renders. `make lint` runs the binary as part of `make verify`; the
//! engine and rule catalogue live in `heapr::lint`
//! (see `docs/ARCHITECTURE.md` §7).

use std::path::PathBuf;
use std::process::ExitCode;

use heapr::lint::{self, rules};

fn usage() {
    println!(
        "usage: heapr-lint [--root <repo-root>] [--json] [--rule <name>]...\n\
         \x20      heapr-lint --list-rules | --explain <rule>"
    );
}

/// The doc paragraph for `name` from [`rules::RULE_DOCS`] (rules and
/// meta-diagnostics alike).
fn explain(name: &str) -> Option<&'static str> {
    rules::RULE_DOCS.iter().find(|(n, _)| *n == name).map(|&(_, doc)| doc)
}

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut json = false;
    let mut only: Vec<String> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("heapr-lint: --root needs a path");
                    return ExitCode::from(2);
                }
            },
            "--json" => json = true,
            "--list-rules" => {
                for rule in rules::RULES {
                    println!("{rule}");
                }
                return ExitCode::SUCCESS;
            }
            "--explain" => match args.next() {
                Some(name) => match explain(&name) {
                    Some(doc) => {
                        println!("{name}\n\n{doc}");
                        return ExitCode::SUCCESS;
                    }
                    None => {
                        eprintln!(
                            "heapr-lint: unknown rule `{name}` (known: {:?})",
                            rules::RULES
                        );
                        usage();
                        return ExitCode::from(2);
                    }
                },
                None => {
                    eprintln!("heapr-lint: --explain needs a rule name");
                    usage();
                    return ExitCode::from(2);
                }
            },
            "--rule" => match args.next() {
                Some(name) => {
                    let known = rules::RULES.contains(&name.as_str())
                        || name == rules::UNKNOWN_RULE
                        || name == rules::ALLOW_JUSTIFY;
                    if !known {
                        eprintln!(
                            "heapr-lint: unknown rule `{name}` (known: {:?})",
                            rules::RULES
                        );
                        return ExitCode::from(2);
                    }
                    only.push(name);
                }
                None => {
                    eprintln!("heapr-lint: --rule needs a rule name");
                    return ExitCode::from(2);
                }
            },
            "-h" | "--help" => {
                usage();
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("heapr-lint: unknown argument `{other}` (try --help)");
                return ExitCode::from(2);
            }
        }
    }
    match lint::lint_repo(&root) {
        Ok(mut diags) => {
            if !only.is_empty() {
                diags.retain(|d| only.iter().any(|r| r == d.rule));
            }
            if diags.is_empty() {
                if !json {
                    println!("heapr-lint: clean");
                }
                return ExitCode::SUCCESS;
            }
            for d in &diags {
                if json {
                    println!("{}", d.to_json());
                } else {
                    println!("{d}");
                }
            }
            eprintln!("heapr-lint: {} finding(s)", diags.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("heapr-lint: {e}");
            ExitCode::from(2)
        }
    }
}
