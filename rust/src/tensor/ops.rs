//! Tensor operations used on the rust side of the pipeline.
//!
//! The coordinator's hot path uses `matmul_tn` (router scores) and
//! `rmsnorm`; weight surgery uses the gather ops; experiments use the
//! reductions; the host runtime backend leans on all of them.
//!
//! The matmuls dispatch into the [`super::gemm`] microkernel subsystem
//! (`HEAPR_KERNEL=naive|blocked|simd`; by default the f32x8 `simd`
//! kernel where runtime CPU detection finds avx2+fma, the cache-blocked
//! `blocked` kernel everywhere else); the remaining row-wise ops (`rmsnorm`,
//! `softmax`) are row-blocked over the [`crate::util::pool`] when the
//! work is large enough. Each output row/element is produced by the same
//! serial arithmetic regardless of the thread count, so results are
//! bitwise identical for any `HEAPR_THREADS`.
//!
//! Non-finite contract (shared across all three matmuls, pinned by tests
//! in `gemm`): zero operands never skip their partner, so `0·NaN` and
//! `0·∞` propagate NaN identically in every layout.

use super::gemm::{self, par_rows, Layout};
use super::Tensor;
use crate::util::cmp::{f32_nan_last, f32_nan_last_desc};

/// C[m,n] = A[m,k] @ B[n,k]^T  (B stored row-major as [n,k] — matches the
/// `router: [E, d]`, `w*: [di, d]` layouts coming from the checkpoints).
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, kb) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, kb, "matmul_tn inner dim {k} vs {kb}");
    let mut out = vec![0.0f32; m * n];
    gemm::gemm(Layout::TN, a.data(), b.data(), &mut out, m, k, n);
    Tensor::from_vec(&[m, n], out)
}

/// C[m,n] = A[m,k] @ B[k,n] (both row-major, no transpose).
pub fn matmul_nn(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (kb, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, kb, "matmul_nn inner dim {k} vs {kb}");
    let mut out = vec![0.0f32; m * n];
    gemm::gemm(Layout::NN, a.data(), b.data(), &mut out, m, k, n);
    Tensor::from_vec(&[m, n], out)
}

/// C[m,n] = A[p,m]^T @ B[p,n] — the gradient-accumulation shape
/// (dW = dOut^T @ X).
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Tensor {
    let (p, m) = (a.shape()[0], a.shape()[1]);
    let (pb, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(p, pb, "matmul_at outer dim {p} vs {pb}");
    let mut out = vec![0.0f32; m * n];
    gemm::gemm(Layout::AT, a.data(), b.data(), &mut out, m, p, n);
    Tensor::from_vec(&[m, n], out)
}

/// RMSNorm along the last axis: x * w / sqrt(mean(x^2) + eps).
pub fn rmsnorm(x: &Tensor, w: &Tensor, eps: f32) -> Tensor {
    let d = *x.shape().last().unwrap();
    assert_eq!(w.shape(), &[d]);
    let rows = x.len() / d;
    let mut out = vec![0.0f32; x.len()];
    let wd = w.data();
    let fill_row = |r: usize, orow: &mut [f32]| {
        let xs = &x.data()[r * d..(r + 1) * d];
        let ms: f32 = xs.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for i in 0..d {
            orow[i] = xs[i] * inv * wd[i];
        }
    };
    par_rows(&mut out, rows, d, rows * d, fill_row);
    Tensor::from_vec(x.shape(), out)
}

/// Elementwise a += b.
pub fn add_assign(a: &mut Tensor, b: &Tensor) {
    assert_eq!(a.shape(), b.shape());
    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += *y;
    }
}

/// Elementwise a += s * b.
pub fn axpy(a: &mut Tensor, s: f32, b: &Tensor) {
    assert_eq!(a.shape(), b.shape());
    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += s * *y;
    }
}

pub fn scale(a: &mut Tensor, s: f32) {
    for x in a.data_mut() {
        *x *= s;
    }
}

/// Softmax along the last axis.
///
/// A row that is entirely `-inf` has no well-defined distribution; the
/// historical code divided by `z = 0` there and emitted a row of NaN
/// that silently poisoned downstream logits. Such rows now come back
/// all-zero instead. (In-tree attention masks at the finite `-1e30`, so
/// today this guard protects external callers / true `-inf` masks, not
/// the prefill/decode path — which yields a uniform row when fully
/// masked, as before.) Rows that merely *contain* `-inf` entries soften
/// those to exact `0.0` as before, and NaN inputs still propagate NaN.
pub fn softmax(x: &Tensor) -> Tensor {
    let d = *x.shape().last().unwrap();
    let rows = x.len() / d;
    let mut out = vec![0.0f32; x.len()];
    let fill_row = |r: usize, orow: &mut [f32]| {
        let xs = &x.data()[r * d..(r + 1) * d];
        let mx = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        // f32::max ignores NaN, so mx == -inf means every entry is -inf
        // (fully masked -> well-defined zero row) or NaN (fall through so
        // the poison stays visible instead of being laundered to zeros).
        if mx == f32::NEG_INFINITY && xs.iter().all(|&v| v == f32::NEG_INFINITY) {
            orow.fill(0.0);
            return;
        }
        let mut z = 0.0f32;
        for i in 0..d {
            let e = (xs[i] - mx).exp();
            orow[i] = e;
            z += e;
        }
        for i in 0..d {
            orow[i] /= z;
        }
    };
    par_rows(&mut out, rows, d, rows * d, fill_row);
    Tensor::from_vec(x.shape(), out)
}

/// Top-k (values, indices) along the last axis, descending. Total and
/// panic-free on NaN: NaN scores order last (never selected over a
/// number).
pub fn topk(x: &Tensor, k: usize) -> (Tensor, Vec<Vec<usize>>) {
    let d = *x.shape().last().unwrap();
    assert!(k <= d);
    let rows = x.len() / d;
    let mut vals = vec![0.0f32; rows * k];
    let mut idxs = Vec::with_capacity(rows);
    for r in 0..rows {
        let xs = &x.data()[r * d..(r + 1) * d];
        let mut order: Vec<usize> = (0..d).collect();
        order.sort_by(|&i, &j| f32_nan_last_desc(xs[i], xs[j]).then(i.cmp(&j)));
        order.truncate(k);
        for (t, &i) in order.iter().enumerate() {
            vals[r * k + t] = xs[i];
        }
        idxs.push(order);
    }
    let mut shape = x.shape().to_vec();
    *shape.last_mut().unwrap() = k;
    (Tensor::from_vec(&shape, vals), idxs)
}

/// Gather rows of a [n, ...] tensor: out[i] = x[rows[i]].
pub fn gather0(x: &Tensor, rows: &[usize]) -> Tensor {
    let stride: usize = x.shape()[1..].iter().product();
    let mut data = Vec::with_capacity(rows.len() * stride);
    for &r in rows {
        assert!(r < x.shape()[0]);
        data.extend_from_slice(&x.data()[r * stride..(r + 1) * stride]);
    }
    let mut shape = x.shape().to_vec();
    shape[0] = rows.len();
    Tensor::from_vec(&shape, data)
}

/// Gather columns of a [r, c] matrix: out[:, j] = x[:, cols[j]].
pub fn gather_cols(x: &Tensor, cols: &[usize]) -> Tensor {
    assert_eq!(x.shape().len(), 2);
    let (r, c) = (x.shape()[0], x.shape()[1]);
    let mut data = Vec::with_capacity(r * cols.len());
    for i in 0..r {
        for &j in cols {
            assert!(j < c);
            data.push(x.data()[i * c + j]);
        }
    }
    Tensor::from_vec(&[r, cols.len()], data)
}

/// Sum along the last axis.
pub fn sum_last(x: &Tensor) -> Tensor {
    let d = *x.shape().last().unwrap();
    let rows = x.len() / d;
    let mut out = vec![0.0f32; rows];
    for r in 0..rows {
        out[r] = x.data()[r * d..(r + 1) * d].iter().sum();
    }
    Tensor::from_vec(&x.shape()[..x.shape().len() - 1], out)
}

/// Frobenius / L2 norm of the whole tensor.
pub fn norm2(x: &Tensor) -> f32 {
    x.data().iter().map(|v| v * v).sum::<f32>().sqrt()
}

/// Argsort (ascending) of a flat slice, stable on ties. Total and
/// panic-free on NaN: NaN entries sort to the end.
pub fn argsort(xs: &[f32]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..xs.len()).collect();
    order.sort_by(|&i, &j| f32_nan_last(xs[i], xs[j]).then(i.cmp(&j)));
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Pcg64;

    fn randt(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal()).collect())
    }

    #[test]
    fn matmul_tn_hand_case() {
        // A=[1,2;3,4], B rows are b0=[1,0], b1=[0,1], b2=[1,1]
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let c = matmul_tn(&a, &b);
        assert_eq!(c.data(), &[1.0, 2.0, 3.0, 3.0, 4.0, 7.0]);
    }

    #[test]
    fn matmul_nn_and_at_hand_cases() {
        // A=[1,2;3,4], B=[5,6;7,8]
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(matmul_nn(&a, &b).data(), &[19.0, 22.0, 43.0, 50.0]);
        // A^T B = [1,3;2,4]@[5,6;7,8]
        assert_eq!(matmul_at(&a, &b).data(), &[26.0, 30.0, 38.0, 44.0]);
        // consistency: A@B == (A^T)^T@B for a rectangular case
        let mut rng = Pcg64::new(3);
        let x = randt(&mut rng, &[4, 3]);
        let y = randt(&mut rng, &[4, 5]);
        let via_at = matmul_at(&x, &y); // [3,5]
        for i in 0..3 {
            for j in 0..5 {
                let mut want = 0.0f32;
                for t in 0..4 {
                    want += x.at(&[t, i]) * y.at(&[t, j]);
                }
                assert!((via_at.at(&[i, j]) - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Pcg64::new(2);
        let x = randt(&mut rng, &[5, 7]);
        let s = softmax(&x);
        for r in 0..5 {
            let sum: f32 = s.data()[r * 7..(r + 1) * 7].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(s.data()[r * 7..(r + 1) * 7].iter().all(|&v| v > 0.0));
        }
    }

    #[test]
    fn topk_returns_descending_max() {
        let x = Tensor::from_vec(&[1, 5], vec![0.1, 0.9, -0.3, 0.9, 0.5]);
        let (vals, idx) = topk(&x, 3);
        assert_eq!(vals.data(), &[0.9, 0.9, 0.5]);
        assert_eq!(idx[0], vec![1, 3, 4]); // stable on ties
    }

    #[test]
    fn gather_ops() {
        let x = Tensor::from_vec(&[3, 2], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(gather0(&x, &[2, 0]).data(), &[4., 5., 0., 1.]);
        assert_eq!(gather_cols(&x, &[1]).data(), &[1., 3., 5.]);
    }

    #[test]
    fn rmsnorm_unit_scale_has_unit_rms() {
        let mut rng = Pcg64::new(4);
        let x = randt(&mut rng, &[3, 16]);
        let w = Tensor::ones(&[16]);
        let y = rmsnorm(&x, &w, 1e-6);
        for r in 0..3 {
            let ms: f32 = y.data()[r * 16..(r + 1) * 16]
                .iter().map(|v| v * v).sum::<f32>() / 16.0;
            assert!((ms - 1.0).abs() < 1e-3, "{ms}");
        }
    }

    #[test]
    fn parallel_rowwise_ops_bitwise_match_serial() {
        // Shapes big enough to cross PAR_MIN_WORK; the pool is forced wide
        // so the parallel path actually runs, then compared against the
        // serial gemm reference / a serial pool. Mutating the process-wide
        // pool is racy against other tests' in-flight par_fors, so every
        // pool-mutating test serializes behind the shared test lock. The
        // kernel is pinned too: under HEAPR_KERNEL=naive the dispatching
        // matmul is only tolerance-equal to the contract reference
        // (blocked and simd are both contract-bitwise; naive is not).
        let _guard = crate::util::pool::test_serial_lock();
        // drop-guard: restore the pool and kernel even when an assert
        // unwinds mid-test, so a failure cannot leak a 4-thread pool or a
        // pinned kernel into the rest of the run (declared after the lock
        // guard, so it restores while the lock is still held)
        struct Restore(crate::tensor::gemm::Kernel);
        impl Drop for Restore {
            fn drop(&mut self) {
                crate::util::pool::set_threads(crate::util::pool::default_threads());
                crate::tensor::gemm::set_kernel(self.0);
            }
        }
        let _restore = Restore(gemm::kernel());
        gemm::set_kernel(gemm::Kernel::Blocked);
        let mut rng = Pcg64::new(11);
        let m = 130; // > 2 row blocks so the blocked kernel really fans out
        let k = 48;
        let n = 40;
        let a = randt(&mut rng, &[m, k]);
        let b = randt(&mut rng, &[n, k]);
        let mut want = vec![0.0f32; m * n];
        gemm::reference(Layout::TN, a.data(), b.data(), &mut want, m, k, n);
        crate::util::pool::set_threads(4);
        let c = matmul_tn(&a, &b);
        assert_eq!(c.data(), &want[..], "parallel matmul_tn must be bitwise serial");

        let x = randt(&mut rng, &[512, 64]);
        let w = randt(&mut rng, &[64]);
        let y_par = rmsnorm(&x, &w, 1e-6);
        let s_par = softmax(&x);
        crate::util::pool::set_threads(1);
        let y_ser = rmsnorm(&x, &w, 1e-6);
        let s_ser = softmax(&x);
        assert_eq!(y_par.data(), y_ser.data(), "rmsnorm thread-count invariant");
        assert_eq!(s_par.data(), s_ser.data(), "softmax thread-count invariant");
        // _restore resets threads + kernel on drop
    }

    #[test]
    fn softmax_fully_masked_row_is_zero_not_nan() {
        let ninf = f32::NEG_INFINITY;
        let x = Tensor::from_vec(&[2, 3], vec![ninf, ninf, ninf, 0.0, 0.0, ninf]);
        let s = softmax(&x);
        assert_eq!(&s.data()[..3], &[0.0, 0.0, 0.0], "masked row must be zeros");
        assert!((s.data()[3] - 0.5).abs() < 1e-6);
        assert!((s.data()[4] - 0.5).abs() < 1e-6);
        assert_eq!(s.data()[5], 0.0);
        assert!(s.data().iter().all(|v| !v.is_nan()));
        // NaN rows are NOT laundered into zeros: the poison stays visible
        let bad = Tensor::from_vec(&[1, 3], vec![f32::NAN, f32::NAN, f32::NAN]);
        assert!(softmax(&bad).data().iter().all(|v| v.is_nan()));
        let mixed = Tensor::from_vec(&[1, 3], vec![f32::NEG_INFINITY, f32::NAN, 1.0]);
        assert!(softmax(&mixed).data().iter().any(|v| v.is_nan()));
    }

    #[test]
    fn topk_and_argsort_order_nan_last_without_panicking() {
        let x = Tensor::from_vec(&[1, 5], vec![0.1, f32::NAN, 0.9, f32::NAN, 0.5]);
        let (vals, idx) = topk(&x, 3);
        assert_eq!(idx[0], vec![2, 4, 0], "NaN must never beat a number");
        assert_eq!(vals.data(), &[0.9, 0.5, 0.1]);
        let (_, idx_all) = topk(&x, 5);
        assert_eq!(&idx_all[0][3..], &[1, 3], "NaNs order last, index-stable");

        let ord = argsort(&[f32::NAN, 2.0, 1.0, f32::NAN]);
        assert_eq!(ord, vec![2, 1, 0, 3], "ascending with NaNs at the end");
    }

    #[test]
    fn prop_matmul_left_distributive() {
        check("matmul-distributive", 30,
              |g: &mut Gen| {
                  let m = g.usize_in(1, 6);
                  let k = g.usize_in(1, 6);
                  let n = g.usize_in(1, 6);
                  let mut r = Pcg64::new(g.rng.next_u64());
                  (randt(&mut r, &[m, k]), randt(&mut r, &[m, k]),
                   randt(&mut r, &[n, k]))
              },
              |(a, b, c)| {
                  let mut ab = a.clone();
                  add_assign(&mut ab, b);
                  let lhs = matmul_tn(&ab, c);
                  let mut rhs = matmul_tn(a, c);
                  add_assign(&mut rhs, &matmul_tn(b, c));
                  lhs.data().iter().zip(rhs.data())
                      .all(|(x, y)| (x - y).abs() < 1e-3)
              });
    }

    #[test]
    fn prop_argsort_is_sorted_permutation() {
        check("argsort", 50,
              |g: &mut Gen| g.vec_f32(32, -10.0, 10.0),
              |xs| {
                  let ord = argsort(xs);
                  let mut seen = vec![false; xs.len()];
                  for &i in &ord { seen[i] = true; }
                  seen.iter().all(|&b| b)
                      && ord.windows(2).all(|w| xs[w[0]] <= xs[w[1]])
              });
    }
}
