//! Tensor operations used on the rust side of the pipeline.
//!
//! The coordinator's hot path uses `matmul_tn` (router scores) and
//! `rmsnorm`; weight surgery uses the gather ops; experiments use the
//! reductions. Everything is straightforward single-threaded f32 — the
//! heavy lifting runs inside XLA.

use super::Tensor;

/// C[m,n] = A[m,k] @ B[n,k]^T  (B stored row-major as [n,k] — matches the
/// `router: [E, d]`, `w*: [di, d]` layouts coming from the checkpoints).
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, kb) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, kb, "matmul_tn inner dim {k} vs {kb}");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for t in 0..k {
                acc += arow[t] * brow[t];
            }
            out[i * n + j] = acc;
        }
    }
    Tensor::from_vec(&[m, n], out)
}

/// RMSNorm along the last axis: x * w / sqrt(mean(x^2) + eps).
pub fn rmsnorm(x: &Tensor, w: &Tensor, eps: f32) -> Tensor {
    let d = *x.shape().last().unwrap();
    assert_eq!(w.shape(), &[d]);
    let rows = x.len() / d;
    let mut out = vec![0.0f32; x.len()];
    for r in 0..rows {
        let xs = &x.data()[r * d..(r + 1) * d];
        let ms: f32 = xs.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for i in 0..d {
            out[r * d + i] = xs[i] * inv * w.data()[i];
        }
    }
    Tensor::from_vec(x.shape(), out)
}

/// Elementwise a += b.
pub fn add_assign(a: &mut Tensor, b: &Tensor) {
    assert_eq!(a.shape(), b.shape());
    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += *y;
    }
}

/// Elementwise a += s * b.
pub fn axpy(a: &mut Tensor, s: f32, b: &Tensor) {
    assert_eq!(a.shape(), b.shape());
    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += s * *y;
    }
}

pub fn scale(a: &mut Tensor, s: f32) {
    for x in a.data_mut() {
        *x *= s;
    }
}

/// Softmax along the last axis.
pub fn softmax(x: &Tensor) -> Tensor {
    let d = *x.shape().last().unwrap();
    let rows = x.len() / d;
    let mut out = vec![0.0f32; x.len()];
    for r in 0..rows {
        let xs = &x.data()[r * d..(r + 1) * d];
        let mx = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for i in 0..d {
            let e = (xs[i] - mx).exp();
            out[r * d + i] = e;
            z += e;
        }
        for i in 0..d {
            out[r * d + i] /= z;
        }
    }
    Tensor::from_vec(x.shape(), out)
}

/// Top-k (values, indices) along the last axis, descending.
pub fn topk(x: &Tensor, k: usize) -> (Tensor, Vec<Vec<usize>>) {
    let d = *x.shape().last().unwrap();
    assert!(k <= d);
    let rows = x.len() / d;
    let mut vals = vec![0.0f32; rows * k];
    let mut idxs = Vec::with_capacity(rows);
    for r in 0..rows {
        let xs = &x.data()[r * d..(r + 1) * d];
        let mut order: Vec<usize> = (0..d).collect();
        order.sort_by(|&i, &j| xs[j].partial_cmp(&xs[i]).unwrap().then(i.cmp(&j)));
        order.truncate(k);
        for (t, &i) in order.iter().enumerate() {
            vals[r * k + t] = xs[i];
        }
        idxs.push(order);
    }
    let mut shape = x.shape().to_vec();
    *shape.last_mut().unwrap() = k;
    (Tensor::from_vec(&shape, vals), idxs)
}

/// Gather rows of a [n, ...] tensor: out[i] = x[rows[i]].
pub fn gather0(x: &Tensor, rows: &[usize]) -> Tensor {
    let stride: usize = x.shape()[1..].iter().product();
    let mut data = Vec::with_capacity(rows.len() * stride);
    for &r in rows {
        assert!(r < x.shape()[0]);
        data.extend_from_slice(&x.data()[r * stride..(r + 1) * stride]);
    }
    let mut shape = x.shape().to_vec();
    shape[0] = rows.len();
    Tensor::from_vec(&shape, data)
}

/// Gather columns of a [r, c] matrix: out[:, j] = x[:, cols[j]].
pub fn gather_cols(x: &Tensor, cols: &[usize]) -> Tensor {
    assert_eq!(x.shape().len(), 2);
    let (r, c) = (x.shape()[0], x.shape()[1]);
    let mut data = Vec::with_capacity(r * cols.len());
    for i in 0..r {
        for &j in cols {
            assert!(j < c);
            data.push(x.data()[i * c + j]);
        }
    }
    Tensor::from_vec(&[r, cols.len()], data)
}

/// Sum along the last axis.
pub fn sum_last(x: &Tensor) -> Tensor {
    let d = *x.shape().last().unwrap();
    let rows = x.len() / d;
    let mut out = vec![0.0f32; rows];
    for r in 0..rows {
        out[r] = x.data()[r * d..(r + 1) * d].iter().sum();
    }
    Tensor::from_vec(&x.shape()[..x.shape().len() - 1], out)
}

/// Frobenius / L2 norm of the whole tensor.
pub fn norm2(x: &Tensor) -> f32 {
    x.data().iter().map(|v| v * v).sum::<f32>().sqrt()
}

/// Argsort (ascending) of a flat slice, stable on ties.
pub fn argsort(xs: &[f32]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..xs.len()).collect();
    order.sort_by(|&i, &j| xs[i].partial_cmp(&xs[j]).unwrap().then(i.cmp(&j)));
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Pcg64;

    fn randt(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal()).collect())
    }

    #[test]
    fn matmul_tn_hand_case() {
        // A=[1,2;3,4], B rows are b0=[1,0], b1=[0,1], b2=[1,1]
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let c = matmul_tn(&a, &b);
        assert_eq!(c.data(), &[1.0, 2.0, 3.0, 3.0, 4.0, 7.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Pcg64::new(2);
        let x = randt(&mut rng, &[5, 7]);
        let s = softmax(&x);
        for r in 0..5 {
            let sum: f32 = s.data()[r * 7..(r + 1) * 7].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(s.data()[r * 7..(r + 1) * 7].iter().all(|&v| v > 0.0));
        }
    }

    #[test]
    fn topk_returns_descending_max() {
        let x = Tensor::from_vec(&[1, 5], vec![0.1, 0.9, -0.3, 0.9, 0.5]);
        let (vals, idx) = topk(&x, 3);
        assert_eq!(vals.data(), &[0.9, 0.9, 0.5]);
        assert_eq!(idx[0], vec![1, 3, 4]); // stable on ties
    }

    #[test]
    fn gather_ops() {
        let x = Tensor::from_vec(&[3, 2], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(gather0(&x, &[2, 0]).data(), &[4., 5., 0., 1.]);
        assert_eq!(gather_cols(&x, &[1]).data(), &[1., 3., 5.]);
    }

    #[test]
    fn rmsnorm_unit_scale_has_unit_rms() {
        let mut rng = Pcg64::new(4);
        let x = randt(&mut rng, &[3, 16]);
        let w = Tensor::ones(&[16]);
        let y = rmsnorm(&x, &w, 1e-6);
        for r in 0..3 {
            let ms: f32 = y.data()[r * 16..(r + 1) * 16]
                .iter().map(|v| v * v).sum::<f32>() / 16.0;
            assert!((ms - 1.0).abs() < 1e-3, "{ms}");
        }
    }

    #[test]
    fn prop_matmul_left_distributive() {
        check("matmul-distributive", 30,
              |g: &mut Gen| {
                  let m = g.usize_in(1, 6);
                  let k = g.usize_in(1, 6);
                  let n = g.usize_in(1, 6);
                  let mut r = Pcg64::new(g.rng.next_u64());
                  (randt(&mut r, &[m, k]), randt(&mut r, &[m, k]),
                   randt(&mut r, &[n, k]))
              },
              |(a, b, c)| {
                  let mut ab = a.clone();
                  add_assign(&mut ab, b);
                  let lhs = matmul_tn(&ab, c);
                  let mut rhs = matmul_tn(a, c);
                  add_assign(&mut rhs, &matmul_tn(b, c));
                  lhs.data().iter().zip(rhs.data())
                      .all(|(x, y)| (x - y).abs() < 1e-3)
              });
    }

    #[test]
    fn prop_argsort_is_sorted_permutation() {
        check("argsort", 50,
              |g: &mut Gen| g.vec_f32(32, -10.0, 10.0),
              |xs| {
                  let ord = argsort(xs);
                  let mut seen = vec![false; xs.len()];
                  for &i in &ord { seen[i] = true; }
                  seen.iter().all(|&b| b)
                      && ord.windows(2).all(|w| xs[w[0]] <= xs[w[1]])
              });
    }
}
