//! Host-side tensors: contiguous row-major f32 / i32 buffers with the ops
//! the L3 pipeline needs (marshalling to PJRT literals, weight surgery,
//! small matmuls for the coordinator's router, reductions for reports).
//!
//! Deliberately minimal — the heavy math lives in the AOT HLO artifacts;
//! this type exists so rust can slice, pack, score and route without a
//! numerics crate.

pub mod gemm;
mod ops;

pub use ops::*;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor::full(shape, 1.0)
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} vs len {}", data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on non-scalar {:?}", self.shape);
        self.data[0]
    }

    /// Row-major flat index of a multi-index.
    pub fn flat(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&x, &s)) in idx.iter().zip(&self.shape).enumerate() {
            assert!(x < s, "index {idx:?} out of bounds {:?} at dim {i}", self.shape);
            off = off * s + x;
        }
        off
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.flat(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let i = self.flat(idx);
        self.data[i] = v;
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        if shape.iter().product::<usize>() != self.data.len() {
            bail!("reshape {:?} -> {shape:?} length mismatch", self.shape);
        }
        Ok(Tensor { shape: shape.to_vec(), data: self.data.clone() })
    }

    /// Sub-tensor along axis 0: rows [lo, hi).
    pub fn slice0(&self, lo: usize, hi: usize) -> Tensor {
        assert!(lo <= hi && hi <= self.shape[0]);
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Tensor { shape, data: self.data[lo * row..hi * row].to_vec() }
    }

    /// Extract index `i` along axis 0 (drops the axis).
    pub fn index0(&self, i: usize) -> Tensor {
        let t = self.slice0(i, i + 1);
        Tensor { shape: self.shape[1..].to_vec(), data: t.data }
    }
}

/// Integer tensor (token ids, routing indices, positions).
#[derive(Clone, Debug, PartialEq)]
pub struct ITensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl ITensor {
    pub fn zeros(shape: &[usize]) -> ITensor {
        let n = shape.iter().product();
        ITensor { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> ITensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        ITensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: i32) -> ITensor {
        ITensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    fn slice0_and_index0() {
        let t = Tensor::from_vec(&[3, 2], (0..6).map(|x| x as f32).collect());
        let s = t.slice0(1, 3);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[2.0, 3.0, 4.0, 5.0]);
        let r = t.index0(2);
        assert_eq!(r.shape(), &[2]);
        assert_eq!(r.data(), &[4.0, 5.0]);
    }

    #[test]
    fn reshape_checks_len() {
        let t = Tensor::zeros(&[4, 4]);
        assert!(t.reshape(&[2, 8]).is_ok());
        assert!(t.reshape(&[3, 5]).is_err());
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_panics() {
        let t = Tensor::zeros(&[2, 2]);
        t.at(&[2, 0]);
    }
}
