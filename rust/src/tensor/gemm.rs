//! Shared GEMM microkernel subsystem for the host backend.
//! (System-level context: `docs/ARCHITECTURE.md` §4; the serving
//! equivalence argument in §3 leans on the per-row independence pinned
//! down here.)
//!
//! Every heavy matmul in the tree — router scores, attention, the expert
//! FFN fan-out, gradient accumulation, `quadform` — reduces to one of
//! three layouts of `C[m,n] = Σ_t A(i,t)·B(t,j)` (see [`Layout`]). This
//! module supplies three interchangeable kernels for all three:
//!
//! * [`naive`] — the historical row-blocked triple loops, kept as the
//!   measured baseline for the bench `kernel` axis.
//! * [`blocked`] — a cache-blocked kernel: `MC×KC×NC` tiling into
//!   L1/L2-sized panels, the strided B panel packed once per `(KC, NC)`
//!   block, and an 8-wide-unrolled [`dot8`] inner kernel whose
//!   `f32::mul_add` accumulators the compiler may (but on a baseline
//!   target need not) vectorize. Correct on every target — the
//!   guaranteed fallback. Known cost of that guarantee: on a CPU with
//!   no FMA hardware at all (pre-2013 x86), `mul_add` is a correct but
//!   slow libm call, so on such hosts `blocked` trades speed for the
//!   accumulation contract; `HEAPR_KERNEL=naive` is the faster
//!   non-contract escape hatch there.
//! * [`simd`] — the same cache-blocked driver on an explicit
//!   `core::arch::x86_64` f32x8 microkernel (`_mm256_fmadd_ps`,
//!   register-tiled two A rows × four packed B columns), selected only
//!   after **runtime** CPU feature detection
//!   (`is_x86_feature_detected!("avx2")` + `("fma")`). On every other
//!   CPU or architecture it *is* [`blocked`] — no compile-time
//!   `target-cpu` assumption, no SIGILL on older hosts.
//!
//! [`gemm`] dispatches on the process-wide kernel selection
//! (`HEAPR_KERNEL=naive|blocked|simd`; the default is
//! [`default_kernel`]: `simd` where detected, else `blocked`).
//! [`set_kernel`] is the programmatic override the benches sweep. The
//! first resolution logs the tier the CPU resolved to.
//!
//! # Accumulation contract
//!
//! The blocked and simd kernels and the [`reference`] mirror compute
//! every output element by the exact same arithmetic, independent of
//! packing, tile sizes over `m`/`n`, and thread count:
//!
//! 1. the reduction axis is split into `KC`-sized blocks, in order;
//! 2. within a block, eight interleaved fused-multiply-add accumulators
//!    (lane `l` takes elements `8u + l`; a remainder of `r` elements
//!    lands on lanes `0..r`), reduced pairwise —
//!    `((a0+a1)+(a2+a3)) + ((a4+a5)+(a6+a7))`;
//! 3. block results are added into the output in block order.
//!
//! The contract was designed so that one f32x8 vector register *is* the
//! eight lanes: `_mm256_fmadd_ps` performs per lane the same exactly
//! rounded fused multiply-add that `f32::mul_add` performs, so `simd` is
//! bitwise identical to `reference` (and to `blocked`) everywhere, and
//! all contract kernels are bitwise thread-count invariant: parallelism
//! only splits `m` into row-disjoint blocks ([`pool::row_block`],
//! shrinking below [`MC`] for small `m` so decode-shaped GEMMs still fan
//! out) over [`pool`], and row blocking never enters the contract.
//!
//! # Non-finite inputs
//!
//! No kernel skips zero operands: `0.0 · NaN` and `0.0 · ∞` contribute
//! NaN, identically in all three layouts (the historical `matmul_at`
//! zero-skip shortcut silently dropped them; that shortcut is gone, and
//! the shared policy is pinned by tests, bit-for-bit across kernels for
//! canonical NaN payloads, denormals included).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use crate::util::pool;
use crate::util::pool::RowsPtr;

/// Row-block height cap: C/A rows per parallel work item (L2-sized A
/// slab); [`pool::row_block`] shrinks below it for small `m`.
pub const MC: usize = 64;
/// Reduction-axis block: one `KC` slice of an A row (1 KiB) stays in L1
/// while the packed B panel streams against it.
pub const KC: usize = 256;
/// Column-panel width: `KC × NC` packed B panel = 64 KiB, L2-resident.
pub const NC: usize = 64;

/// Below this many scalar multiply-adds a kernel stays on the caller
/// thread — pool dispatch would cost more than it saves. (Shared with the
/// row-wise ops in `tensor::ops`.)
pub(crate) const PAR_MIN_WORK: usize = 1 << 14;

/// Operand layouts, named after the historical `tensor::ops` entry points.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// `C[m,n] = A[m,k] · B[n,k]ᵀ` — `A(i,t) = a[i·k+t]`, `B(t,j) = b[j·k+t]`.
    TN,
    /// `C[m,n] = A[m,k] · B[k,n]` — `A(i,t) = a[i·k+t]`, `B(t,j) = b[t·n+j]`.
    NN,
    /// `C[m,n] = A[k,m]ᵀ · B[k,n]` — `A(i,t) = a[t·m+i]`, `B(t,j) = b[t·n+j]`
    /// (the gradient-accumulation shape; `k` is the historical `p`).
    AT,
}

/// Kernel selection for the dispatching [`gemm`] entry point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Historical row-blocked triple loops (bench baseline).
    Naive = 0,
    /// Cache-blocked + packed + 8-lane `mul_add` microkernel; the
    /// guaranteed fallback on every target.
    Blocked = 1,
    /// Cache-blocked driver on the explicit f32x8 avx2+fma microkernel;
    /// requires runtime detection and degrades to `Blocked` without it.
    Simd = 2,
}

impl Kernel {
    /// Parse a `HEAPR_KERNEL` / `--kernel` value (case/space tolerant).
    pub fn parse(s: &str) -> Option<Kernel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "naive" => Some(Kernel::Naive),
            "blocked" => Some(Kernel::Blocked),
            "simd" => Some(Kernel::Simd),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Kernel::Naive => "naive",
            Kernel::Blocked => "blocked",
            Kernel::Simd => "simd",
        }
    }
}

/// True when this CPU supports the [`simd`] kernel: x86-64 with avx2 and
/// fma, detected at **runtime** — never a compile-time `target-cpu`
/// assumption. Cached after the first probe.
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static AVAIL: OnceLock<bool> = OnceLock::new();
        *AVAIL.get_or_init(|| {
            is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
        })
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The kernel tier this CPU resolves to absent any `HEAPR_KERNEL` /
/// [`set_kernel`] override: `simd` where detection finds avx2+fma,
/// `blocked` everywhere else.
pub fn default_kernel() -> Kernel {
    if simd_available() {
        Kernel::Simd
    } else {
        Kernel::Blocked
    }
}

static KERNEL_CELL: OnceLock<AtomicU8> = OnceLock::new();

/// The selection cell, lazily initialized from `HEAPR_KERNEL` (with
/// warnings for values that cannot apply). [`set_kernel`] bypasses this
/// resolution on purpose — see there.
fn kernel_cell() -> &'static AtomicU8 {
    KERNEL_CELL.get_or_init(|| {
        let auto = default_kernel();
        let k = match std::env::var("HEAPR_KERNEL") {
            Ok(v) => match Kernel::parse(&v) {
                Some(Kernel::Simd) if !simd_available() => {
                    crate::warn!(
                        "HEAPR_KERNEL=simd but this CPU lacks avx2+fma; using blocked"
                    );
                    Kernel::Blocked
                }
                Some(k) => k,
                None => {
                    crate::warn!(
                        "HEAPR_KERNEL={v:?} is not naive|blocked|simd; using {}",
                        auto.name()
                    );
                    auto
                }
            },
            Err(_) => auto,
        };
        AtomicU8::new(k as u8)
    })
}

/// Current process-wide kernel selection. The first call emits the
/// startup log line reporting the tier that will *actually* execute —
/// deliberately here rather than in the env resolution, so a
/// `set_kernel` override applied before first use (the `--kernel` flag)
/// can never leave a stale tier in the logs.
pub fn kernel() -> Kernel {
    let k = match kernel_cell().load(Ordering::Relaxed) {
        0 => Kernel::Naive,
        1 => Kernel::Blocked,
        _ => Kernel::Simd,
    };
    static STARTUP_LOG: std::sync::Once = std::sync::Once::new();
    STARTUP_LOG.call_once(|| {
        crate::info!(
            "gemm kernel tier: {} (runtime detection: avx2+fma {})",
            k.name(),
            if simd_available() { "present" } else { "absent" }
        );
    });
    k
}

/// Swap the process-wide kernel (the `--kernel` flag and the benches'
/// `kernel` axis; library code never calls this). Selecting `Simd` on a
/// CPU without avx2+fma is safe: every `Simd` entry point re-checks
/// detection and degrades to the blocked kernel. If the cell is not yet
/// initialized this seeds it with the override directly instead of
/// running the `HEAPR_KERNEL` resolution first — an overridden env value
/// must not emit warnings about a tier that will never run. Tests that
/// call this must hold [`pool::test_serial_lock`].
pub fn set_kernel(k: Kernel) {
    KERNEL_CELL.get_or_init(|| AtomicU8::new(k as u8)).store(k as u8, Ordering::Relaxed);
}

/// `C[m,n] = op_A(A) · op_B(B)` per `layout`, into `out` (overwritten),
/// with the process-selected kernel.
pub fn gemm(layout: Layout, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    match kernel() {
        Kernel::Naive => naive(layout, a, b, out, m, k, n),
        Kernel::Blocked => blocked(layout, a, b, out, m, k, n),
        Kernel::Simd => simd(layout, a, b, out, m, k, n),
    }
}

// ------------------------------------------------------------ microkernel

/// The contract's pairwise lane reduction —
/// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` — shared by every kernel tier
/// (the avx2 tier spills its register to lanes and reduces through this
/// same function, so the reduce cannot drift between tiers).
#[inline]
fn reduce8(acc: &[f32; 8]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// The inner kernel of the accumulation contract: eight interleaved
/// `mul_add` lanes over two equal-length contiguous slices, reduced
/// pairwise. Remainder elements (len % 8) land on lanes `0..r`.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xs, ys) in (&mut ca).zip(&mut cb) {
        for l in 0..8 {
            acc[l] = xs[l].mul_add(ys[l], acc[l]);
        }
    }
    for (l, (x, y)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
        acc[l] = x.mul_add(*y, acc[l]);
    }
    reduce8(&acc)
}

/// Kernel-dispatched dot product for non-GEMM call sites (the host
/// backend's decode-attention score loop): the contract [`dot`] under
/// `Blocked`, its intrinsics twin under `Simd`, and the historical
/// single-accumulator serial sum under `Naive` — so the bench `kernel`
/// axis compares the true pre-blocked arithmetic end to end, not a
/// hybrid.
#[inline]
pub fn dot_k(a: &[f32], b: &[f32]) -> f32 {
    match kernel() {
        Kernel::Naive => a.iter().zip(b).map(|(x, y)| x * y).sum(),
        Kernel::Blocked => dot(a, b),
        Kernel::Simd => dot_simd(a, b),
    }
}

/// Contract dot product over arbitrary length: `KC`-sized blocks, each
/// reduced by [`dot8`], summed in block order — exactly the per-element
/// accumulation every contract GEMM here performs.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut c = 0.0f32;
    let mut pc = 0;
    while pc < a.len() {
        let kc = KC.min(a.len() - pc);
        c += dot8(&a[pc..pc + kc], &b[pc..pc + kc]);
        pc += kc;
    }
    c
}

/// [`dot`] on the avx2 f32x8 microkernel: identical `KC` blocking, lane
/// assignment and reduction, so it is bitwise equal to [`dot`] on every
/// input. Falls back to [`dot`] itself when the CPU lacks avx2+fma.
#[inline]
pub fn dot_simd(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: avx2+fma presence was just checked at runtime.
        return unsafe { avx2::dot(a, b) };
    }
    dot(a, b)
}

// --------------------------------------------------------------- blocked

/// Micro-kernel tier for the shared cache-blocked [`driver`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Micro {
    /// [`dot8`] scalar lanes — compiles on (and is correct for) every
    /// target.
    Scalar,
    /// Explicit f32x8 intrinsics — only constructed behind
    /// [`simd_available`].
    #[cfg(target_arch = "x86_64")]
    Avx2,
}

/// Gather the `(pc, jc)` panel of `op_B` into `packb`: `nc` contiguous
/// columns of length `kc`, so the microkernel streams both operands.
/// Only the `[k, n]`-layout operands (NN/AT) need the transposing copy;
/// TN's B rows are already contract-shaped slices and skip packing.
fn pack_b(b: &[f32], packb: &mut [f32], pc: usize, kc: usize, jc: usize, nc: usize, n: usize) {
    for j in 0..nc {
        let dst = &mut packb[j * kc..(j + 1) * kc];
        for (t, d) in dst.iter_mut().enumerate() {
            *d = b[(pc + t) * n + jc + j];
        }
    }
}

/// Transpose the full `kc`-deep A slab of the AT layout (`A(i,t) =
/// a[t·m+i]`) into row-major `packa[i·kc+t]`, once per `pc` block, so the
/// microkernel sees contiguous rows for every column panel and row block.
fn pack_a_slab(a: &[f32], packa: &mut [f32], pc: usize, kc: usize, m: usize) {
    for t in 0..kc {
        let arow = &a[(pc + t) * m..(pc + t) * m + m];
        for (i, &v) in arow.iter().enumerate() {
            packa[i * kc + t] = v;
        }
    }
}

/// One row-block × `NC` output tile for the current `(pc, jc)` block:
/// `out_rows` is the caller's row range `[i0, i0+ic)` (full `n`-wide
/// rows); only columns `[jc, jc+nc)` are touched. `packa` is the
/// AT-layout slab from [`pack_a_slab`] (empty for TN/NN, whose A rows
/// are already contiguous along the reduction axis).
#[allow(clippy::too_many_arguments)]
fn mc_block(
    layout: Layout,
    a: &[f32],
    packa: &[f32],
    packb: &[f32],
    b: &[f32],
    out_rows: &mut [f32],
    i0: usize,
    ic: usize,
    pc: usize,
    kc: usize,
    jc: usize,
    nc: usize,
    k: usize,
    n: usize,
) {
    for i in 0..ic {
        let arow: &[f32] = match layout {
            Layout::AT => &packa[(i0 + i) * kc..(i0 + i + 1) * kc],
            _ => &a[(i0 + i) * k + pc..(i0 + i) * k + pc + kc],
        };
        let orow = &mut out_rows[i * n + jc..i * n + jc + nc];
        for (j, o) in orow.iter_mut().enumerate() {
            let bcol: &[f32] = match layout {
                Layout::TN => &b[(jc + j) * k + pc..(jc + j) * k + pc + kc],
                _ => &packb[j * kc..(j + 1) * kc],
            };
            *o += dot8(arow, bcol);
        }
    }
}

/// Run one row-block on the selected micro-kernel tier. The avx2 arm is
/// the only unsafe call in the driver.
#[allow(clippy::too_many_arguments)]
fn run_block(
    micro: Micro,
    layout: Layout,
    a: &[f32],
    packa: &[f32],
    packb: &[f32],
    b: &[f32],
    out_rows: &mut [f32],
    i0: usize,
    ic: usize,
    pc: usize,
    kc: usize,
    jc: usize,
    nc: usize,
    k: usize,
    n: usize,
) {
    match micro {
        Micro::Scalar => {
            mc_block(layout, a, packa, packb, b, out_rows, i0, ic, pc, kc, jc, nc, k, n)
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Micro::Avx2 is only constructed behind simd_available().
        Micro::Avx2 => unsafe {
            avx2::mc_block(layout, a, packa, packb, b, out_rows, i0, ic, pc, kc, jc, nc, k, n)
        },
    }
}

/// The shared cache-blocked GEMM driver (see the module docs for the
/// tiling and the accumulation contract). Row-blocks fan out over the
/// pool when the work is large enough; results are bitwise identical to
/// [`reference`] for every micro-kernel tier and thread count.
#[allow(clippy::too_many_arguments)]
fn driver(
    micro: Micro,
    layout: Layout,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // Row blocks are the parallel work items; pool::row_block keeps them
    // L2-friendly (<= MC rows) but shrinks them — down to single rows —
    // for small m, so decode-shaped GEMMs (m = batch) still fan out.
    // Row/column blocking never affects the accumulation contract; only
    // KC does.
    let threads = pool::threads();
    let rb = pool::row_block(m, MC, threads);
    let rblocks = m.div_ceil(rb);
    let parallel = m * n * k >= PAR_MIN_WORK && rblocks > 1 && threads > 1;
    // TN's B rows double as the packed panel; NN/AT gather one. AT also
    // transposes its column-strided A into a full slab, once per KC block
    // (it depends only on pc, hence the pc-outer loop order — per-element
    // accumulation is over pc in ascending order either way, so the
    // contract is untouched).
    let mut packb = match layout {
        Layout::TN => Vec::new(),
        // lint:allow(hot-path-alloc) one pack panel per GEMM call, reused across every (pc, jc) tile; sized by cache blocking, not by the matrix
        _ => vec![0.0f32; KC.min(k) * NC.min(n)],
    };
    let mut packa = match layout {
        // lint:allow(hot-path-alloc) one transposed A slab per GEMM call, repacked once per KC block and reused across its column tiles
        Layout::AT => vec![0.0f32; m * KC.min(k)],
        _ => Vec::new(),
    };
    for pc in (0..k).step_by(KC) {
        let kc = KC.min(k - pc);
        if layout == Layout::AT {
            pack_a_slab(a, &mut packa, pc, kc, m);
        }
        let pa = &packa[..];
        for jc in (0..n).step_by(NC) {
            let nc = NC.min(n - jc);
            if layout != Layout::TN {
                pack_b(b, &mut packb, pc, kc, jc, nc, n);
            }
            let pb = &packb[..];
            // One fork-join per (pc, jc) tile, with the B panel packed
            // serially between joins: for this tree's shapes (n, k up to
            // ~1k) that is tens of dispatches against >=1 ms of tile
            // compute — <1% overhead, in exchange for packing each panel
            // exactly once. Revisit (per-lane panels, row-major outer
            // loop) if shapes ever grow past that.
            if parallel {
                let ptr = RowsPtr::new(out);
                pool::par_for(rblocks, |ib| {
                    let i0 = ib * rb;
                    let ic = rb.min(m - i0);
                    // SAFETY: row blocks are disjoint across lanes and the
                    // buffer outlives the par_for (RowsPtr contract).
                    let rows = unsafe { ptr.slice(i0 * n, ic * n) };
                    run_block(micro, layout, a, pa, pb, b, rows, i0, ic, pc, kc, jc, nc, k, n);
                });
            } else {
                for ib in 0..rblocks {
                    let i0 = ib * rb;
                    let ic = rb.min(m - i0);
                    let rows = &mut out[i0 * n..(i0 + ic) * n];
                    run_block(micro, layout, a, pa, pb, b, rows, i0, ic, pc, kc, jc, nc, k, n);
                }
            }
        }
    }
}

/// Cache-blocked GEMM on the scalar-lane microkernel — the guaranteed
/// fallback tier: compiles and runs correctly on a baseline build of any
/// target. Bitwise identical to [`reference`] for every thread count.
pub fn blocked(layout: Layout, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    driver(Micro::Scalar, layout, a, b, out, m, k, n);
}

/// Cache-blocked GEMM on the explicit f32x8 avx2+fma microkernel when
/// runtime detection finds the features, else exactly [`blocked`] — the
/// guaranteed fallback that keeps a baseline x86-64 (or non-x86) binary
/// correct without `-C target-cpu=native`. Bitwise identical to
/// [`reference`] (and [`blocked`]) on every input, shape and thread
/// count.
pub fn simd(layout: Layout, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        return driver(Micro::Avx2, layout, a, b, out, m, k, n);
    }
    driver(Micro::Scalar, layout, a, b, out, m, k, n);
}

// ------------------------------------------------------------------ avx2
//
// The `simd` tier. One _mm256 register IS the contract's eight
// interleaved lanes: `_mm256_fmadd_ps` performs, per lane, the same
// exactly rounded fused multiply-add over elements `8u + l` that dot8's
// scalar `mul_add` lanes perform; the kc % 8 tail is finished with
// scalar `mul_add` on lanes 0..r after spilling the register (compiled
// to an inline vfmadd here — `fma` is enabled on these functions); and
// the reduction is the shared `reduce8`. Bitwise identity with
// `reference` is therefore structural, not approximate, and the
// property tests pin it. (NaN *payloads* beyond the canonical quiet NaN
// are the one soft spot — both tiers run on x86 FMA hardware whenever
// this module is reachable, so payloads agree in practice and the
// non-finite tests assert them for canonical inputs.)
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{reduce8, Layout, KC};
    use core::arch::x86_64::{
        _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_setzero_ps, _mm256_storeu_ps,
    };

    /// [`super::dot8`] on f32x8 registers: same lanes, same tail, same
    /// reduction.
    ///
    /// # Safety
    /// Requires avx2 + fma (callers check [`super::simd_available`]).
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn dot8(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        // Bound every access by the shorter slice: a caller's length
        // mismatch is a contract violation (caught by the debug_assert),
        // but it must degrade to a wrong *value* — like the scalar
        // tier's truncating zip — never to an out-of-bounds read in a
        // release build. Equal lengths (every in-tree caller) are
        // untouched.
        let len = a.len().min(b.len());
        let chunks = len / 8;
        let mut acc = _mm256_setzero_ps();
        for u in 0..chunks {
            let av = _mm256_loadu_ps(a.as_ptr().add(8 * u));
            let bv = _mm256_loadu_ps(b.as_ptr().add(8 * u));
            acc = _mm256_fmadd_ps(av, bv, acc);
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for (l, t) in (chunks * 8..len).enumerate() {
            lanes[l] = a[t].mul_add(b[t], lanes[l]);
        }
        reduce8(&lanes)
    }

    /// [`super::dot`] (the KC-blocked contract dot) on [`dot8`].
    ///
    /// # Safety
    /// Requires avx2 + fma (callers check [`super::simd_available`]).
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut c = 0.0f32;
        let mut pc = 0;
        while pc < a.len() {
            let kc = KC.min(a.len() - pc);
            c += dot8(&a[pc..pc + kc], &b[pc..pc + kc]);
            pc += kc;
        }
        c
    }

    /// Register tile: two A rows × eight f32 lanes per accumulator (one
    /// ymm register — the ROADMAP's "2×8" register tile), unrolled over
    /// four packed B columns, so the eight outputs own eight independent
    /// FMA chains — enough to cover the ~4-cycle FMA latency on two
    /// issue ports — while each B load is shared by two rows and each A
    /// load by four columns. Per-element arithmetic is exactly
    /// [`dot8`]'s.
    ///
    /// # Safety
    /// Requires avx2 + fma; all four B slices and both A slices must
    /// share one length.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn tile_2x4(a0: &[f32], a1: &[f32], b: [&[f32]; 4]) -> [[f32; 4]; 2] {
        let kc = a0.len();
        let chunks = kc / 8;
        let mut acc = [[_mm256_setzero_ps(); 4]; 2];
        for u in 0..chunks {
            let off = 8 * u;
            let av0 = _mm256_loadu_ps(a0.as_ptr().add(off));
            let av1 = _mm256_loadu_ps(a1.as_ptr().add(off));
            for (j, bj) in b.iter().enumerate() {
                let bv = _mm256_loadu_ps(bj.as_ptr().add(off));
                acc[0][j] = _mm256_fmadd_ps(av0, bv, acc[0][j]);
                acc[1][j] = _mm256_fmadd_ps(av1, bv, acc[1][j]);
            }
        }
        let mut out = [[0.0f32; 4]; 2];
        for (r, arow) in [a0, a1].into_iter().enumerate() {
            for (j, bj) in b.iter().enumerate() {
                let mut lanes = [0.0f32; 8];
                _mm256_storeu_ps(lanes.as_mut_ptr(), acc[r][j]);
                for (l, t) in (chunks * 8..kc).enumerate() {
                    lanes[l] = arow[t].mul_add(bj[t], lanes[l]);
                }
                out[r][j] = reduce8(&lanes);
            }
        }
        out
    }

    /// The avx2 mirror of [`super::mc_block`]: identical row/column
    /// ranges and per-element arithmetic, with 2×4 register tiles in the
    /// interior and [`dot8`] singles on the ragged edges (nc % 4 columns,
    /// the odd last row).
    ///
    /// # Safety
    /// Requires avx2 + fma (callers check [`super::simd_available`]).
    #[target_feature(enable = "avx2,fma")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn mc_block(
        layout: Layout,
        a: &[f32],
        packa: &[f32],
        packb: &[f32],
        b: &[f32],
        out_rows: &mut [f32],
        i0: usize,
        ic: usize,
        pc: usize,
        kc: usize,
        jc: usize,
        nc: usize,
        k: usize,
        n: usize,
    ) {
        let arow = |i: usize| -> &[f32] {
            match layout {
                Layout::AT => &packa[(i0 + i) * kc..(i0 + i + 1) * kc],
                _ => &a[(i0 + i) * k + pc..(i0 + i) * k + pc + kc],
            }
        };
        let bcol = |j: usize| -> &[f32] {
            match layout {
                Layout::TN => &b[(jc + j) * k + pc..(jc + j) * k + pc + kc],
                _ => &packb[j * kc..(j + 1) * kc],
            }
        };
        let mut i = 0;
        while i + 2 <= ic {
            let (a0, a1) = (arow(i), arow(i + 1));
            let mut j = 0;
            while j + 4 <= nc {
                let tile = tile_2x4(a0, a1, [bcol(j), bcol(j + 1), bcol(j + 2), bcol(j + 3)]);
                for (r, row) in tile.iter().enumerate() {
                    for (jj, v) in row.iter().enumerate() {
                        out_rows[(i + r) * n + jc + j + jj] += v;
                    }
                }
                j += 4;
            }
            while j < nc {
                let bc = bcol(j);
                out_rows[i * n + jc + j] += dot8(a0, bc);
                out_rows[(i + 1) * n + jc + j] += dot8(a1, bc);
                j += 1;
            }
            i += 2;
        }
        if i < ic {
            let a0 = arow(i);
            for j in 0..nc {
                out_rows[i * n + jc + j] += dot8(a0, bcol(j));
            }
        }
    }
}

// ------------------------------------------------------------- reference

/// Naive mirror of the accumulation contract: plain loops, no packing,
/// no tiling over `m`/`n`, no parallelism — but the identical per-element
/// reduction ([`dot`]). The bitwise ground truth the property tests hold
/// [`blocked`] and [`simd`] to, across every shape and thread count.
pub fn reference(
    layout: Layout,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    let mut arowbuf = vec![0.0f32; k];
    let mut bcolbuf = vec![0.0f32; k];
    for i in 0..m {
        let arow: &[f32] = match layout {
            Layout::AT => {
                for (t, v) in arowbuf.iter_mut().enumerate() {
                    *v = a[t * m + i];
                }
                &arowbuf
            }
            _ => &a[i * k..(i + 1) * k],
        };
        for j in 0..n {
            let bcol: &[f32] = match layout {
                Layout::TN => &b[j * k..(j + 1) * k],
                _ => {
                    for (t, v) in bcolbuf.iter_mut().enumerate() {
                        *v = b[t * n + j];
                    }
                    &bcolbuf
                }
            };
            out[i * n + j] = dot(arow, bcol);
        }
    }
}

// ----------------------------------------------------------------- naive

/// Fill `rows` disjoint rows of `out` (each `len` wide) with `f(i, row_i)`,
/// in parallel when `work` (scalar ops) crosses [`PAR_MIN_WORK`]. The single
/// audited unsafe site behind the naive GEMMs and the row-wise tensor ops.
pub(crate) fn par_rows<F: Fn(usize, &mut [f32]) + Sync>(
    out: &mut [f32],
    rows: usize,
    len: usize,
    work: usize,
    f: F,
) {
    debug_assert_eq!(out.len(), rows * len);
    if work < PAR_MIN_WORK {
        for i in 0..rows {
            f(i, &mut out[i * len..(i + 1) * len]);
        }
    } else {
        let ptr = RowsPtr::new(out);
        // SAFETY: lane i writes only its own row — the ranges
        // [i*len, (i+1)*len) are disjoint across lanes and in bounds
        // (out.len() == rows * len), and `out` outlives the par_for.
        pool::par_for(rows, |i| f(i, unsafe { ptr.slice(i * len, len) }));
    }
}

/// The historical kernels: row-parallel triple loops with a single
/// serial accumulator (TN) or a broadcast row update (NN/AT). Kept as
/// the bench baseline the contract kernels' speedup is measured against.
pub fn naive(layout: Layout, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let fill_row = |i: usize, crow: &mut [f32]| match layout {
        Layout::TN => {
            let arow = &a[i * k..(i + 1) * k];
            for (j, c) in crow.iter_mut().enumerate() {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (x, y) in arow.iter().zip(brow) {
                    acc += x * y;
                }
                *c = acc;
            }
        }
        Layout::NN => {
            let arow = &a[i * k..(i + 1) * k];
            for (t, &av) in arow.iter().enumerate() {
                let brow = &b[t * n..(t + 1) * n];
                for (c, &bv) in crow.iter_mut().zip(brow) {
                    *c += av * bv;
                }
            }
        }
        Layout::AT => {
            for t in 0..k {
                let av = a[t * m + i];
                let brow = &b[t * n..(t + 1) * n];
                for (c, &bv) in crow.iter_mut().zip(brow) {
                    *c += av * bv;
                }
            }
        }
    };
    par_rows(out, m, n, m * n * k, fill_row);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Pcg64;

    fn randv(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    const LAYOUTS: [Layout; 3] = [Layout::TN, Layout::NN, Layout::AT];

    type KernelFn = fn(Layout, &[f32], &[f32], &mut [f32], usize, usize, usize);
    /// The two contract kernels the bitwise claims cover. On hosts
    /// without avx2+fma `simd` degrades to `blocked`, so the pair stays
    /// meaningful (if redundant) everywhere — and CI additionally runs
    /// the whole suite under each HEAPR_KERNEL value.
    const CONTRACT_KERNELS: [(KernelFn, &str); 2] = [(blocked, "blocked"), (simd, "simd")];

    #[test]
    fn dot8_matches_exact_integer_sum() {
        // integer values < 2^24: every order of summation is exact, so
        // dot8 must equal the plain sum bitwise
        let a: Vec<f32> = (1..=21).map(|x| x as f32).collect();
        let b: Vec<f32> = (1..=21).map(|x| (x % 5) as f32).collect();
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot8(&a, &b), want);
        assert_eq!(dot(&a, &b), want);
        assert_eq!(dot_simd(&a, &b), want);
        assert_eq!(dot8(&[], &[]), 0.0);
        assert_eq!(dot_simd(&[], &[]), 0.0);
    }

    #[test]
    fn dot_tiers_are_bitwise_identical() {
        // lengths straddling the 8-lane chunks and the KC block boundary
        let mut rng = Pcg64::new(5);
        for len in [0usize, 1, 7, 8, 9, 63, 255, 256, 257, 515] {
            let a = randv(&mut rng, len);
            let b = randv(&mut rng, len);
            assert_eq!(
                dot_simd(&a, &b).to_bits(),
                dot(&a, &b).to_bits(),
                "dot tiers diverged at len {len}"
            );
        }
    }

    #[test]
    fn hand_cases_exact_in_every_contract_kernel() {
        // small integers: every kernel is exact
        for (kfn, name) in CONTRACT_KERNELS {
            let a = vec![1.0, 2.0, 3.0, 4.0]; // [2,2]
            let b = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]; // [3,2] rows
            let mut out = vec![0.0f32; 6];
            kfn(Layout::TN, &a, &b, &mut out, 2, 2, 3);
            assert_eq!(out, vec![1.0, 2.0, 3.0, 3.0, 4.0, 7.0], "{name}");
            let bb = vec![5.0, 6.0, 7.0, 8.0]; // [2,2]
            let mut out = vec![0.0f32; 4];
            kfn(Layout::NN, &a, &bb, &mut out, 2, 2, 2);
            assert_eq!(out, vec![19.0, 22.0, 43.0, 50.0], "{name}");
            let mut out = vec![0.0f32; 4];
            kfn(Layout::AT, &a, &bb, &mut out, 2, 2, 2);
            assert_eq!(out, vec![26.0, 30.0, 38.0, 44.0], "{name}");
        }
    }

    #[test]
    fn prop_contract_kernels_match_reference_bitwise() {
        // ragged shapes straddling MC/NC (64) and KC (256) boundaries —
        // and the simd tile edges (m % 2, n % 4)
        check(
            "gemm-contract-vs-reference",
            24,
            |g: &mut Gen| {
                let m = g.usize_in(1, 66);
                let n = g.usize_in(1, 66);
                let k = if g.usize_in(0, 4) == 0 {
                    254 + g.usize_in(0, 5) // cross the KC block boundary
                } else {
                    g.usize_in(1, 40)
                };
                let mut rng = Pcg64::new(g.rng.next_u64());
                (m, k, n, randv(&mut rng, m * k), randv(&mut rng, n * k))
            },
            |(m, k, n, a, b)| {
                for layout in LAYOUTS {
                    let mut want = vec![0.0f32; m * n];
                    reference(layout, a, b, &mut want, *m, *k, *n);
                    for (kfn, _name) in CONTRACT_KERNELS {
                        let mut got = vec![0.0f32; m * n];
                        kfn(layout, a, b, &mut got, *m, *k, *n);
                        if got != want {
                            return false;
                        }
                    }
                }
                true
            },
        );
    }

    #[test]
    fn prop_contract_kernels_match_naive_within_tolerance() {
        check(
            "gemm-contract-vs-naive",
            20,
            |g: &mut Gen| {
                let m = g.usize_in(1, 32);
                let k = g.usize_in(1, 48);
                let n = g.usize_in(1, 32);
                let mut rng = Pcg64::new(g.rng.next_u64());
                (m, k, n, randv(&mut rng, m * k), randv(&mut rng, n * k))
            },
            |(m, k, n, a, b)| {
                for layout in LAYOUTS {
                    let mut y = vec![0.0f32; m * n];
                    naive(layout, a, b, &mut y, *m, *k, *n);
                    for (kfn, _name) in CONTRACT_KERNELS {
                        let mut x = vec![0.0f32; m * n];
                        kfn(layout, a, b, &mut x, *m, *k, *n);
                        let ok = x.iter().zip(&y).all(|(p, q)| {
                            (p - q).abs() <= 1e-4 * p.abs().max(q.abs()).max(1.0)
                        });
                        if !ok {
                            return false;
                        }
                    }
                }
                true
            },
        );
    }

    #[test]
    fn contract_kernels_are_bitwise_thread_count_invariant() {
        let _guard = pool::test_serial_lock();
        // drop-guard: an unwinding assert must not leak a resized pool
        struct Restore;
        impl Drop for Restore {
            fn drop(&mut self) {
                pool::set_threads(pool::default_threads());
            }
        }
        let _restore = Restore;
        let mut rng = Pcg64::new(9);
        // big enough that the row blocks really fan out (mblocks > 1,
        // work >> PAR_MIN_WORK)
        let (m, k, n) = (130, 96, 70);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, n * k);
        for (kfn, name) in CONTRACT_KERNELS {
            for layout in LAYOUTS {
                let mut want = vec![0.0f32; m * n];
                pool::set_threads(1);
                kfn(layout, &a, &b, &mut want, m, k, n);
                for threads in [2usize, 4, 8] {
                    pool::set_threads(threads);
                    let mut got = vec![0.0f32; m * n];
                    kfn(layout, &a, &b, &mut got, m, k, n);
                    assert_eq!(got, want, "{name}/{layout:?} diverged at {threads} threads");
                }
                let mut reference_out = vec![0.0f32; m * n];
                reference(layout, &a, &b, &mut reference_out, m, k, n);
                assert_eq!(want, reference_out, "{name}/{layout:?} diverged from reference");
            }
        }
        // _restore resets the pool on drop
    }

    #[test]
    fn nested_contract_gemm_matches_toplevel() {
        // a gemm issued from inside a pool worker (the attention / expert
        // fan-out pattern) takes the caller-helps path; results must be
        // bitwise identical to the top-level call
        let mut rng = Pcg64::new(12);
        let (m, k, n) = (128, 64, 64); // mblocks = 2, work >> PAR_MIN_WORK
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, n * k);
        for (kfn, name) in CONTRACT_KERNELS {
            let mut want = vec![0.0f32; m * n];
            kfn(Layout::TN, &a, &b, &mut want, m, k, n);
            pool::par_for(4, |_| {
                let mut got = vec![0.0f32; m * n];
                kfn(Layout::TN, &a, &b, &mut got, m, k, n);
                assert_eq!(got, want, "nested {name} gemm diverged");
            });
        }
    }

    #[test]
    fn zero_times_nonfinite_contributes_nan_in_every_layout() {
        // the shared no-skip contract: a zero operand does not silence a
        // NaN/inf partner (regression for the old matmul_at shortcut)
        for layout in LAYOUTS {
            let a = vec![0.0f32; 4]; // [2,2] of zeros
            let b = vec![f32::NAN, 1.0, 2.0, 3.0]; // [2,2], NaN at (0,0)
            for kernel in [naive as KernelFn, blocked as KernelFn, simd as KernelFn] {
                let mut out = vec![0.0f32; 4];
                kernel(layout, &a, &b, &mut out, 2, 2, 2);
                assert!(
                    out.iter().any(|v| v.is_nan()),
                    "{layout:?}: 0·NaN must propagate, got {out:?}"
                );
            }
        }
    }

    #[test]
    fn nonfinite_and_denormal_inputs_bitwise_match_reference() {
        // The simd kernel's non-finite policy, pinned bit-for-bit: 0·NaN
        // and 0·∞ products (canonical payloads), ±inf operands, negative
        // zeros, and denormal operands (no FTZ/DAZ assumption), with k
        // crossing the KC boundary so both full f32x8 chunks and the
        // scalar tail run. Outputs are compared via to_bits against the
        // contract reference in all three layouts, for both contract
        // kernels. (Exotic NaN payloads are out of scope: all inputs use
        // the canonical quiet NaN, which every tier propagates
        // identically.)
        let mut rng = Pcg64::new(21);
        let (m, k, n) = (5, 259, 6);
        let mut a = randv(&mut rng, m * k);
        let mut b = randv(&mut rng, n * k);
        let denorm = f32::from_bits(1); // smallest positive subnormal
        for t in 0..k {
            match t % 7 {
                0 => a[t] = 0.0,
                1 => a[t] = denorm,
                2 => a[t] = -0.0,
                3 => a[t] = f32::MIN_POSITIVE / 2.0,
                _ => {}
            }
            match t % 5 {
                0 => b[t] = f32::NAN,
                1 => b[t] = f32::INFINITY,
                2 => b[t] = f32::NEG_INFINITY,
                3 => b[t] = -denorm,
                _ => {}
            }
        }
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        for layout in LAYOUTS {
            let mut want = vec![0.0f32; m * n];
            reference(layout, &a, &b, &mut want, m, k, n);
            assert!(
                want.iter().any(|v| v.is_nan()),
                "{layout:?}: the fixture must actually exercise NaN outputs"
            );
            for (kfn, name) in CONTRACT_KERNELS {
                let mut got = vec![0.0f32; m * n];
                kfn(layout, &a, &b, &mut got, m, k, n);
                assert_eq!(bits(&got), bits(&want), "{name}/{layout:?} non-finite policy");
            }
        }
    }

    #[test]
    fn forced_fallback_dispatch_matches_reference() {
        // HEAPR_KERNEL=blocked semantics, in-process: pin each contract
        // tier and push it through the dispatching gemm()/dot_k() entry
        // points — so CI runners without avx2 exercise the same suite the
        // simd tier does, and a Simd selection on such a runner provably
        // degrades to the blocked kernel instead of faulting.
        let _guard = pool::test_serial_lock();
        struct Restore(Kernel);
        impl Drop for Restore {
            fn drop(&mut self) {
                set_kernel(self.0);
            }
        }
        let _restore = Restore(kernel());
        let mut rng = Pcg64::new(33);
        let (m, k, n) = (20, 70, 18);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, n * k);
        for sel in [Kernel::Blocked, Kernel::Simd] {
            set_kernel(sel);
            for layout in LAYOUTS {
                let mut want = vec![0.0f32; m * n];
                reference(layout, &a, &b, &mut want, m, k, n);
                let mut got = vec![0.0f32; m * n];
                gemm(layout, &a, &b, &mut got, m, k, n);
                assert_eq!(got, want, "{layout:?} dispatch under {sel:?}");
            }
            assert_eq!(
                dot_k(&a[..k], &b[..k]).to_bits(),
                dot(&a[..k], &b[..k]).to_bits(),
                "dot_k under {sel:?} must be the contract dot"
            );
        }
    }

    #[test]
    fn kernel_dispatch_roundtrip() {
        let _guard = pool::test_serial_lock();
        let prev = kernel();
        for sel in [Kernel::Naive, Kernel::Blocked, Kernel::Simd] {
            set_kernel(sel);
            assert_eq!(kernel(), sel);
        }
        set_kernel(prev);
        assert_eq!(Kernel::parse(" SIMD "), Some(Kernel::Simd));
        assert_eq!(Kernel::parse("blocked"), Some(Kernel::Blocked));
        assert_eq!(Kernel::parse("naive"), Some(Kernel::Naive));
        assert_eq!(Kernel::parse("avx512"), None);
        // the auto default never assumes features the CPU lacks
        let auto = default_kernel();
        assert!(auto == Kernel::Simd && simd_available() || auto == Kernel::Blocked);
    }

    #[test]
    fn degenerate_shapes_are_fine() {
        for (kfn, name) in CONTRACT_KERNELS {
            for layout in LAYOUTS {
                let mut out = vec![0.0f32; 0];
                kfn(layout, &[], &[], &mut out, 0, 3, 0);
                let mut out = vec![1.0f32; 4];
                kfn(layout, &[], &[], &mut out, 2, 0, 2);
                assert_eq!(out, vec![0.0; 4], "{name}: k=0 must zero the output");
            }
        }
    }
}
