//! Shared GEMM microkernel subsystem for the host backend.
//!
//! Every heavy matmul in the tree — router scores, attention, the expert
//! FFN fan-out, gradient accumulation, `quadform` — reduces to one of
//! three layouts of `C[m,n] = Σ_t A(i,t)·B(t,j)` (see [`Layout`]). This
//! module supplies two interchangeable kernels for all three:
//!
//! * [`naive`] — the historical row-blocked triple loops, kept as the
//!   measured baseline for the bench `kernel` axis.
//! * [`blocked`] — a cache-blocked kernel: `MC×KC×NC` tiling into
//!   L1/L2-sized panels, the strided B panel packed once per `(KC, NC)`
//!   block, and an 8-wide-unrolled [`dot8`] inner kernel whose
//!   `f32::mul_add` accumulators autovectorize to FMA lanes.
//!
//! [`gemm`] dispatches on the process-wide kernel selection
//! (`HEAPR_KERNEL=naive|blocked`, default `blocked`; [`set_kernel`] is
//! the programmatic override the benches sweep).
//!
//! # Accumulation contract
//!
//! Both the blocked kernel and the [`reference`] mirror compute every
//! output element by the exact same arithmetic, independent of packing,
//! tile sizes over `m`/`n`, and thread count:
//!
//! 1. the reduction axis is split into `KC`-sized blocks, in order;
//! 2. within a block, eight interleaved `f32::mul_add` accumulators
//!    (lane `l` takes elements `8u + l`; a remainder of `r` elements
//!    lands on lanes `0..r`), reduced pairwise —
//!    `((a0+a1)+(a2+a3)) + ((a4+a5)+(a6+a7))`;
//! 3. block results are added into the output in block order.
//!
//! `mul_add` is exactly rounded on every target, so `blocked` is bitwise
//! identical to `reference` everywhere, and bitwise thread-count
//! invariant: parallelism only splits `m` into row-disjoint blocks (at
//! most `MC` rows, shrinking for small `m` so decode-shaped GEMMs still
//! fan out) over [`pool`] (same [`RowsPtr`] contract as the row-wise
//! tensor ops), and row blocking never enters the contract.
//!
//! # Non-finite inputs
//!
//! No kernel skips zero operands: `0.0 · NaN` and `0.0 · ∞` contribute
//! NaN, identically in all three layouts (the historical `matmul_at`
//! zero-skip shortcut silently dropped them; that shortcut is gone, and
//! the shared policy is pinned by tests).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use crate::util::pool;
use crate::util::pool::RowsPtr;

/// Row-block height: C/A rows per parallel work item (L2-sized A slab).
pub const MC: usize = 64;
/// Reduction-axis block: one `KC` slice of an A row (1 KiB) stays in L1
/// while the packed B panel streams against it.
pub const KC: usize = 256;
/// Column-panel width: `KC × NC` packed B panel = 64 KiB, L2-resident.
pub const NC: usize = 64;

/// Below this many scalar multiply-adds a kernel stays on the caller
/// thread — pool dispatch would cost more than it saves. (Shared with the
/// row-wise ops in `tensor::ops`.)
pub(crate) const PAR_MIN_WORK: usize = 1 << 14;

/// Operand layouts, named after the historical `tensor::ops` entry points.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// `C[m,n] = A[m,k] · B[n,k]ᵀ` — `A(i,t) = a[i·k+t]`, `B(t,j) = b[j·k+t]`.
    TN,
    /// `C[m,n] = A[m,k] · B[k,n]` — `A(i,t) = a[i·k+t]`, `B(t,j) = b[t·n+j]`.
    NN,
    /// `C[m,n] = A[k,m]ᵀ · B[k,n]` — `A(i,t) = a[t·m+i]`, `B(t,j) = b[t·n+j]`
    /// (the gradient-accumulation shape; `k` is the historical `p`).
    AT,
}

/// Kernel selection for the dispatching [`gemm`] entry point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Historical row-blocked triple loops (bench baseline).
    Naive = 0,
    /// Cache-blocked + packed + 8-wide FMA microkernel (default).
    Blocked = 1,
}

fn kernel_cell() -> &'static AtomicU8 {
    static CELL: OnceLock<AtomicU8> = OnceLock::new();
    CELL.get_or_init(|| {
        let k = match std::env::var("HEAPR_KERNEL") {
            Ok(v) => match v.trim().to_ascii_lowercase().as_str() {
                "naive" => Kernel::Naive,
                "blocked" => Kernel::Blocked,
                other => {
                    crate::warn!(
                        "HEAPR_KERNEL={other:?} is not naive|blocked; using blocked"
                    );
                    Kernel::Blocked
                }
            },
            Err(_) => Kernel::Blocked,
        };
        AtomicU8::new(k as u8)
    })
}

/// Current process-wide kernel selection.
pub fn kernel() -> Kernel {
    if kernel_cell().load(Ordering::Relaxed) == Kernel::Naive as u8 {
        Kernel::Naive
    } else {
        Kernel::Blocked
    }
}

/// Swap the process-wide kernel (benchmark `kernel` axis; library code
/// never calls this). Tests that call it must hold
/// [`pool::test_serial_lock`].
pub fn set_kernel(k: Kernel) {
    kernel_cell().store(k as u8, Ordering::Relaxed);
}

/// `C[m,n] = op_A(A) · op_B(B)` per `layout`, into `out` (overwritten),
/// with the process-selected kernel.
pub fn gemm(layout: Layout, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    match kernel() {
        Kernel::Naive => naive(layout, a, b, out, m, k, n),
        Kernel::Blocked => blocked(layout, a, b, out, m, k, n),
    }
}

// ------------------------------------------------------------ microkernel

/// The inner kernel of the accumulation contract: eight interleaved
/// `mul_add` lanes over two equal-length contiguous slices, reduced
/// pairwise. Remainder elements (len % 8) land on lanes `0..r`.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xs, ys) in (&mut ca).zip(&mut cb) {
        for l in 0..8 {
            acc[l] = xs[l].mul_add(ys[l], acc[l]);
        }
    }
    for (l, (x, y)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
        acc[l] = x.mul_add(*y, acc[l]);
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Kernel-dispatched dot product for non-GEMM call sites (the host
/// backend's decode-attention score loop): the contract [`dot`] under
/// `Blocked`, the historical single-accumulator serial sum under
/// `Naive` — so the bench `kernel` axis compares the true pre-blocked
/// arithmetic end to end, not a hybrid.
#[inline]
pub fn dot_k(a: &[f32], b: &[f32]) -> f32 {
    match kernel() {
        Kernel::Naive => a.iter().zip(b).map(|(x, y)| x * y).sum(),
        Kernel::Blocked => dot(a, b),
    }
}

/// Contract dot product over arbitrary length: `KC`-sized blocks, each
/// reduced by [`dot8`], summed in block order — exactly the per-element
/// accumulation every blocked GEMM here performs.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut c = 0.0f32;
    let mut pc = 0;
    while pc < a.len() {
        let kc = KC.min(a.len() - pc);
        c += dot8(&a[pc..pc + kc], &b[pc..pc + kc]);
        pc += kc;
    }
    c
}

// --------------------------------------------------------------- blocked

/// Gather the `(pc, jc)` panel of `op_B` into `packb`: `nc` contiguous
/// columns of length `kc`, so the microkernel streams both operands.
/// Only the `[k, n]`-layout operands (NN/AT) need the transposing copy;
/// TN's B rows are already contract-shaped slices and skip packing.
fn pack_b(b: &[f32], packb: &mut [f32], pc: usize, kc: usize, jc: usize, nc: usize, n: usize) {
    for j in 0..nc {
        let dst = &mut packb[j * kc..(j + 1) * kc];
        for (t, d) in dst.iter_mut().enumerate() {
            *d = b[(pc + t) * n + jc + j];
        }
    }
}

/// Transpose the full `kc`-deep A slab of the AT layout (`A(i,t) =
/// a[t·m+i]`) into row-major `packa[i·kc+t]`, once per `pc` block, so the
/// microkernel sees contiguous rows for every column panel and row block.
fn pack_a_slab(a: &[f32], packa: &mut [f32], pc: usize, kc: usize, m: usize) {
    for t in 0..kc {
        let arow = &a[(pc + t) * m..(pc + t) * m + m];
        for (i, &v) in arow.iter().enumerate() {
            packa[i * kc + t] = v;
        }
    }
}

/// One row-block × `NC` output tile for the current `(pc, jc)` block:
/// `out_rows` is the caller's row range `[i0, i0+ic)` (full `n`-wide
/// rows); only columns `[jc, jc+nc)` are touched. `packa` is the
/// AT-layout slab from [`pack_a_slab`] (empty for TN/NN, whose A rows
/// are already contiguous along the reduction axis).
#[allow(clippy::too_many_arguments)]
fn mc_block(
    layout: Layout,
    a: &[f32],
    packa: &[f32],
    packb: &[f32],
    b: &[f32],
    out_rows: &mut [f32],
    i0: usize,
    ic: usize,
    pc: usize,
    kc: usize,
    jc: usize,
    nc: usize,
    k: usize,
    n: usize,
) {
    for i in 0..ic {
        let arow: &[f32] = match layout {
            Layout::AT => &packa[(i0 + i) * kc..(i0 + i + 1) * kc],
            _ => &a[(i0 + i) * k + pc..(i0 + i) * k + pc + kc],
        };
        let orow = &mut out_rows[i * n + jc..i * n + jc + nc];
        for (j, o) in orow.iter_mut().enumerate() {
            let bcol: &[f32] = match layout {
                Layout::TN => &b[(jc + j) * k + pc..(jc + j) * k + pc + kc],
                _ => &packb[j * kc..(j + 1) * kc],
            };
            *o += dot8(arow, bcol);
        }
    }
}

/// Cache-blocked GEMM (see the module docs for the tiling and the
/// accumulation contract). Row-blocks fan out over the pool when the
/// work is large enough; results are bitwise identical to [`reference`]
/// for every thread count.
pub fn blocked(layout: Layout, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // Row blocks are the parallel work items. MC keeps the A slab
    // L2-friendly, but when m is small the blocks shrink — down to single
    // rows — so decode-shaped GEMMs (m = batch) still fan out. Row/column
    // blocking never affects the accumulation contract; only KC does.
    let threads = pool::threads();
    let rb = MC.min(m.div_ceil(threads * 4)).max(1);
    let rblocks = m.div_ceil(rb);
    let parallel = m * n * k >= PAR_MIN_WORK && rblocks > 1 && threads > 1;
    // TN's B rows double as the packed panel; NN/AT gather one. AT also
    // transposes its column-strided A into a full slab, once per KC block
    // (it depends only on pc, hence the pc-outer loop order — per-element
    // accumulation is over pc in ascending order either way, so the
    // contract is untouched).
    let mut packb = match layout {
        Layout::TN => Vec::new(),
        _ => vec![0.0f32; KC.min(k) * NC.min(n)],
    };
    let mut packa = match layout {
        Layout::AT => vec![0.0f32; m * KC.min(k)],
        _ => Vec::new(),
    };
    for pc in (0..k).step_by(KC) {
        let kc = KC.min(k - pc);
        if layout == Layout::AT {
            pack_a_slab(a, &mut packa, pc, kc, m);
        }
        let pa = &packa[..];
        for jc in (0..n).step_by(NC) {
            let nc = NC.min(n - jc);
            if layout != Layout::TN {
                pack_b(b, &mut packb, pc, kc, jc, nc, n);
            }
            let pb = &packb[..];
            // One fork-join per (pc, jc) tile, with the B panel packed
            // serially between joins: for this tree's shapes (n, k up to
            // ~1k) that is tens of dispatches against >=1 ms of tile
            // compute — <1% overhead, in exchange for packing each panel
            // exactly once. Revisit (per-lane panels, row-major outer
            // loop) if shapes ever grow past that.
            if parallel {
                let ptr = RowsPtr::new(out);
                pool::par_for(rblocks, |ib| {
                    let i0 = ib * rb;
                    let ic = rb.min(m - i0);
                    // SAFETY: row blocks are disjoint across lanes and the
                    // buffer outlives the par_for (RowsPtr contract).
                    let rows = unsafe { ptr.slice(i0 * n, ic * n) };
                    mc_block(layout, a, pa, pb, b, rows, i0, ic, pc, kc, jc, nc, k, n);
                });
            } else {
                for ib in 0..rblocks {
                    let i0 = ib * rb;
                    let ic = rb.min(m - i0);
                    let rows = &mut out[i0 * n..(i0 + ic) * n];
                    mc_block(layout, a, pa, pb, b, rows, i0, ic, pc, kc, jc, nc, k, n);
                }
            }
        }
    }
}

// ------------------------------------------------------------- reference

/// Naive mirror of the accumulation contract: plain loops, no packing,
/// no tiling over `m`/`n`, no parallelism — but the identical per-element
/// reduction ([`dot`]). The bitwise ground truth the property tests hold
/// [`blocked`] to, across every shape and thread count.
pub fn reference(
    layout: Layout,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    let mut arowbuf = vec![0.0f32; k];
    let mut bcolbuf = vec![0.0f32; k];
    for i in 0..m {
        let arow: &[f32] = match layout {
            Layout::AT => {
                for (t, v) in arowbuf.iter_mut().enumerate() {
                    *v = a[t * m + i];
                }
                &arowbuf
            }
            _ => &a[i * k..(i + 1) * k],
        };
        for j in 0..n {
            let bcol: &[f32] = match layout {
                Layout::TN => &b[j * k..(j + 1) * k],
                _ => {
                    for (t, v) in bcolbuf.iter_mut().enumerate() {
                        *v = b[t * n + j];
                    }
                    &bcolbuf
                }
            };
            out[i * n + j] = dot(arow, bcol);
        }
    }
}

// ----------------------------------------------------------------- naive

/// Fill `rows` disjoint rows of `out` (each `len` wide) with `f(i, row_i)`,
/// in parallel when `work` (scalar ops) crosses [`PAR_MIN_WORK`]. The single
/// audited unsafe site behind the naive GEMMs and the row-wise tensor ops.
pub(crate) fn par_rows<F: Fn(usize, &mut [f32]) + Sync>(
    out: &mut [f32],
    rows: usize,
    len: usize,
    work: usize,
    f: F,
) {
    debug_assert_eq!(out.len(), rows * len);
    if work < PAR_MIN_WORK {
        for i in 0..rows {
            f(i, &mut out[i * len..(i + 1) * len]);
        }
    } else {
        let ptr = RowsPtr::new(out);
        pool::par_for(rows, |i| f(i, unsafe { ptr.slice(i * len, len) }));
    }
}

/// The historical kernels: row-parallel triple loops with a single
/// serial accumulator (TN) or a broadcast row update (NN/AT). Kept as
/// the bench baseline the blocked kernel's speedup is measured against.
pub fn naive(layout: Layout, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let fill_row = |i: usize, crow: &mut [f32]| match layout {
        Layout::TN => {
            let arow = &a[i * k..(i + 1) * k];
            for (j, c) in crow.iter_mut().enumerate() {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (x, y) in arow.iter().zip(brow) {
                    acc += x * y;
                }
                *c = acc;
            }
        }
        Layout::NN => {
            let arow = &a[i * k..(i + 1) * k];
            for (t, &av) in arow.iter().enumerate() {
                let brow = &b[t * n..(t + 1) * n];
                for (c, &bv) in crow.iter_mut().zip(brow) {
                    *c += av * bv;
                }
            }
        }
        Layout::AT => {
            for t in 0..k {
                let av = a[t * m + i];
                let brow = &b[t * n..(t + 1) * n];
                for (c, &bv) in crow.iter_mut().zip(brow) {
                    *c += av * bv;
                }
            }
        }
    };
    par_rows(out, m, n, m * n * k, fill_row);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Pcg64;

    fn randv(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    const LAYOUTS: [Layout; 3] = [Layout::TN, Layout::NN, Layout::AT];

    #[test]
    fn dot8_matches_exact_integer_sum() {
        // integer values < 2^24: every order of summation is exact, so
        // dot8 must equal the plain sum bitwise
        let a: Vec<f32> = (1..=21).map(|x| x as f32).collect();
        let b: Vec<f32> = (1..=21).map(|x| (x % 5) as f32).collect();
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot8(&a, &b), want);
        assert_eq!(dot(&a, &b), want);
        assert_eq!(dot8(&[], &[]), 0.0);
    }

    #[test]
    fn blocked_hand_case_exact() {
        // small integers: blocked, naive and reference all exact
        let a = vec![1.0, 2.0, 3.0, 4.0]; // [2,2]
        let b = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]; // [3,2] rows
        let mut out = vec![0.0f32; 6];
        blocked(Layout::TN, &a, &b, &mut out, 2, 2, 3);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 3.0, 4.0, 7.0]);
        let bb = vec![5.0, 6.0, 7.0, 8.0]; // [2,2]
        let mut out = vec![0.0f32; 4];
        blocked(Layout::NN, &a, &bb, &mut out, 2, 2, 2);
        assert_eq!(out, vec![19.0, 22.0, 43.0, 50.0]);
        let mut out = vec![0.0f32; 4];
        blocked(Layout::AT, &a, &bb, &mut out, 2, 2, 2);
        assert_eq!(out, vec![26.0, 30.0, 38.0, 44.0]);
    }

    #[test]
    fn prop_blocked_matches_reference_bitwise() {
        // ragged shapes straddling MC/NC (64) and KC (256) boundaries
        check(
            "gemm-blocked-vs-reference",
            24,
            |g: &mut Gen| {
                let m = g.usize_in(1, 66);
                let n = g.usize_in(1, 66);
                let k = if g.usize_in(0, 4) == 0 {
                    254 + g.usize_in(0, 5) // cross the KC block boundary
                } else {
                    g.usize_in(1, 40)
                };
                let mut rng = Pcg64::new(g.rng.next_u64());
                (m, k, n, randv(&mut rng, m * k), randv(&mut rng, n * k))
            },
            |(m, k, n, a, b)| {
                for layout in LAYOUTS {
                    let mut got = vec![0.0f32; m * n];
                    let mut want = vec![0.0f32; m * n];
                    blocked(layout, a, b, &mut got, *m, *k, *n);
                    reference(layout, a, b, &mut want, *m, *k, *n);
                    if got != want {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn prop_blocked_matches_naive_within_tolerance() {
        check(
            "gemm-blocked-vs-naive",
            20,
            |g: &mut Gen| {
                let m = g.usize_in(1, 32);
                let k = g.usize_in(1, 48);
                let n = g.usize_in(1, 32);
                let mut rng = Pcg64::new(g.rng.next_u64());
                (m, k, n, randv(&mut rng, m * k), randv(&mut rng, n * k))
            },
            |(m, k, n, a, b)| {
                for layout in LAYOUTS {
                    let mut x = vec![0.0f32; m * n];
                    let mut y = vec![0.0f32; m * n];
                    blocked(layout, a, b, &mut x, *m, *k, *n);
                    naive(layout, a, b, &mut y, *m, *k, *n);
                    let ok = x.iter().zip(&y).all(|(p, q)| {
                        (p - q).abs() <= 1e-4 * p.abs().max(q.abs()).max(1.0)
                    });
                    if !ok {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn blocked_is_bitwise_thread_count_invariant() {
        let _guard = pool::test_serial_lock();
        // drop-guard: an unwinding assert must not leak a resized pool
        struct Restore;
        impl Drop for Restore {
            fn drop(&mut self) {
                pool::set_threads(pool::default_threads());
            }
        }
        let _restore = Restore;
        let mut rng = Pcg64::new(9);
        // big enough that the row blocks really fan out (mblocks > 1,
        // work >> PAR_MIN_WORK)
        let (m, k, n) = (130, 96, 70);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, n * k);
        for layout in LAYOUTS {
            let mut want = vec![0.0f32; m * n];
            pool::set_threads(1);
            blocked(layout, &a, &b, &mut want, m, k, n);
            for threads in [2usize, 4, 8] {
                pool::set_threads(threads);
                let mut got = vec![0.0f32; m * n];
                blocked(layout, &a, &b, &mut got, m, k, n);
                assert_eq!(got, want, "{layout:?} diverged at {threads} threads");
            }
            let mut reference_out = vec![0.0f32; m * n];
            reference(layout, &a, &b, &mut reference_out, m, k, n);
            assert_eq!(want, reference_out, "{layout:?} diverged from reference");
        }
        // _restore resets the pool on drop
    }

    #[test]
    fn nested_blocked_gemm_matches_toplevel() {
        // a gemm issued from inside a pool worker (the attention / expert
        // fan-out pattern) takes the caller-helps path; results must be
        // bitwise identical to the top-level call
        let mut rng = Pcg64::new(12);
        let (m, k, n) = (128, 64, 64); // mblocks = 2, work >> PAR_MIN_WORK
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, n * k);
        let mut want = vec![0.0f32; m * n];
        blocked(Layout::TN, &a, &b, &mut want, m, k, n);
        pool::par_for(4, |_| {
            let mut got = vec![0.0f32; m * n];
            blocked(Layout::TN, &a, &b, &mut got, m, k, n);
            assert_eq!(got, want, "nested gemm diverged");
        });
    }

    #[test]
    fn zero_times_nonfinite_contributes_nan_in_every_layout() {
        // the shared no-skip contract: a zero operand does not silence a
        // NaN/inf partner (regression for the old matmul_at shortcut)
        for layout in LAYOUTS {
            let a = vec![0.0f32; 4]; // [2,2] of zeros
            let b = vec![f32::NAN, 1.0, 2.0, 3.0]; // [2,2], NaN at (0,0)
            for kernel in [naive as fn(Layout, &[f32], &[f32], &mut [f32], usize, usize, usize),
                           blocked as _] {
                let mut out = vec![0.0f32; 4];
                kernel(layout, &a, &b, &mut out, 2, 2, 2);
                assert!(
                    out.iter().any(|v| v.is_nan()),
                    "{layout:?}: 0·NaN must propagate, got {out:?}"
                );
            }
        }
    }

    #[test]
    fn kernel_dispatch_roundtrip() {
        let _guard = pool::test_serial_lock();
        let prev = kernel();
        set_kernel(Kernel::Naive);
        assert_eq!(kernel(), Kernel::Naive);
        set_kernel(Kernel::Blocked);
        assert_eq!(kernel(), Kernel::Blocked);
        set_kernel(prev);
    }

    #[test]
    fn degenerate_shapes_are_fine() {
        for layout in LAYOUTS {
            let mut out = vec![0.0f32; 0];
            blocked(layout, &[], &[], &mut out, 0, 3, 0);
            let mut out = vec![1.0f32; 4];
            blocked(layout, &[], &[], &mut out, 2, 0, 2);
            assert_eq!(out, vec![0.0; 4], "k=0 must zero the output");
        }
    }
}
