//! HEAPr — Hessian-based Efficient Atomic Expert Pruning in Output Space.
//!
//! Full-system reproduction of the paper as a three-layer Rust + JAX +
//! Pallas stack. This crate is Layer 3: it owns the event loop, training
//! loop, pruning pipeline, evaluation harness and serving coordinator, and
//! executes AOT-compiled HLO artifacts through the PJRT C API (`xla` crate).
//! Python never runs at request time.
//!
//! Module map (see DESIGN.md for the full inventory):
//! * [`util`] — substrates the offline image lacks crates for: PCG64 rng,
//!   JSON, CLI args, logging, property-test helper.
//! * [`tensor`] — host-side f32/i32 tensors + the ops the pipeline needs.
//! * [`config`] — model/run presets mirrored from `python/compile/configs.py`.
//! * [`data`] — synthetic topic-grammar corpus, tokenizers, calibration
//!   sampler (paper Appendix B sampling strategy).
//! * [`runtime`] — PJRT client wrapper, artifact manifest, executable cache.
//! * [`model`] — parameter store, checkpoint IO, width profiles, FLOPs.
//! * [`train`] — training-loop driver over the `train_step` artifact.
//! * [`heapr`] — the paper's contribution: calibration accumulators,
//!   atomic-expert importance, global/layerwise ranking, weight surgery.
//! * [`baselines`] — expert-drop / frequency / random / magnitude /
//!   CAMERA-P / expert-level-HEAPr comparison methods.
//! * [`eval`] — perplexity + 7 synthetic zero-shot tasks + FLOPs accounting.
//! * [`coordinator`] — serving engine with width-bucketed expert dispatch.
//! * [`experiments`] — one module per paper table/figure.
//! * [`bench`] — criterion-substitute micro-benchmark harness.

pub mod util;
pub mod tensor;
pub mod config;
pub mod data;
pub mod runtime;
pub mod model;
pub mod train;
pub mod heapr;
pub mod baselines;
pub mod eval;
pub mod coordinator;
pub mod experiments;
pub mod bench;
