//! HEAPr — Hessian-based Efficient Atomic Expert Pruning in Output Space.
//!
//! Full-system reproduction of the paper as a three-layer Rust + JAX +
//! Pallas stack. This crate is Layer 3: it owns the event loop, training
//! loop, pruning pipeline, evaluation harness and serving coordinator,
//! and executes the AOT artifact contract behind [`runtime::Engine`] —
//! by default on the pure-rust host backend, optionally (feature `pjrt`)
//! through the PJRT C API. Python never runs at request time.
//!
//! The system document is `docs/ARCHITECTURE.md`: the L1/L2/L3 layer
//! map, the artifact/manifest contract, the serving lifecycle (prefill →
//! decode → admission → release), the GEMM kernel tiers with their
//! accumulation contract, and the thread-pool scheduler. Start there;
//! the module docs below carry the local invariants.
//!
//! Module map:
//! * [`util`] — substrates the offline image lacks crates for: PCG64 rng,
//!   JSON, CLI args, logging, property-test helper, the thread pool.
//! * [`tensor`] — host-side f32/i32 tensors, ops, and the
//!   [`tensor::gemm`] microkernel subsystem.
//! * [`config`] — model/run presets mirrored from `python/compile/configs.py`.
//! * [`data`] — synthetic topic-grammar corpus, tokenizers, calibration
//!   sampler (paper Appendix B sampling strategy).
//! * [`runtime`] — backends, artifact manifest, engine-resident sessions.
//! * [`model`] — parameter store, checkpoint IO, width profiles, FLOPs.
//! * [`train`] — training-loop driver over the `train_step` artifact.
//! * [`crate::heapr`] — the paper's contribution: calibration accumulators,
//!   atomic-expert importance, global/layerwise ranking, weight surgery.
//! * [`baselines`] — expert-drop / frequency / random / magnitude /
//!   CAMERA-P / expert-level-HEAPr comparison methods.
//! * [`eval`] — perplexity + 7 synthetic zero-shot tasks + FLOPs accounting.
//! * [`coordinator`] — serving: request queue + admission policy, routing,
//!   the batch-synchronous reference loop and the continuous-batching
//!   lane scheduler.
//! * [`experiments`] — one module per paper table/figure.
//! * [`bench`] — criterion-substitute micro-benchmark harness.
//! * [`lint`] — the `heapr-lint` static-analysis engine: a Rust surface
//!   lexer plus the five repo rules behind `make lint` (SAFETY-comment
//!   audit, NaN-ordering ban, spawn policy, env/test registries).

pub mod util;
pub mod tensor;
pub mod config;
pub mod data;
pub mod runtime;
pub mod model;
pub mod train;
pub mod heapr;
pub mod baselines;
pub mod eval;
pub mod coordinator;
pub mod experiments;
pub mod bench;
pub mod lint;
