//! `heapr` — CLI entrypoint for the HEAPr reproduction.
//!
//! Subcommands:
//!   pipeline    train → calibrate → prune → eval (the end-to-end driver)
//!   train       train a MiniMoE LM and save the checkpoint + loss curve
//!   prune       calibrate + prune at a ratio, save pruned checkpoint
//!   eval        evaluate a (possibly masked) checkpoint on the suite
//!   serve       serving demo: batched requests through the coordinator
//!               (--continuous for the in-flight-admission lane
//!               scheduler, --stream to print tokens as they land,
//!               --lanes N to cap the lane count, --group-extent for
//!               extent-grouped admission; --http for the HTTP/1.1
//!               front-end with --port, --max-queue and --deadline-ms)
//!   experiment  regenerate a paper table/figure: table1|table2|table3|
//!               table5|fig2|fig3|fig4|fig56|all
//!   corpus      print corpus statistics (substrate sanity)
//!
//! Common flags: --preset tiny|small|base (default small), --out DIR,
//! --steps N, --lr F, --calib N, --ratio F, --seed N, --verbose,
//! --kernel auto|naive|blocked|simd (GEMM kernel; auto = runtime CPU
//! detection, same values as HEAPR_KERNEL).

use anyhow::{bail, Result};

use heapr::config::RunConfig;
use heapr::coordinator::{
    serve_continuous, Batcher, HttpOpts, HttpServer, Request, SchedulerOpts, Server, StreamEvent,
};
use heapr::data::corpus::Grammar;
use heapr::data::sampler::Split;
use heapr::data::tokenizer::ByteTokenizer;
use heapr::experiments::{common::Ctx, fig2, fig3, fig4, fig56, table1, table2, table3, table5};
use heapr::heapr::{heapr_scores, surgery, PrunePlan, Scope};
use heapr::info;
use heapr::model::checkpoint::Checkpoint;
use heapr::model::flops::flops_reduction;
use heapr::tensor::gemm;
use heapr::util::args::Args;
use heapr::util::json::Json;
use heapr::util::logging::{set_level, Level};
use heapr::util::pool;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut args = Args::from_env()?;
    let sub = args.subcommand.clone();
    if args.flag("verbose") {
        set_level(Level::Debug);
    }
    // --kernel overrides HEAPR_KERNEL; `auto` is runtime CPU detection:
    // simd where avx2+fma exist, blocked elsewhere. An *explicit*
    // `--kernel auto` overrides a HEAPR_KERNEL still exported in the
    // environment; with no flag at all the env var keeps its say.
    let explicit = args.opt_str("kernel").is_some();
    let kernel = args.choice("kernel", "auto", &["auto", "naive", "blocked", "simd"])?;
    match gemm::Kernel::parse(&kernel) {
        // same degradation rule as HEAPR_KERNEL=simd: warn, don't let the
        // logs attribute blocked-kernel numbers to a simd label
        Some(gemm::Kernel::Simd) if !gemm::simd_available() => {
            heapr::warn!("--kernel simd but this CPU lacks avx2+fma; using blocked");
            gemm::set_kernel(gemm::Kernel::Blocked);
        }
        Some(k) => gemm::set_kernel(k),
        None if explicit => gemm::set_kernel(gemm::default_kernel()),
        None => {}
    }
    // first use emits the startup "gemm kernel tier" line — after any
    // override, so it always names the tier that will actually run
    gemm::kernel();
    let preset = args.str("preset", "small");
    let artifact_dir = args.str("artifacts", &format!("artifacts/{preset}"));
    let out = args.str("out", &format!("runs/{preset}"));
    let run = RunConfig {
        seed: args.usize("seed", 0)? as u64,
        train_steps: args.usize("steps", default_steps(&preset))?,
        lr: args.f64("lr", 3e-3)?,
        corpus_mb: args.f64("corpus-mb", 2.0)?,
        calib_samples: args.usize("calib", 128)?,
        eval_batches: args.usize("eval-batches", 16)?,
    };

    match sub.as_str() {
        "pipeline" => {
            let ratio = args.f64("ratio", 0.25)?;
            args.finish()?;
            cmd_pipeline(&artifact_dir, run, &out, ratio)
        }
        "train" => {
            args.finish()?;
            let _ctx = Ctx::prepare(&artifact_dir, run, &out)?;
            info!("checkpoint ready under {out}");
            Ok(())
        }
        "prune" => {
            let ratio = args.f64("ratio", 0.25)?;
            let scope = args.str("scope", "global");
            args.finish()?;
            cmd_prune(&artifact_dir, run, &out, ratio, &scope)
        }
        "eval" => {
            let ratio = args.f64("ratio", 0.0)?;
            args.finish()?;
            cmd_eval(&artifact_dir, run, &out, ratio)
        }
        "serve" => {
            let ratio = args.f64("ratio", 0.25)?;
            let n_req = args.usize("requests", 16)?;
            let new_tokens = args.usize("new-tokens", 16)?;
            let group_extent = args.flag("group-extent");
            let continuous = args.flag("continuous");
            let stream = args.flag("stream");
            let lanes = args.usize("lanes", 0)?; // 0 = widest bucket
            let http = args.flag("http");
            // wire knobs: flags override the HEAPR_* env defaults
            let port = args.opt_str("port");
            let max_queue = args.opt_str("max-queue");
            let deadline_ms = args.opt_str("deadline-ms");
            args.finish()?;
            if http {
                let mut hopts = HttpOpts::from_env();
                if let Some(p) = port {
                    hopts.port = p.parse().map_err(|_| anyhow::anyhow!("--port {p:?}"))?;
                }
                if let Some(q) = max_queue {
                    hopts.max_queue =
                        q.parse().map_err(|_| anyhow::anyhow!("--max-queue {q:?}"))?;
                }
                if let Some(ms) = deadline_ms {
                    let ms: u64 =
                        ms.parse().map_err(|_| anyhow::anyhow!("--deadline-ms {ms:?}"))?;
                    hopts.deadline = (ms > 0).then(|| std::time::Duration::from_millis(ms));
                }
                hopts.lanes = (lanes > 0).then_some(lanes);
                hopts.group_extent = group_extent;
                hopts.default_max_new_tokens = new_tokens;
                return cmd_serve_http(&artifact_dir, run, &out, ratio, hopts);
            }
            cmd_serve(
                &artifact_dir,
                run,
                &out,
                ratio,
                n_req,
                new_tokens,
                ServeMode { group_extent, continuous, stream, lanes },
            )
        }
        "experiment" => {
            let which = args.str("id", "all");
            let ratios: Vec<f64> = args
                .str("ratios", "0.25,0.5")
                .split(',')
                .map(|s| s.trim().parse::<f64>())
                .collect::<Result<_, _>>()?;
            args.finish()?;
            cmd_experiment(&artifact_dir, run, &out, &which, &ratios)
        }
        "corpus" => {
            args.finish()?;
            cmd_corpus(run)
        }
        "" | "help" => {
            println!("usage: heapr <pipeline|train|prune|eval|serve|experiment|corpus> [--flags]");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `heapr help`)"),
    }
}

fn default_steps(preset: &str) -> usize {
    match preset {
        "tiny" => 120,
        "base" => 400,
        _ => 300,
    }
}

/// The end-to-end driver: train → calibrate → prune → eval, printing the
/// paper's headline comparison (original vs HEAPr-pruned at `ratio`).
fn cmd_pipeline(artifact_dir: &str, run: RunConfig, out: &str, ratio: f64) -> Result<()> {
    use heapr::experiments::common::{eval_suite, print_table, suite_headers, suite_row};
    let ctx = Ctx::prepare(artifact_dir, run, out)?;
    let cfg = ctx.engine.config().clone();

    info!("calibrating ({} samples)…", ctx.run.calib_samples);
    let calib = ctx.calib_wiki(ctx.run.calib_samples, 0);
    let (scores, stats) = heapr_scores(&ctx.engine, &ctx.params, &calib)?;
    info!("calibration CE {:.4} over {} sequences", stats.calib_ce, stats.n_sequences);

    let plan = PrunePlan::from_scores(&scores, ratio, Scope::Global);
    let rr = flops_reduction(&cfg, &plan.widths());
    info!(
        "pruned {:.1}% of atomic experts; activated-FLOPs reduction {:.1}%",
        plan.pruned_ratio() * 100.0,
        rr * 100.0
    );

    let aligned = plan.bucket_aligned(&scores, cfg.blk_i);
    let sliced = surgery(&ctx.params, &aligned)?;
    let ckpt = Checkpoint {
        store: sliced,
        widths: Some(aligned.widths()),
        meta: Json::obj(vec![("ratio", Json::n(ratio))]),
    };
    let pruned_path = ctx.out_dir.join(format!("pruned-{:.0}.ckpt", ratio * 100.0));
    ckpt.save(&pruned_path)?;
    info!("pruned checkpoint -> {pruned_path:?}");

    let base = eval_suite(&ctx, &ctx.params, &ctx.ones())?;
    let pruned = eval_suite(&ctx, &ctx.params, &plan.mask())?;
    print_table(
        &format!("pipeline — original vs {:.0}% HEAPr", ratio * 100.0),
        &suite_headers(),
        &[
            ("Original".to_string(), suite_row(&base)),
            (format!("HEAPr {:.0}%", ratio * 100.0), suite_row(&pruned)),
        ],
    );
    Ok(())
}

fn cmd_prune(artifact_dir: &str, run: RunConfig, out: &str, ratio: f64, scope: &str) -> Result<()> {
    let ctx = Ctx::prepare(artifact_dir, run, out)?;
    let cfg = ctx.engine.config().clone();
    let scope = match scope {
        "global" => Scope::Global,
        "layerwise" => Scope::Layerwise,
        other => bail!("scope must be global|layerwise, got {other:?}"),
    };
    let calib = ctx.calib_wiki(ctx.run.calib_samples, 0);
    let (scores, _stats) = heapr_scores(&ctx.engine, &ctx.params, &calib)?;
    let plan = PrunePlan::from_scores(&scores, ratio, scope).bucket_aligned(&scores, cfg.blk_i);
    let sliced = surgery(&ctx.params, &plan)?;
    let path = ctx.out_dir.join(format!("pruned-{:.0}.ckpt", ratio * 100.0));
    Checkpoint {
        store: sliced,
        widths: Some(plan.widths()),
        meta: Json::obj(vec![("ratio", Json::n(ratio))]),
    }
    .save(&path)?;
    info!(
        "saved {path:?} (keep ratio {:.3}, flops rr {:.3})",
        plan.widths().keep_ratio(cfg.d_inter),
        flops_reduction(&cfg, &plan.widths())
    );
    Ok(())
}

fn cmd_eval(artifact_dir: &str, run: RunConfig, out: &str, ratio: f64) -> Result<()> {
    use heapr::experiments::common::{eval_suite, print_table, suite_headers, suite_row};
    let ctx = Ctx::prepare(artifact_dir, run, out)?;
    let mask = if ratio > 0.0 {
        let calib = ctx.calib_wiki(ctx.run.calib_samples, 0);
        let (scores, _) = heapr_scores(&ctx.engine, &ctx.params, &calib)?;
        PrunePlan::from_scores(&scores, ratio, Scope::Global).mask()
    } else {
        ctx.ones()
    };
    let suite = eval_suite(&ctx, &ctx.params, &mask)?;
    print_table(
        &format!("eval (ratio {ratio})"),
        &suite_headers(),
        &[(format!("ratio {ratio}"), suite_row(&suite))],
    );
    Ok(())
}

/// `serve` subcommand switches beyond the shared run knobs.
struct ServeMode {
    /// Extent-grouped admission (`AdmissionPolicy::GroupExtent`).
    group_extent: bool,
    /// Continuous batching (`--continuous`): in-flight admission through
    /// the lane scheduler instead of closed batch-at-once batches.
    continuous: bool,
    /// Print tokens as they land (`--stream`, continuous mode only).
    stream: bool,
    /// Lane count for continuous mode (`--lanes N`); 0 = widest bucket.
    lanes: usize,
}

fn cmd_serve(
    artifact_dir: &str,
    run: RunConfig,
    out: &str,
    ratio: f64,
    n_req: usize,
    new_tokens: usize,
    mode: ServeMode,
) -> Result<()> {
    let ctx = Ctx::prepare(artifact_dir, run, out)?;
    let cfg = ctx.engine.config().clone();

    let plan = if ratio > 0.0 {
        let calib = ctx.calib_wiki(ctx.run.calib_samples, 0);
        let (scores, _) = heapr_scores(&ctx.engine, &ctx.params, &calib)?;
        Some(PrunePlan::from_scores(&scores, ratio, Scope::Global)
            .bucket_aligned(&scores, cfg.blk_i))
    } else {
        None
    };
    let mut server = Server::new(&ctx.engine, &ctx.params, plan.as_ref())?;

    // producer thread feeds the batcher; the engine thread (here) serves.
    let (tx, rx) = std::sync::mpsc::channel();
    let grammar = Grammar::standard();
    let tok = ByteTokenizer;
    let producer = pool::spawn_named("producer", move || {
        let mut rng = heapr::util::rng::Pcg64::new(1);
        for i in 0..n_req {
            let doc = grammar.document(&mut rng, &[1.0; 6]);
            let prompt: Vec<i32> = tok.encode(&doc[..doc.len().min(48)]).to_vec();
            tx.send(Request::new(i as u64, prompt, new_tokens)).unwrap();
        }
    });
    let mut batcher = Batcher::new(
        rx,
        cfg.serve_batches.clone(),
        std::time::Duration::from_millis(2),
    )
    .group_by_extent(mode.group_extent);

    // per-request latency, submission -> completion, measured the same
    // way in both modes (queue wait included) so the printed p50/p99 are
    // comparable; serve_batch's own latencies_ms excludes queue wait
    let mut request_lats_ms: Vec<f64> = Vec::new();
    let responses = if mode.continuous {
        // streaming consumer: print tokens the moment they land
        let (ev_tx, ev_rx) = std::sync::mpsc::channel::<StreamEvent>();
        let printer = mode.stream.then(|| {
            pool::spawn_named("stream-printer", move || {
                for ev in ev_rx {
                    info!(
                        "  stream req {} #{}: token {}{}",
                        ev.id,
                        ev.index,
                        ev.token,
                        if ev.done { " (done)" } else { "" }
                    );
                }
            })
        });
        let opts = SchedulerOpts {
            lanes: (mode.lanes > 0).then_some(mode.lanes),
            stream: mode.stream.then_some(ev_tx),
            compact: true,
            ..SchedulerOpts::default()
        };
        let responses = serve_continuous(&mut server, &mut batcher, opts)?;
        if let Some(p) = printer {
            p.join().unwrap(); // sender dropped with opts; printer drains
        }
        // scheduler latencies are already submission -> retirement
        request_lats_ms.extend(responses.iter().map(|r| r.latency_ms));
        responses
    } else {
        let mut responses = Vec::new();
        while let Some(batch) = batcher.next_batch() {
            responses.extend(server.serve_batch(&batch)?);
            // the whole batch completes together, here
            request_lats_ms
                .extend(batch.iter().map(|r| r.submitted.elapsed().as_secs_f64() * 1000.0));
        }
        responses
    };
    producer.join().unwrap();

    let m = &server.metrics;
    info!(
        "served {} requests ({}): {} prompt tok, {} generated tok, {:.1} tok/s, \
         request latency (submit→done) p50 {:.0}ms p99 {:.0}ms, \
         {:.0} upload B/step ({:?} residency)",
        m.requests,
        if mode.continuous { "continuous" } else { "batch-at-once" },
        m.prompt_tokens,
        m.generated_tokens,
        m.throughput_tps(),
        heapr::util::stats::percentile(&request_lats_ms, 50.0),
        heapr::util::stats::percentile(&request_lats_ms, 99.0),
        m.upload_bytes_per_step(),
        server.residency(),
    );
    if mode.continuous && m.kv_pages_allocated > 0 {
        info!(
            "  kv paging: {} pages allocated (peak {} live), prefix hit rate {:.1}% \
             ({} pages reused, {} prefill rows skipped)",
            m.kv_pages_allocated,
            m.kv_pages_peak,
            m.prefix_hit_rate() * 100.0,
            m.prefix_pages_reused,
            m.prefill_rows_skipped,
        );
    }
    for r in responses.iter().take(2) {
        info!("  req {} -> {:?}", r.id, ByteTokenizer.decode(&r.tokens));
    }
    Ok(())
}

/// `serve --http`: expose the continuous scheduler over the wire
/// (`coordinator::http`) and serve until stdin reaches EOF — Ctrl-D
/// interactively, or the supervisor closing the pipe — which starts the
/// graceful drain (stop accepting, finish in-flight lanes, exit).
fn cmd_serve_http(
    artifact_dir: &str,
    run: RunConfig,
    out: &str,
    ratio: f64,
    opts: HttpOpts,
) -> Result<()> {
    use std::io::Read;

    let ctx = Ctx::prepare(artifact_dir, run, out)?;
    let cfg = ctx.engine.config().clone();
    let plan = if ratio > 0.0 {
        let calib = ctx.calib_wiki(ctx.run.calib_samples, 0);
        let (scores, _) = heapr_scores(&ctx.engine, &ctx.params, &calib)?;
        Some(PrunePlan::from_scores(&scores, ratio, Scope::Global).bucket_aligned(&scores, cfg.blk_i))
    } else {
        None
    };
    let mut server = Server::new(&ctx.engine, &ctx.params, plan.as_ref())?;

    let http = HttpServer::bind(opts)?;
    let addr = http.local_addr();
    let shutdown = http.shutdown_handle();
    info!("serving on http://{addr} — POST /generate, GET /healthz; stdin EOF drains and exits");
    // detached on purpose: if the drain is triggered some other way the
    // watcher must not hold up process exit, so it is never joined
    let _stdin_watcher = pool::spawn_named("stdin-eof", move || {
        let mut sink = [0u8; 256];
        let mut stdin = std::io::stdin();
        loop {
            match stdin.read(&mut sink) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
        }
        shutdown.store(true, std::sync::atomic::Ordering::Release);
    });
    let report = http.serve(&mut server)?;

    let m = &server.metrics;
    info!(
        "drained: {} served over the wire ({} shed by the bounded queue, {} cancelled), \
         {} generated tok, {:.1} tok/s",
        report.admitted,
        report.shed,
        m.cancelled_requests,
        m.generated_tokens,
        m.throughput_tps(),
    );
    Ok(())
}

fn cmd_experiment(
    artifact_dir: &str,
    run: RunConfig,
    out: &str,
    which: &str,
    ratios: &[f64],
) -> Result<()> {
    let ctx = Ctx::prepare(artifact_dir, run, out)?;
    let all = which == "all";
    if all || which == "table1" {
        table1::run(&ctx, ratios)?;
    }
    if all || which == "table2" {
        table2::run(&ctx, ratios)?;
    }
    if all || which == "table3" {
        table3::run(&ctx, ratios)?;
    }
    if all || which == "table5" {
        table5::run(&ctx)?;
    }
    if all || which == "fig2" {
        fig2::run(&ctx, &[0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9])?;
    }
    if all || which == "fig3" {
        fig3::run(&ctx, 10)?;
    }
    if all || which == "fig4" {
        fig4::run(&ctx, 0.25, &[8, 32, 128], &[0, 1, 2])?;
    }
    if all || which == "fig56" {
        fig56::run(&ctx, &[0.25, 0.5])?;
    }
    if !all
        && !["table1", "table2", "table3", "table5", "fig2", "fig3", "fig4", "fig56"]
            .contains(&which)
    {
        bail!("unknown experiment {which:?}");
    }
    info!("results appended to {}/results.md", out);
    Ok(())
}

fn cmd_corpus(run: RunConfig) -> Result<()> {
    let g = Grammar::standard();
    let docs = g.corpus("wiki", run.seed, (run.corpus_mb * 1e6) as usize);
    let total: usize = docs.iter().map(|d| d.len()).sum();
    let split = Split::from_docs(&docs, 128);
    println!(
        "corpus: {} docs, {} bytes, {} chunks of 128 tokens",
        docs.len(),
        total,
        split.n_chunks()
    );
    let bpe = heapr::data::tokenizer::Bpe::train(&docs[..docs.len().min(200)].join(" "), 64);
    let enc = bpe.encode(&docs[0]);
    println!(
        "bpe: vocab {}, compression {:.2}x on doc0 ({} bytes -> {} tokens)",
        bpe.vocab_size(),
        docs[0].len() as f64 / enc.len() as f64,
        docs[0].len(),
        enc.len()
    );
    println!("sample: {}", &docs[0][..docs[0].len().min(200)]);
    Ok(())
}
