//! Criterion-substitute micro-benchmark harness.
//!
//! Used by `rust/benches/*.rs` (registered with `harness = false`). Each
//! benchmark gets warmup iterations, then timed iterations until both a
//! minimum count and a minimum wall budget are met; reports mean / p50 /
//! p99 and writes machine-readable JSON lines for EXPERIMENTS.md §Perf.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::{mean, percentile};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub throughput: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let tp = match self.throughput {
            Some((v, unit)) => format!("  {v:12.1} {unit}"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>7} iters  mean {:>10.1}us  p50 {:>10.1}us  p99 {:>10.1}us{}",
            self.name, self.iters, self.mean_us, self.p50_us, self.p99_us, tp
        )
    }

    pub fn json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::s(self.name.clone())),
            ("iters", Json::n(self.iters as f64)),
            ("mean_us", Json::n(self.mean_us)),
            ("p50_us", Json::n(self.p50_us)),
            ("p99_us", Json::n(self.p99_us)),
        ];
        if let Some((v, unit)) = self.throughput {
            pairs.push(("throughput", Json::n(v)));
            pairs.push(("throughput_unit", Json::s(unit)));
        }
        Json::obj(pairs)
    }
}

pub struct Bench {
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_secs: f64,
    pub warmup: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            min_iters: 10,
            max_iters: 2000,
            min_secs: 0.5,
            warmup: 3,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Bench {
        Bench { min_iters: 5, max_iters: 200, min_secs: 0.2, warmup: 1, ..Bench::default() }
    }

    /// Time `f`; `work` optionally converts per-iter seconds into a
    /// throughput (value, unit), e.g. tokens/s.
    pub fn run<F: FnMut()>(
        &mut self,
        name: &str,
        mut f: F,
        work: Option<(f64, &'static str)>,
    ) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed().as_secs_f64() < self.min_secs
                && samples.len() < self.max_iters)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64() * 1e6);
        }
        let mean_us = mean(&samples);
        let result = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_us,
            p50_us: percentile(&samples, 50.0),
            p99_us: percentile(&samples, 99.0),
            throughput: work.map(|(units, label)| (units / (mean_us / 1e6), label)),
        };
        println!("{}", result.report());
        self.results.push(result.clone());
        result
    }

    /// Write all results as a JSON array (consumed by EXPERIMENTS.md §Perf).
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        let arr = Json::Arr(self.results.iter().map(|r| r.json()).collect());
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, arr.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_and_reports() {
        let mut b =
            Bench { min_iters: 5, max_iters: 10, min_secs: 0.0, warmup: 1, results: vec![] };
        let r = b.run("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        }, Some((1000.0, "adds/s")));
        assert!(r.iters >= 5);
        assert!(r.mean_us >= 0.0);
        assert!(r.throughput.unwrap().0 > 0.0);
        let j = r.json().to_string();
        assert!(j.contains("\"name\":\"spin\""));
    }
}
