//! Host backend: pure-rust execution of every AOT artifact, by name.
//!
//! The offline image cannot link PJRT (no `xla` crate), so this backend
//! re-implements each artifact's semantics over [`crate::tensor`] ops —
//! the same math `python/compile` lowers to HLO, validated against JAX
//! autodiff (gradients matched to ~1e-7 relative during bring-up):
//!
//! * `train_step` — full forward + reverse-mode backward + Adam.
//! * `forward_masked` / `loss_masked` / `seq_nll` — masked inference.
//! * `calib_pass1` — backward w.r.t. per-layer MoE output taps, then
//!   Ḡ_{l,e} = Σ_t (gate·g)(gate·g)^T (eq. 15).
//! * `calib_pass2` — routed atomic-activation statistics (eq. 16).
//! * `quadform` and the serving sub-graphs (`attn_prefill_b*`,
//!   `attn_decode_b*`, `moe_gate_n*`, `lm_head_n*`, `expert_n*_w*`).
//!
//! Heavy matmuls route through the [`crate::tensor::gemm`] microkernel
//! subsystem (three tiers: runtime-detected f32x8 `simd` where the CPU
//! has avx2+fma, cache-blocked `blocked` as the guaranteed fallback,
//! `HEAPR_KERNEL=naive` for the historical triple loops), and attention
//! — prefill
//! forward, training backward and the decode append+attend — fans
//! (batch, head) pairs out over the pool; the GEMMs nested under those
//! worker lanes subdivide further via the pool's caller-helps scheduler.
//! `HEAPR_THREADS` scales the whole pipeline and results are bitwise
//! identical for every thread count (row-disjoint writes only). The
//! decode score loop shares the GEMM kernel dispatch via
//! [`crate::tensor::gemm::dot_k`].
//!
//! [`HostBackend::run_s`] is the session entry point: resident buffers
//! aliased to same-named outputs (the decode KV caches) are mutated in
//! place instead of cloned and returned.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelConfig;
use crate::runtime::kv::PagedKv;
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::value::Value;
use crate::tensor::{
    gather0, gemm, matmul_at, matmul_nn, matmul_tn, rmsnorm, softmax, ITensor, Tensor,
};
use crate::util::pool;
use crate::util::pool::RowsPtr;

const EPS: f32 = 1e-6;
const NEG: f32 = -1e30;
const PAD: i32 = 256;
/// Mirror of `configs.py` `aux_coef` (same for every preset).
const AUX_COEF: f32 = 0.01;
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

pub struct HostBackend {
    cfg: ModelConfig,
    param_names: Vec<String>,
}

// ---------------------------------------------------------------- helpers

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Fetch a non-resident input slot of a session call ([`HostBackend::run_s`]).
fn req<'a>(inputs: &[Option<&'a Value>], i: usize) -> Result<&'a Value> {
    inputs
        .get(i)
        .copied()
        .flatten()
        .ok_or_else(|| anyhow!("session call: missing input {i}"))
}

/// Decode attention tail shared by the contiguous and paged cache walks:
/// shifted softmax over the attended scores (one per cache row 0..=pos),
/// then the V reduction `softmax(scores) · V` as a 1×kk·kk×hd GEMM under
/// the process kernel's accumulation contract. Because the reduction runs
/// over exactly the attended rows, a decode step at position p is bitwise
/// identical to masked prefill row p of the same sequence for every
/// kernel tier — the invariant the prefix-reuse admission path (seat
/// shared pages, decode only the tail) rests on. `out` is overwritten;
/// `scores` is normalized in place (it holds the softmax weights on
/// return), which keeps the per-position attention tail allocation-free.
fn attend_softmax_v(scores: &mut [f32], vrows: &[f32], out: &mut [f32], hd: usize) {
    let kk = scores.len();
    debug_assert_eq!(vrows.len(), kk * hd);
    let mx = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0f32;
    for sc in scores.iter_mut() {
        *sc = (*sc - mx).exp();
        z += *sc;
    }
    for sc in scores.iter_mut() {
        *sc /= z;
    }
    gemm::gemm(gemm::Layout::NN, scores, vrows, out, 1, kk, hd);
}

/// Copy sub-matrix `idx` (of `rows * cols` elements) out of a stacked
/// tensor laid out [..., rows, cols].
fn sub2(t: &Tensor, idx: usize, rows: usize, cols: usize) -> Tensor {
    let base = idx * rows * cols;
    Tensor::from_vec(&[rows, cols], t.data()[base..base + rows * cols].to_vec())
}

/// out[n] = a[n] * s[n] (row-scaled copy); a: [N, d], s: [N].
fn row_scale(a: &Tensor, s: &[f32]) -> Tensor {
    let d = a.shape()[1];
    let mut out = a.data().to_vec();
    for (n, &w) in s.iter().enumerate() {
        for x in &mut out[n * d..(n + 1) * d] {
            *x *= w;
        }
    }
    Tensor::from_vec(a.shape(), out)
}

fn add_into(a: &mut Tensor, b: &Tensor) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += *y;
    }
}

/// Backward of row-wise softmax: dz = p * (dp - Σ p·dp), rows of width
/// `last axis`.
fn softmax_backward(p: &Tensor, dp: &Tensor) -> Tensor {
    // lint:allow(panic-free-serve) shape invariant: a Tensor always has >= 1 axis, so last() is Some
    let d = *p.shape().last().unwrap();
    let rows = p.len() / d;
    let mut out = vec![0.0f32; p.len()];
    for r in 0..rows {
        let ps = &p.data()[r * d..(r + 1) * d];
        let dps = &dp.data()[r * d..(r + 1) * d];
        let dot: f32 = ps.iter().zip(dps).map(|(a, b)| a * b).sum();
        for i in 0..d {
            out[r * d + i] = ps[i] * (dps[i] - dot);
        }
    }
    Tensor::from_vec(p.shape(), out)
}

/// Backward of `y = rmsnorm(x, w)` over rows; returns (dx, dw).
fn rmsnorm_backward(dy: &Tensor, x: &Tensor, w: &Tensor) -> (Tensor, Tensor) {
    // lint:allow(panic-free-serve) shape invariant: a Tensor always has >= 1 axis, so last() is Some
    let d = *x.shape().last().unwrap();
    let rows = x.len() / d;
    let mut dx = vec![0.0f32; x.len()];
    let mut dw = vec![0.0f32; d];
    let wd = w.data();
    for r in 0..rows {
        let xs = &x.data()[r * d..(r + 1) * d];
        let dys = &dy.data()[r * d..(r + 1) * d];
        let ms: f32 = xs.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        let mut s = 0.0f32;
        for i in 0..d {
            dw[i] += dys[i] * xs[i] * inv;
            s += dys[i] * wd[i] * xs[i];
        }
        let c = inv * inv * inv * s / d as f32;
        for i in 0..d {
            dx[r * d + i] = dys[i] * wd[i] * inv - c * xs[i];
        }
    }
    (Tensor::from_vec(x.shape(), dx), Tensor::from_vec(&[d], dw))
}

/// [N, H*hd] -> [B, H, T, hd]
fn split_heads(x: &Tensor, b: usize, t: usize, h: usize, hd: usize) -> Tensor {
    let mut out = vec![0.0f32; b * h * t * hd];
    for bi in 0..b {
        for ti in 0..t {
            for hi in 0..h {
                let src = (bi * t + ti) * h * hd + hi * hd;
                let dst = ((bi * h + hi) * t + ti) * hd;
                out[dst..dst + hd].copy_from_slice(&x.data()[src..src + hd]);
            }
        }
    }
    Tensor::from_vec(&[b, h, t, hd], out)
}

/// [B, H, T, hd] -> [N, H*hd]
fn merge_heads(x: &Tensor) -> Tensor {
    // lint:allow(panic-free-serve) shape invariant: callers build the input via split_heads/attention, always [B,H,T,hd]
    let &[b, h, t, hd] = x.shape() else { panic!("merge_heads wants [B,H,T,hd]") };
    let mut out = vec![0.0f32; b * t * h * hd];
    for bi in 0..b {
        for hi in 0..h {
            for ti in 0..t {
                let src = ((bi * h + hi) * t + ti) * hd;
                let dst = (bi * t + ti) * h * hd + hi * hd;
                out[dst..dst + hd].copy_from_slice(&x.data()[src..src + hd]);
            }
        }
    }
    Tensor::from_vec(&[b * t, h * hd], out)
}

// ----------------------------------------------------------- model pieces

struct Params<'a> {
    map: HashMap<&'a str, &'a Tensor>,
}

impl<'a> Params<'a> {
    fn get(&self, name: &str) -> Result<&'a Tensor> {
        self.map
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("host backend: no param {name:?}"))
    }
}

struct AttnCache {
    q: Tensor,    // [B,H,T,hd]
    k: Tensor,    // [B,H,T,hd]
    v: Tensor,    // [B,H,T,hd]
    attn: Tensor, // [B,H,T,T]
    outf: Tensor, // [N,d] (merged heads, pre-Wo)
}

struct LayerCache {
    x_in: Tensor,           // [N,d]
    xn1: Tensor,            // [N,d]
    att: AttnCache,
    x1: Tensor,             // [N,d]
    xn2: Tensor,            // [N,d]
    idx: Vec<Vec<usize>>,   // [N][k] routed expert ids, rank order
    weights: Tensor,        // [N,k] softmax(top-k logits)
    gates: Tensor,          // [N,E]
    probs: Tensor,          // [N,E]
    f: Vec<f32>,            // [E] routed fraction
    pre: Vec<Tensor>,       // per e: [N,di] gate pre-activation
    u: Vec<Tensor>,         // per e: [N,di]
    h: Vec<Tensor>,         // per e: [N,di] silu(pre)*u (pre-mask)
    out_e: Vec<Tensor>,     // per e: [N,d] (h*mask) @ wd^T
}

struct Cache {
    b: usize,
    t: usize,
    layers: Vec<LayerCache>,
    x_final: Tensor, // [N,d]
    xf: Tensor,      // [N,d]
    logits: Tensor,  // [N,V]
    aux_mean: f32,
}

/// Causal multi-head attention over `xn1` [N,d]; returns the Wo-projected
/// output (no residual) plus the cache backward needs.
#[allow(clippy::too_many_arguments)]
fn attention_forward(
    xn1: &Tensor,
    wq: &Tensor,
    wk: &Tensor,
    wv: &Tensor,
    wo: &Tensor,
    b: usize,
    t: usize,
    h: usize,
    hd: usize,
    len_mask: Option<&[f32]>,
) -> (Tensor, AttnCache) {
    let q = split_heads(&matmul_tn(xn1, wq), b, t, h, hd);
    let k = split_heads(&matmul_tn(xn1, wk), b, t, h, hd);
    let v = split_heads(&matmul_tn(xn1, wv), b, t, h, hd);
    let scale = 1.0 / (hd as f32).sqrt();
    let mut attn = vec![0.0f32; b * h * t * t];
    let mut outs = vec![0.0f32; b * h * t * hd];
    {
        // (batch, head) pairs are independent; fan them out over the pool
        // with each lane writing only its own attn/outs block. Per-lane
        // arithmetic is unchanged, so results are bitwise identical for
        // every thread count.
        let ap = RowsPtr::new(&mut attn);
        let op = RowsPtr::new(&mut outs);
        pool::par_for(b * h, |bh| {
            let bi = bh / h;
            let qm = sub2(&q, bh, t, hd);
            let km = sub2(&k, bh, t, hd);
            let mut scores = matmul_tn(&qm, &km);
            for i in 0..t {
                for j in 0..t {
                    let masked = j > i
                        || len_mask.map(|m| m[bi * t + j] == 0.0).unwrap_or(false);
                    let cell = &mut scores.data_mut()[i * t + j];
                    *cell = if masked { NEG } else { *cell * scale };
                }
            }
            let a = softmax(&scores);
            let o = matmul_nn(&a, &sub2(&v, bh, t, hd));
            // SAFETY: lane bh writes only its own attn block — the ranges
            // [bh*t*t, (bh+1)*t*t) are disjoint across lanes and in
            // bounds (attn has b*h*t*t elements), and attn outlives the
            // par_for.
            unsafe { ap.slice(bh * t * t, t * t) }.copy_from_slice(a.data());
            // SAFETY: same argument for the outs buffer (b*h*t*hd
            // elements, lane-disjoint blocks of t*hd).
            unsafe { op.slice(bh * t * hd, t * hd) }.copy_from_slice(o.data());
        });
    }
    let attn = Tensor::from_vec(&[b, h, t, t], attn);
    let outf = merge_heads(&Tensor::from_vec(&[b, h, t, hd], outs));
    let y = matmul_tn(&outf, wo);
    (y, AttnCache { q, k, v, attn, outf })
}

/// Backward through [`attention_forward`]; returns dxn1 and, when
/// `need_pg`, the four weight gradients (dwq, dwk, dwv, dwo).
#[allow(clippy::too_many_arguments, clippy::type_complexity)]
fn attention_backward(
    dy: &Tensor,
    cache: &AttnCache,
    xn1: &Tensor,
    wq: &Tensor,
    wk: &Tensor,
    wv: &Tensor,
    wo: &Tensor,
    b: usize,
    t: usize,
    h: usize,
    hd: usize,
    need_pg: bool,
) -> (Tensor, Option<[Tensor; 4]>) {
    let scale = 1.0 / (hd as f32).sqrt();
    let dwo = if need_pg { Some(matmul_at(dy, &cache.outf)) } else { None };
    let dout = split_heads(&matmul_nn(dy, wo), b, t, h, hd);
    let mut dq = vec![0.0f32; b * h * t * hd];
    let mut dk = vec![0.0f32; b * h * t * hd];
    let mut dv = vec![0.0f32; b * h * t * hd];
    {
        // same (batch, head) fan-out as the forward pass: disjoint
        // dq/dk/dv blocks per lane, bitwise thread-count invariant.
        let qp = RowsPtr::new(&mut dq);
        let kp = RowsPtr::new(&mut dk);
        let vp = RowsPtr::new(&mut dv);
        pool::par_for(b * h, |bh| {
            let dout_m = sub2(&dout, bh, t, hd);
            let a = sub2(&cache.attn, bh, t, t);
            let vm = sub2(&cache.v, bh, t, hd);
            let da = matmul_tn(&dout_m, &vm); // [T,T]
            let dv_m = matmul_at(&a, &dout_m); // [T,hd]
            let mut ds = softmax_backward(&a, &da);
            for x in ds.data_mut() {
                *x *= scale;
            }
            let dq_m = matmul_nn(&ds, &sub2(&cache.k, bh, t, hd));
            let dk_m = matmul_at(&ds, &sub2(&cache.q, bh, t, hd));
            // SAFETY: lane bh writes only its own t*hd block of dq —
            // disjoint across lanes, in bounds (b*h*t*hd elements), and
            // dq outlives the par_for.
            unsafe { qp.slice(bh * t * hd, t * hd) }.copy_from_slice(dq_m.data());
            // SAFETY: same argument for dk (separate buffer, same layout).
            unsafe { kp.slice(bh * t * hd, t * hd) }.copy_from_slice(dk_m.data());
            // SAFETY: same argument for dv (separate buffer, same layout).
            unsafe { vp.slice(bh * t * hd, t * hd) }.copy_from_slice(dv_m.data());
        });
    }
    let dq = merge_heads(&Tensor::from_vec(&[b, h, t, hd], dq));
    let dk = merge_heads(&Tensor::from_vec(&[b, h, t, hd], dk));
    let dv = merge_heads(&Tensor::from_vec(&[b, h, t, hd], dv));
    let mut dxn1 = matmul_nn(&dq, wq);
    add_into(&mut dxn1, &matmul_nn(&dk, wk));
    add_into(&mut dxn1, &matmul_nn(&dv, wv));
    let dws = dwo.map(|dwo| {
        [
            matmul_at(&dq, xn1),
            matmul_at(&dk, xn1),
            matmul_at(&dv, xn1),
            dwo,
        ]
    });
    (dxn1, dws)
}

/// Iterative-argmax top-k routing (ties -> lowest index, matching
/// `model.py::topk_iterative`); returns (idx, weights [N,k], gates [N,E]).
fn route(logits_r: &Tensor, k: usize) -> (Vec<Vec<usize>>, Tensor, Tensor) {
    // lint:allow(panic-free-serve) shape invariant: the router matmul always produces [N,E]
    let &[n, e] = logits_r.shape() else { panic!("router logits must be [N,E]") };
    let mut idx = Vec::with_capacity(n);
    let mut weights = vec![0.0f32; n * k];
    let mut gates = vec![0.0f32; n * e];
    for r in 0..n {
        let mut row = logits_r.data()[r * e..(r + 1) * e].to_vec();
        let mut picks = Vec::with_capacity(k);
        let mut vals = Vec::with_capacity(k);
        for _ in 0..k {
            let mut best = 0usize;
            for j in 1..e {
                if row[j] > row[best] {
                    best = j;
                }
            }
            picks.push(best);
            vals.push(row[best]);
            row[best] -= 1e30;
        }
        // softmax over the k selected logits
        let mx = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = vals.iter().map(|v| (v - mx).exp()).collect();
        let z: f32 = exps.iter().sum();
        for (j, &p) in picks.iter().enumerate() {
            let w = exps[j] / z;
            weights[r * k + j] = w;
            gates[r * e + p] += w;
        }
        idx.push(picks);
    }
    (
        idx,
        Tensor::from_vec(&[n, k], weights),
        Tensor::from_vec(&[n, e], gates),
    )
}

struct CeOut {
    ce: f32,
    cnt: f32,
    nll_rows: Vec<f32>, // per token
    w_rows: Vec<f32>,   // per token (1.0 unless target == PAD)
    dlogits: Option<Tensor>,
}

/// Mean cross-entropy over non-PAD targets (`model.py::ce_loss`), with the
/// loss gradient when `need_grad`. Target ids are bounds-checked — unlike
/// input tokens they never pass through the embedding lookup's validation.
fn ce_loss(logits: &Tensor, targets: &[i32], need_grad: bool) -> Result<CeOut> {
    let &[n, v] = logits.shape() else { bail!("logits must be [N,V]") };
    assert_eq!(targets.len(), n);
    let mut nll_rows = vec![0.0f32; n];
    let mut w_rows = vec![0.0f32; n];
    let mut logz = vec![0.0f32; n];
    for r in 0..n {
        let xs = &logits.data()[r * v..(r + 1) * v];
        let mx = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = xs.iter().map(|x| (x - mx).exp()).sum();
        logz[r] = mx + z.ln();
        let tgt = targets[r];
        if tgt < 0 || tgt as usize >= v {
            bail!("target id {tgt} out of range for vocab {v} (row {r})");
        }
        nll_rows[r] = logz[r] - xs[tgt as usize];
        w_rows[r] = if tgt == PAD { 0.0 } else { 1.0 };
    }
    let cnt: f32 = w_rows.iter().sum();
    let norm = cnt.max(1.0);
    let ce = nll_rows
        .iter()
        .zip(&w_rows)
        .map(|(l, w)| l * w)
        .sum::<f32>()
        / norm;
    let dlogits = need_grad.then(|| {
        let mut d = vec![0.0f32; n * v];
        for r in 0..n {
            let w = w_rows[r] / norm;
            if w == 0.0 {
                continue;
            }
            let xs = &logits.data()[r * v..(r + 1) * v];
            for c in 0..v {
                d[r * v + c] = (xs[c] - logz[r]).exp() * w;
            }
            d[r * v + targets[r] as usize] -= w;
        }
        Tensor::from_vec(&[n, v], d)
    });
    Ok(CeOut { ce, cnt, nll_rows, w_rows, dlogits })
}

impl HostBackend {
    pub fn new(cfg: ModelConfig, param_names: Vec<String>) -> HostBackend {
        HostBackend { cfg, param_names }
    }

    fn params<'a>(&'a self, inputs: &[&'a Value]) -> Result<Params<'a>> {
        let np = self.param_names.len();
        if inputs.len() < np {
            bail!("host backend: {} inputs < {np} params", inputs.len());
        }
        let mut map = HashMap::with_capacity(np);
        for (name, v) in self.param_names.iter().zip(inputs) {
            map.insert(name.as_str(), v.as_f32()?);
        }
        Ok(Params { map })
    }

    // ------------------------------------------------------------ forward

    /// Forward pass over flat tokens; caches everything backward needs.
    fn forward(&self, p: &Params, tokens: &ITensor, mask: &Tensor) -> Result<Cache> {
        let cfg = &self.cfg;
        let (b, t) = (tokens.shape()[0], tokens.shape()[1]);
        let (d, e, di, kk) = (cfg.d_model, cfg.n_experts, cfg.d_inter, cfg.top_k);
        let (h, hd) = (cfg.n_heads, cfg.d_head);
        let n = b * t;

        let embed = p.get("embed")?;
        let posw = p.get("pos")?;
        let mut x = vec![0.0f32; n * d];
        for (i, &tok) in tokens.data().iter().enumerate() {
            let tok = tok as usize;
            if tok >= cfg.vocab {
                bail!("token id {tok} >= vocab {}", cfg.vocab);
            }
            let trow = &embed.data()[tok * d..(tok + 1) * d];
            let prow = &posw.data()[(i % t) * d..(i % t + 1) * d];
            for j in 0..d {
                x[i * d + j] = trow[j] + prow[j];
            }
        }
        let mut x = Tensor::from_vec(&[n, d], x);

        let mut layers = Vec::with_capacity(cfg.n_layers);
        let mut aux_total = 0.0f32;
        for l in 0..cfg.n_layers {
            let pre_name = |suffix: &str| format!("l{l}.{suffix}");
            let x_in = x.clone();
            let xn1 = rmsnorm(&x_in, p.get(&pre_name("ln1"))?, EPS);
            let (y_att, att) = attention_forward(
                &xn1,
                p.get(&pre_name("wq"))?,
                p.get(&pre_name("wk"))?,
                p.get(&pre_name("wv"))?,
                p.get(&pre_name("wo"))?,
                b,
                t,
                h,
                hd,
                None,
            );
            let mut x1 = x_in.clone();
            add_into(&mut x1, &y_att);
            let xn2 = rmsnorm(&x1, p.get(&pre_name("ln2"))?, EPS);
            let logits_r = matmul_tn(&xn2, p.get(&pre_name("router"))?);
            let (idx, weights, gates) = route(&logits_r, kk);
            let probs = softmax(&logits_r);

            let wg_all = p.get(&pre_name("wg"))?;
            let wu_all = p.get(&pre_name("wu"))?;
            let wd_all = p.get(&pre_name("wd"))?;
            let mask_l = &mask.data()[l * e * di..(l + 1) * e * di];
            // experts are independent: fan out over the pool (each writes
            // only its own cache slot), engine-free pure math.
            let expert_out: Vec<(Tensor, Tensor, Tensor, Tensor)> =
                pool::par_map(e, |ei| {
                    let wg = sub2(wg_all, ei, di, d);
                    let wu = sub2(wu_all, ei, di, d);
                    let wd = sub2(wd_all, ei, d, di);
                    let pre_g = matmul_tn(&xn2, &wg);
                    let u = matmul_tn(&xn2, &wu);
                    let mut hmat = vec![0.0f32; n * di];
                    for i in 0..n * di {
                        let pg = pre_g.data()[i];
                        hmat[i] = pg * sigmoid(pg) * u.data()[i];
                    }
                    let hmat = Tensor::from_vec(&[n, di], hmat);
                    let me = &mask_l[ei * di..(ei + 1) * di];
                    let mut hm = hmat.data().to_vec();
                    for r in 0..n {
                        for c in 0..di {
                            hm[r * di + c] *= me[c];
                        }
                    }
                    let hm = Tensor::from_vec(&[n, di], hm);
                    let out_e = matmul_tn(&hm, &wd);
                    (pre_g, u, hmat, out_e)
                });
            let mut y = Tensor::zeros(&[n, d]);
            let mut pre_v = Vec::with_capacity(e);
            let mut u_v = Vec::with_capacity(e);
            let mut h_v = Vec::with_capacity(e);
            let mut out_v = Vec::with_capacity(e);
            for (ei, (pre_g, u, hmat, out_e)) in expert_out.into_iter().enumerate() {
                for r in 0..n {
                    let g = gates.data()[r * e + ei];
                    if g != 0.0 {
                        for c in 0..d {
                            y.data_mut()[r * d + c] += g * out_e.data()[r * d + c];
                        }
                    }
                }
                pre_v.push(pre_g);
                u_v.push(u);
                h_v.push(hmat);
                out_v.push(out_e);
            }

            let mut f = vec![0.0f32; e];
            for r in 0..n {
                for ei in 0..e {
                    if gates.data()[r * e + ei] > 0.0 {
                        f[ei] += 1.0;
                    }
                }
            }
            for v in &mut f {
                *v /= n as f32;
            }
            let mut aux = 0.0f32;
            for ei in 0..e {
                let pbar: f32 =
                    (0..n).map(|r| probs.data()[r * e + ei]).sum::<f32>() / n as f32;
                aux += f[ei] * pbar;
            }
            aux_total += e as f32 * aux;

            let mut x2 = x1.clone();
            add_into(&mut x2, &y);
            layers.push(LayerCache {
                x_in,
                xn1,
                att,
                x1,
                xn2,
                idx,
                weights,
                gates,
                probs,
                f,
                pre: pre_v,
                u: u_v,
                h: h_v,
                out_e: out_v,
            });
            x = x2;
        }
        let xf = rmsnorm(&x, p.get("lnf")?, EPS);
        let logits = matmul_tn(&xf, embed);
        Ok(Cache {
            b,
            t,
            layers,
            x_final: x,
            xf,
            logits,
            aux_mean: aux_total / cfg.n_layers as f32,
        })
    }

    // ----------------------------------------------------------- backward

    /// Reverse-mode pass from a CE gradient. Returns per-parameter grads
    /// (empty map when `need_pg` is false) and the per-layer MoE-output
    /// tap gradients ∂ℓ/∂y_moe_l (what `calib_pass1` needs).
    fn backward(
        &self,
        p: &Params,
        tokens: &ITensor,
        cache: &Cache,
        dlogits: &Tensor,
        mask: &Tensor,
        need_pg: bool,
    ) -> Result<(HashMap<String, Tensor>, Vec<Tensor>)> {
        let cfg = &self.cfg;
        let (b, t) = (cache.b, cache.t);
        let (d, e, di, kk) = (cfg.d_model, cfg.n_experts, cfg.d_inter, cfg.top_k);
        let (h, hd) = (cfg.n_heads, cfg.d_head);
        let n = b * t;
        let aux_scale = AUX_COEF / cfg.n_layers as f32;

        let mut g: HashMap<String, Tensor> = HashMap::new();
        let embed = p.get("embed")?;

        // head (tied embedding)
        let mut dx = {
            let dxf = matmul_nn(dlogits, embed);
            if need_pg {
                g.insert("embed".into(), matmul_at(dlogits, &cache.xf));
            }
            let (dx, dlnf) = rmsnorm_backward(&dxf, &cache.x_final, p.get("lnf")?);
            if need_pg {
                g.insert("lnf".into(), dlnf);
            }
            dx
        };

        let mut dtaps = vec![Tensor::zeros(&[0]); cfg.n_layers];
        for l in (0..cfg.n_layers).rev() {
            let pre_name = |suffix: &str| format!("l{l}.{suffix}");
            let lc = &cache.layers[l];
            let dy = dx.clone();
            dtaps[l] = dx.clone();
            let mut dx1 = dx.clone();

            let wg_all = p.get(&pre_name("wg"))?;
            let wu_all = p.get(&pre_name("wu"))?;
            let wd_all = p.get(&pre_name("wd"))?;
            let mask_l = &mask.data()[l * e * di..(l + 1) * e * di];

            // per-expert backward, fanned out over the pool; each returns
            // (dxn2 contribution, dgate column, optional [dwg,dwu,dwd]).
            // Only routed tokens (gate > 0) carry gradient through an
            // expert — every unrouted row of dout_e is an exact zero and
            // the GEMM layer no longer skips zeros — so the whole chain
            // runs on gathered [routed, ·] matrices and the dxn2/dgate
            // results scatter back (same pattern as calib_pass1). Entries
            // of dgate for unrouted rows are only ever read multiplied by
            // a zero routing weight, so zeroing them is grad-equivalent.
            // NaN gates count as routed: a poisoned routing weight must
            // keep poisoning its gradients, not be filtered into silent
            // zeros (the same no-silencing contract the kernels pin).
            let parts: Vec<(Tensor, Vec<f32>, Option<[Tensor; 3]>)> =
                pool::par_map(e, |ei| {
                    let me = &mask_l[ei * di..(ei + 1) * di];
                    let routed: Vec<usize> = (0..n)
                        .filter(|&r| {
                            let g = lc.gates.data()[r * e + ei];
                            g > 0.0 || g.is_nan()
                        })
                        .collect();
                    let nr = routed.len();
                    if nr == 0 {
                        let dws = need_pg.then(|| {
                            [
                                Tensor::zeros(&[di, d]),
                                Tensor::zeros(&[di, d]),
                                Tensor::zeros(&[d, di]),
                            ]
                        });
                        return (Tensor::zeros(&[n, d]), vec![0.0f32; n], dws);
                    }
                    let w: Vec<f32> =
                        routed.iter().map(|&r| lc.gates.data()[r * e + ei]).collect();
                    let dy_sub = gather0(&dy, &routed);
                    let dout_e = row_scale(&dy_sub, &w);
                    let out_e = &lc.out_e[ei];
                    let mut dgate = vec![0.0f32; n];
                    for (s, &r) in routed.iter().enumerate() {
                        let a = &dy_sub.data()[s * d..(s + 1) * d];
                        let o = &out_e.data()[r * d..(r + 1) * d];
                        dgate[r] = a.iter().zip(o).map(|(x, y)| x * y).sum();
                    }
                    let wd = sub2(wd_all, ei, d, di);
                    let hmat = gather0(&lc.h[ei], &routed);
                    let dwd = need_pg.then(|| {
                        // dwd wants hm = h*mask as its right factor
                        let mut hm = hmat.data().to_vec();
                        for r in 0..nr {
                            for c in 0..di {
                                hm[r * di + c] *= me[c];
                            }
                        }
                        matmul_at(&dout_e, &Tensor::from_vec(&[nr, di], hm))
                    });
                    let dhm = matmul_nn(&dout_e, &wd);
                    let mut dh = dhm.data().to_vec();
                    for r in 0..nr {
                        for c in 0..di {
                            dh[r * di + c] *= me[c];
                        }
                    }
                    let upre = gather0(&lc.pre[ei], &routed);
                    let uu = gather0(&lc.u[ei], &routed);
                    let mut dact = vec![0.0f32; nr * di];
                    let mut du = vec![0.0f32; nr * di];
                    let mut dpre = vec![0.0f32; nr * di];
                    for i in 0..nr * di {
                        let pg = upre.data()[i];
                        let s = sigmoid(pg);
                        let silu = pg * s;
                        dact[i] = dh[i] * uu.data()[i];
                        du[i] = dh[i] * silu;
                        dpre[i] = dact[i] * (s * (1.0 + pg * (1.0 - s)));
                    }
                    let du = Tensor::from_vec(&[nr, di], du);
                    let dpre = Tensor::from_vec(&[nr, di], dpre);
                    let mut dxn2_sub = matmul_nn(&du, &sub2(wu_all, ei, di, d));
                    add_into(&mut dxn2_sub, &matmul_nn(&dpre, &sub2(wg_all, ei, di, d)));
                    let mut dxn2 = Tensor::zeros(&[n, d]);
                    for (s, &r) in routed.iter().enumerate() {
                        dxn2.data_mut()[r * d..(r + 1) * d]
                            .copy_from_slice(&dxn2_sub.data()[s * d..(s + 1) * d]);
                    }
                    let dws = dwd.map(|dwd| {
                        let xn2_sub = gather0(&lc.xn2, &routed);
                        [
                            matmul_at(&dpre, &xn2_sub), // dwg
                            matmul_at(&du, &xn2_sub),   // dwu
                            dwd,                        // dwd
                        ]
                    });
                    (dxn2, dgate, dws)
                });

            let mut dxn2 = Tensor::zeros(&[n, d]);
            let mut dgates = vec![0.0f32; n * e];
            if need_pg {
                g.insert(pre_name("wg"), Tensor::zeros(&[e, di, d]));
                g.insert(pre_name("wu"), Tensor::zeros(&[e, di, d]));
                g.insert(pre_name("wd"), Tensor::zeros(&[e, d, di]));
            }
            for (ei, (dxn2_e, dgate, dws)) in parts.into_iter().enumerate() {
                add_into(&mut dxn2, &dxn2_e);
                for r in 0..n {
                    dgates[r * e + ei] = dgate[r];
                }
                if let Some([dwg, dwu, dwd]) = dws {
                    let dst = g.get_mut(&pre_name("wg")).context("grad buffer wg")?;
                    dst.data_mut()[ei * di * d..(ei + 1) * di * d]
                        .copy_from_slice(dwg.data());
                    let dst = g.get_mut(&pre_name("wu")).context("grad buffer wu")?;
                    dst.data_mut()[ei * di * d..(ei + 1) * di * d]
                        .copy_from_slice(dwu.data());
                    let dst = g.get_mut(&pre_name("wd")).context("grad buffer wd")?;
                    dst.data_mut()[ei * d * di..(ei + 1) * d * di]
                        .copy_from_slice(dwd.data());
                }
            }

            // gates -> router logits via the top-k softmax
            let mut dlr = vec![0.0f32; n * e];
            {
                let mut dweights = vec![0.0f32; n * kk];
                for r in 0..n {
                    for j in 0..kk {
                        dweights[r * kk + j] = dgates[r * e + lc.idx[r][j]];
                    }
                }
                let dvals = softmax_backward(
                    &lc.weights,
                    &Tensor::from_vec(&[n, kk], dweights),
                );
                for r in 0..n {
                    for j in 0..kk {
                        dlr[r * e + lc.idx[r][j]] += dvals.data()[r * kk + j];
                    }
                }
            }
            // aux loss -> probs -> router logits
            {
                let mut dprobs = vec![0.0f32; n * e];
                for ei in 0..e {
                    let v = aux_scale * e as f32 * lc.f[ei] / n as f32;
                    for r in 0..n {
                        dprobs[r * e + ei] = v;
                    }
                }
                let dz = softmax_backward(
                    &lc.probs,
                    &Tensor::from_vec(&[n, e], dprobs),
                );
                for i in 0..n * e {
                    dlr[i] += dz.data()[i];
                }
            }
            let dlr = Tensor::from_vec(&[n, e], dlr);
            let router = p.get(&pre_name("router"))?;
            if need_pg {
                g.insert(pre_name("router"), matmul_at(&dlr, &lc.xn2));
            }
            add_into(&mut dxn2, &matmul_nn(&dlr, router));

            let (dx1_rms, dln2) =
                rmsnorm_backward(&dxn2, &lc.x1, p.get(&pre_name("ln2"))?);
            if need_pg {
                g.insert(pre_name("ln2"), dln2);
            }
            add_into(&mut dx1, &dx1_rms);

            // attention: x1 = x_in + attn(xn1)
            let dx_in = dx1.clone();
            let (dxn1, dws) = attention_backward(
                &dx1,
                &lc.att,
                &lc.xn1,
                p.get(&pre_name("wq"))?,
                p.get(&pre_name("wk"))?,
                p.get(&pre_name("wv"))?,
                p.get(&pre_name("wo"))?,
                b,
                t,
                h,
                hd,
                need_pg,
            );
            if let Some([dwq, dwk, dwv, dwo]) = dws {
                g.insert(pre_name("wq"), dwq);
                g.insert(pre_name("wk"), dwk);
                g.insert(pre_name("wv"), dwv);
                g.insert(pre_name("wo"), dwo);
            }
            let (dx_rms, dln1) =
                rmsnorm_backward(&dxn1, &lc.x_in, p.get(&pre_name("ln1"))?);
            if need_pg {
                g.insert(pre_name("ln1"), dln1);
            }
            dx = dx_in;
            add_into(&mut dx, &dx_rms);
        }

        if need_pg {
            // embedding lookups + positional embedding
            let gemb = g.get_mut("embed").context("grad buffer embed")?;
            for (i, &tok) in tokens.data().iter().enumerate() {
                let base = tok as usize * d;
                for j in 0..d {
                    gemb.data_mut()[base + j] += dx.data()[i * d + j];
                }
            }
            let mut gpos = Tensor::zeros(&[cfg.seq_len, d]);
            for i in 0..n {
                let pos = i % t;
                for j in 0..d {
                    gpos.data_mut()[pos * d + j] += dx.data()[i * d + j];
                }
            }
            g.insert("pos".into(), gpos);
        }
        Ok((g, dtaps))
    }

    fn ones_mask(&self) -> Tensor {
        Tensor::ones(&[self.cfg.n_layers, self.cfg.n_experts, self.cfg.d_inter])
    }

    // ---------------------------------------------------------- artifacts

    fn train_step(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let np = self.param_names.len();
        if inputs.len() != 3 * np + 4 {
            bail!("train_step wants {} inputs, got {}", 3 * np + 4, inputs.len());
        }
        let p = self.params(&inputs[..np])?;
        let step = inputs[3 * np].as_i32()?.data()[0];
        let lr = inputs[3 * np + 1].as_f32()?.data()[0];
        let tokens = inputs[3 * np + 2].as_i32()?;
        let targets = inputs[3 * np + 3].as_i32()?;

        let mask = self.ones_mask();
        let cache = self.forward(&p, tokens, &mask)?;
        let ce = ce_loss(&cache.logits, targets.data(), true)?;
        let loss = ce.ce + AUX_COEF * cache.aux_mean;
        let dlogits = ce.dlogits.as_ref().context("ce_loss(need_grad) returns dlogits")?;
        let (grads, _taps) = self.backward(&p, tokens, &cache, dlogits, &mask, true)?;

        let t = (step + 1) as f32;
        let bc1 = 1.0 - ADAM_B1.powf(t);
        let bc2 = 1.0 - ADAM_B2.powf(t);
        let mut new_p = Vec::with_capacity(np);
        let mut new_m = Vec::with_capacity(np);
        let mut new_v = Vec::with_capacity(np);
        for (i, name) in self.param_names.iter().enumerate() {
            let pw = inputs[i].as_f32()?;
            let mw = inputs[np + i].as_f32()?;
            let vw = inputs[2 * np + i].as_f32()?;
            let gw = grads
                .get(name)
                .ok_or_else(|| anyhow!("train_step: missing grad for {name}"))?;
            let len = pw.len();
            let mut p2 = vec![0.0f32; len];
            let mut m2 = vec![0.0f32; len];
            let mut v2 = vec![0.0f32; len];
            for j in 0..len {
                let gj = gw.data()[j];
                let mj = ADAM_B1 * mw.data()[j] + (1.0 - ADAM_B1) * gj;
                let vj = ADAM_B2 * vw.data()[j] + (1.0 - ADAM_B2) * gj * gj;
                let update = lr * (mj / bc1) / ((vj / bc2).sqrt() + ADAM_EPS);
                p2[j] = pw.data()[j] - update;
                m2[j] = mj;
                v2[j] = vj;
            }
            new_p.push(Value::F32(Tensor::from_vec(pw.shape(), p2)));
            new_m.push(Value::F32(Tensor::from_vec(mw.shape(), m2)));
            new_v.push(Value::F32(Tensor::from_vec(vw.shape(), v2)));
        }
        let mut out = vec![Value::scalar_f32(loss), Value::scalar_f32(ce.ce)];
        out.extend(new_p);
        out.extend(new_m);
        out.extend(new_v);
        Ok(out)
    }

    fn forward_masked(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let np = self.param_names.len();
        let p = self.params(inputs)?;
        let mask = inputs[np].as_f32()?;
        let tokens = inputs[np + 1].as_i32()?;
        let cache = self.forward(&p, tokens, mask)?;
        let (b, t, v) = (cache.b, cache.t, self.cfg.vocab);
        Ok(vec![Value::F32(cache.logits.reshape(&[b, t, v])?)])
    }

    fn loss_masked(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let np = self.param_names.len();
        let p = self.params(inputs)?;
        let mask = inputs[np].as_f32()?;
        let tokens = inputs[np + 1].as_i32()?;
        let targets = inputs[np + 2].as_i32()?;
        let cache = self.forward(&p, tokens, mask)?;
        let ce = ce_loss(&cache.logits, targets.data(), false)?;
        Ok(vec![
            Value::scalar_f32(ce.ce * ce.cnt),
            Value::scalar_f32(ce.cnt),
        ])
    }

    fn seq_nll(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let np = self.param_names.len();
        let p = self.params(inputs)?;
        let mask = inputs[np].as_f32()?;
        let tokens = inputs[np + 1].as_i32()?;
        let targets = inputs[np + 2].as_i32()?;
        let cache = self.forward(&p, tokens, mask)?;
        let ce = ce_loss(&cache.logits, targets.data(), false)?;
        let (b, t) = (cache.b, cache.t);
        let mut nll_rows = vec![0.0f32; b];
        let mut cnt_rows = vec![0.0f32; b];
        for bi in 0..b {
            for ti in 0..t {
                let i = bi * t + ti;
                nll_rows[bi] += ce.nll_rows[i] * ce.w_rows[i];
                cnt_rows[bi] += ce.w_rows[i];
            }
        }
        Ok(vec![
            Value::F32(Tensor::from_vec(&[b], nll_rows)),
            Value::F32(Tensor::from_vec(&[b], cnt_rows)),
        ])
    }

    fn calib_pass1(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let np = self.param_names.len();
        let p = self.params(inputs)?;
        let tokens = inputs[np].as_i32()?;
        let targets = inputs[np + 1].as_i32()?;
        let cfg = &self.cfg;
        let (l, e, d) = (cfg.n_layers, cfg.n_experts, cfg.d_model);
        let mask = self.ones_mask();
        let cache = self.forward(&p, tokens, &mask)?;
        let ce = ce_loss(&cache.logits, targets.data(), true)?;
        let dlogits = ce.dlogits.as_ref().context("ce_loss(need_grad) returns dlogits")?;
        let (_g, dtaps) = self.backward(&p, tokens, &cache, dlogits, &mask, false)?;

        let n = cache.b * cache.t;
        let mut gsum = Tensor::zeros(&[l, e, d, d]);
        let mut counts = Tensor::zeros(&[l, e]);
        // (layer, expert) pairs are independent: compute each Ḡ_{l,e} on
        // the pool, then copy into the stacked output. Only routed tokens
        // (gate > 0) contribute — gather them first so the GEMM runs on a
        // dense [routed, d] matrix instead of a mostly-zero [n, d] one
        // (the GEMM layer itself never skips zeros; see tensor::gemm).
        // NaN gates count as routed so a poisoned routing weight keeps
        // poisoning the covariance instead of vanishing into zeros.
        let covs: Vec<(Tensor, f32)> = pool::par_map(l * e, |pair| {
            let (li, ei) = (pair / e, pair % e);
            let lc = &cache.layers[li];
            let routed: Vec<usize> = (0..n)
                .filter(|&r| {
                    let g = lc.gates.data()[r * e + ei];
                    g > 0.0 || g.is_nan()
                })
                .collect();
            if routed.is_empty() {
                return (Tensor::zeros(&[d, d]), 0.0);
            }
            let w: Vec<f32> =
                routed.iter().map(|&r| lc.gates.data()[r * e + ei]).collect();
            let a = row_scale(&gather0(&dtaps[li], &routed), &w);
            let cov = matmul_at(&a, &a);
            (cov, routed.len() as f32)
        });
        for (pair, (cov, cnt)) in covs.into_iter().enumerate() {
            gsum.data_mut()[pair * d * d..(pair + 1) * d * d].copy_from_slice(cov.data());
            counts.data_mut()[pair] = cnt;
        }
        Ok(vec![
            Value::scalar_f32(ce.ce),
            Value::F32(gsum),
            Value::F32(counts),
        ])
    }

    fn calib_pass2(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let np = self.param_names.len();
        let p = self.params(inputs)?;
        let tokens = inputs[np].as_i32()?;
        let cfg = &self.cfg;
        let (l, e, di) = (cfg.n_layers, cfg.n_experts, cfg.d_inter);
        let mask = self.ones_mask();
        let cache = self.forward(&p, tokens, &mask)?;
        let n = cache.b * cache.t;
        let mut hsq = Tensor::zeros(&[l, e, di]);
        let mut hmax = Tensor::zeros(&[l, e, di]);
        let mut counts = Tensor::zeros(&[l, e]);
        for li in 0..l {
            let lc = &cache.layers[li];
            for ei in 0..e {
                let h = &lc.h[ei];
                let base = (li * e + ei) * di;
                let mut cnt = 0.0f32;
                for r in 0..n {
                    if lc.gates.data()[r * e + ei] > 0.0 {
                        cnt += 1.0;
                        for c in 0..di {
                            let hv = h.data()[r * di + c];
                            hsq.data_mut()[base + c] += hv * hv;
                            let a = hv.abs();
                            if a > hmax.data()[base + c] {
                                hmax.data_mut()[base + c] = a;
                            }
                        }
                    }
                }
                counts.data_mut()[li * e + ei] = cnt;
            }
        }
        let probe =
            cache.xf.data().iter().sum::<f32>() / cache.xf.len() as f32;
        Ok(vec![
            Value::F32(hsq),
            Value::F32(hmax),
            Value::F32(counts),
            Value::scalar_f32(probe),
        ])
    }

    fn quadform(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let wd = inputs[0].as_f32()?; // [d, di]
        let gm = inputs[1].as_f32()?; // [d, d]
        let (d, di) = (wd.shape()[0], wd.shape()[1]);
        let gw = matmul_nn(gm, wd); // [d, di]
        let mut q = vec![0.0f32; di];
        for c in 0..di {
            let mut acc = 0.0f32;
            for r in 0..d {
                acc += wd.data()[r * di + c] * gw.data()[r * di + c];
            }
            q[c] = acc;
        }
        Ok(vec![Value::F32(Tensor::from_vec(&[di], q))])
    }

    fn attn_prefill(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let x = inputs[0].as_f32()?; // [b, T, d]
        let &[b, t, d] = x.shape() else { bail!("attn_prefill x must be [b,T,d]") };
        let (h, hd) = (self.cfg.n_heads, self.cfg.d_head);
        let ln1 = inputs[1].as_f32()?;
        let lm = inputs[6].as_f32()?;
        let xf = x.reshape(&[b * t, d])?;
        let xn = rmsnorm(&xf, ln1, EPS);
        let (y_att, att) = attention_forward(
            &xn,
            inputs[2].as_f32()?,
            inputs[3].as_f32()?,
            inputs[4].as_f32()?,
            inputs[5].as_f32()?,
            b,
            t,
            h,
            hd,
            Some(lm.data()),
        );
        let mut y = xf.clone();
        add_into(&mut y, &y_att);
        Ok(vec![
            Value::F32(y.reshape(&[b, t, d])?),
            Value::F32(att.k),
            Value::F32(att.v),
        ])
    }

    /// Shared decode-attention core: project the new position, append it
    /// into the caches at `pos[bi]`, and attend over the 0..=pos prefix.
    /// The caches may have any capacity S > pos — the session path binds
    /// right-sized residents, the stateless path the compiled maximum;
    /// scores, softmax and the V reduction all run over exactly the
    /// attended pos+1 rows ([`attend_softmax_v`]), so logits are bitwise
    /// independent of S *and* bitwise identical to the corresponding
    /// masked prefill row under every kernel tier. (batch, head) pairs
    /// fan out over the pool with each lane owning its cache block and
    /// output slice, so results are also bitwise thread-invariant.
    /// Mutates `kc`/`vc` in place; returns y = x + attn(x) as [b, 1, d].
    #[allow(clippy::too_many_arguments)]
    fn decode_attend(
        &self,
        x: &Tensor,
        ln1: &Tensor,
        wq: &Tensor,
        wk: &Tensor,
        wv: &Tensor,
        wo: &Tensor,
        kc: &mut Tensor,
        vc: &mut Tensor,
        pos: &ITensor,
    ) -> Result<Tensor> {
        let &[b, one, d] = x.shape() else { bail!("attn_decode x must be [b,1,d]") };
        if one != 1 {
            bail!("attn_decode wants a single position, got {one}");
        }
        let (h, hd) = (self.cfg.n_heads, self.cfg.d_head);
        let &[bk, hk, s, hdk] = kc.shape() else { bail!("kcache must be [b,H,S,hd]") };
        if bk != b || hk != h || hdk != hd || vc.shape() != kc.shape() {
            bail!(
                "decode caches must be [b={b}, h={h}, S, hd={hd}]; got k {:?} v {:?}",
                kc.shape(),
                vc.shape()
            );
        }
        for bi in 0..b {
            let p = pos.data()[bi];
            if p < 0 || p as usize >= s {
                bail!("decode position {p} outside cache capacity {s}");
            }
        }
        let xf = x.reshape(&[b, d])?;
        let xn = rmsnorm(&xf, ln1, EPS);
        let q = matmul_tn(&xn, wq); // [b, d] viewed as [b, H, hd]
        let kn = matmul_tn(&xn, wk);
        let vn = matmul_tn(&xn, wv);
        let scale = 1.0 / (hd as f32).sqrt();
        // lint:allow(hot-path-alloc) attention output buffer is consumed by the value-ABI `Tensor::from_vec` below, into the output projection
        let mut out = vec![0.0f32; b * d];
        {
            let kp = RowsPtr::new(kc.data_mut());
            let vp = RowsPtr::new(vc.data_mut());
            let op = RowsPtr::new(&mut out);
            pool::par_for(b * h, |bh| {
                let (bi, hi) = (bh / h, bh % h);
                let pmax = pos.data()[bi] as usize;
                // this lane owns the whole (bi, hi) cache block: append
                // the new position, then attend over the 0..=pmax prefix.
                // SAFETY: the s*hd k-cache blocks at bh*s*hd are disjoint
                // across lanes, in bounds (kc is b*h*s*hd), and kc
                // outlives the par_for.
                let krows = unsafe { kp.slice(bh * s * hd, s * hd) };
                // SAFETY: same argument for the v-cache (same layout).
                let vrows = unsafe { vp.slice(bh * s * hd, s * hd) };
                let src = bi * d + hi * hd;
                krows[pmax * hd..(pmax + 1) * hd]
                    .copy_from_slice(&kn.data()[src..src + hd]);
                vrows[pmax * hd..(pmax + 1) * hd]
                    .copy_from_slice(&vn.data()[src..src + hd]);
                let qrow = &q.data()[src..src + hd];
                let kk = pmax + 1;
                // lint:allow(hot-path-alloc) per-lane score row: lanes run concurrently, so shared scratch would need a per-lane pool; kk*4 bytes per (batch, head) pair
                let mut scores = vec![0.0f32; kk];
                for (si, sc) in scores.iter_mut().enumerate() {
                    let krow = &krows[si * hd..(si + 1) * hd];
                    *sc = gemm::dot_k(qrow, krow) * scale;
                }
                // SAFETY: lane bh writes only its own hd-wide block of
                // out at src = bi*d + hi*hd — disjoint per (bi, hi), in
                // bounds (out is b*d = b*h*hd), and out outlives the
                // par_for.
                let orow = unsafe { op.slice(src, hd) };
                attend_softmax_v(&mut scores, &vrows[..kk * hd], orow, hd);
            });
        }
        let y_att = matmul_tn(&Tensor::from_vec(&[b, d], out), wo);
        let mut y = xf;
        add_into(&mut y, &y_att);
        y.reshape(&[b, 1, d])
    }

    /// Stateless `attn_decode_b*` (legacy path): clones the caller's
    /// caches, appends, and returns all three outputs per the manifest.
    fn attn_decode(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        // lint:allow(hot-path-alloc) stateless artifact contract: caches are immutable inputs and owned outputs, so both copy — `attn_decode_inplace` is the no-copy path
        let (mut kc, mut vc) = (inputs[6].as_f32()?.clone(), inputs[7].as_f32()?.clone());
        let y = self.decode_attend(
            inputs[0].as_f32()?,
            inputs[1].as_f32()?,
            inputs[2].as_f32()?,
            inputs[3].as_f32()?,
            inputs[4].as_f32()?,
            inputs[5].as_f32()?,
            &mut kc,
            &mut vc,
            inputs[8].as_i32()?,
        )?;
        // lint:allow(hot-path-alloc) the artifact ABI returns owned `Vec<Value>`: a 3-element vec per call is the engine contract, not a per-token buffer
        Ok(vec![Value::F32(y), Value::F32(kc), Value::F32(vc)])
    }

    /// `attn_decode_b*` on engine-resident caches: positions 6/7
    /// (kcache/vcache) must arrive as `inout` residents; they are appended
    /// to in place — zero cache copies — and only `y` is returned.
    fn attn_decode_inplace(
        &self,
        inputs: &[Option<&Value>],
        inout: &mut [(usize, &mut Value)],
    ) -> Result<Vec<Value>> {
        let mut kc = None;
        let mut vc = None;
        for (i, v) in inout.iter_mut() {
            match *i {
                6 => kc = Some(v),
                7 => vc = Some(v),
                other => bail!("attn_decode: input {other} cannot be resident-aliased"),
            }
        }
        let (Some(kc), Some(vc)) = (kc, vc) else {
            bail!("attn_decode session call needs kcache+vcache residents")
        };
        let y = self.decode_attend(
            req(inputs, 0)?.as_f32()?,
            req(inputs, 1)?.as_f32()?,
            req(inputs, 2)?.as_f32()?,
            req(inputs, 3)?.as_f32()?,
            req(inputs, 4)?.as_f32()?,
            req(inputs, 5)?.as_f32()?,
            kc.as_f32_mut()?,
            vc.as_f32_mut()?,
            req(inputs, 8)?.as_i32()?,
        )?;
        // lint:allow(hot-path-alloc) the artifact ABI returns owned `Vec<Value>`: a 1-element vec per call is the engine contract, not a per-token buffer
        Ok(vec![Value::F32(y)])
    }

    /// `attn_decode_b*` against paged KV residents: the same projections
    /// and per-position attention as [`Self::decode_attend`], but K/V rows
    /// are appended into and read back from per-lane page tables
    /// ([`PagedKv`]) instead of a contiguous lane rectangle. `lanes[bi]`
    /// names the page-table lane batch row `bi` decodes against (the
    /// prefix-reuse tail decode binds a single shared-state lane;
    /// whole-state decode binds the identity mapping). The walk is serial
    /// over (lane, head) pairs — each pair's computation is independent
    /// and the attended V rows are gathered into one contiguous slab for
    /// [`attend_softmax_v`], so outputs are bitwise identical to the
    /// contiguous path at any capacity and thread count.
    pub(crate) fn attn_decode_paged(
        &self,
        inputs: &[Option<&Value>],
        pk: &mut PagedKv,
        kname: &str,
        vname: &str,
        lanes: &[usize],
    ) -> Result<Vec<Value>> {
        let x = req(inputs, 0)?.as_f32()?;
        let &[b, one, d] = x.shape() else { bail!("attn_decode x must be [b,1,d]") };
        if one != 1 {
            bail!("attn_decode wants a single position, got {one}");
        }
        if lanes.len() != b {
            bail!("attn_decode_paged: {} lanes bound for batch {b}", lanes.len());
        }
        let (h, hd) = (self.cfg.n_heads, self.cfg.d_head);
        if pk.heads() != h || pk.head_dim() != hd {
            bail!(
                "attn_decode_paged: pool geometry {}x{} does not match \
                 model {h}x{hd}",
                pk.heads(),
                pk.head_dim()
            );
        }
        let cap = match (pk.logical_shape(kname), pk.logical_shape(vname)) {
            (Some(ks), Some(vs)) if ks == vs => ks[2],
            (Some(ks), Some(vs)) => bail!(
                "attn_decode_paged: cache shapes differ (k {ks:?} v {vs:?})"
            ),
            _ => bail!("attn_decode_paged: {kname:?}/{vname:?} are not paged residents"),
        };
        let pos = req(inputs, 8)?.as_i32()?;
        for bi in 0..b {
            let p = pos.data()[bi];
            if p < 0 || p as usize >= cap {
                bail!("decode position {p} outside cache capacity {cap}");
            }
        }
        let ln1 = req(inputs, 1)?.as_f32()?;
        let xf = x.reshape(&[b, d])?;
        let xn = rmsnorm(&xf, ln1, EPS);
        let q = matmul_tn(&xn, req(inputs, 2)?.as_f32()?);
        let kn = matmul_tn(&xn, req(inputs, 3)?.as_f32()?);
        let vn = matmul_tn(&xn, req(inputs, 4)?.as_f32()?);
        let scale = 1.0 / (hd as f32).sqrt();
        // lint:allow(hot-path-alloc) attention output buffer is consumed by the value-ABI `Tensor::from_vec` below, into the output projection
        let mut out = vec![0.0f32; b * d];
        // the paged walk is serial, so one score row and one gathered V
        // slab serve every (lane, head) pair: grown to the deepest lane
        // once, then reused — no per-position allocations
        let mut scores: Vec<f32> = Vec::new();
        let mut vslab: Vec<f32> = Vec::new();
        for bi in 0..b {
            let pmax = pos.data()[bi] as usize;
            let lane = lanes[bi];
            let kk = pmax + 1;
            for hi in 0..h {
                let src = bi * d + hi * hd;
                pk.append_row(kname, lane, hi, pmax, &kn.data()[src..src + hd])?;
                pk.append_row(vname, lane, hi, pmax, &vn.data()[src..src + hd])?;
                let qrow = &q.data()[src..src + hd];
                scores.clear();
                scores.resize(kk, 0.0);
                for (si, sc) in scores.iter_mut().enumerate() {
                    *sc = gemm::dot_k(qrow, pk.row(kname, lane, hi, si)?) * scale;
                }
                vslab.clear();
                vslab.resize(kk * hd, 0.0);
                for si in 0..kk {
                    vslab[si * hd..(si + 1) * hd]
                        .copy_from_slice(pk.row(vname, lane, hi, si)?);
                }
                attend_softmax_v(&mut scores, &vslab, &mut out[src..src + hd], hd);
            }
        }
        let y_att = matmul_tn(&Tensor::from_vec(&[b, d], out), req(inputs, 5)?.as_f32()?);
        let mut y = xf;
        add_into(&mut y, &y_att);
        // lint:allow(hot-path-alloc) the artifact ABI returns owned `Vec<Value>`: a 1-element vec per call is the engine contract, not a per-token buffer
        Ok(vec![Value::F32(y.reshape(&[b, 1, d])?)])
    }

    /// Session entry point ([`crate::runtime::Session::run_s`]): execute
    /// `name` with manifest-ordered `inputs`, where the positions listed
    /// in `inout` are resident buffers aliased to the same-named output.
    /// Aliased residents are updated in place and omitted from the
    /// returned outputs. `attn_decode_b*` takes the no-copy append path;
    /// every other artifact falls back to the stateless path plus a
    /// write-back, so any artifact can run against residents.
    pub fn run_s(
        &self,
        name: &str,
        spec: &ArtifactSpec,
        inputs: &[Option<&Value>],
        inout: &mut [(usize, &mut Value)],
    ) -> Result<Vec<Value>> {
        if name.starts_with("attn_decode_b") {
            return self.attn_decode_inplace(inputs, inout);
        }
        // lint:allow(hot-path-alloc) non-decode fallback: `attn_decode_b*` returned above, and the remaining artifacts run per request, not per token
        let mut full: Vec<&Value> = Vec::with_capacity(inputs.len());
        for (i, slot) in inputs.iter().enumerate() {
            match slot {
                Some(v) => full.push(v),
                None => {
                    let (_, v) = inout
                        .iter()
                        .find(|(j, _)| *j == i)
                        .ok_or_else(|| anyhow!("{name}: input {i} neither given nor resident"))?;
                    full.push(v);
                }
            }
        }
        let outs = self.run(name, &full)?;
        drop(full);
        let mut kept = Vec::new();
        for (oi, out_val) in outs.into_iter().enumerate() {
            let oname = &spec.outputs[oi].name;
            let alias = spec
                .inputs
                .iter()
                .position(|io| io.name == *oname)
                .and_then(|pos| inout.iter_mut().find(|(j, _)| *j == pos));
            match alias {
                Some((_, v)) => **v = out_val,
                None => kept.push(out_val),
            }
        }
        Ok(kept)
    }

    fn moe_gate(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let x = inputs[0].as_f32()?; // [n, d]
        let ln2 = inputs[1].as_f32()?;
        let router = inputs[2].as_f32()?;
        let xn = rmsnorm(x, ln2, EPS);
        let logits = matmul_tn(&xn, router);
        let (_idx, _w, gates) = route(&logits, self.cfg.top_k);
        Ok(vec![Value::F32(xn), Value::F32(gates)])
    }

    fn lm_head(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let x = inputs[0].as_f32()?;
        let lnf = inputs[1].as_f32()?;
        let embed = inputs[2].as_f32()?;
        let xn = rmsnorm(x, lnf, EPS);
        // lint:allow(hot-path-alloc) the artifact ABI returns owned `Vec<Value>`: a 1-element vec per call is the engine contract, not a per-token buffer
        Ok(vec![Value::F32(matmul_tn(&xn, embed))])
    }

    fn expert(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let xs = inputs[0].as_f32()?; // [n, d]
        let wg = inputs[1].as_f32()?; // [w, d]
        let wu = inputs[2].as_f32()?; // [w, d]
        let wd = inputs[3].as_f32()?; // [d, w]
        let pre = matmul_tn(xs, wg);
        let u = matmul_tn(xs, wu);
        let mut h = vec![0.0f32; pre.len()];
        for i in 0..pre.len() {
            let pg = pre.data()[i];
            h[i] = pg * sigmoid(pg) * u.data()[i];
        }
        let h = Tensor::from_vec(pre.shape(), h);
        Ok(vec![Value::F32(matmul_tn(&h, wd))])
    }

    /// Execute artifact `name`. Inputs were already shape-validated against
    /// the manifest by the engine.
    pub fn run(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>> {
        match name {
            "train_step" => self.train_step(inputs),
            "forward_masked" => self.forward_masked(inputs),
            "loss_masked" => self.loss_masked(inputs),
            "seq_nll" => self.seq_nll(inputs),
            "calib_pass1" => self.calib_pass1(inputs),
            "calib_pass2" => self.calib_pass2(inputs),
            "quadform" => self.quadform(inputs),
            _ if name.starts_with("attn_prefill_b") => self.attn_prefill(inputs),
            _ if name.starts_with("attn_decode_b") => self.attn_decode(inputs),
            _ if name.starts_with("moe_gate_n") => self.moe_gate(inputs),
            _ if name.starts_with("lm_head_n") => self.lm_head(inputs),
            _ if name.starts_with("expert_n") => self.expert(inputs),
            other => bail!("host backend: unknown artifact {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::preset;
    use crate::util::rng::Pcg64;

    fn backend() -> HostBackend {
        let cfg = preset::builtin("tiny").unwrap();
        let names = preset::param_specs(&cfg).into_iter().map(|(n, _)| n).collect();
        HostBackend::new(cfg, names)
    }

    fn randt(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal() * 0.1).collect())
    }

    #[test]
    fn quadform_matches_naive_triple_loop() {
        let be = backend();
        let mut rng = Pcg64::new(1);
        let (d, di) = (16, 6);
        let wd = randt(&mut rng, &[d, di]);
        let a = randt(&mut rng, &[d, d]);
        let g = matmul_tn(&a, &a); // PSD
        let out = be
            .run("quadform", &[&Value::F32(wd.clone()), &Value::F32(g.clone())])
            .unwrap();
        let q = out.into_iter().next().unwrap().f32().unwrap();
        for c in 0..di {
            let mut want = 0.0f32;
            for i in 0..d {
                for j in 0..d {
                    want += wd.at(&[i, c]) * g.at(&[i, j]) * wd.at(&[j, c]);
                }
            }
            assert!((q.data()[c] - want).abs() < 1e-3 * want.abs().max(1e-3));
        }
    }

    #[test]
    fn route_topk_ties_pick_lowest_index() {
        let logits = Tensor::from_vec(&[1, 4], vec![1.0, 5.0, 5.0, 0.0]);
        let (idx, w, gates) = route(&logits, 2);
        assert_eq!(idx[0], vec![1, 2]); // tie -> lowest index first
        assert!((w.data()[0] - 0.5).abs() < 1e-6);
        assert!((gates.at(&[0, 1]) - 0.5).abs() < 1e-6);
        assert_eq!(gates.at(&[0, 0]), 0.0);
        assert_eq!(gates.at(&[0, 3]), 0.0);
    }

    #[test]
    fn ce_loss_uniform_logits_is_log_v() {
        let logits = Tensor::zeros(&[3, 10]);
        let out = ce_loss(&logits, &[1, 2, 3], true).unwrap();
        assert!((out.ce - (10.0f32).ln()).abs() < 1e-5);
        assert_eq!(out.cnt, 3.0);
        // gradient sums to zero per row (softmax minus one-hot)
        let d = out.dlogits.unwrap();
        for r in 0..3 {
            let s: f32 = d.data()[r * 10..(r + 1) * 10].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn rmsnorm_backward_finite_difference() {
        let mut rng = Pcg64::new(2);
        let x = randt(&mut rng, &[2, 5]);
        let w = randt(&mut rng, &[5]);
        let dy = randt(&mut rng, &[2, 5]);
        let (dx, dw) = rmsnorm_backward(&dy, &x, &w);
        let loss = |x: &Tensor, w: &Tensor| -> f32 {
            rmsnorm(x, w, EPS)
                .data()
                .iter()
                .zip(dy.data())
                .map(|(a, b)| a * b)
                .sum()
        };
        let h = 1e-3;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp.data_mut()[i] += h;
            let mut xm = x.clone();
            xm.data_mut()[i] -= h;
            let fd = (loss(&xp, &w) - loss(&xm, &w)) / (2.0 * h);
            assert!(
                (fd - dx.data()[i]).abs() < 2e-2 * fd.abs().max(0.1),
                "dx[{i}] fd={fd} got={}",
                dx.data()[i]
            );
        }
        for i in 0..w.len() {
            let mut wp = w.clone();
            wp.data_mut()[i] += h;
            let mut wm = w.clone();
            wm.data_mut()[i] -= h;
            let fd = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * h);
            assert!(
                (fd - dw.data()[i]).abs() < 2e-2 * fd.abs().max(0.1),
                "dw[{i}] fd={fd} got={}",
                dw.data()[i]
            );
        }
    }

    #[test]
    fn decode_attend_is_capacity_invariant() {
        // the session path binds right-sized KV residents (S = capacity)
        // while the stateless path runs at the compiled maximum; y and the
        // shared cache prefix must agree bitwise.
        let be = backend(); // tiny: d=64, h=2, hd=32
        let mut rng = Pcg64::new(7);
        let (b, h, hd, d) = (1, 2, 32, 64);
        let x = randt(&mut rng, &[b, 1, d]);
        let ln1 = randt(&mut rng, &[d]);
        let wq = randt(&mut rng, &[d, d]);
        let wk = randt(&mut rng, &[d, d]);
        let wv = randt(&mut rng, &[d, d]);
        let wo = randt(&mut rng, &[d, d]);
        let pos = ITensor::from_vec(&[b], vec![5]);
        let big_k = randt(&mut rng, &[b, h, 96, hd]);
        let big_v = randt(&mut rng, &[b, h, 96, hd]);
        // small caches = first 8 rows of every (b, h) block; K and V stay
        // distinct so a K/V mix-up in decode_attend cannot cancel out
        let shrink = |big: &Tensor| {
            let mut small = vec![0.0f32; b * h * 8 * hd];
            for bh in 0..b * h {
                small[bh * 8 * hd..(bh + 1) * 8 * hd]
                    .copy_from_slice(&big.data()[bh * 96 * hd..bh * 96 * hd + 8 * hd]);
            }
            Tensor::from_vec(&[b, h, 8, hd], small)
        };
        let (small_k, small_v) = (shrink(&big_k), shrink(&big_v));
        let run = |kc: &Tensor, vc: &Tensor| {
            be.run(
                "attn_decode_b1",
                &[
                    &Value::F32(x.clone()),
                    &Value::F32(ln1.clone()),
                    &Value::F32(wq.clone()),
                    &Value::F32(wk.clone()),
                    &Value::F32(wv.clone()),
                    &Value::F32(wo.clone()),
                    &Value::F32(kc.clone()),
                    &Value::F32(vc.clone()),
                    &Value::I32(pos.clone()),
                ],
            )
            .unwrap()
        };
        let out_big = run(&big_k, &big_v);
        let out_small = run(&small_k, &small_v);
        let yb = out_big[0].clone().f32().unwrap();
        let ys = out_small[0].clone().f32().unwrap();
        assert_eq!(yb, ys, "logit path must not depend on cache capacity");
        // appended row matches across capacities too
        let kb = out_big[1].clone().f32().unwrap();
        let ks = out_small[1].clone().f32().unwrap();
        for bh in 0..b * h {
            assert_eq!(
                &kb.data()[(bh * 96 + 5) * hd..(bh * 96 + 6) * hd],
                &ks.data()[(bh * 8 + 5) * hd..(bh * 8 + 6) * hd],
            );
        }
        // a position outside the small capacity is rejected
        let bad = ITensor::from_vec(&[b], vec![8]);
        let r = be.run(
            "attn_decode_b1",
            &[
                &Value::F32(x.clone()),
                &Value::F32(ln1.clone()),
                &Value::F32(wq.clone()),
                &Value::F32(wk.clone()),
                &Value::F32(wv.clone()),
                &Value::F32(wo.clone()),
                &Value::F32(small_k.clone()),
                &Value::F32(small_v.clone()),
                &Value::I32(bad),
            ],
        );
        assert!(r.is_err(), "position >= capacity must error");
    }

    #[test]
    fn expert_artifact_is_silu_gated_ffn() {
        let be = backend();
        let mut rng = Pcg64::new(3);
        let (n, d, w) = (4, 8, 6);
        let xs = randt(&mut rng, &[n, d]);
        let wg = randt(&mut rng, &[w, d]);
        let wu = randt(&mut rng, &[w, d]);
        let wd = randt(&mut rng, &[d, w]);
        let out = be
            .run(
                "expert_n4_w6",
                &[
                    &Value::F32(xs.clone()),
                    &Value::F32(wg.clone()),
                    &Value::F32(wu.clone()),
                    &Value::F32(wd.clone()),
                ],
            )
            .unwrap();
        let ys = out.into_iter().next().unwrap().f32().unwrap();
        // one element by hand
        let (r, c) = (1, 2);
        let mut want = 0.0f32;
        for k in 0..w {
            let mut pre = 0.0f32;
            let mut up = 0.0f32;
            for j in 0..d {
                pre += xs.at(&[r, j]) * wg.at(&[k, j]);
                up += xs.at(&[r, j]) * wu.at(&[k, j]);
            }
            want += (pre * sigmoid(pre) * up) * wd.at(&[c, k]);
        }
        assert!((ys.at(&[r, c]) - want).abs() < 1e-4);
    }
}
