//! Page-granular KV residency: a refcounted pool of fixed-size pages plus
//! per-lane page tables, generalizing the contiguous lane rectangle that
//! [`super::Session`] residents used to be.
//!
//! A *page* holds `page` consecutive sequence positions of one lane across
//! all heads — an `[h, page, hd]` f32 slab. A paged resident (one per KV
//! cache tensor, e.g. `kc0`) is a table of `ceil(capacity / page)` page
//! slots per lane; `None` slots read as logical zeros, so allocating a
//! resident maps nothing and moves no bytes. Pages are refcounted: two
//! lanes whose prompts share a prefix can map the same physical pages
//! (`share_prefix`), and a retiring lane's release only returns a page to
//! the free list when the last mapping drops ([`PagedKv::zero_lane`] is
//! refcount-aware by construction). Shared pages are immutable —
//! [`KvPool::page_mut`] refuses refcounts above one, so the decode append
//! path can never write through an alias; tails always land on fresh
//! (refcount 1) pages.
//!
//! The accounting story mirrors the dense resident contract upside down:
//! dense `alloc_resident` pays the full `[lanes, h, capacity, hd]` upload
//! at admission even though a short request touches a fraction of it;
//! paged allocation pays nothing until rows are written, a prefix map pays
//! nothing ever, and a lane's footprint is `ceil(rows / page)` pages —
//! which is what lets a fixed byte budget seat strictly more mixed-extent
//! lanes (see `rust/tests/paged_kv.rs`).

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

use crate::tensor::Tensor;

/// Index of a physical page inside a [`KvPool`].
pub type PageId = usize;

struct PageSlot {
    data: Vec<f32>,
    /// 0 = on the free list; otherwise the number of lane-table mappings.
    refs: u32,
}

/// Refcounted pool of equally-sized f32 pages with an optional hard
/// budget. Freed pages are recycled (and re-zeroed at allocation, so a
/// recycled page can never leak a previous occupant's rows).
pub struct KvPool {
    page_elems: usize,
    slots: Vec<PageSlot>,
    free: Vec<PageId>,
    /// Hard cap on simultaneously-live pages (`None` = unbounded).
    budget: Option<usize>,
    live: usize,
    peak: usize,
    allocated_total: u64,
}

impl KvPool {
    fn new(page_elems: usize, budget: Option<usize>) -> KvPool {
        KvPool {
            page_elems,
            slots: Vec::new(),
            free: Vec::new(),
            budget,
            live: 0,
            peak: 0,
            allocated_total: 0,
        }
    }

    /// Allocate a zeroed page with refcount 1.
    fn alloc(&mut self) -> Result<PageId> {
        if let Some(b) = self.budget {
            if self.live >= b {
                bail!("kv pool budget exhausted: {b} pages live");
            }
        }
        let id = match self.free.pop() {
            Some(id) => {
                let s = &mut self.slots[id];
                debug_assert_eq!(s.refs, 0);
                s.data.fill(0.0);
                s.refs = 1;
                id
            }
            None => {
                self.slots.push(PageSlot {
                    data: vec![0.0; self.page_elems],
                    refs: 1,
                });
                self.slots.len() - 1
            }
        };
        self.live += 1;
        self.peak = self.peak.max(self.live);
        self.allocated_total += 1;
        Ok(id)
    }

    /// Add a mapping to a live page (prefix sharing).
    fn retain(&mut self, id: PageId) -> Result<()> {
        let s = self
            .slots
            .get_mut(id)
            .ok_or_else(|| anyhow!("kv pool: retain of unknown page {id}"))?;
        if s.refs == 0 {
            bail!("kv pool: retain of freed page {id}");
        }
        s.refs += 1;
        Ok(())
    }

    /// Drop one mapping; frees the page when the last mapping drops.
    /// Returns whether the page was actually freed.
    fn release(&mut self, id: PageId) -> Result<bool> {
        let s = self
            .slots
            .get_mut(id)
            .ok_or_else(|| anyhow!("kv pool: release of unknown page {id}"))?;
        if s.refs == 0 {
            bail!("kv pool: double release of page {id}");
        }
        s.refs -= 1;
        if s.refs == 0 {
            self.free.push(id);
            self.live -= 1;
            return Ok(true);
        }
        Ok(false)
    }

    fn page(&self, id: PageId) -> &[f32] {
        &self.slots[id].data
    }

    /// Mutable page access — refused for shared pages, which is the
    /// aliasing guarantee: a decode append can never write through a
    /// mapping another lane also holds.
    fn page_mut(&mut self, id: PageId) -> Result<&mut [f32]> {
        let s = &mut self.slots[id];
        if s.refs != 1 {
            bail!(
                "kv pool: mutable access to page {id} with {} mappings \
                 (shared pages are immutable)",
                s.refs
            );
        }
        Ok(&mut s.data)
    }
}

/// One paged resident: per-lane page tables over the shared pool.
struct PagedResident {
    /// Logical `[lanes, h, capacity, hd]` shape (what the dense resident
    /// would have been).
    shape: Vec<usize>,
    pages_per_lane: usize,
    /// `tables[lane][pg]` maps logical page `pg` (positions
    /// `pg*page .. (pg+1)*page`) to a physical page; `None` reads as
    /// zeros.
    tables: Vec<Vec<Option<PageId>>>,
}

/// The paged replacement for a session's KV residents: named logical
/// `[lanes, h, capacity, hd]` tensors whose storage is page tables over
/// one shared [`KvPool`].
pub struct PagedKv {
    /// Sequence positions per page.
    page: usize,
    h: usize,
    hd: usize,
    pool: KvPool,
    residents: BTreeMap<String, PagedResident>,
    /// Zero row returned for reads of unmapped pages.
    zero_row: Vec<f32>,
}

impl PagedKv {
    /// `page` positions per page, `h`×`hd` attention geometry,
    /// `budget_pages` optional hard cap on live physical pages.
    pub fn new(page: usize, h: usize, hd: usize, budget_pages: Option<usize>) -> Result<PagedKv> {
        if page == 0 || h == 0 || hd == 0 {
            bail!("paged kv: page/heads/head_dim must be nonzero (got {page}/{h}/{hd})");
        }
        Ok(PagedKv {
            page,
            h,
            hd,
            pool: KvPool::new(h * page * hd, budget_pages),
            residents: BTreeMap::new(),
            zero_row: vec![0.0; hd],
        })
    }

    pub fn page_size(&self) -> usize {
        self.page
    }

    pub fn heads(&self) -> usize {
        self.h
    }

    pub fn head_dim(&self) -> usize {
        self.hd
    }

    /// Bytes of one physical page.
    pub fn page_bytes(&self) -> usize {
        self.pool.page_elems * 4
    }

    /// Physical pages currently live / high-water mark / ever allocated.
    pub fn live_pages(&self) -> usize {
        self.pool.live
    }

    pub fn peak_pages(&self) -> usize {
        self.pool.peak
    }

    pub fn pages_allocated_total(&self) -> u64 {
        self.pool.allocated_total
    }

    /// Bytes currently held by live pages (the paged analogue of
    /// `Session::resident_bytes`).
    pub fn resident_bytes(&self) -> u64 {
        (self.pool.live * self.pool.page_elems * 4) as u64
    }

    pub fn has(&self, name: &str) -> bool {
        self.residents.contains_key(name)
    }

    /// Logical dense shape the resident stands in for.
    pub fn logical_shape(&self, name: &str) -> Option<&[usize]> {
        self.residents.get(name).map(|r| r.shape.as_slice())
    }

    /// Names of every paged resident (deterministic order).
    pub fn resident_names(&self) -> impl Iterator<Item = &str> {
        self.residents.keys().map(String::as_str)
    }

    fn resident(&self, name: &str) -> Result<&PagedResident> {
        self.residents
            .get(name)
            .ok_or_else(|| anyhow!("no paged resident {name:?}"))
    }

    fn lane_table<'a>(&'a self, name: &str, lane: usize) -> Result<&'a [Option<PageId>]> {
        let r = self.resident(name)?;
        r.tables
            .get(lane)
            .map(|t| t.as_slice())
            .ok_or_else(|| anyhow!("paged resident {name:?}: lane {lane} out of range"))
    }

    /// Allocate (or replace) a paged resident: `lanes` all-unmapped page
    /// tables covering `capacity` positions. Maps no pages and moves no
    /// bytes — storage is paid lazily as rows are written.
    pub fn alloc_resident(
        &mut self,
        name: impl Into<String>,
        lanes: usize,
        capacity: usize,
    ) -> Result<()> {
        let name = name.into();
        if lanes == 0 || capacity == 0 {
            bail!("paged resident {name:?}: lanes/capacity must be nonzero");
        }
        self.free_resident(&name)?;
        let pages_per_lane = capacity.div_ceil(self.page);
        self.residents.insert(
            name,
            PagedResident {
                shape: vec![lanes, self.h, capacity, self.hd],
                pages_per_lane,
                tables: vec![vec![None; pages_per_lane]; lanes],
            },
        );
        Ok(())
    }

    /// Release every page a resident maps and drop it; returns whether it
    /// existed.
    pub fn free_resident(&mut self, name: &str) -> Result<bool> {
        let Some(r) = self.residents.remove(name) else {
            return Ok(false);
        };
        for table in &r.tables {
            for id in table.iter().flatten() {
                self.pool.release(*id)?;
            }
        }
        Ok(true)
    }

    /// Mapped page count of one lane (its physical footprint in pages,
    /// shared or not).
    pub fn lane_pages(&self, name: &str, lane: usize) -> Result<usize> {
        Ok(self.lane_table(name, lane)?.iter().flatten().count())
    }

    /// Seat a lane from a dense single-lane tensor (`[1, h, rows, hd]`,
    /// exact head geometry): release whatever the lane mapped, then map
    /// `ceil(min(rows, capacity) / page)` fresh pages and copy the rows
    /// in. Rows beyond `rows` read as zeros (unmapped) — the paged
    /// equivalent of dense `write_lane`'s zero-then-copy contract.
    pub fn write_lane(&mut self, name: &str, lane: usize, src: &Tensor) -> Result<()> {
        let ss = src.shape().to_vec();
        let (h, hd) = (self.h, self.hd);
        if ss.len() != 4 || ss[0] != 1 || ss[1] != h || ss[3] != hd {
            bail!(
                "paged write_lane {name:?}: src shape {ss:?} is not \
                 [1, {h}, rows, {hd}]"
            );
        }
        let rows_src = ss[2];
        let r = self.resident(name)?;
        let cap = r.shape[2];
        if lane >= r.tables.len() {
            bail!("paged write_lane {name:?}: lane {lane} out of range");
        }
        let rows = rows_src.min(cap);
        let npages = rows.div_ceil(self.page);
        self.zero_lane(name, lane)?;
        let mut ids = Vec::with_capacity(npages);
        for _ in 0..npages {
            match self.pool.alloc() {
                Ok(id) => ids.push(id),
                Err(e) => {
                    // roll back the partial allocation so nothing leaks
                    for id in ids {
                        self.pool.release(id)?;
                    }
                    return Err(e);
                }
            }
        }
        let page = self.page;
        let data = src.data();
        for (pg, id) in ids.iter().enumerate() {
            let slab = self.pool.page_mut(*id)?;
            let lo = pg * page;
            let hi_row = ((pg + 1) * page).min(rows);
            for hi in 0..h {
                for si in lo..hi_row {
                    let s = (hi * rows_src + si) * hd;
                    let d = (hi * page + (si - lo)) * hd;
                    slab[d..d + hd].copy_from_slice(&data[s..s + hd]);
                }
            }
        }
        let r = self.residents.get_mut(name).context("resident exists: checked above")?;
        for (pg, id) in ids.into_iter().enumerate() {
            r.tables[lane][pg] = Some(id);
        }
        Ok(())
    }

    /// Unmap every page of a lane (lane retirement). Refcount-aware: a
    /// page still mapped by another lane (a shared prefix page) survives —
    /// only this lane's mappings drop.
    pub fn zero_lane(&mut self, name: &str, lane: usize) -> Result<()> {
        let r = self
            .residents
            .get_mut(name)
            .ok_or_else(|| anyhow!("no paged resident {name:?}"))?;
        if lane >= r.tables.len() {
            bail!("paged zero_lane {name:?}: lane {lane} out of range");
        }
        let ids: Vec<PageId> = r.tables[lane].iter_mut().filter_map(|e| e.take()).collect();
        for id in ids {
            self.pool.release(id)?;
        }
        Ok(())
    }

    /// Map the first `npages` pages of `src_lane` into `dst_lane`
    /// (refcount++, zero bytes moved) — the prefix-reuse admission
    /// primitive. Requires those source pages mapped and the destination
    /// slots unmapped. Returns the number of physical pages shared.
    pub fn share_prefix(
        &mut self,
        name: &str,
        src_lane: usize,
        dst_lane: usize,
        npages: usize,
    ) -> Result<usize> {
        if src_lane == dst_lane {
            bail!("paged share_prefix {name:?}: src and dst are both lane {src_lane}");
        }
        let r = self.resident(name)?;
        if src_lane >= r.tables.len() || dst_lane >= r.tables.len() {
            bail!("paged share_prefix {name:?}: lane out of range");
        }
        if npages > r.pages_per_lane {
            bail!(
                "paged share_prefix {name:?}: {npages} pages exceed the \
                 {}-page table",
                r.pages_per_lane
            );
        }
        let mut ids = Vec::with_capacity(npages);
        for pg in 0..npages {
            match r.tables[src_lane][pg] {
                Some(id) => ids.push(id),
                None => bail!(
                    "paged share_prefix {name:?}: source lane {src_lane} \
                     page {pg} is unmapped"
                ),
            }
            if r.tables[dst_lane][pg].is_some() {
                bail!(
                    "paged share_prefix {name:?}: destination lane {dst_lane} \
                     page {pg} is already mapped"
                );
            }
        }
        for id in &ids {
            self.pool.retain(*id)?;
        }
        let r = self.residents.get_mut(name).context("resident exists: checked above")?;
        for (pg, id) in ids.iter().enumerate() {
            r.tables[dst_lane][pg] = Some(*id);
        }
        Ok(ids.len())
    }

    /// Append one head-row at position `si` (the decode KV append).
    /// Allocates the covering page on first touch; refuses to write a
    /// shared page (tails must land on fresh pages — see `page_mut`).
    pub fn append_row(
        &mut self,
        name: &str,
        lane: usize,
        hi: usize,
        si: usize,
        row: &[f32],
    ) -> Result<()> {
        if row.len() != self.hd || hi >= self.h {
            bail!(
                "paged append_row {name:?}: head {hi}/{} row len {}/{}",
                self.h,
                row.len(),
                self.hd
            );
        }
        let r = self.resident(name)?;
        let cap = r.shape[2];
        if lane >= r.tables.len() || si >= cap {
            bail!(
                "paged append_row {name:?}: lane {lane} position {si} out of \
                 range (capacity {cap})"
            );
        }
        let (page, hd) = (self.page, self.hd);
        let pg = si / page;
        let id = match r.tables[lane][pg] {
            Some(id) => id,
            None => {
                let id = self.pool.alloc()?;
                self.residents
                    .get_mut(name)
                    .context("resident exists: checked above")?
                    .tables[lane][pg] = Some(id);
                id
            }
        };
        let slab = self.pool.page_mut(id)?;
        let d = (hi * page + si % page) * hd;
        slab[d..d + hd].copy_from_slice(row);
        Ok(())
    }

    /// Read one head-row at position `si`; unmapped pages read as zeros.
    pub fn row(&self, name: &str, lane: usize, hi: usize, si: usize) -> Result<&[f32]> {
        let r = self.resident(name)?;
        let cap = r.shape[2];
        if lane >= r.tables.len() || hi >= self.h || si >= cap {
            bail!(
                "paged row {name:?}: lane {lane} head {hi} position {si} out \
                 of range"
            );
        }
        Ok(match r.tables[lane][si / self.page] {
            Some(id) => {
                let d = (hi * self.page + si % self.page) * self.hd;
                &self.pool.page(id)[d..d + self.hd]
            }
            None => &self.zero_row,
        })
    }

    /// Gather `rows` positions of one lane into a dense `[1, h, rows, hd]`
    /// tensor (compaction / readback).
    pub fn lane_rows(&self, name: &str, lane: usize, rows: usize) -> Result<Tensor> {
        let r = self.resident(name)?;
        let cap = r.shape[2];
        let rows = rows.min(cap).max(1);
        let (h, hd) = (self.h, self.hd);
        let mut out = vec![0.0f32; h * rows * hd];
        for hi in 0..h {
            for si in 0..rows {
                let src = self.row(name, lane, hi, si)?;
                let d = (hi * rows + si) * hd;
                out[d..d + hd].copy_from_slice(src);
            }
        }
        Ok(Tensor::from_vec(&[1, h, rows, hd], out))
    }

    /// Gather the full logical `[lanes, h, capacity, hd]` dense tensor
    /// (unmapped pages read as zeros) — the paged `download`.
    pub fn dense(&self, name: &str) -> Result<Tensor> {
        let r = self.resident(name)?;
        let (lanes, h, cap, hd) = (r.shape[0], r.shape[1], r.shape[2], r.shape[3]);
        let mut out = vec![0.0f32; lanes * h * cap * hd];
        let lane_sz = h * cap * hd;
        for lane in 0..lanes {
            for hi in 0..h {
                for si in 0..cap {
                    let src = self.row(name, lane, hi, si)?;
                    let d = lane * lane_sz + (hi * cap + si) * hd;
                    out[d..d + hd].copy_from_slice(src);
                }
            }
        }
        Ok(Tensor::from_vec(&[lanes, h, cap, hd], out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pk(page: usize) -> PagedKv {
        PagedKv::new(page, 2, 4, None).unwrap()
    }

    fn lane_tensor(h: usize, rows: usize, hd: usize, base: f32) -> Tensor {
        let data: Vec<f32> = (0..h * rows * hd).map(|i| base + i as f32).collect();
        Tensor::from_vec(&[1, h, rows, hd], data)
    }

    #[test]
    fn alloc_is_lazy_and_write_maps_ceil_rows_over_page() {
        let mut p = pk(4);
        p.alloc_resident("kc0", 3, 16).unwrap();
        assert_eq!(p.live_pages(), 0);
        p.write_lane("kc0", 1, &lane_tensor(2, 6, 4, 0.0)).unwrap();
        assert_eq!(p.lane_pages("kc0", 1).unwrap(), 2); // ceil(6/4)
        assert_eq!(p.live_pages(), 2);
        // reads round-trip, rows beyond the write read as zeros
        assert_eq!(p.row("kc0", 1, 0, 0).unwrap(), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(p.row("kc0", 1, 1, 5).unwrap(), &[44.0, 45.0, 46.0, 47.0]);
        assert_eq!(p.row("kc0", 1, 0, 7).unwrap(), &[0.0; 4]);
        assert_eq!(p.row("kc0", 1, 0, 15).unwrap(), &[0.0; 4]);
    }

    #[test]
    fn shared_page_survives_sharer_retirement_and_refuses_writes() {
        let mut p = pk(4);
        p.alloc_resident("kc0", 2, 16).unwrap();
        p.write_lane("kc0", 0, &lane_tensor(2, 8, 4, 1.0)).unwrap();
        assert_eq!(p.share_prefix("kc0", 0, 1, 2).unwrap(), 2);
        assert_eq!(p.live_pages(), 2); // shared, not copied
        // appends into a shared page are refused
        assert!(p.append_row("kc0", 1, 0, 3, &[9.0; 4]).is_err());
        // the sharer retires; the pages stay live for lane 0
        p.zero_lane("kc0", 1).unwrap();
        assert_eq!(p.live_pages(), 2);
        assert_eq!(p.row("kc0", 0, 0, 0).unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        // now exclusive again: lane 0 may append past its rows
        p.append_row("kc0", 0, 0, 8, &[5.0; 4]).unwrap();
        assert_eq!(p.live_pages(), 3);
    }

    #[test]
    fn budget_caps_live_pages() {
        let mut p = PagedKv::new(4, 2, 4, Some(2)).unwrap();
        p.alloc_resident("kc0", 2, 32).unwrap();
        p.write_lane("kc0", 0, &lane_tensor(2, 8, 4, 0.0)).unwrap(); // 2 pages
        assert!(p.append_row("kc0", 1, 0, 0, &[1.0; 4]).is_err()); // over budget
        p.zero_lane("kc0", 0).unwrap();
        p.append_row("kc0", 1, 0, 0, &[1.0; 4]).unwrap(); // freed capacity reusable
        assert_eq!(p.live_pages(), 1);
    }

    #[test]
    fn recycled_page_is_zeroed() {
        let mut p = pk(4);
        p.alloc_resident("kc0", 2, 8).unwrap();
        p.write_lane("kc0", 0, &lane_tensor(2, 4, 4, 7.0)).unwrap();
        p.zero_lane("kc0", 0).unwrap();
        // the freed physical page comes back for lane 1; only position 0
        // row 0 is written — everything else must read zero
        p.append_row("kc0", 1, 0, 0, &[1.0; 4]).unwrap();
        assert_eq!(p.row("kc0", 1, 0, 1).unwrap(), &[0.0; 4]);
        assert_eq!(p.row("kc0", 1, 1, 0).unwrap(), &[0.0; 4]);
    }

    #[test]
    fn write_lane_truncates_to_capacity_and_validates_geometry() {
        let mut p = pk(4);
        p.alloc_resident("kc0", 1, 8).unwrap();
        p.write_lane("kc0", 0, &lane_tensor(2, 12, 4, 0.0)).unwrap();
        assert_eq!(p.lane_pages("kc0", 0).unwrap(), 2); // capacity 8 = 2 pages
        assert!(p.write_lane("kc0", 0, &Tensor::zeros(&[1, 3, 4, 4])).is_err());
        assert!(p.write_lane("kc0", 0, &Tensor::zeros(&[2, 2, 4, 4])).is_err());
    }

    #[test]
    fn share_prefix_validates_mapping_state() {
        let mut p = pk(4);
        p.alloc_resident("kc0", 3, 16).unwrap();
        p.write_lane("kc0", 0, &lane_tensor(2, 4, 4, 0.0)).unwrap();
        // more pages than the source has mapped
        assert!(p.share_prefix("kc0", 0, 1, 2).is_err());
        p.write_lane("kc0", 1, &lane_tensor(2, 4, 4, 0.0)).unwrap();
        // destination already mapped
        assert!(p.share_prefix("kc0", 0, 1, 1).is_err());
        assert!(p.share_prefix("kc0", 0, 0, 1).is_err()); // self-share
        assert_eq!(p.share_prefix("kc0", 0, 2, 1).unwrap(), 1);
    }

    #[test]
    fn free_resident_returns_every_page() {
        let mut p = pk(4);
        p.alloc_resident("kc0", 2, 8).unwrap();
        p.write_lane("kc0", 0, &lane_tensor(2, 8, 4, 0.0)).unwrap();
        p.share_prefix("kc0", 0, 1, 2).unwrap();
        assert!(p.free_resident("kc0").unwrap());
        assert_eq!(p.live_pages(), 0);
        assert!(!p.free_resident("kc0").unwrap());
    }

    #[test]
    fn dense_and_lane_rows_gather_with_zero_fill() {
        let mut p = pk(4);
        p.alloc_resident("kc0", 2, 8).unwrap();
        let t = lane_tensor(2, 4, 4, 3.0);
        p.write_lane("kc0", 1, &t).unwrap();
        let d = p.dense("kc0").unwrap();
        assert_eq!(d.shape(), &[2, 2, 8, 4]);
        assert!(d.data()[..2 * 8 * 4].iter().all(|&x| x == 0.0)); // lane 0 unmapped
        let g = p.lane_rows("kc0", 1, 4).unwrap();
        assert_eq!(g.shape(), &[1, 2, 4, 4]);
        assert_eq!(g.data(), t.data());
    }
}
