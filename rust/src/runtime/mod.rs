//! PJRT runtime: load HLO-text artifacts, compile once, execute many.
//!
//! The contract with the build side (python/compile/aot.py):
//! * artifacts are HLO *text* — xla_extension 0.5.1 rejects jax>=0.5's
//!   64-bit-id serialized protos, the text parser reassigns ids;
//! * every artifact returns a tuple (lowered with return_tuple=True);
//! * `manifest.json` records each artifact's ordered input/output specs,
//!   which [`Engine::run`] validates on every call — a shape mismatch is a
//!   bug report at the call site instead of a PJRT abort.

pub mod manifest;
pub mod value;

pub use manifest::{ArtifactSpec, IoSpec, Manifest};
pub use value::Value;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelConfig;
use crate::debug;

/// Compiled-executable cache keyed by artifact name, over one PJRT CPU
/// client. Not Send/Sync (PJRT handles are raw pointers): the serving
/// coordinator owns one Engine on a dedicated execution thread.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// (artifact, calls) counters for the perf report.
    calls: RefCell<HashMap<String, usize>>,
}

impl Engine {
    /// Open `artifacts/<preset>/` (must contain manifest.json).
    pub fn open(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?}"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Engine {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            calls: RefCell::new(HashMap::new()),
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.manifest.preset
    }

    /// Compile (or fetch cached) an artifact's executable.
    fn executable(&self, name: &str) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.dir.join(&spec.file);
        let t = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        debug!("compiled {name} in {:.2}s", t.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Pre-compile a set of artifacts (serving startup).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute `name` with `inputs` (order per manifest). Returns outputs
    /// in manifest order.
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self.manifest.artifact(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (v, io) in inputs.iter().zip(&spec.inputs) {
            if v.shape() != io.shape.as_slice() || v.dtype() != io.dtype {
                bail!(
                    "{name}: input {:?} got shape {:?} dtype {}, want {:?} {}",
                    io.name,
                    v.shape(),
                    v.dtype(),
                    io.shape,
                    io.dtype
                );
            }
        }
        self.executable(name)?;
        *self.calls.borrow_mut().entry(name.to_string()).or_insert(0) += 1;

        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        let cache = self.cache.borrow();
        let exe = cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e}"))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} output: {e}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{name}: {} outputs, manifest wants {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, io)| Value::from_literal(&lit, io))
            .collect()
    }

    /// Per-artifact call counts (perf accounting).
    pub fn call_counts(&self) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> =
            self.calls.borrow().iter().map(|(k, &c)| (k.clone(), c)).collect();
        v.sort();
        v
    }

    // -- device-resident inputs (perf path) ---------------------------------
    //
    // `run` marshals every input host->literal->device on every call. For
    // loops that reuse large constant inputs (model params in eval/calib,
    // expert weights in serving) that is pure overhead: `upload` pins a
    // Value as a device buffer once, and `run_b` executes on buffers.
    // Measured impact is logged in EXPERIMENTS.md §Perf.

    /// Pin a host value as a device-resident buffer.
    ///
    /// The source Literal MUST outlive the transfer: BufferFromHostLiteral
    /// is asynchronous and the 0.5.1 C shim does not await the copy (the
    /// literal-input `execute` path does, explicitly, for this reason).
    /// DeviceTensor therefore owns the literal for the buffer's lifetime.
    pub fn upload(&self, v: &Value) -> Result<DeviceTensor> {
        let lit = v.to_literal()?;
        let buf = self
            .client
            .buffer_from_host_literal(None, &lit)
            .map_err(|e| anyhow!("upload: {e}"))?;
        Ok(DeviceTensor { _lit: lit, buf })
    }

    /// Execute on pre-uploaded buffers (mixed with per-call inputs the
    /// caller uploads itself). Shape validation already happened at upload
    /// construction time; PJRT still checks buffer count/types.
    pub fn run_b(&self, name: &str, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<Value>> {
        let spec = self.manifest.artifact(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} buffers given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        self.executable(name)?;
        *self.calls.borrow_mut().entry(name.to_string()).or_insert(0) += 1;
        let cache = self.cache.borrow();
        let exe = cache.get(name).unwrap();
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| anyhow!("executing {name} (buffers): {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e}"))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} output: {e}"))?;
        if parts.len() != spec.outputs.len() {
            bail!("{name}: {} outputs, manifest wants {}", parts.len(), spec.outputs.len());
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, io)| Value::from_literal(&lit, io))
            .collect()
    }
}

/// A device-resident tensor: the PJRT buffer plus the host literal backing
/// the (possibly still in-flight) transfer.
pub struct DeviceTensor {
    _lit: xla::Literal,
    pub buf: xla::PjRtBuffer,
}

/// A set of pre-uploaded buffers (e.g. all model params), reusable across
/// many `run_b` calls.
pub struct BufferSet {
    pub tensors: Vec<DeviceTensor>,
}

impl BufferSet {
    pub fn upload(engine: &Engine, values: &[Value]) -> Result<BufferSet> {
        Ok(BufferSet {
            tensors: values
                .iter()
                .map(|v| engine.upload(v))
                .collect::<Result<_>>()?,
        })
    }

    pub fn refs(&self) -> Vec<&xla::PjRtBuffer> {
        self.tensors.iter().map(|t| &t.buf).collect()
    }
}
