//! Runtime: execute AOT artifacts behind a backend-agnostic [`Engine`].
//!
//! Two backends implement the same artifact contract (manifest-validated
//! inputs in, manifest-ordered outputs out):
//!
//! * **host** (default) — pure-rust execution of every artifact by name
//!   ([`host`]), pool-parallel via `HEAPR_THREADS`. Needs no artifacts on
//!   disk: when `manifest.json` is absent the manifest is synthesized from
//!   the built-in preset tables ([`preset`]), which mirror
//!   `python/compile/configs.py` exactly.
//! * **pjrt** (feature `pjrt`) — the original PJRT path: parse HLO text,
//!   compile once through the `xla` crate, execute many. The offline image
//!   has no `xla` crate, so the feature is off by default and enabling it
//!   requires adding that dependency (see README §Backends).
//!
//! The host engine is `Send + Sync` (state behind a `Mutex`), which is
//! what lets `heapr::importance_scores` fan `quadform` calls across the
//! thread pool. The PJRT engine is neither (raw FFI pointers) — callers
//! that share an engine across threads only compile in host builds.
//!
//! # Calling conventions, in increasing residency
//!
//! 1. [`Engine::run`] — every input marshalled host->device per call;
//! 2. [`Engine::upload`] + [`Engine::run_b`] — constants pinned once,
//!    per-call inputs only;
//! 3. [`Engine::session`] + [`Session::run_s`] — named *mutable*
//!    residents that artifacts read and write in place (an input whose
//!    manifest name matches an output is aliased — the decode KV append).
//!
//! Residents are additionally **lane-addressable**: index `i` of a
//! resident's leading (batch) axis can be overwritten
//! ([`Session::write_lane`]) or cleared ([`Session::zero_lane`])
//! without touching the other lanes — the primitive the continuous
//! scheduler uses to admit a new sequence into a decode lane freed
//! mid-flight, and to retire lanes one by one instead of per batch.
//! [`Engine::upload_stats`] prices every convention so the serving
//! metrics can prove what moved: `run` pays per call, `upload` /
//! `alloc_resident` / `write_lane` pay once, `run_b` and resident args
//! are free.
//!
//! A session may instead hold its KV residents **paged**
//! ([`Session::alloc_paged`] + [`Session::alloc_paged_resident`]): lanes
//! become page tables over a refcounted pool ([`kv::PagedKv`]) rather
//! than slices of a dense rectangle. The lane primitives keep their
//! contracts (`write_lane` pays the source bytes, `zero_lane` is free and
//! leak-proof), allocation itself pays *nothing* (pages map lazily as
//! rows are written), and two lanes can share prompt-prefix pages by
//! refcount ([`Session::map_prefix`], also free). [`SArg::ResLane`] binds
//! a single lane of a paged resident to a batch-1 decode artifact — the
//! prefix-reuse tail-prefill primitive.

pub mod host;
pub mod kv;
pub mod manifest;
pub mod preset;
pub mod value;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use kv::PagedKv;
pub use manifest::{ArtifactSpec, Dtype, IoSpec, Manifest};
pub use value::{Literal, Value};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelConfig;
use crate::debug;
use crate::tensor::Tensor;

enum Backend {
    Host(host::HostBackend),
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtBackend),
}

/// Artifact executor over one backend, with per-artifact call accounting.
pub struct Engine {
    dir: PathBuf,
    pub manifest: Manifest,
    backend: Backend,
    /// (artifact, calls) counters for the perf report.
    calls: Mutex<HashMap<String, usize>>,
    /// (transfer events, bytes) of host->device traffic: `upload` pins,
    /// per-call `run` literal marshalling, session `Val` args and resident
    /// allocation. `run_b` consumes pre-uploaded buffers and adds nothing —
    /// the delta between the two is exactly what the serving §Perf
    /// before/after measures.
    uploads: Mutex<(usize, u64)>,
}

impl Engine {
    /// Open `artifacts/<preset>/`. Loads `manifest.json` when present;
    /// otherwise synthesizes the manifest for a built-in preset named by
    /// the directory's basename (`tiny` | `small` | `base`), which is all
    /// the host backend needs.
    pub fn open(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let manifest = if mpath.exists() {
            Manifest::load(&mpath)
                .with_context(|| format!("loading manifest from {dir:?}"))?
        } else {
            let base = dir
                .file_name()
                .and_then(|s| s.to_str())
                .unwrap_or_default();
            let cfg = preset::builtin(base).ok_or_else(|| {
                anyhow!(
                    "no manifest.json under {dir:?} and {base:?} is not a \
                     built-in preset (tiny|small|base); run `make artifacts` \
                     or point at a preset directory"
                )
            })?;
            debug!("no manifest on disk; synthesized preset {base:?}");
            preset::synthesize(&cfg)
        };
        let backend = Self::pick_backend(&dir, &manifest);
        Ok(Engine {
            dir,
            manifest,
            backend,
            calls: Mutex::new(HashMap::new()),
            uploads: Mutex::new((0, 0)),
        })
    }

    #[cfg(not(feature = "pjrt"))]
    fn pick_backend(_dir: &Path, manifest: &Manifest) -> Backend {
        let names = manifest.params.iter().map(|(n, _)| n.clone()).collect();
        Backend::Host(host::HostBackend::new(manifest.preset.clone(), names))
    }

    #[cfg(feature = "pjrt")]
    fn pick_backend(dir: &Path, manifest: &Manifest) -> Backend {
        match pjrt::PjrtBackend::open(dir) {
            Ok(b) => Backend::Pjrt(b),
            Err(e) => {
                // Loud on purpose: a pjrt build silently executing on the
                // host backend would invalidate any PJRT measurement.
                crate::warn!(
                    "pjrt feature is enabled but the PJRT backend failed to \
                     initialize ({e}); FALLING BACK to the host backend — \
                     results are host-executed"
                );
                let names = manifest.params.iter().map(|(n, _)| n.clone()).collect();
                Backend::Host(host::HostBackend::new(manifest.preset.clone(), names))
            }
        }
    }

    /// The artifact directory this engine was opened on.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn config(&self) -> &ModelConfig {
        &self.manifest.preset
    }

    /// Pre-compile a set of artifacts (serving startup). The host backend
    /// only validates that the names exist.
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            let spec = self.manifest.artifact(n)?;
            match &self.backend {
                Backend::Host(_) => {}
                #[cfg(feature = "pjrt")]
                Backend::Pjrt(b) => b.compile(n, &self.dir.join(&spec.file))?,
            }
            let _ = spec;
        }
        Ok(())
    }

    fn count_call(&self, name: &str) {
        let mut calls = self.calls.lock().unwrap();
        if let Some(c) = calls.get_mut(name) {
            *c += 1; // steady state: the key exists after the first call
        } else {
            // lint:allow(hot-path-alloc) first call of each artifact name interns its key once; every later call takes the get_mut arm above
            calls.insert(name.to_string(), 1);
        }
    }

    fn note_upload(&self, events: usize, bytes: u64) {
        let mut u = self.uploads.lock().unwrap();
        u.0 += events;
        u.1 += bytes;
    }

    /// Cumulative host->device transfer accounting as (events, bytes).
    /// This is the counter behind the serving upload metrics and the
    /// zero-KV-upload decode test: `run` pays for every input each call,
    /// `upload`/`alloc_resident` pay once, `run_b`/resident args are free.
    pub fn upload_stats(&self) -> (usize, u64) {
        *self.uploads.lock().unwrap()
    }

    fn dispatch(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>> {
        self.count_call(name);
        match &self.backend {
            Backend::Host(b) => b.run(name, inputs),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.run(name, inputs, self.manifest.artifact(name)?),
        }
    }

    /// Execute `name` with `inputs` (order per manifest). Returns outputs
    /// in manifest order.
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self.manifest.artifact(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (v, io) in inputs.iter().zip(&spec.inputs) {
            check_input(name, io, v, None)?;
        }
        self.note_upload(
            inputs.len(),
            inputs.iter().map(|v| v.byte_len() as u64).sum(),
        );
        let refs: Vec<&Value> = inputs.iter().collect();
        let out = self.dispatch(name, &refs)?;
        check_outputs(name, spec, &out)?;
        Ok(out)
    }

    /// Per-artifact call counts (perf accounting).
    pub fn call_counts(&self) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> = self
            .calls
            .lock()
            .unwrap()
            .iter()
            .map(|(k, &c)| (k.clone(), c))
            .collect();
        v.sort();
        v
    }

    // -- device-resident inputs (perf path) ---------------------------------
    //
    // `run` hands every input to the backend per call. For loops that reuse
    // large constant inputs (model params in eval/calib, expert weights in
    // serving), `upload` pins a Value once and `run_b` executes on the
    // pinned buffers — on PJRT that skips the host->device copy, on the
    // host backend it skips the caller-side clone-per-call of the legacy
    // path (HEAPR_NO_BUFFER_CACHE=1 re-measures that path).

    /// Pin a value as a device-resident buffer. Takes the value by move so
    /// the host backend pins it with zero copies (callers construct fresh
    /// `Value`s at every upload site).
    pub fn upload(&self, v: Value) -> Result<DeviceTensor> {
        self.note_upload(1, v.byte_len() as u64);
        match &self.backend {
            Backend::Host(_) => Ok(DeviceTensor {
                buf: DeviceBuffer { value: v },
            }),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.upload(v),
        }
    }

    /// Open a [`Session`] of named engine-resident buffers (decode state).
    pub fn session(&self) -> Session<'_> {
        Session {
            engine: self,
            residents: HashMap::new(),
            paged: None,
        }
    }

    /// Execute on pre-uploaded buffers (mixed with per-call inputs the
    /// caller uploads itself). Buffers are shape-validated against the
    /// manifest exactly like `run` inputs — the backends assume validated
    /// inputs.
    pub fn run_b(&self, name: &str, inputs: &[&DeviceBuffer]) -> Result<Vec<Value>> {
        let spec = self.manifest.artifact(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} buffers given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (b, io) in inputs.iter().zip(&spec.inputs) {
            check_input(name, io, &b.value, None)?;
        }
        let refs: Vec<&Value> = inputs.iter().map(|b| &b.value).collect();
        let out = self.dispatch(name, &refs)?;
        check_outputs(name, spec, &out)?;
        Ok(out)
    }
}

/// Backend outputs must honor the manifest contract — count, shape and
/// dtype — so a kernel bug surfaces here as an error naming the artifact,
/// not as wrong numerics or a slice panic downstream.
fn check_outputs(name: &str, spec: &ArtifactSpec, out: &[Value]) -> Result<()> {
    if out.len() != spec.outputs.len() {
        bail!(
            "{name}: backend produced {} outputs, manifest wants {}",
            out.len(),
            spec.outputs.len()
        );
    }
    for (v, io) in out.iter().zip(&spec.outputs) {
        if v.shape() != io.shape.as_slice() || v.dtype() != io.dtype {
            bail!(
                "{name}: output {:?} has shape {:?} dtype {}, manifest wants {:?} {}",
                io.name,
                v.shape(),
                v.dtype(),
                io.shape,
                io.dtype
            );
        }
    }
    Ok(())
}

/// Validate one input against its manifest spec. `capacity_axis` (from
/// [`manifest::capacity_axis`]) relaxes exactly one dimension for session
/// residents: cache-like state may be allocated at any capacity up to the
/// compiled maximum, and the backends index that axis dynamically.
fn check_input(
    name: &str,
    io: &IoSpec,
    v: &Value,
    capacity_axis: Option<usize>,
) -> Result<()> {
    check_shape(name, io, v.shape(), v.dtype(), capacity_axis)
}

/// Shape/dtype half of [`check_input`], callable for paged residents
/// (which have a logical shape but no dense [`Value`] to borrow).
fn check_shape(
    name: &str,
    io: &IoSpec,
    shape: &[usize],
    dtype: Dtype,
    capacity_axis: Option<usize>,
) -> Result<()> {
    let shape_ok = match capacity_axis {
        None => shape == io.shape.as_slice(),
        Some(ax) => {
            shape.len() == io.shape.len()
                && shape
                    .iter()
                    .zip(&io.shape)
                    .enumerate()
                    .all(|(d, (&got, &want))| {
                        if d == ax {
                            got >= 1 && got <= want
                        } else {
                            got == want
                        }
                    })
        }
    };
    if shape_ok && dtype == io.dtype {
        return Ok(());
    }
    match capacity_axis {
        None => bail!(
            "{name}: input {:?} got shape {shape:?} dtype {dtype}, want {:?} {}",
            io.name,
            io.shape,
            io.dtype
        ),
        Some(ax) => bail!(
            "{name}: resident {:?} got shape {shape:?} dtype {dtype}, want {:?} {} \
             (axis {ax} is capacity: 1..={} allowed)",
            io.name,
            io.shape,
            io.dtype,
            io.shape[ax]
        ),
    }
}

/// Output contract for a session call: the outputs aliased to residents
/// (names in `skip`) were written in place and are not returned; the rest
/// must match the manifest exactly, like [`check_outputs`].
fn check_session_outputs(
    name: &str,
    spec: &ArtifactSpec,
    skip: &[&str],
    out: &[Value],
) -> Result<()> {
    // two filter passes instead of a collected Vec: this check runs on
    // every session call, so it stays allocation-free
    let expected = spec
        .outputs
        .iter()
        .filter(|io| !skip.contains(&io.name.as_str()));
    // lint:allow(hot-path-alloc) Clone of a borrowing filter iterator: a cursor copy for the count pass, no element is duplicated
    let n_expected = expected.clone().count();
    if out.len() != n_expected {
        bail!(
            "{name}: session call produced {} outputs, manifest wants {} \
             ({} aliased to residents)",
            out.len(),
            n_expected,
            skip.len()
        );
    }
    for (v, io) in out.iter().zip(expected) {
        if v.shape() != io.shape.as_slice() || v.dtype() != io.dtype {
            bail!(
                "{name}: output {:?} has shape {:?} dtype {}, manifest wants {:?} {}",
                io.name,
                v.shape(),
                v.dtype(),
                io.shape,
                io.dtype
            );
        }
    }
    Ok(())
}

/// Copy the overlapping hyper-rectangle of `src` into `dst` (same rank;
/// per-axis extent `min(src, dst)`), leaving the rest of `dst` untouched.
/// The last axis copies as one contiguous row.
fn copy_rect(dst: &mut [f32], dshape: &[usize], src: &[f32], sshape: &[usize]) {
    debug_assert_eq!(dshape.len(), sshape.len());
    if dshape.is_empty() {
        dst[0] = src[0]; // rank exhausted: a single scalar remains
        return;
    }
    let take = dshape[0].min(sshape[0]);
    if dshape.len() == 1 {
        dst[..take].copy_from_slice(&src[..take]);
        return;
    }
    let drow: usize = dshape[1..].iter().product();
    let srow: usize = sshape[1..].iter().product();
    for i in 0..take {
        copy_rect(
            &mut dst[i * drow..(i + 1) * drow],
            &dshape[1..],
            &src[i * srow..(i + 1) * srow],
            &sshape[1..],
        );
    }
}

/// Overwrite index `lane` of `dst`'s leading (batch/lane) axis with the
/// single-lane tensor `src` (`src.shape()[0] == 1`, same rank).
///
/// The whole destination lane is zeroed first, then the overlapping
/// hyper-rectangle of `src` is copied in — so a lane recycled for a new
/// occupant can never expose the previous occupant's rows, and a source
/// allocated at a different capacity is truncated or zero-extended
/// exactly like `fit_cache` re-seats a prefill cache.
pub fn write_lane_f32(dst: &mut Tensor, lane: usize, src: &Tensor) -> Result<()> {
    let (ds, ss) = (dst.shape().to_vec(), src.shape().to_vec());
    if ss.len() != ds.len() || ss.is_empty() || ss[0] != 1 {
        bail!("write_lane: src shape {ss:?} is not a single lane of {ds:?}");
    }
    if lane >= ds[0] {
        bail!("write_lane: lane {lane} out of range for {ds:?}");
    }
    let row: usize = ds[1..].iter().product();
    let slab = &mut dst.data_mut()[lane * row..(lane + 1) * row];
    slab.fill(0.0);
    copy_rect(slab, &ds[1..], src.data(), &ss[1..]);
    Ok(())
}

/// Zero index `lane` of `dst`'s leading axis (lane retirement).
pub fn zero_lane_f32(dst: &mut Tensor, lane: usize) -> Result<()> {
    let ds = dst.shape().to_vec();
    if ds.is_empty() || lane >= ds[0] {
        bail!("zero_lane: lane {lane} out of range for {ds:?}");
    }
    let row: usize = ds[1..].iter().product();
    dst.data_mut()[lane * row..(lane + 1) * row].fill(0.0);
    Ok(())
}

/// One argument to [`Session::run_s`]: a per-call host value (marshalled
/// this call), a pinned [`DeviceBuffer`], a named session resident, or a
/// single-lane view of a *paged* resident (`ResLane(name, lane)`) — the
/// shape the artifact sees is the resident's logical shape with the
/// leading (lane) axis collapsed to 1, which is how a batch-1 decode
/// artifact prefills one tail position of a shared multi-lane state.
pub enum SArg<'a> {
    Val(&'a Value),
    Buf(&'a DeviceBuffer),
    Res(&'a str),
    ResLane(&'a str, usize),
}

/// Engine-resident mutable state for a decode sequence (or any loop that
/// carries device state across calls): named buffers allocated once
/// ([`Session::alloc_resident`]), read and written in place by
/// [`Session::run_s`], copied back with [`Session::download`] — or simply
/// dropped — at end of sequence.
///
/// A resident bound to an input whose name also appears among the
/// artifact's outputs (e.g. `kcache`/`vcache` of `attn_decode_b*`) is
/// *aliased*: the backend updates it in place and omits it from the
/// returned outputs. On the host backend the decode KV append therefore
/// costs one row write — never a cache copy or re-upload. The PJRT
/// backend (feature `pjrt`) stubs `run_s` on the literal path; the trait
/// boundary (named residents, capacity sizing, aliasing by manifest IO
/// name) is exactly what PJRT buffer donation needs, so re-enabling real
/// device residency is local to `runtime/pjrt.rs`.
///
/// # Example
///
/// ```no_run
/// use heapr::runtime::{Engine, SArg, Value};
/// use heapr::tensor::Tensor;
///
/// let engine = Engine::open("artifacts/tiny").unwrap();
/// let mut sess = engine.session();
/// // pin a weight as a named resident once…
/// sess.alloc_resident("wd", Value::F32(Tensor::zeros(&[64, 32])));
/// // …then execute against it; per-call inputs ride along as SArg::Val
/// let g = Value::F32(Tensor::zeros(&[64, 64]));
/// let out = sess
///     .run_s("quadform", &[SArg::Res("wd"), SArg::Val(&g)])
///     .unwrap();
/// assert_eq!(out[0].shape(), &[32]);
/// ```
pub struct Session<'e> {
    engine: &'e Engine,
    residents: HashMap<String, Value>,
    /// Paged KV storage, when this session holds page-table residents
    /// ([`Session::alloc_paged`]). Dense and paged residents coexist by
    /// name: lane primitives and `run_s` dispatch per resident.
    paged: Option<PagedKv>,
}

impl<'e> Session<'e> {
    /// Allocate (or overwrite) a named resident from a host value — the
    /// one host->device transfer of the resident's lifetime.
    pub fn alloc_resident(&mut self, name: impl Into<String>, v: Value) {
        self.engine.note_upload(1, v.byte_len() as u64);
        self.residents.insert(name.into(), v);
    }

    /// Switch this session to paged KV storage: `page` positions per
    /// page over an `h`×`hd` attention geometry, optionally hard-capped
    /// at `budget_pages` live pages. Must precede
    /// [`Session::alloc_paged_resident`]. Allocates nothing and moves no
    /// bytes.
    pub fn alloc_paged(
        &mut self,
        page: usize,
        h: usize,
        hd: usize,
        budget_pages: Option<usize>,
    ) -> Result<()> {
        if self.paged.is_some() {
            bail!("session already holds paged state");
        }
        self.paged = Some(PagedKv::new(page, h, hd, budget_pages)?);
        Ok(())
    }

    /// Allocate a named *paged* resident: `lanes` page tables spanning
    /// `capacity` positions, all unmapped. Unlike [`Session::alloc_resident`]
    /// this is free — no pages map and no upload is priced until rows are
    /// written ([`Session::write_lane`]) or appended (decode) — which is
    /// exactly the over-allocation the dense rectangle paid per lane.
    pub fn alloc_paged_resident(
        &mut self,
        name: impl Into<String>,
        lanes: usize,
        capacity: usize,
    ) -> Result<()> {
        let pk = self
            .paged
            .as_mut()
            .ok_or_else(|| anyhow!("alloc_paged_resident before alloc_paged"))?;
        pk.alloc_resident(name, lanes, capacity)
    }

    /// Whether this session holds paged KV state.
    pub fn is_paged(&self) -> bool {
        self.paged.is_some()
    }

    /// The paged KV pool, for stats readback (live/peak/total pages).
    pub fn paged(&self) -> Option<&PagedKv> {
        self.paged.as_ref()
    }

    /// Map the first `npages` prompt-prefix pages of `src_lane` into
    /// `dst_lane` across *every* paged resident (each KV cache tensor of
    /// every layer) — refcount increments only, zero bytes copied or
    /// uploaded. Returns the total number of physical page mappings
    /// added. This is the prefix-reuse admission primitive: the new
    /// lane's first `npages * page` positions read the donor's rows.
    pub fn map_prefix(&mut self, src_lane: usize, dst_lane: usize, npages: usize) -> Result<usize> {
        let pk = self
            .paged
            .as_mut()
            .ok_or_else(|| anyhow!("map_prefix on a session without paged state"))?;
        let names: Vec<String> = pk.resident_names().map(String::from).collect();
        if names.is_empty() {
            bail!("map_prefix: no paged residents");
        }
        let mut mapped = 0;
        for n in &names {
            mapped += pk.share_prefix(n, src_lane, dst_lane, npages)?;
        }
        Ok(mapped)
    }

    pub fn has_resident(&self, name: &str) -> bool {
        self.residents.contains_key(name)
            || self.paged.as_ref().is_some_and(|pk| pk.has(name))
    }

    pub fn resident_shape(&self, name: &str) -> Option<&[usize]> {
        self.residents
            .get(name)
            .map(|v| v.shape())
            .or_else(|| self.paged.as_ref().and_then(|pk| pk.logical_shape(name)))
    }

    /// Total bytes held by residents (capacity accounting). Paged
    /// residents count their *live pages*, not their logical extent —
    /// the whole point of paging.
    pub fn resident_bytes(&self) -> u64 {
        self.residents.values().map(|v| v.byte_len() as u64).sum::<u64>()
            + self.paged.as_ref().map_or(0, |pk| pk.resident_bytes())
    }

    /// Copy a resident back to the host (end-of-sequence readback). A
    /// paged resident gathers to its dense logical shape, unmapped pages
    /// reading as zeros.
    pub fn download(&self, name: &str) -> Result<Value> {
        if let Some(v) = self.residents.get(name) {
            return Ok(v.clone());
        }
        if let Some(pk) = &self.paged {
            if pk.has(name) {
                return Ok(Value::F32(pk.dense(name)?));
            }
        }
        bail!("no resident {name:?} in session")
    }

    /// Drop one resident; returns whether it existed. Dropping a paged
    /// resident releases every page it mapped.
    pub fn free_resident(&mut self, name: &str) -> bool {
        if self.residents.remove(name).is_some() {
            return true;
        }
        self.paged
            .as_mut()
            .and_then(|pk| pk.free_resident(name).ok())
            .unwrap_or(false)
    }

    /// Release every resident (the sequence is finished).
    pub fn clear(&mut self) {
        self.residents.clear();
        self.paged = None;
    }

    /// Overwrite one index of resident `name`'s leading (batch/lane) axis
    /// with the single-lane tensor `src` — the continuous scheduler's
    /// admission primitive: a freed decode lane is re-seated with a new
    /// sequence's KV rows without reallocating (or even touching) the
    /// other lanes of the resident.
    ///
    /// The destination lane is zeroed before the copy (see
    /// [`write_lane_f32`]), so a recycled lane can never expose its
    /// previous occupant's rows. Counts as one host->device transfer of
    /// `src`'s bytes in [`Engine::upload_stats`] — per-lane admission
    /// traffic, not per-step decode traffic. On a device backend this
    /// maps to a strided host->device copy into an existing buffer.
    pub fn write_lane(&mut self, name: &str, lane: usize, src: &Tensor) -> Result<()> {
        if let Some(v) = self.residents.get_mut(name) {
            let dst = v.as_f32_mut()?;
            write_lane_f32(dst, lane, src)?;
            self.engine.note_upload(1, (src.data().len() * 4) as u64);
            return Ok(());
        }
        if let Some(pk) = self.paged.as_mut() {
            if pk.has(name) {
                // paged seating maps ceil(rows/page) fresh pages for the
                // lane; same upload price as the dense path — the source
                // rows cross the host->device boundary either way
                pk.write_lane(name, lane, src)?;
                self.engine.note_upload(1, (src.data().len() * 4) as u64);
                return Ok(());
            }
        }
        bail!("write_lane: no resident {name:?} in session")
    }

    /// Zero one index of resident `name`'s leading axis (lane
    /// retirement). Moves no host->device bytes on the host backend; a
    /// device backend would issue a device-side fill. On a paged resident
    /// this unmaps the lane's page table — refcount-aware, so a prefix
    /// page still mapped by a live sharer survives untouched.
    pub fn zero_lane(&mut self, name: &str, lane: usize) -> Result<()> {
        if let Some(v) = self.residents.get_mut(name) {
            return zero_lane_f32(v.as_f32_mut()?, lane);
        }
        if let Some(pk) = self.paged.as_mut() {
            if pk.has(name) {
                return pk.zero_lane(name, lane);
            }
        }
        bail!("zero_lane: no resident {name:?} in session")
    }

    /// Execute `name` against a mix of per-call values, pinned buffers and
    /// residents (order per manifest). Inputs are shape-validated exactly
    /// like [`Engine::run_b`], except that residents on a declared
    /// capacity axis ([`manifest::capacity_axis`]) may be smaller than the
    /// compiled maximum. Aliased residents (input name == an output name)
    /// are updated in place and omitted from the returned outputs.
    pub fn run_s(&mut self, name: &str, args: &[SArg]) -> Result<Vec<Value>> {
        let spec = self.engine.manifest.artifact(name)?;
        if args.len() != spec.inputs.len() {
            bail!(
                "{name}: {} args given, manifest wants {}",
                args.len(),
                spec.inputs.len()
            );
        }
        // calls touching paged residents (by name or lane view) take the
        // page-table walk instead of the dense in-place path
        let paged_call = args.iter().any(|a| match a {
            SArg::ResLane(..) => true,
            SArg::Res(n) => self.paged.as_ref().is_some_and(|pk| pk.has(n)),
            _ => false,
        });
        if paged_call {
            return self.run_s_paged(name, spec, args);
        }
        let mut aliased: Vec<(usize, &str)> = Vec::new();
        let mut val_events = 0usize;
        let mut val_bytes = 0u64;
        for (i, (arg, io)) in args.iter().zip(&spec.inputs).enumerate() {
            match arg {
                SArg::Val(v) => {
                    check_input(name, io, v, None)?;
                    val_events += 1;
                    val_bytes += v.byte_len() as u64;
                }
                SArg::Buf(b) => check_input(name, io, &b.value, None)?,
                SArg::Res(n) => {
                    let v = self
                        .residents
                        .get(*n)
                        .ok_or_else(|| anyhow!("{name}: no resident {n:?} in session"))?;
                    check_input(name, io, v, manifest::capacity_axis(name, &io.name))?;
                    if spec.outputs.iter().any(|o| o.name == io.name) {
                        aliased.push((i, *n));
                    }
                }
                // lane views were routed to run_s_paged above
                SArg::ResLane(n, _) => {
                    bail!("{name}: lane view of {n:?} requires paged session state")
                }
            }
        }
        self.engine.note_upload(val_events, val_bytes);
        self.engine.count_call(name);
        let skip: Vec<&str> = aliased
            .iter()
            .map(|(i, _)| spec.inputs[*i].name.as_str())
            // lint:allow(hot-path-alloc) argument-marshalling vector sized by artifact arity; lifetime-bound to this call, it cannot live in session state
            .collect();
        match &self.engine.backend {
            Backend::Host(hb) => {
                // take aliased residents out of the table for independent
                // mutable access (Value moves — no copies; `remove_entry`
                // hands back the map-owned key String for reinsertion)
                // lint:allow(hot-path-alloc) argument-marshalling vector sized by artifact arity; lifetime-bound to this call, it cannot live in session state
                let mut taken: Vec<(usize, String, Value)> = Vec::with_capacity(aliased.len());
                for (i, n) in &aliased {
                    let v = self.residents.remove_entry(*n).ok_or_else(|| {
                        anyhow!("{name}: resident {n:?} bound to more than one in-place input")
                    });
                    match v {
                        Ok((key, v)) => taken.push((*i, key, v)),
                        Err(e) => {
                            // undo the removals before surfacing the error
                            for (_, n, v) in taken {
                                self.residents.insert(n, v);
                            }
                            return Err(e);
                        }
                    }
                }
                // a name used for BOTH an in-place and a read-only input
                // would be absent from the table here; error, don't panic
                let conflict = args.iter().enumerate().any(|(i, a)| {
                    matches!(a, SArg::Res(n)
                        if !taken.iter().any(|(j, _, _)| *j == i)
                            && !self.residents.contains_key(*n))
                });
                if conflict {
                    for (_, n, v) in taken {
                        self.residents.insert(n, v);
                    }
                    bail!(
                        "{name}: a resident is bound to both an in-place \
                         and a read-only input"
                    );
                }
                let inputs: Vec<Option<&Value>> = args
                    .iter()
                    .enumerate()
                    .map(|(i, a)| match a {
                        SArg::Val(v) => Some(*v),
                        SArg::Buf(b) => Some(&b.value),
                        SArg::Res(n) => {
                            if taken.iter().any(|(j, _, _)| *j == i) {
                                None
                            } else {
                                Some(&self.residents[*n])
                            }
                        }
                        SArg::ResLane(..) => {
                            unreachable!("lane views route to run_s_paged")
                        }
                    })
                    // lint:allow(hot-path-alloc) argument-marshalling vector sized by artifact arity; lifetime-bound to this call, it cannot live in session state
                    .collect();
                let mut inout: Vec<(usize, &mut Value)> =
                    // lint:allow(hot-path-alloc) argument-marshalling vector sized by artifact arity; lifetime-bound to this call, it cannot live in session state
                    taken.iter_mut().map(|(i, _, v)| (*i, v)).collect();
                let out = hb.run_s(name, spec, &inputs, &mut inout);
                drop(inout);
                drop(inputs);
                // reinsert even on error so the session stays consistent
                for (_, n, v) in taken {
                    self.residents.insert(n, v);
                }
                let out = out?;
                check_session_outputs(name, spec, &skip, &out)?;
                Ok(out)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(pb) => {
                let full: Vec<&Value> = args
                    .iter()
                    .map(|a| match a {
                        SArg::Val(v) => *v,
                        SArg::Buf(b) => &b.value,
                        SArg::Res(n) => &self.residents[*n],
                        SArg::ResLane(..) => {
                            unreachable!("lane views route to run_s_paged")
                        }
                    })
                    // lint:allow(hot-path-alloc) argument-marshalling vector sized by artifact arity; lifetime-bound to this call, it cannot live in session state
                    .collect();
                let outs = pb.run_s(name, &full, spec)?;
                drop(full);
                let mut kept = Vec::new();
                for (oi, v) in outs.into_iter().enumerate() {
                    let oname = spec.outputs[oi].name.as_str();
                    let alias = aliased
                        .iter()
                        .find(|(i, _)| spec.inputs[*i].name == oname);
                    match alias {
                        Some((_, n)) => {
                            // lint:allow(hot-path-alloc) pjrt write-back keys the resident table: one short name String per aliased output per call
                            self.residents.insert(n.to_string(), v);
                        }
                        None => kept.push(v),
                    }
                }
                check_session_outputs(name, spec, &skip, &kept)?;
                Ok(kept)
            }
        }
    }

    /// [`Session::run_s`] for calls touching paged residents: host-only,
    /// decode-only. The KV caches arrive as paged names (whole state) or
    /// [`SArg::ResLane`] views (one lane, batch-1 artifact); both are
    /// validated against the manifest on their *logical* shapes with the
    /// usual capacity-axis relaxation, then the backend appends and
    /// attends through the page tables in place. Accounting matches the
    /// dense path exactly: `Val` args are priced, residents are free.
    fn run_s_paged(
        &mut self,
        name: &str,
        spec: &ArtifactSpec,
        args: &[SArg],
    ) -> Result<Vec<Value>> {
        if !name.starts_with("attn_decode_b") {
            bail!("{name}: paged residents only serve attn_decode_b* session calls");
        }
        let hb = match &self.engine.backend {
            Backend::Host(hb) => hb,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => bail!("{name}: paged residents are host-backend only"),
        };
        let pk = self
            .paged
            .as_mut()
            .ok_or_else(|| anyhow!("{name}: lane view without paged session state"))?;
        let mut val_events = 0usize;
        let mut val_bytes = 0u64;
        // lint:allow(hot-path-alloc) argument-marshalling vector sized by artifact arity; lifetime-bound to this call, it cannot live in session state
        let mut inputs: Vec<Option<&Value>> = vec![None; args.len()];
        // (kcache|vcache, resident name, lane view)
        let mut karg: Option<(&str, Option<usize>)> = None;
        let mut varg: Option<(&str, Option<usize>)> = None;
        for (i, (arg, io)) in args.iter().zip(&spec.inputs).enumerate() {
            match arg {
                SArg::Val(v) => {
                    check_input(name, io, v, None)?;
                    val_events += 1;
                    val_bytes += v.byte_len() as u64;
                    inputs[i] = Some(*v);
                }
                SArg::Buf(b) => {
                    check_input(name, io, &b.value, None)?;
                    inputs[i] = Some(&b.value);
                }
                SArg::Res(n) | SArg::ResLane(n, _) => {
                    let lane = match arg {
                        SArg::ResLane(_, l) => Some(*l),
                        _ => None,
                    };
                    let shape = pk.logical_shape(n).ok_or_else(|| {
                        anyhow!(
                            "{name}: resident {n:?} is not paged (a paged call \
                             cannot mix dense residents)"
                        )
                    })?;
                    // lint:allow(hot-path-alloc) logical-shape scratch: a handful of usizes per paged call, consumed by the shape check
                    let mut eff = shape.to_vec();
                    if let Some(l) = lane {
                        if l >= eff[0] {
                            bail!("{name}: lane {l} out of range for {n:?} ({} lanes)", eff[0]);
                        }
                        eff[0] = 1; // the artifact sees a single-lane view
                    }
                    let cap_ax = manifest::capacity_axis(name, &io.name);
                    check_shape(name, io, &eff, Dtype::F32, cap_ax)?;
                    match io.name.as_str() {
                        "kcache" => karg = Some((*n, lane)),
                        "vcache" => varg = Some((*n, lane)),
                        other => bail!(
                            "{name}: paged resident bound to input {other:?} \
                             (only kcache/vcache may be paged)"
                        ),
                    }
                }
            }
        }
        let (Some((kname, klane)), Some((vname, vlane))) = (karg, varg) else {
            bail!("{name}: paged decode needs both kcache and vcache residents")
        };
        if klane != vlane {
            bail!("{name}: kcache/vcache lane views disagree ({klane:?} vs {vlane:?})");
        }
        // batch rows map to page-table lanes: identity for whole-state
        // decode, the single named lane for a ResLane view
        let b = spec.inputs[0].shape[0];
        let lanes: Vec<usize> = match klane {
            // lint:allow(hot-path-alloc) lane-map vector: one usize per batch row, lifetime-bound to this call
            None => (0..b).collect(),
            // lint:allow(hot-path-alloc) lane-map vector: a single usize for a lane view, lifetime-bound to this call
            Some(l) => vec![l],
        };
        self.engine.note_upload(val_events, val_bytes);
        self.engine.count_call(name);
        let out = hb.attn_decode_paged(&inputs, pk, kname, vname, &lanes)?;
        let skip: Vec<&str> = spec
            .outputs
            .iter()
            .filter(|o| o.name == "kcache" || o.name == "vcache")
            .map(|o| o.name.as_str())
            // lint:allow(hot-path-alloc) argument-marshalling vector sized by artifact arity; lifetime-bound to this call, it cannot live in session state
            .collect();
        check_session_outputs(name, spec, &skip, &out)?;
        Ok(out)
    }
}

/// A pinned runtime buffer. Host backend: the value itself. PJRT backend:
/// the device buffer plus the literal backing the (possibly in-flight)
/// transfer.
pub struct DeviceBuffer {
    value: Value,
}

impl DeviceBuffer {
    pub fn value(&self) -> &Value {
        &self.value
    }
}

/// A pinned tensor; `buf` is what `run_b` consumes.
pub struct DeviceTensor {
    pub buf: DeviceBuffer,
}

/// A set of pre-uploaded buffers (e.g. all model params), reusable across
/// many `run_b` calls.
pub struct BufferSet {
    pub tensors: Vec<DeviceTensor>,
}

impl BufferSet {
    pub fn upload(engine: &Engine, values: &[Value]) -> Result<BufferSet> {
        Ok(BufferSet {
            tensors: values
                .iter()
                .map(|v| engine.upload(v.clone()))
                .collect::<Result<_>>()?,
        })
    }

    pub fn refs(&self) -> Vec<&DeviceBuffer> {
        self.tensors.iter().map(|t| &t.buf).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_synthesizes_builtin_presets() {
        let e = Engine::open("artifacts/tiny").unwrap();
        assert_eq!(e.config().name, "tiny");
        assert_eq!(e.config().d_model, 64);
        assert!(e.manifest.artifact("train_step").is_ok());
        assert!(Engine::open("artifacts/no-such-preset").is_err());
    }

    #[test]
    fn run_validates_shapes_and_counts_calls() {
        let e = Engine::open("artifacts/tiny").unwrap();
        // wrong arity
        assert!(e.run("quadform", &[]).is_err());
        // wrong shape
        let bad = Value::F32(crate::tensor::Tensor::zeros(&[3, 3]));
        let g = Value::F32(crate::tensor::Tensor::zeros(&[64, 64]));
        assert!(e.run("quadform", &[bad, g.clone()]).is_err());
        // correct call executes on the host backend and is counted
        let wd = Value::F32(crate::tensor::Tensor::zeros(&[64, 32]));
        let out = e.run("quadform", &[wd, g]).unwrap();
        assert_eq!(out[0].shape(), &[32]);
        assert_eq!(e.call_counts(), vec![("quadform".to_string(), 1)]);
    }

    #[test]
    fn upload_run_b_matches_run() {
        let e = Engine::open("artifacts/tiny").unwrap();
        let mut rng = crate::util::rng::Pcg64::new(5);
        let mk = |shape: &[usize], rng: &mut crate::util::rng::Pcg64| {
            let n: usize = shape.iter().product();
            crate::tensor::Tensor::from_vec(
                shape,
                (0..n).map(|_| rng.normal() * 0.1).collect(),
            )
        };
        let wd = Value::F32(mk(&[64, 32], &mut rng));
        let a = mk(&[64, 64], &mut rng);
        let g = Value::F32(crate::tensor::matmul_tn(&a, &a));
        let direct = e.run("quadform", &[wd.clone(), g.clone()]).unwrap();
        let wd_b = e.upload(wd).unwrap();
        let g_b = e.upload(g).unwrap();
        let via_buf = e.run_b("quadform", &[&wd_b.buf, &g_b.buf]).unwrap();
        let (x, y) = (
            direct[0].clone().f32().unwrap(),
            via_buf[0].clone().f32().unwrap(),
        );
        assert_eq!(x, y, "buffer path must match literal path bitwise");
    }

    #[test]
    fn warmup_checks_artifact_names() {
        let e = Engine::open("artifacts/tiny").unwrap();
        assert!(e.warmup(&["quadform", "moe_gate_n8"]).is_ok());
        assert!(e.warmup(&["not_an_artifact"]).is_err());
    }

    fn randt(rng: &mut crate::util::rng::Pcg64, shape: &[usize]) -> crate::tensor::Tensor {
        let n: usize = shape.iter().product();
        crate::tensor::Tensor::from_vec(shape, (0..n).map(|_| rng.normal() * 0.1).collect())
    }

    /// Full arg list for `attn_decode_b1` (tiny preset): x, 5 weights,
    /// kcache/vcache at capacity `s`, pos.
    fn decode_args(s: usize, p: i32) -> Vec<Value> {
        let mut rng = crate::util::rng::Pcg64::new(21);
        let d = 64;
        let mut v = vec![Value::F32(randt(&mut rng, &[1, 1, d]))];
        v.push(Value::F32(randt(&mut rng, &[d])));
        for _ in 0..4 {
            v.push(Value::F32(randt(&mut rng, &[d, d])));
        }
        v.push(Value::F32(randt(&mut rng, &[1, 2, s, 32])));
        v.push(Value::F32(randt(&mut rng, &[1, 2, s, 32])));
        v.push(Value::I32(crate::tensor::ITensor::from_vec(&[1], vec![p])));
        v
    }

    #[test]
    fn session_inplace_decode_matches_stateless() {
        let e = Engine::open("artifacts/tiny").unwrap();
        let smax = e.config().max_decode_len; // 96
        let full = decode_args(smax, 5);
        let want = e.run("attn_decode_b1", &full).unwrap();

        // session: capacity-8 residents whose prefix rows match the full
        // caches (decode_args is deterministic, so slice the same data)
        let (cap, hd) = (8usize, 32usize);
        let shrink = |v: &Value| {
            let t = v.as_f32().unwrap();
            let mut small = vec![0.0f32; 2 * cap * hd];
            for bh in 0..2 {
                small[bh * cap * hd..(bh + 1) * cap * hd]
                    .copy_from_slice(&t.data()[bh * smax * hd..bh * smax * hd + cap * hd]);
            }
            Value::F32(crate::tensor::Tensor::from_vec(&[1, 2, cap, hd], small))
        };
        let mut sess = e.session();
        sess.alloc_resident("kc", shrink(&full[6]));
        sess.alloc_resident("vc", shrink(&full[7]));
        let before = e.upload_stats();
        let out = sess
            .run_s(
                "attn_decode_b1",
                &[
                    SArg::Val(&full[0]),
                    SArg::Val(&full[1]),
                    SArg::Val(&full[2]),
                    SArg::Val(&full[3]),
                    SArg::Val(&full[4]),
                    SArg::Val(&full[5]),
                    SArg::Res("kc"),
                    SArg::Res("vc"),
                    SArg::Val(&full[8]),
                ],
            )
            .unwrap();
        // aliased residents are not returned; y matches bitwise
        assert_eq!(out.len(), 1);
        let y_s = out.into_iter().next().unwrap().f32().unwrap();
        let y = want[0].clone().f32().unwrap();
        assert_eq!(y, y_s, "in-place decode must match the stateless path bitwise");
        // the append landed in the resident, matching the stateless cache
        let kc = sess.download("kc").unwrap().f32().unwrap();
        let kc_want = want[1].clone().f32().unwrap();
        for bh in 0..2 {
            assert_eq!(
                &kc.data()[(bh * cap + 5) * hd..(bh * cap + 6) * hd],
                &kc_want.data()[(bh * smax + 5) * hd..(bh * smax + 6) * hd],
            );
        }
        // and the caches were never re-uploaded: only the 7 Val args moved
        let after = e.upload_stats();
        let val_bytes: u64 = [0, 1, 2, 3, 4, 5, 8]
            .iter()
            .map(|&i| full[i].byte_len() as u64)
            .sum();
        assert_eq!(after.1 - before.1, val_bytes, "KV bytes must not move");
    }

    #[test]
    fn run_s_validates_residents_like_run_b() {
        let e = Engine::open("artifacts/tiny").unwrap();
        let smax = e.config().max_decode_len;
        let full = decode_args(smax, 3);
        let call = |sess: &mut Session<'_>| {
            let a = [
                SArg::Val(&full[0]),
                SArg::Val(&full[1]),
                SArg::Val(&full[2]),
                SArg::Val(&full[3]),
                SArg::Val(&full[4]),
                SArg::Val(&full[5]),
                SArg::Res("kc"),
                SArg::Res("vc"),
                SArg::Val(&full[8]),
            ];
            sess.run_s("attn_decode_b1", &a).map(|_| ())
        };
        // missing resident
        let mut sess = e.session();
        let err = call(&mut sess).unwrap_err().to_string();
        assert!(err.contains("no resident"), "got: {err}");
        // capacity above the compiled maximum is rejected
        sess.alloc_resident("kc", Value::F32(crate::tensor::Tensor::zeros(&[1, 2, smax + 8, 32])));
        sess.alloc_resident("vc", Value::F32(crate::tensor::Tensor::zeros(&[1, 2, smax + 8, 32])));
        let err = call(&mut sess).unwrap_err().to_string();
        assert!(err.contains("capacity"), "got: {err}");
        // non-capacity dim mismatch is rejected (head dim 16 != 32)
        sess.alloc_resident("kc", Value::F32(crate::tensor::Tensor::zeros(&[1, 2, 8, 16])));
        sess.alloc_resident("vc", Value::F32(crate::tensor::Tensor::zeros(&[1, 2, 8, 16])));
        assert!(call(&mut sess).is_err());
        // wrong arity
        let err = sess.run_s("attn_decode_b1", &[]).unwrap_err().to_string();
        assert!(err.contains("manifest wants"), "got: {err}");
        // capacity at or below the maximum passes (pos=3 < 8)
        sess.alloc_resident("kc", Value::F32(crate::tensor::Tensor::zeros(&[1, 2, 8, 32])));
        sess.alloc_resident("vc", Value::F32(crate::tensor::Tensor::zeros(&[1, 2, 8, 32])));
        call(&mut sess).unwrap();
    }

    #[test]
    fn run_s_without_aliasing_matches_run() {
        // quadform has no input/output name overlap: residents are read
        // in place and every output is returned, identical to `run`.
        let e = Engine::open("artifacts/tiny").unwrap();
        let mut rng = crate::util::rng::Pcg64::new(9);
        let wd = Value::F32(randt(&mut rng, &[64, 32]));
        let a = randt(&mut rng, &[64, 64]);
        let g = Value::F32(crate::tensor::matmul_tn(&a, &a));
        let want = e.run("quadform", &[wd.clone(), g.clone()]).unwrap();
        let mut sess = e.session();
        sess.alloc_resident("wd", wd);
        let out = sess.run_s("quadform", &[SArg::Res("wd"), SArg::Val(&g)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(
            want[0].clone().f32().unwrap(),
            out[0].clone().f32().unwrap(),
        );
        // resident untouched by the non-aliased call
        assert_eq!(sess.resident_shape("wd"), Some(&[64usize, 32][..]));
        sess.clear();
        assert!(!sess.has_resident("wd"));
    }

    #[test]
    fn write_lane_zeroes_then_copies_and_truncates() {
        // dst [3, 2, 4]: lanes 0..3, each a [2, 4] slab
        let mut dst = Tensor::from_vec(&[3, 2, 4], (0..24).map(|x| x as f32 + 1.0).collect());
        // src smaller on the middle axis (capacity): [1, 2, 2]
        let src = Tensor::from_vec(&[1, 2, 2], vec![10.0, 11.0, 12.0, 13.0]);
        write_lane_f32(&mut dst, 1, &src).unwrap();
        // lane 1 rows: src rect copied, tail zeroed (old values 9..16 gone)
        assert_eq!(&dst.data()[8..16], &[10.0, 11.0, 0.0, 0.0, 12.0, 13.0, 0.0, 0.0]);
        // lanes 0 and 2 untouched
        assert_eq!(dst.data()[0], 1.0);
        assert_eq!(dst.data()[16], 17.0);
        // src larger than dst on an axis truncates (fit_cache semantics)
        let big = Tensor::from_vec(&[1, 2, 8], (0..16).map(|x| x as f32 + 50.0).collect());
        write_lane_f32(&mut dst, 0, &big).unwrap();
        assert_eq!(&dst.data()[0..4], &[50.0, 51.0, 52.0, 53.0]);
        assert_eq!(&dst.data()[4..8], &[58.0, 59.0, 60.0, 61.0]);
        // shape misuse is an error, not a panic
        assert!(write_lane_f32(&mut dst, 3, &src).is_err());
        let wrong_rank = Tensor::from_vec(&[1, 4], vec![0.0; 4]);
        assert!(write_lane_f32(&mut dst, 0, &wrong_rank).is_err());
        let two_lanes = Tensor::from_vec(&[2, 2, 4], vec![0.0; 16]);
        assert!(write_lane_f32(&mut dst, 0, &two_lanes).is_err());
    }

    #[test]
    fn zero_lane_clears_exactly_one_lane() {
        let mut t = Tensor::from_vec(&[2, 3], vec![1.0; 6]);
        zero_lane_f32(&mut t, 0).unwrap();
        assert_eq!(t.data(), &[0.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        assert!(zero_lane_f32(&mut t, 2).is_err());
    }

    #[test]
    fn session_write_lane_counts_upload_and_validates() {
        let e = Engine::open("artifacts/tiny").unwrap();
        let mut sess = e.session();
        sess.alloc_resident("kc", Value::F32(Tensor::zeros(&[4, 2, 8, 32])));
        let (_, b0) = e.upload_stats();
        let src = Tensor::from_vec(&[1, 2, 4, 32], vec![1.0; 2 * 4 * 32]);
        sess.write_lane("kc", 2, &src).unwrap();
        let (_, b1) = e.upload_stats();
        assert_eq!(b1 - b0, (2 * 4 * 32 * 4) as u64, "admission pays src bytes");
        let kc = sess.download("kc").unwrap().f32().unwrap();
        // lane 2 holds the src rows (head 0, rows 0..4), lane 1 untouched
        assert_eq!(kc.at(&[2, 0, 0, 0]), 1.0);
        assert_eq!(kc.at(&[2, 0, 4, 0]), 0.0); // zero-extended tail
        assert_eq!(kc.at(&[1, 0, 0, 0]), 0.0);
        // zero_lane retires it without an upload event
        let (_, b2) = e.upload_stats();
        sess.zero_lane("kc", 2).unwrap();
        assert_eq!(e.upload_stats().1, b2, "zero_lane moves no bytes");
        let kc = sess.download("kc").unwrap().f32().unwrap();
        assert_eq!(kc.at(&[2, 0, 0, 0]), 0.0);
        // unknown resident errors
        assert!(sess.write_lane("nope", 0, &src).is_err());
        assert!(sess.zero_lane("nope", 0).is_err());
    }

    #[test]
    fn session_paged_lane_primitives_price_like_dense_but_alloc_is_free() {
        let e = Engine::open("artifacts/tiny").unwrap();
        let mut sess = e.session();
        let (_, b0) = e.upload_stats();
        sess.alloc_paged(4, 2, 32, None).unwrap();
        sess.alloc_paged_resident("kc0", 4, 8).unwrap();
        sess.alloc_paged_resident("vc0", 4, 8).unwrap();
        let (_, b1) = e.upload_stats();
        assert_eq!(b1, b0, "paged allocation moves no bytes");
        assert!(sess.is_paged());
        assert!(sess.has_resident("kc0"));
        assert_eq!(sess.resident_shape("kc0"), Some(&[4usize, 2, 8, 32][..]));
        assert_eq!(sess.resident_bytes(), 0, "no live pages before seating");
        let src = Tensor::from_vec(&[1, 2, 6, 32], vec![1.0; 2 * 6 * 32]);
        sess.write_lane("kc0", 0, &src).unwrap();
        sess.write_lane("vc0", 0, &src).unwrap();
        let (_, b2) = e.upload_stats();
        assert_eq!(b2 - b1, 2 * (2 * 6 * 32 * 4) as u64, "seating pays src bytes");
        // ceil(6/4) = 2 live pages per cache, each [h=2, page=4, hd=32] f32
        assert_eq!(sess.resident_bytes(), 4 * (2 * 4 * 32 * 4) as u64);
        // prefix map: lane 1 shares lane 0's first page in both caches
        let mapped = sess.map_prefix(0, 1, 1).unwrap();
        assert_eq!(mapped, 2);
        assert_eq!(e.upload_stats().1, b2, "prefix maps move no bytes");
        let kc = sess.download("kc0").unwrap().f32().unwrap();
        assert_eq!(kc.at(&[1, 0, 3, 0]), 1.0); // shared page rows visible
        assert_eq!(kc.at(&[1, 0, 4, 0]), 0.0); // beyond the mapped prefix
        // donor retires; the sharer still reads the prefix page
        sess.zero_lane("kc0", 0).unwrap();
        sess.zero_lane("vc0", 0).unwrap();
        let kc = sess.download("kc0").unwrap().f32().unwrap();
        assert_eq!(kc.at(&[0, 0, 0, 0]), 0.0);
        assert_eq!(
            kc.at(&[1, 0, 3, 0]),
            1.0,
            "refcounted prefix page survives donor retirement"
        );
        // freeing releases every page
        assert!(sess.free_resident("kc0"));
        assert!(sess.free_resident("vc0"));
        assert_eq!(sess.resident_bytes(), 0);
    }

    #[test]
    fn upload_stats_price_run_not_run_b() {
        let e = Engine::open("artifacts/tiny").unwrap();
        let wd = Value::F32(crate::tensor::Tensor::zeros(&[64, 32]));
        let g = Value::F32(crate::tensor::Tensor::zeros(&[64, 64]));
        let (e0, b0) = e.upload_stats();
        e.run("quadform", &[wd.clone(), g.clone()]).unwrap();
        let (e1, b1) = e.upload_stats();
        assert_eq!(e1 - e0, 2);
        assert_eq!(b1 - b0, ((64 * 32 + 64 * 64) * 4) as u64);
        let wd_b = e.upload(wd).unwrap();
        let g_b = e.upload(g).unwrap();
        let (_, b2) = e.upload_stats();
        assert_eq!(b2 - b1, ((64 * 32 + 64 * 64) * 4) as u64);
        e.run_b("quadform", &[&wd_b.buf, &g_b.buf]).unwrap();
        let (_, b3) = e.upload_stats();
        assert_eq!(b3, b2, "run_b must move zero bytes");
    }
}
