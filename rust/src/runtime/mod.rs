//! Runtime: execute AOT artifacts behind a backend-agnostic [`Engine`].
//!
//! Two backends implement the same artifact contract (manifest-validated
//! inputs in, manifest-ordered outputs out):
//!
//! * **host** (default) — pure-rust execution of every artifact by name
//!   ([`host`]), pool-parallel via `HEAPR_THREADS`. Needs no artifacts on
//!   disk: when `manifest.json` is absent the manifest is synthesized from
//!   the built-in preset tables ([`preset`]), which mirror
//!   `python/compile/configs.py` exactly.
//! * **pjrt** (feature `pjrt`) — the original PJRT path: parse HLO text,
//!   compile once through the `xla` crate, execute many. The offline image
//!   has no `xla` crate, so the feature is off by default and enabling it
//!   requires adding that dependency (see README §Backends).
//!
//! The host engine is `Send + Sync` (state behind a `Mutex`), which is
//! what lets `heapr::importance_scores` fan `quadform` calls across the
//! thread pool. The PJRT engine is neither (raw FFI pointers) — callers
//! that share an engine across threads only compile in host builds.

pub mod host;
pub mod manifest;
pub mod preset;
pub mod value;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use manifest::{ArtifactSpec, IoSpec, Manifest};
pub use value::{Literal, Value};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelConfig;
use crate::debug;

enum Backend {
    Host(host::HostBackend),
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtBackend),
}

/// Artifact executor over one backend, with per-artifact call accounting.
pub struct Engine {
    dir: PathBuf,
    pub manifest: Manifest,
    backend: Backend,
    /// (artifact, calls) counters for the perf report.
    calls: Mutex<HashMap<String, usize>>,
}

impl Engine {
    /// Open `artifacts/<preset>/`. Loads `manifest.json` when present;
    /// otherwise synthesizes the manifest for a built-in preset named by
    /// the directory's basename (`tiny` | `small` | `base`), which is all
    /// the host backend needs.
    pub fn open(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let manifest = if mpath.exists() {
            Manifest::load(&mpath)
                .with_context(|| format!("loading manifest from {dir:?}"))?
        } else {
            let base = dir
                .file_name()
                .and_then(|s| s.to_str())
                .unwrap_or_default();
            let cfg = preset::builtin(base).ok_or_else(|| {
                anyhow!(
                    "no manifest.json under {dir:?} and {base:?} is not a \
                     built-in preset (tiny|small|base); run `make artifacts` \
                     or point at a preset directory"
                )
            })?;
            debug!("no manifest on disk; synthesized preset {base:?}");
            preset::synthesize(&cfg)
        };
        let backend = Self::pick_backend(&dir, &manifest);
        Ok(Engine {
            dir,
            manifest,
            backend,
            calls: Mutex::new(HashMap::new()),
        })
    }

    #[cfg(not(feature = "pjrt"))]
    fn pick_backend(_dir: &Path, manifest: &Manifest) -> Backend {
        let names = manifest.params.iter().map(|(n, _)| n.clone()).collect();
        Backend::Host(host::HostBackend::new(manifest.preset.clone(), names))
    }

    #[cfg(feature = "pjrt")]
    fn pick_backend(dir: &Path, manifest: &Manifest) -> Backend {
        match pjrt::PjrtBackend::open(dir) {
            Ok(b) => Backend::Pjrt(b),
            Err(e) => {
                // Loud on purpose: a pjrt build silently executing on the
                // host backend would invalidate any PJRT measurement.
                crate::warn!(
                    "pjrt feature is enabled but the PJRT backend failed to \
                     initialize ({e}); FALLING BACK to the host backend — \
                     results are host-executed"
                );
                let names = manifest.params.iter().map(|(n, _)| n.clone()).collect();
                Backend::Host(host::HostBackend::new(manifest.preset.clone(), names))
            }
        }
    }

    /// The artifact directory this engine was opened on.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn config(&self) -> &ModelConfig {
        &self.manifest.preset
    }

    /// Pre-compile a set of artifacts (serving startup). The host backend
    /// only validates that the names exist.
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            let spec = self.manifest.artifact(n)?;
            match &self.backend {
                Backend::Host(_) => {}
                #[cfg(feature = "pjrt")]
                Backend::Pjrt(b) => b.compile(n, &self.dir.join(&spec.file))?,
            }
            let _ = spec;
        }
        Ok(())
    }

    fn dispatch(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>> {
        *self
            .calls
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += 1;
        match &self.backend {
            Backend::Host(b) => b.run(name, inputs),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.run(name, inputs, self.manifest.artifact(name)?),
        }
    }

    /// Execute `name` with `inputs` (order per manifest). Returns outputs
    /// in manifest order.
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self.manifest.artifact(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (v, io) in inputs.iter().zip(&spec.inputs) {
            if v.shape() != io.shape.as_slice() || v.dtype() != io.dtype {
                bail!(
                    "{name}: input {:?} got shape {:?} dtype {}, want {:?} {}",
                    io.name,
                    v.shape(),
                    v.dtype(),
                    io.shape,
                    io.dtype
                );
            }
        }
        let refs: Vec<&Value> = inputs.iter().collect();
        let out = self.dispatch(name, &refs)?;
        check_outputs(name, spec, &out)?;
        Ok(out)
    }

    /// Per-artifact call counts (perf accounting).
    pub fn call_counts(&self) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> = self
            .calls
            .lock()
            .unwrap()
            .iter()
            .map(|(k, &c)| (k.clone(), c))
            .collect();
        v.sort();
        v
    }

    // -- device-resident inputs (perf path) ---------------------------------
    //
    // `run` hands every input to the backend per call. For loops that reuse
    // large constant inputs (model params in eval/calib, expert weights in
    // serving), `upload` pins a Value once and `run_b` executes on the
    // pinned buffers — on PJRT that skips the host->device copy, on the
    // host backend it skips the caller-side clone-per-call of the legacy
    // path (HEAPR_NO_BUFFER_CACHE=1 re-measures that path).

    /// Pin a value as a device-resident buffer. Takes the value by move so
    /// the host backend pins it with zero copies (callers construct fresh
    /// `Value`s at every upload site).
    pub fn upload(&self, v: Value) -> Result<DeviceTensor> {
        match &self.backend {
            Backend::Host(_) => Ok(DeviceTensor {
                buf: DeviceBuffer { value: v },
            }),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.upload(v),
        }
    }

    /// Execute on pre-uploaded buffers (mixed with per-call inputs the
    /// caller uploads itself). Buffers are shape-validated against the
    /// manifest exactly like `run` inputs — the backends assume validated
    /// inputs.
    pub fn run_b(&self, name: &str, inputs: &[&DeviceBuffer]) -> Result<Vec<Value>> {
        let spec = self.manifest.artifact(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} buffers given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (b, io) in inputs.iter().zip(&spec.inputs) {
            let v = &b.value;
            if v.shape() != io.shape.as_slice() || v.dtype() != io.dtype {
                bail!(
                    "{name}: buffer {:?} got shape {:?} dtype {}, want {:?} {}",
                    io.name,
                    v.shape(),
                    v.dtype(),
                    io.shape,
                    io.dtype
                );
            }
        }
        let refs: Vec<&Value> = inputs.iter().map(|b| &b.value).collect();
        let out = self.dispatch(name, &refs)?;
        check_outputs(name, spec, &out)?;
        Ok(out)
    }
}

/// Backend outputs must honor the manifest contract — count, shape and
/// dtype — so a kernel bug surfaces here as an error naming the artifact,
/// not as wrong numerics or a slice panic downstream.
fn check_outputs(name: &str, spec: &ArtifactSpec, out: &[Value]) -> Result<()> {
    if out.len() != spec.outputs.len() {
        bail!(
            "{name}: backend produced {} outputs, manifest wants {}",
            out.len(),
            spec.outputs.len()
        );
    }
    for (v, io) in out.iter().zip(&spec.outputs) {
        if v.shape() != io.shape.as_slice() || v.dtype() != io.dtype {
            bail!(
                "{name}: output {:?} has shape {:?} dtype {}, manifest wants {:?} {}",
                io.name,
                v.shape(),
                v.dtype(),
                io.shape,
                io.dtype
            );
        }
    }
    Ok(())
}

/// A pinned runtime buffer. Host backend: the value itself. PJRT backend:
/// the device buffer plus the literal backing the (possibly in-flight)
/// transfer.
pub struct DeviceBuffer {
    value: Value,
}

impl DeviceBuffer {
    pub fn value(&self) -> &Value {
        &self.value
    }
}

/// A pinned tensor; `buf` is what `run_b` consumes.
pub struct DeviceTensor {
    pub buf: DeviceBuffer,
}

/// A set of pre-uploaded buffers (e.g. all model params), reusable across
/// many `run_b` calls.
pub struct BufferSet {
    pub tensors: Vec<DeviceTensor>,
}

impl BufferSet {
    pub fn upload(engine: &Engine, values: &[Value]) -> Result<BufferSet> {
        Ok(BufferSet {
            tensors: values
                .iter()
                .map(|v| engine.upload(v.clone()))
                .collect::<Result<_>>()?,
        })
    }

    pub fn refs(&self) -> Vec<&DeviceBuffer> {
        self.tensors.iter().map(|t| &t.buf).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_synthesizes_builtin_presets() {
        let e = Engine::open("artifacts/tiny").unwrap();
        assert_eq!(e.config().name, "tiny");
        assert_eq!(e.config().d_model, 64);
        assert!(e.manifest.artifact("train_step").is_ok());
        assert!(Engine::open("artifacts/no-such-preset").is_err());
    }

    #[test]
    fn run_validates_shapes_and_counts_calls() {
        let e = Engine::open("artifacts/tiny").unwrap();
        // wrong arity
        assert!(e.run("quadform", &[]).is_err());
        // wrong shape
        let bad = Value::F32(crate::tensor::Tensor::zeros(&[3, 3]));
        let g = Value::F32(crate::tensor::Tensor::zeros(&[64, 64]));
        assert!(e.run("quadform", &[bad, g.clone()]).is_err());
        // correct call executes on the host backend and is counted
        let wd = Value::F32(crate::tensor::Tensor::zeros(&[64, 32]));
        let out = e.run("quadform", &[wd, g]).unwrap();
        assert_eq!(out[0].shape(), &[32]);
        assert_eq!(e.call_counts(), vec![("quadform".to_string(), 1)]);
    }

    #[test]
    fn upload_run_b_matches_run() {
        let e = Engine::open("artifacts/tiny").unwrap();
        let mut rng = crate::util::rng::Pcg64::new(5);
        let mk = |shape: &[usize], rng: &mut crate::util::rng::Pcg64| {
            let n: usize = shape.iter().product();
            crate::tensor::Tensor::from_vec(
                shape,
                (0..n).map(|_| rng.normal() * 0.1).collect(),
            )
        };
        let wd = Value::F32(mk(&[64, 32], &mut rng));
        let a = mk(&[64, 64], &mut rng);
        let g = Value::F32(crate::tensor::matmul_tn(&a, &a));
        let direct = e.run("quadform", &[wd.clone(), g.clone()]).unwrap();
        let wd_b = e.upload(wd).unwrap();
        let g_b = e.upload(g).unwrap();
        let via_buf = e.run_b("quadform", &[&wd_b.buf, &g_b.buf]).unwrap();
        let (x, y) = (
            direct[0].clone().f32().unwrap(),
            via_buf[0].clone().f32().unwrap(),
        );
        assert_eq!(x, y, "buffer path must match literal path bitwise");
    }

    #[test]
    fn warmup_checks_artifact_names() {
        let e = Engine::open("artifacts/tiny").unwrap();
        assert!(e.warmup(&["quadform", "moe_gate_n8"]).is_ok());
        assert!(e.warmup(&["not_an_artifact"]).is_err());
    }
}
