//! PJRT backend (feature `pjrt`): compile HLO-text artifacts once through
//! the published `xla` crate, execute many.
//!
//! This module is OFF by default: the offline build image vendors no
//! crates.io registry, so the `xla = "0.1.6"` dependency cannot resolve
//! there. To re-enable on a networked machine:
//!
//! 1. add `xla = "0.1.6"` to `[dependencies]` in Cargo.toml,
//! 2. make it non-optional or wire `pjrt = ["dep:xla"]`,
//! 3. build with `--features pjrt`, and re-plumb `DeviceBuffer` to carry
//!    the `xla::PjRtBuffer` (+ backing literal — BufferFromHostLiteral is
//!    asynchronous in the 0.5.1 C shim; the literal must outlive the
//!    transfer) instead of a host `Value`.
//!
//! The artifact contract is unchanged from the host backend: HLO text (not
//! serialized protos — xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id
//! protos; the text parser reassigns ids), every artifact lowered with
//! `return_tuple=True`, inputs/outputs ordered per manifest.json.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::debug;
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::value::Value;

/// Compiled-executable cache keyed by artifact name, over one PJRT CPU
/// client. Not Send/Sync (PJRT handles are raw pointers): the serving
/// coordinator owns one engine on a dedicated execution thread.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl PjrtBackend {
    /// Open a PJRT CPU client over `dir` (must contain the .hlo.txt files
    /// named by the manifest).
    pub fn open(dir: &Path) -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(PjrtBackend {
            client,
            dir: dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Compile (or fetch cached) an artifact's executable.
    pub fn compile(&self, name: &str, path: &Path) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let t = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        debug!("compiled {name} in {:.2}s", t.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Pin a value for `run_b`. Until `DeviceBuffer` carries a real
    /// `xla::PjRtBuffer` (see module docs), values stay host-held and the
    /// literal marshalling happens per call.
    pub fn upload(&self, v: Value) -> Result<crate::runtime::DeviceTensor> {
        Ok(crate::runtime::DeviceTensor {
            buf: crate::runtime::DeviceBuffer { value: v },
        })
    }

    /// Execute `name` (literal-marshalled path).
    pub fn run(&self, name: &str, inputs: &[&Value], spec: &ArtifactSpec) -> Result<Vec<Value>> {
        self.compile(name, &self.dir.join(&spec.file))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| host_to_xla_literal(v))
            .collect::<Result<_>>()?;
        let cache = self.cache.borrow();
        let exe = cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e}"))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} output: {e}"))?;
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, io)| xla_literal_to_value(&lit, io))
            .collect()
    }

    /// Session stub: execute a [`crate::runtime::Session::run_s`] call on
    /// the literal path. A real PJRT session would keep one donated
    /// `PjRtBuffer` per resident and declare input/output aliasing at
    /// compile time (`HloInputOutputAliasConfig`) — that is what makes the
    /// in-place KV append free on device, and the `Session` trait boundary
    /// is already shaped for it: residents are named, capacity-sized, and
    /// never round-trip through the caller. Until `DeviceBuffer` carries a
    /// real `PjRtBuffer` (see module docs) this marshals every input per
    /// call and returns every output; the engine-level session writes the
    /// aliased outputs back into its resident table.
    pub fn run_s(&self, name: &str, inputs: &[&Value], spec: &ArtifactSpec) -> Result<Vec<Value>> {
        self.run(name, inputs, spec)
    }
}

fn host_to_xla_literal(v: &Value) -> Result<xla::Literal> {
    // Serialize once, straight from the tensor — no intermediate host
    // Literal (its byte buffer would be built and thrown away).
    let (ty, bytes) = match v {
        Value::F32(t) => (
            xla::ElementType::F32,
            t.data()
                .iter()
                .flat_map(|x| x.to_le_bytes())
                .collect::<Vec<u8>>(),
        ),
        Value::I32(t) => (
            xla::ElementType::S32,
            t.data()
                .iter()
                .flat_map(|x| x.to_le_bytes())
                .collect::<Vec<u8>>(),
        ),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, v.shape(), &bytes)
        .map_err(|e| anyhow!("literal from shape {:?}: {e}", v.shape()))
}

fn xla_literal_to_value(
    lit: &xla::Literal,
    io: &crate::runtime::manifest::IoSpec,
) -> Result<Value> {
    use crate::runtime::manifest::Dtype;
    use crate::tensor::{ITensor, Tensor};
    match io.dtype {
        Dtype::F32 => {
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("output {:?} as f32: {e}", io.name))?;
            Ok(Value::F32(Tensor::from_vec(&io.shape, data)))
        }
        Dtype::I32 => {
            let data = lit
                .to_vec::<i32>()
                .map_err(|e| anyhow!("output {:?} as i32: {e}", io.name))?;
            Ok(Value::I32(ITensor::from_vec(&io.shape, data)))
        }
    }
}
