//! Value: the marshalling type between host tensors and runtime literals.
//!
//! [`Literal`] is the untyped-bytes wire format artifacts consume. The
//! host backend reads it directly; the PJRT backend (feature `pjrt`)
//! converts it to an `xla::Literal` at the FFI boundary.

use anyhow::{anyhow, bail, Result};

use crate::runtime::manifest::{Dtype, IoSpec};
use crate::tensor::{ITensor, Tensor};

#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(ITensor),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(t) => t.shape(),
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Value::F32(_) => Dtype::F32,
            Value::I32(_) => Dtype::I32,
        }
    }

    pub fn f32(self) -> Result<Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 value, got i32"),
        }
    }

    pub fn i32(self) -> Result<ITensor> {
        match self {
            Value::I32(t) => Ok(t),
            Value::F32(_) => bail!("expected i32 value, got f32"),
        }
    }

    /// Borrow the f32 tensor (host-backend fast path; no clone).
    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 value, got i32"),
        }
    }

    /// Borrow the i32 tensor (host-backend fast path; no clone).
    pub fn as_i32(&self) -> Result<&ITensor> {
        match self {
            Value::I32(t) => Ok(t),
            Value::F32(_) => bail!("expected i32 value, got f32"),
        }
    }

    /// Mutably borrow the f32 tensor (the session in-place update path:
    /// resident KV caches are appended to without a round trip).
    pub fn as_f32_mut(&mut self) -> Result<&mut Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 value, got i32"),
        }
    }

    /// Marshalled size in bytes (both dtypes are 4-byte scalars). Upload
    /// accounting uses this to price host->device traffic.
    pub fn byte_len(&self) -> usize {
        self.shape().iter().product::<usize>() * 4
    }

    pub fn scalar_f32(v: f32) -> Value {
        Value::F32(Tensor::scalar(v))
    }

    pub fn scalar_i32(v: i32) -> Value {
        Value::I32(ITensor::scalar(v))
    }

    pub fn to_literal(&self) -> Result<Literal> {
        // Build the byte buffer once and hand it over — no re-copy through
        // the validating constructor (lengths are correct by construction).
        let (dtype, shape, bytes) = match self {
            Value::F32(t) => (Dtype::F32, t.shape(), bytes_f32(t.data())),
            Value::I32(t) => (Dtype::I32, t.shape(), bytes_i32(t.data())),
        };
        Ok(Literal { dtype, shape: shape.to_vec(), bytes })
    }

    pub fn from_literal(lit: &Literal, io: &IoSpec) -> Result<Value> {
        if lit.shape != io.shape {
            bail!(
                "literal shape {:?} does not match spec {:?} for {:?}",
                lit.shape,
                io.shape,
                io.name
            );
        }
        match io.dtype {
            Dtype::F32 => {
                let data = lit
                    .to_f32_vec()
                    .map_err(|e| anyhow!("output {:?} as f32: {e}", io.name))?;
                Ok(Value::F32(Tensor::from_vec(&io.shape, data)))
            }
            Dtype::I32 => {
                let data = lit
                    .to_i32_vec()
                    .map_err(|e| anyhow!("output {:?} as i32: {e}", io.name))?;
                Ok(Value::I32(ITensor::from_vec(&io.shape, data)))
            }
        }
    }
}

/// Shape- and dtype-tagged little-endian byte buffer, mirroring the slice
/// of the PJRT literal API the pipeline uses.
#[derive(Clone, Debug)]
pub struct Literal {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        dtype: Dtype,
        shape: &[usize],
        bytes: &[u8],
    ) -> Result<Literal> {
        let n: usize = shape.iter().product();
        if bytes.len() != n * 4 {
            bail!(
                "literal shape {shape:?} wants {} bytes, got {}",
                n * 4,
                bytes.len()
            );
        }
        Ok(Literal { dtype, shape: shape.to_vec(), bytes: bytes.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("literal is {}, not f32", self.dtype);
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_i32_vec(&self) -> Result<Vec<i32>> {
        if self.dtype != Dtype::I32 {
            bail!("literal is {}, not i32", self.dtype);
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

fn bytes_f32(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_i32(xs: &[i32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]);
        let v = Value::F32(t.clone());
        let lit = v.to_literal().unwrap();
        let io = IoSpec { name: "x".into(), shape: vec![2, 3], dtype: Dtype::F32 };
        let back = Value::from_literal(&lit, &io).unwrap().f32().unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = ITensor::from_vec(&[4], vec![1, -2, 300, 65536]);
        let lit = Value::I32(t.clone()).to_literal().unwrap();
        let io = IoSpec { name: "x".into(), shape: vec![4], dtype: Dtype::I32 };
        let back = Value::from_literal(&lit, &io).unwrap().i32().unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_literals() {
        let lit = Value::scalar_f32(2.5).to_literal().unwrap();
        let io = IoSpec { name: "s".into(), shape: vec![], dtype: Dtype::F32 };
        let v = Value::from_literal(&lit, &io).unwrap().f32().unwrap();
        assert_eq!(v.item(), 2.5);
    }

    #[test]
    fn byte_len_and_mut_borrow() {
        let mut v = Value::F32(Tensor::zeros(&[2, 3]));
        assert_eq!(v.byte_len(), 24);
        assert_eq!(Value::scalar_i32(7).byte_len(), 4);
        v.as_f32_mut().unwrap().data_mut()[0] = 5.0;
        assert_eq!(v.as_f32().unwrap().data()[0], 5.0);
        assert!(Value::scalar_i32(0).as_f32_mut().is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let lit = Value::scalar_f32(1.0).to_literal().unwrap();
        assert!(lit.to_i32_vec().is_err());
        let io = IoSpec { name: "s".into(), shape: vec![2], dtype: Dtype::F32 };
        assert!(Value::from_literal(&lit, &io).is_err());
    }
}
