//! Value: the marshalling type between host tensors and PJRT literals.

use anyhow::{anyhow, bail, Result};

use crate::runtime::manifest::{Dtype, IoSpec};
use crate::tensor::{ITensor, Tensor};

#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(ITensor),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(t) => t.shape(),
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Value::F32(_) => Dtype::F32,
            Value::I32(_) => Dtype::I32,
        }
    }

    pub fn f32(self) -> Result<Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 value, got i32"),
        }
    }

    pub fn i32(self) -> Result<ITensor> {
        match self {
            Value::I32(t) => Ok(t),
            Value::F32(_) => bail!("expected i32 value, got f32"),
        }
    }

    pub fn scalar_f32(v: f32) -> Value {
        Value::F32(Tensor::scalar(v))
    }

    pub fn scalar_i32(v: i32) -> Value {
        Value::I32(ITensor::scalar(v))
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let (ty, shape, bytes): (xla::ElementType, &[usize], &[u8]) = match self {
            Value::F32(t) => (
                xla::ElementType::F32,
                t.shape(),
                bytemuck_f32(t.data()),
            ),
            Value::I32(t) => (
                xla::ElementType::S32,
                t.shape(),
                bytemuck_i32(t.data()),
            ),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, shape, bytes)
            .map_err(|e| anyhow!("literal from shape {shape:?}: {e}"))
    }

    pub fn from_literal(lit: &xla::Literal, io: &IoSpec) -> Result<Value> {
        match io.dtype {
            Dtype::F32 => {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("output {:?} as f32: {e}", io.name))?;
                Ok(Value::F32(Tensor::from_vec(&io.shape, data)))
            }
            Dtype::I32 => {
                let data = lit
                    .to_vec::<i32>()
                    .map_err(|e| anyhow!("output {:?} as i32: {e}", io.name))?;
                Ok(Value::I32(ITensor::from_vec(&io.shape, data)))
            }
        }
    }
}

fn bytemuck_f32(xs: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) }
}

fn bytemuck_i32(xs: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]);
        let v = Value::F32(t.clone());
        let lit = v.to_literal().unwrap();
        let io = IoSpec { name: "x".into(), shape: vec![2, 3], dtype: Dtype::F32 };
        let back = Value::from_literal(&lit, &io).unwrap().f32().unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = ITensor::from_vec(&[4], vec![1, -2, 300, 65536]);
        let lit = Value::I32(t.clone()).to_literal().unwrap();
        let io = IoSpec { name: "x".into(), shape: vec![4], dtype: Dtype::I32 };
        let back = Value::from_literal(&lit, &io).unwrap().i32().unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_literals() {
        let lit = Value::scalar_f32(2.5).to_literal().unwrap();
        let io = IoSpec { name: "s".into(), shape: vec![], dtype: Dtype::F32 };
        let v = Value::from_literal(&lit, &io).unwrap().f32().unwrap();
        assert_eq!(v.item(), 2.5);
    }
}
