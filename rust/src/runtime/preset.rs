//! Built-in presets + manifest synthesis for the host backend.
//!
//! `python/compile/configs.py` is the source of truth when artifacts are
//! exported (`make artifacts` writes manifest.json and `Engine::open`
//! loads it). When no manifest exists — the offline image cannot run the
//! AOT exporter's PJRT toolchain — the host backend synthesizes an
//! identical manifest from the preset tables mirrored here, so every
//! consumer (ParamStore layout, shape validation, serving buckets) sees
//! the same contract either way.

use crate::config::ModelConfig;
use crate::runtime::manifest::{ArtifactSpec, Dtype, IoSpec, Manifest};

/// Mirror of `configs.py::PRESETS`. `width_buckets` = blk_i..=d_inter.
pub fn builtin(name: &str) -> Option<ModelConfig> {
    let cfg = match name {
        "tiny" => ModelConfig {
            name: "tiny".into(),
            vocab: 260,
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            d_head: 32,
            n_experts: 4,
            top_k: 2,
            d_inter: 32,
            seq_len: 64,
            batch: 4,
            blk_n: 16,
            blk_i: 8,
            serve_batches: vec![1, 4],
            token_buckets: vec![8, 32],
            width_buckets: (1..=4).map(|i| i * 8).collect(),
            max_decode_len: 96,
        },
        "small" => ModelConfig {
            name: "small".into(),
            vocab: 260,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_head: 32,
            n_experts: 8,
            top_k: 2,
            d_inter: 64,
            seq_len: 128,
            batch: 8,
            blk_n: 32,
            blk_i: 16,
            serve_batches: vec![1, 8],
            token_buckets: vec![8, 32, 128],
            width_buckets: (1..=4).map(|i| i * 16).collect(),
            max_decode_len: 160,
        },
        "base" => ModelConfig {
            name: "base".into(),
            vocab: 260,
            d_model: 192,
            n_layers: 6,
            n_heads: 6,
            d_head: 32,
            n_experts: 16,
            top_k: 2,
            d_inter: 96,
            seq_len: 128,
            batch: 8,
            blk_n: 32,
            blk_i: 16,
            serve_batches: vec![1, 8],
            token_buckets: vec![8, 32, 128],
            width_buckets: (1..=6).map(|i| i * 16).collect(),
            max_decode_len: 160,
        },
        _ => return None,
    };
    Some(cfg)
}

/// Mirror of `model.py::param_specs` — the flat layout contract.
pub fn param_specs(cfg: &ModelConfig) -> Vec<(String, Vec<usize>)> {
    let (d, di, e) = (cfg.d_model, cfg.d_inter, cfg.n_experts);
    let mut specs = vec![
        ("embed".to_string(), vec![cfg.vocab, d]),
        ("pos".to_string(), vec![cfg.seq_len, d]),
    ];
    for l in 0..cfg.n_layers {
        specs.push((format!("l{l}.ln1"), vec![d]));
        specs.push((format!("l{l}.wq"), vec![d, d]));
        specs.push((format!("l{l}.wk"), vec![d, d]));
        specs.push((format!("l{l}.wv"), vec![d, d]));
        specs.push((format!("l{l}.wo"), vec![d, d]));
        specs.push((format!("l{l}.ln2"), vec![d]));
        specs.push((format!("l{l}.router"), vec![e, d]));
        specs.push((format!("l{l}.wg"), vec![e, di, d]));
        specs.push((format!("l{l}.wu"), vec![e, di, d]));
        specs.push((format!("l{l}.wd"), vec![e, d, di]));
    }
    specs.push(("lnf".to_string(), vec![d]));
    specs
}

fn fspec(name: &str, shape: &[usize]) -> IoSpec {
    IoSpec { name: name.to_string(), shape: shape.to_vec(), dtype: Dtype::F32 }
}

fn ispec(name: &str, shape: &[usize]) -> IoSpec {
    IoSpec { name: name.to_string(), shape: shape.to_vec(), dtype: Dtype::I32 }
}

/// Synthesize the manifest `aot.py` would export for `cfg` (same artifact
/// names and I/O specs; the `.hlo.txt` files simply do not exist, which
/// only the PJRT backend would need).
pub fn synthesize(cfg: &ModelConfig) -> Manifest {
    let params = param_specs(cfg);
    let (b, t, v) = (cfg.batch, cfg.seq_len, cfg.vocab);
    let (l, e, d, di) = (cfg.n_layers, cfg.n_experts, cfg.d_model, cfg.d_inter);
    let (h, hd, smax) = (cfg.n_heads, cfg.d_head, cfg.max_decode_len);

    let pspecs: Vec<IoSpec> = params.iter().map(|(n, s)| fspec(n, s)).collect();
    let mut artifacts = std::collections::BTreeMap::new();
    let mut add = |name: &str, inputs: Vec<IoSpec>, outputs: Vec<IoSpec>| {
        artifacts.insert(
            name.to_string(),
            ArtifactSpec { file: format!("{name}.hlo.txt"), inputs, outputs },
        );
    };

    // train_step: params + m + v + step + lr + tokens + targets
    let mut inp = pspecs.clone();
    inp.extend(params.iter().map(|(n, s)| fspec(&format!("m.{n}"), s)));
    inp.extend(params.iter().map(|(n, s)| fspec(&format!("v.{n}"), s)));
    inp.push(ispec("step", &[]));
    inp.push(fspec("lr", &[]));
    inp.push(ispec("tokens", &[b, t]));
    inp.push(ispec("targets", &[b, t]));
    let mut out = vec![fspec("loss", &[]), fspec("ce", &[])];
    out.extend(params.iter().map(|(n, s)| fspec(n, s)));
    out.extend(params.iter().map(|(n, s)| fspec(&format!("m.{n}"), s)));
    out.extend(params.iter().map(|(n, s)| fspec(&format!("v.{n}"), s)));
    add("train_step", inp, out);

    let masked = |extra: &[IoSpec]| -> Vec<IoSpec> {
        let mut v = pspecs.clone();
        v.push(fspec("mask", &[l, e, di]));
        v.extend(extra.iter().cloned());
        v
    };
    add(
        "forward_masked",
        masked(&[ispec("tokens", &[b, t])]),
        vec![fspec("logits", &[b, t, v])],
    );
    add(
        "loss_masked",
        masked(&[ispec("tokens", &[b, t]), ispec("targets", &[b, t])]),
        vec![fspec("nll_sum", &[]), fspec("tok_cnt", &[])],
    );
    add(
        "seq_nll",
        masked(&[ispec("tokens", &[b, t]), ispec("targets", &[b, t])]),
        vec![fspec("nll_rows", &[b]), fspec("cnt_rows", &[b])],
    );

    let mut inp = pspecs.clone();
    inp.push(ispec("tokens", &[b, t]));
    inp.push(ispec("targets", &[b, t]));
    add(
        "calib_pass1",
        inp,
        vec![fspec("ce", &[]), fspec("gsum", &[l, e, d, d]), fspec("counts", &[l, e])],
    );
    let mut inp = pspecs.clone();
    inp.push(ispec("tokens", &[b, t]));
    add(
        "calib_pass2",
        inp,
        vec![
            fspec("hsq", &[l, e, di]),
            fspec("hmax", &[l, e, di]),
            fspec("counts", &[l, e]),
            fspec("probe", &[]),
        ],
    );
    add(
        "quadform",
        vec![fspec("wd", &[d, di]), fspec("G", &[d, d])],
        vec![fspec("q", &[di])],
    );

    let attn_w = |v: &mut Vec<IoSpec>| {
        v.push(fspec("ln1", &[d]));
        v.push(fspec("wq", &[d, d]));
        v.push(fspec("wk", &[d, d]));
        v.push(fspec("wv", &[d, d]));
        v.push(fspec("wo", &[d, d]));
    };
    for &bb in &cfg.serve_batches {
        let mut inp = vec![fspec("x", &[bb, t, d])];
        attn_w(&mut inp);
        inp.push(fspec("len_mask", &[bb, t]));
        add(
            &format!("attn_prefill_b{bb}"),
            inp,
            vec![
                fspec("y", &[bb, t, d]),
                fspec("k", &[bb, h, t, hd]),
                fspec("v", &[bb, h, t, hd]),
            ],
        );
        let mut inp = vec![fspec("x", &[bb, 1, d])];
        attn_w(&mut inp);
        inp.push(fspec("kcache", &[bb, h, smax, hd]));
        inp.push(fspec("vcache", &[bb, h, smax, hd]));
        inp.push(ispec("pos", &[bb]));
        add(
            &format!("attn_decode_b{bb}"),
            inp,
            vec![
                fspec("y", &[bb, 1, d]),
                fspec("kcache", &[bb, h, smax, hd]),
                fspec("vcache", &[bb, h, smax, hd]),
            ],
        );
    }
    for &n in &cfg.token_buckets {
        add(
            &format!("moe_gate_n{n}"),
            vec![fspec("x", &[n, d]), fspec("ln2", &[d]), fspec("router", &[e, d])],
            vec![fspec("xn", &[n, d]), fspec("gates", &[n, e])],
        );
        add(
            &format!("lm_head_n{n}"),
            vec![fspec("x", &[n, d]), fspec("lnf", &[d]), fspec("embed", &[v, d])],
            vec![fspec("logits", &[n, v])],
        );
        for &w in &cfg.width_buckets {
            add(
                &format!("expert_n{n}_w{w}"),
                vec![
                    fspec("xs", &[n, d]),
                    fspec("wg", &[w, d]),
                    fspec("wu", &[w, d]),
                    fspec("wd", &[d, w]),
                ],
                vec![fspec("ys", &[n, d])],
            );
        }
    }

    Manifest { preset: cfg.clone(), params, artifacts }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_preset_matches_configs_py() {
        let c = builtin("tiny").unwrap();
        assert_eq!(c.d_model, 64);
        assert_eq!(c.d_head, 32);
        assert_eq!(c.width_buckets, vec![8, 16, 24, 32]);
        assert!(builtin("nope").is_none());
    }

    #[test]
    fn synthesized_manifest_is_complete() {
        let cfg = builtin("tiny").unwrap();
        let m = synthesize(&cfg);
        // param registry: embed, pos, 10 per layer, lnf
        assert_eq!(m.params.len(), 2 + 10 * cfg.n_layers + 1);
        assert_eq!(m.params[0].0, "embed");
        assert_eq!(m.params.last().unwrap().0, "lnf");
        // core + serving artifacts all present
        for name in [
            "train_step",
            "forward_masked",
            "loss_masked",
            "seq_nll",
            "calib_pass1",
            "calib_pass2",
            "quadform",
            "attn_prefill_b1",
            "attn_decode_b4",
            "moe_gate_n8",
            "lm_head_n32",
            "expert_n8_w16",
            "expert_n32_w32",
        ] {
            assert!(m.artifact(name).is_ok(), "missing {name}");
        }
        let ts = m.artifact("train_step").unwrap();
        assert_eq!(ts.inputs.len(), 3 * m.params.len() + 4);
        assert_eq!(ts.outputs.len(), 2 + 3 * m.params.len());
        let q = m.artifact("quadform").unwrap();
        assert_eq!(q.inputs[0].shape, vec![64, 32]);
        assert_eq!(q.outputs[0].shape, vec![32]);
    }

    #[test]
    fn param_specs_order_matches_store_expectations() {
        let cfg = builtin("tiny").unwrap();
        let specs = param_specs(&cfg);
        let names: Vec<&str> = specs.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(&names[..3], &["embed", "pos", "l0.ln1"]);
        assert!(names.contains(&"l1.router"));
        let wd = specs.iter().find(|(n, _)| n == "l0.wd").unwrap();
        assert_eq!(wd.1, vec![cfg.n_experts, cfg.d_model, cfg.d_inter]);
    }
}
