//! manifest.json parsing — the shape contract between aot.py and rust.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::config::ModelConfig;
use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub enum Dtype {
    F32,
    I32,
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
        })
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug)]
pub struct Manifest {
    pub preset: ModelConfig,
    /// Flat parameter registry in artifact order: (name, shape).
    pub params: Vec<(String, Vec<usize>)>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn io_spec(j: &Json) -> Result<IoSpec> {
    let dtype = match j.get("dtype")?.as_str()? {
        "f32" => Dtype::F32,
        "i32" => Dtype::I32,
        other => return Err(anyhow!("unknown dtype {other:?}")),
    };
    Ok(IoSpec {
        name: j.get("name")?.as_str()?.to_string(),
        shape: j.get("shape")?.usize_vec()?,
        dtype,
    })
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let src = std::fs::read_to_string(path)?;
        Self::parse(&src)
    }

    pub fn parse(src: &str) -> Result<Manifest> {
        let j = Json::parse(src)?;
        let preset = ModelConfig::from_json(j.get("preset")?)?;
        let params = j
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok((
                    p.get("name")?.as_str()?.to_string(),
                    p.get("shape")?.usize_vec()?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in j.get("artifacts")?.as_obj()? {
            let inputs = a
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(io_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(io_spec)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: a.get("file")?.as_str()?.to_string(),
                    inputs,
                    outputs,
                },
            );
        }
        Ok(Manifest { preset, params, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest (have: {:?})",
                                   // lint:allow(hot-path-alloc) error-path only: the keys list renders the missing-artifact message inside `ok_or_else`, never on a hit
                                   self.artifacts.keys().collect::<Vec<_>>()))
    }

    /// Total parameter element count.
    pub fn n_param_elems(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

/// Capacity-axis contract for engine-resident state: some artifact IOs are
/// ring-buffer-like caches whose compiled shape is a *maximum* — a session
/// may bind a resident whose extent along this axis is smaller (the
/// caller-chosen capacity), and the backends index it dynamically. Today
/// that is the decode KV caches' sequence axis; the rule lives here (next
/// to the shape contract) so `Session::run_s` validation and the backends
/// agree on it.
pub fn capacity_axis(artifact: &str, io_name: &str) -> Option<usize> {
    if artifact.starts_with("attn_decode_b") && (io_name == "kcache" || io_name == "vcache") {
        Some(2)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "preset": {"name":"tiny","vocab":260,"d_model":64,"n_layers":2,
        "n_heads":2,"d_head":32,"n_experts":4,"top_k":2,"d_inter":32,
        "seq_len":64,"batch":4,"blk_n":16,"blk_i":8,"aux_coef":0.01,
        "serve_batches":[1,4],"token_buckets":[8,32],
        "width_buckets":[8,16,24,32],"max_decode_len":96},
      "params": [{"name":"embed","shape":[260,64]},{"name":"lnf","shape":[64]}],
      "artifacts": {
        "quadform": {"file":"quadform.hlo.txt",
          "inputs":[{"name":"wd","shape":[64,32],"dtype":"f32"},
                    {"name":"G","shape":[64,64],"dtype":"f32"}],
          "outputs":[{"name":"q","shape":[32],"dtype":"f32"}]}
      }
    }"#;

    #[test]
    fn capacity_axis_names_the_decode_cache_seq_dim() {
        assert_eq!(capacity_axis("attn_decode_b4", "kcache"), Some(2));
        assert_eq!(capacity_axis("attn_decode_b1", "vcache"), Some(2));
        assert_eq!(capacity_axis("attn_decode_b4", "x"), None);
        assert_eq!(capacity_axis("attn_prefill_b4", "kcache"), None);
        assert_eq!(capacity_axis("quadform", "wd"), None);
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.preset.d_model, 64);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.n_param_elems(), 260 * 64 + 64);
        let a = m.artifact("quadform").unwrap();
        assert_eq!(a.inputs[1].shape, vec![64, 64]);
        assert_eq!(a.outputs[0].dtype, Dtype::F32);
        assert!(m.artifact("nope").is_err());
    }
}
