//! Data substrate: synthetic corpus, tokenizers, calibration sampler.
//!
//! The paper calibrates on WikiText-2/C4 and evaluates on LM-Eval zero-shot
//! tasks; neither is available offline, so we build the closest synthetic
//! equivalent (see docs/ARCHITECTURE.md): a deterministic *topic grammar* whose
//! documents carry (a) topic-clustered vocabulary — which drives MoE expert
//! specialisation, the statistical structure HEAPr's routed-token
//! calibration depends on — and (b) recurring linguistic patterns
//! (agreement, retrieval, negation, ...) that the 7 zero-shot tasks probe
//! with held-out instantiations.

pub mod corpus;
pub mod tokenizer;
pub mod sampler;

pub use corpus::{Grammar, TaskItem, TaskKind};
pub use sampler::{CalibSampler, Split};
pub use tokenizer::{ByteTokenizer, Bpe, PAD, BOS, EOS, SEP};
