//! Tokenizers.
//!
//! The compiled models use the *byte* tokenizer (vocab 260 = 256 bytes +
//! specials) — simple, lossless, and matches the vocab baked into the HLO
//! artifacts. A small BPE trainer/encoder is provided as a substrate for
//! corpus analysis and for validating the data pipeline against a
//! merged-token view (it is exercised by tests and the corpus-stats tool,
//! not by the model path).

use std::collections::HashMap;

pub const PAD: i32 = 256;
pub const BOS: i32 = 257;
pub const EOS: i32 = 258;
pub const SEP: i32 = 259;
pub const VOCAB: usize = 260;

/// Lossless byte-level tokenizer.
#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn encode(&self, s: &str) -> Vec<i32> {
        s.bytes().map(|b| b as i32).collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

/// Byte-pair encoding with a trained merge table.
#[derive(Clone, Debug)]
pub struct Bpe {
    /// merge rank: (left, right) -> merged symbol id (>= 256)
    merges: HashMap<(u32, u32), u32>,
    /// symbol id -> byte expansion
    pieces: Vec<Vec<u8>>,
}

impl Bpe {
    /// Train `n_merges` merges on `text` by iterated most-frequent-pair.
    pub fn train(text: &str, n_merges: usize) -> Bpe {
        let mut pieces: Vec<Vec<u8>> = (0..256u32).map(|b| vec![b as u8]).collect();
        let mut merges = HashMap::new();
        // work on a sample of words to keep training cheap
        let mut words: Vec<Vec<u32>> = text
            .split_whitespace()
            .take(50_000)
            .map(|w| w.bytes().map(|b| b as u32).collect())
            .collect();
        for _ in 0..n_merges {
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in &words {
                for pair in w.windows(2) {
                    *counts.entry((pair[0], pair[1])).or_insert(0) += 1;
                }
            }
            let best = counts.iter().max_by_key(|(p, &c)| (c, std::cmp::Reverse(**p)));
            let Some((&pair, &cnt)) = best else {
                break;
            };
            if cnt < 2 {
                break;
            }
            let new_id = pieces.len() as u32;
            let mut expansion = pieces[pair.0 as usize].clone();
            expansion.extend_from_slice(&pieces[pair.1 as usize]);
            pieces.push(expansion);
            merges.insert(pair, new_id);
            for w in &mut words {
                Self::apply_merge(w, pair, new_id);
            }
        }
        Bpe { merges, pieces }
    }

    fn apply_merge(w: &mut Vec<u32>, pair: (u32, u32), new_id: u32) {
        let mut out = Vec::with_capacity(w.len());
        let mut i = 0;
        while i < w.len() {
            if i + 1 < w.len() && (w[i], w[i + 1]) == pair {
                out.push(new_id);
                i += 2;
            } else {
                out.push(w[i]);
                i += 1;
            }
        }
        *w = out;
    }

    pub fn vocab_size(&self) -> usize {
        self.pieces.len()
    }

    pub fn encode(&self, s: &str) -> Vec<u32> {
        let mut out = Vec::new();
        for word in s.split_inclusive(' ') {
            let mut syms: Vec<u32> = word.bytes().map(|b| b as u32).collect();
            loop {
                // find the applicable merge that was learned (any; repeat to
                // fixpoint — merge table is closed under composition order)
                let mut applied = false;
                let mut i = 0;
                while i + 1 < syms.len() {
                    if let Some(&id) = self.merges.get(&(syms[i], syms[i + 1])) {
                        syms[i] = id;
                        syms.remove(i + 1);
                        applied = true;
                    } else {
                        i += 1;
                    }
                }
                if !applied {
                    break;
                }
            }
            out.extend(syms);
        }
        out
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            bytes.extend_from_slice(&self.pieces[id as usize]);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_roundtrip() {
        let t = ByteTokenizer;
        let s = "the brak slom kesh . 123";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn byte_decode_skips_specials() {
        let t = ByteTokenizer;
        let mut ids = t.encode("hi");
        ids.insert(0, BOS);
        ids.push(EOS);
        ids.push(PAD);
        assert_eq!(t.decode(&ids), "hi");
    }

    #[test]
    fn bpe_roundtrip_and_compresses() {
        let text = "the brak likes the brak . the brak is big . ".repeat(50);
        let bpe = Bpe::train(&text, 40);
        assert!(bpe.vocab_size() > 256);
        let enc = bpe.encode(&text);
        assert_eq!(bpe.decode(&enc), text);
        assert!(enc.len() < text.len(), "{} !< {}", enc.len(), text.len());
    }

    #[test]
    fn bpe_handles_unseen_text() {
        let bpe = Bpe::train("aaa bbb aaa bbb", 10);
        let s = "zq xw";
        assert_eq!(bpe.decode(&bpe.encode(s)), s);
    }
}
