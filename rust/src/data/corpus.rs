//! Deterministic topic-grammar corpus generator.
//!
//! Structure (all seeded, fully reproducible):
//! * `N_TOPICS` topics, each with disjoint noun/verb/adjective banks built
//!   from per-topic syllable inventories — documents stay within one topic,
//!   so the router learns topic-specialised experts (the redundancy pattern
//!   HEAPr exploits).
//! * Shared function words and person names.
//! * Sentence templates embed the patterns the zero-shot tasks test:
//!   subject-verb agreement, fact retrieval, antonym negation, phrase copy,
//!   token alternation, counting.
//!
//! Two "corpora" (synth-wiki / synth-c4) differ by seed stream and topic
//! mixture — standing in for the paper's WikiText-2 vs C4 calibration
//! robustness study (Figure 4).

use crate::util::rng::Pcg64;

pub const N_TOPICS: usize = 6;
const NOUNS_PER_TOPIC: usize = 8;
const VERBS_PER_TOPIC: usize = 6;
const ADJ_PAIRS_PER_TOPIC: usize = 4;

const NAMES: [&str; 10] = [
    "ana", "bo", "cleo", "dag", "eli", "finn", "gia", "hugo", "iris", "jun",
];

const NUMBERS: [&str; 10] = [
    "one", "two", "three", "four", "five", "six", "seven", "eight", "nine",
    "ten",
];

/// Per-topic syllable inventories keep topic vocabularies disjoint and
/// visually distinct (useful when eyeballing generations).
const ONSETS: [[&str; 4]; N_TOPICS] = [
    ["br", "gr", "dr", "tr"],
    ["sl", "sm", "sn", "sp"],
    ["k", "kl", "kr", "qu"],
    ["v", "z", "zh", "w"],
    ["pl", "pr", "fl", "fr"],
    ["m", "n", "l", "r"],
];
const VOWELS: [&str; 5] = ["a", "e", "i", "o", "u"];
const CODAS: [[&str; 4]; N_TOPICS] = [
    ["k", "g", "t", "d"],
    ["p", "b", "m", "n"],
    ["sh", "ch", "x", "s"],
    ["l", "r", "v", "z"],
    ["nt", "nd", "mp", "st"],
    ["ff", "ll", "ss", "zz"],
];

#[derive(Clone, Debug)]
pub struct Topic {
    pub nouns: Vec<String>,
    pub verbs: Vec<String>,
    /// Antonym pairs (a, b): corpus guarantees "not a ... b" co-occurrence.
    pub adj_pairs: Vec<(String, String)>,
}

#[derive(Clone, Debug)]
pub struct Grammar {
    pub topics: Vec<Topic>,
}

/// Zero-shot task kinds (the 7 synthetic benchmarks; see docs/ARCHITECTURE.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    TopicCloze,
    Agreement,
    Retrieval,
    Negation,
    Copy,
    Pattern,
    Counting,
}

pub const ALL_TASKS: [TaskKind; 7] = [
    TaskKind::TopicCloze,
    TaskKind::Agreement,
    TaskKind::Retrieval,
    TaskKind::Negation,
    TaskKind::Copy,
    TaskKind::Pattern,
    TaskKind::Counting,
];

impl TaskKind {
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::TopicCloze => "TopicCloze",
            TaskKind::Agreement => "Agreement",
            TaskKind::Retrieval => "Retrieval",
            TaskKind::Negation => "Negation",
            TaskKind::Copy => "Copy",
            TaskKind::Pattern => "Pattern",
            TaskKind::Counting => "Counting",
        }
    }
}

/// A multiple-choice item scored LM-Eval style: the model must assign the
/// correct continuation a higher length-normalised log-likelihood.
#[derive(Clone, Debug)]
pub struct TaskItem {
    pub kind: TaskKind,
    pub prefix: String,
    pub choices: Vec<String>,
    pub correct: usize,
}

impl Grammar {
    /// The grammar itself is fixed (independent of corpus seed): tasks and
    /// corpus must share word banks.
    pub fn standard() -> Grammar {
        let mut topics = Vec::with_capacity(N_TOPICS);
        for t in 0..N_TOPICS {
            let mut words = Vec::new();
            // enumerate syllable products deterministically
            for &on in &ONSETS[t] {
                for &v in &VOWELS {
                    for &cod in &CODAS[t] {
                        words.push(format!("{on}{v}{cod}"));
                    }
                }
            }
            let need = NOUNS_PER_TOPIC + VERBS_PER_TOPIC + 2 * ADJ_PAIRS_PER_TOPIC;
            assert!(words.len() >= need);
            // deterministic stride sampling so banks are spread out
            let stride = words.len() / need;
            let picks: Vec<String> =
                (0..need).map(|i| words[i * stride].clone()).collect();
            let nouns = picks[..NOUNS_PER_TOPIC].to_vec();
            let verbs =
                picks[NOUNS_PER_TOPIC..NOUNS_PER_TOPIC + VERBS_PER_TOPIC].to_vec();
            let adjs = &picks[NOUNS_PER_TOPIC + VERBS_PER_TOPIC..];
            let adj_pairs = (0..ADJ_PAIRS_PER_TOPIC)
                .map(|i| (adjs[2 * i].clone(), adjs[2 * i + 1].clone()))
                .collect();
            topics.push(Topic { nouns, verbs, adj_pairs });
        }
        Grammar { topics }
    }

    // ---------------------------------------------------------------------
    // sentence generators (each mirrors a task pattern)
    // ---------------------------------------------------------------------

    fn s_topic(&self, t: usize, rng: &mut Pcg64) -> String {
        let tp = &self.topics[t];
        let n1 = &tp.nouns[rng.below(tp.nouns.len())];
        let v = &tp.verbs[rng.below(tp.verbs.len())];
        let n2 = &tp.nouns[rng.below(tp.nouns.len())];
        format!("the {n1} {v} the {n2} .")
    }

    fn s_agreement(&self, t: usize, rng: &mut Pcg64) -> String {
        let tp = &self.topics[t];
        let n = &tp.nouns[rng.below(tp.nouns.len())];
        let (a, b) = &tp.adj_pairs[rng.below(tp.adj_pairs.len())];
        let adj = if rng.below(2) == 0 { a } else { b };
        if rng.below(2) == 0 {
            format!("the {n} is {adj} .")
        } else {
            format!("the {n}s are {adj} .")
        }
    }

    fn s_fact(&self, t: usize, name: &str, noun: &str, _rng: &mut Pcg64) -> String {
        let _ = t;
        format!("{name} likes the {noun} .")
    }

    fn s_negation(&self, t: usize, rng: &mut Pcg64) -> String {
        let tp = &self.topics[t];
        let n = &tp.nouns[rng.below(tp.nouns.len())];
        let (a, b) = &tp.adj_pairs[rng.below(tp.adj_pairs.len())];
        let (neg, pos) = if rng.below(2) == 0 { (a, b) } else { (b, a) };
        format!("the {n} is not {neg} . the {n} is {pos} .")
    }

    fn s_copy(&self, t: usize, rng: &mut Pcg64) -> String {
        let tp = &self.topics[t];
        let w: Vec<&String> =
            (0..3).map(|_| &tp.nouns[rng.below(tp.nouns.len())]).collect();
        format!("{} {} {} . {} {} {} .", w[0], w[1], w[2], w[0], w[1], w[2])
    }

    fn s_pattern(&self, t: usize, rng: &mut Pcg64) -> String {
        let tp = &self.topics[t];
        let a = &tp.nouns[rng.below(tp.nouns.len())];
        let b = &tp.verbs[rng.below(tp.verbs.len())];
        format!("{a} {b} {a} {b} {a} {b} .")
    }

    fn s_counting(&self, rng: &mut Pcg64) -> String {
        let start = rng.below(6);
        let len = 4 + rng.below(3);
        let words: Vec<&str> = NUMBERS[start..(start + len).min(10)].to_vec();
        format!("{} .", words.join(" "))
    }

    /// One document: a topic, 4–9 sentences mixing the pattern families.
    pub fn document(&self, rng: &mut Pcg64, topic_weights: &[f32]) -> String {
        let t = rng.weighted(topic_weights);
        let n_sent = 4 + rng.below(6);
        let mut sents = Vec::with_capacity(n_sent);
        // one persistent fact per doc supports the retrieval pattern
        let name = NAMES[rng.below(NAMES.len())];
        let noun = self.topics[t].nouns[rng.below(NOUNS_PER_TOPIC)].clone();
        for _ in 0..n_sent {
            let s = match rng.below(10) {
                0..=3 => self.s_topic(t, rng),
                4 => self.s_agreement(t, rng),
                5 => self.s_fact(t, name, &noun, rng),
                6 => self.s_negation(t, rng),
                7 => self.s_copy(t, rng),
                8 => self.s_pattern(t, rng),
                _ => self.s_counting(rng),
            };
            sents.push(s);
        }
        // restate the fact at the end: retrieval is learnable in-context
        sents.push(self.s_fact(t, name, &noun, rng));
        sents.join(" ")
    }

    /// Generate a corpus of roughly `target_bytes` as a list of documents.
    /// `flavor` selects the seed stream + topic mixture — "wiki" is uniform,
    /// "c4" is skewed (some topics rarer), "ptb" is a different skew used as
    /// the second perplexity column in Table 1.
    pub fn corpus(&self, flavor: &str, seed: u64, target_bytes: usize) -> Vec<String> {
        let (stream, weights): (u64, Vec<f32>) = match flavor {
            "wiki" => (1, vec![1.0; N_TOPICS]),
            "c4" => (2, (0..N_TOPICS).map(|t| 1.0 / (1.0 + t as f32)).collect()),
            "ptb" => (3, (0..N_TOPICS).map(|t| 0.3 + ((t * 7) % 5) as f32).collect()),
            _ => panic!("unknown corpus flavor {flavor:?}"),
        };
        let mut rng = Pcg64::with_stream(seed, stream);
        let mut docs = Vec::new();
        let mut total = 0usize;
        while total < target_bytes {
            let d = self.document(&mut rng, &weights);
            total += d.len() + 2;
            docs.push(d);
        }
        docs
    }

    // ---------------------------------------------------------------------
    // zero-shot task items (held-out instantiations of the same patterns)
    // ---------------------------------------------------------------------

    pub fn task_items(&self, kind: TaskKind, n: usize, seed: u64) -> Vec<TaskItem> {
        let mut rng = Pcg64::with_stream(seed, 100 + kind as u64);
        (0..n).map(|_| self.task_item(kind, &mut rng)).collect()
    }

    fn task_item(&self, kind: TaskKind, rng: &mut Pcg64) -> TaskItem {
        match kind {
            TaskKind::TopicCloze => {
                let t = rng.below(N_TOPICS);
                let other = (t + 1 + rng.below(N_TOPICS - 1)) % N_TOPICS;
                let tp = &self.topics[t];
                let ctx = format!("{} {}", self.s_topic(t, rng), self.s_topic(t, rng));
                let v = &tp.verbs[rng.below(tp.verbs.len())];
                let n1 = &tp.nouns[rng.below(tp.nouns.len())];
                let good = &tp.nouns[rng.below(tp.nouns.len())];
                let bad = &self.topics[other].nouns
                    [rng.below(self.topics[other].nouns.len())];
                TaskItem {
                    kind,
                    prefix: format!("{ctx} the {n1} {v} the"),
                    choices: vec![format!(" {good}"), format!(" {bad}")],
                    correct: 0,
                }
            }
            TaskKind::Agreement => {
                let t = rng.below(N_TOPICS);
                let tp = &self.topics[t];
                let n = &tp.nouns[rng.below(tp.nouns.len())];
                let plural = rng.below(2) == 1;
                let subj = if plural { format!("{n}s") } else { n.clone() };
                let (good, bad) = if plural { (" are", " is") } else { (" is", " are") };
                TaskItem {
                    kind,
                    prefix: format!("{} the {subj}", self.s_topic(t, rng)),
                    choices: vec![good.to_string(), bad.to_string()],
                    correct: 0,
                }
            }
            TaskKind::Retrieval => {
                let t = rng.below(N_TOPICS);
                let tp = &self.topics[t];
                let name = NAMES[rng.below(NAMES.len())];
                let good = &tp.nouns[rng.below(tp.nouns.len())];
                let mut bad = &tp.nouns[rng.below(tp.nouns.len())];
                while bad == good {
                    bad = &tp.nouns[rng.below(tp.nouns.len())];
                }
                let filler = self.s_topic(t, rng);
                TaskItem {
                    kind,
                    prefix: format!("{name} likes the {good} . {filler} {name} likes the"),
                    choices: vec![format!(" {good}"), format!(" {bad}")],
                    correct: 0,
                }
            }
            TaskKind::Negation => {
                let t = rng.below(N_TOPICS);
                let tp = &self.topics[t];
                let n = &tp.nouns[rng.below(tp.nouns.len())];
                let (a, b) = &tp.adj_pairs[rng.below(tp.adj_pairs.len())];
                let (neg, pos) = if rng.below(2) == 0 { (a, b) } else { (b, a) };
                TaskItem {
                    kind,
                    prefix: format!("the {n} is not {neg} . the {n} is"),
                    choices: vec![format!(" {pos}"), format!(" {neg}")],
                    correct: 0,
                }
            }
            TaskKind::Copy => {
                let t = rng.below(N_TOPICS);
                let tp = &self.topics[t];
                let w: Vec<String> = (0..3)
                    .map(|_| tp.nouns[rng.below(tp.nouns.len())].clone())
                    .collect();
                let mut bad = tp.nouns[rng.below(tp.nouns.len())].clone();
                while bad == w[2] {
                    bad = tp.nouns[rng.below(tp.nouns.len())].clone();
                }
                TaskItem {
                    kind,
                    prefix: format!("{} {} {} . {} {}", w[0], w[1], w[2], w[0], w[1]),
                    choices: vec![format!(" {}", w[2]), format!(" {bad}")],
                    correct: 0,
                }
            }
            TaskKind::Pattern => {
                let t = rng.below(N_TOPICS);
                let tp = &self.topics[t];
                let a = &tp.nouns[rng.below(tp.nouns.len())];
                let b = &tp.verbs[rng.below(tp.verbs.len())];
                let mut bad = &tp.verbs[rng.below(tp.verbs.len())];
                while bad == b {
                    bad = &tp.verbs[rng.below(tp.verbs.len())];
                }
                TaskItem {
                    kind,
                    prefix: format!("{a} {b} {a} {b} {a}"),
                    choices: vec![format!(" {b}"), format!(" {bad}")],
                    correct: 0,
                }
            }
            TaskKind::Counting => {
                let start = rng.below(5);
                let len = 3 + rng.below(3);
                let prefix = NUMBERS[start..start + len].join(" ");
                let good = NUMBERS[start + len];
                let mut bi = rng.below(10);
                while bi == start + len {
                    bi = rng.below(10);
                }
                TaskItem {
                    kind,
                    prefix,
                    choices: vec![format!(" {good}"), format!(" {}", NUMBERS[bi])],
                    correct: 0,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_topics_are_disjoint() {
        let g = Grammar::standard();
        assert_eq!(g.topics.len(), N_TOPICS);
        let mut all: Vec<&String> = Vec::new();
        for t in &g.topics {
            all.extend(t.nouns.iter());
            all.extend(t.verbs.iter());
            for (a, b) in &t.adj_pairs {
                all.push(a);
                all.push(b);
            }
        }
        let n = all.len();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), n, "word banks must be globally disjoint");
    }

    #[test]
    fn corpus_is_deterministic_and_sized() {
        let g = Grammar::standard();
        let a = g.corpus("wiki", 7, 10_000);
        let b = g.corpus("wiki", 7, 10_000);
        assert_eq!(a, b);
        // target counts "\n\n" separators; allow for them here
        let total: usize = a.iter().map(|d| d.len() + 2).sum();
        assert!(total >= 10_000);
        let c = g.corpus("wiki", 8, 10_000);
        assert_ne!(a, c, "different seed -> different corpus");
    }

    #[test]
    fn flavors_differ() {
        let g = Grammar::standard();
        assert_ne!(g.corpus("wiki", 7, 5_000), g.corpus("c4", 7, 5_000));
        assert_ne!(g.corpus("c4", 7, 5_000), g.corpus("ptb", 7, 5_000));
    }

    #[test]
    fn task_items_well_formed() {
        let g = Grammar::standard();
        for kind in ALL_TASKS {
            let items = g.task_items(kind, 50, 3);
            assert_eq!(items.len(), 50);
            for it in &items {
                assert_eq!(it.choices.len(), 2);
                assert_eq!(it.correct, 0);
                assert_ne!(it.choices[0], it.choices[1], "{it:?}");
                assert!(!it.prefix.is_empty());
                assert!(it.choices.iter().all(|c| c.starts_with(' ')), "{it:?}");
            }
        }
    }

    #[test]
    fn task_items_deterministic_per_seed() {
        let g = Grammar::standard();
        let a = g.task_items(TaskKind::Retrieval, 5, 11);
        let b = g.task_items(TaskKind::Retrieval, 5, 11);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn documents_restate_fact() {
        let g = Grammar::standard();
        let mut rng = Pcg64::new(5);
        let d = g.document(&mut rng, &[1.0; N_TOPICS]);
        assert!(d.contains("likes the"));
        assert!(d.ends_with('.'));
    }
}
