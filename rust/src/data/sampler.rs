//! Corpus chunking + the paper's calibration sampling strategy.
//!
//! Paper Appendix B: concatenate all documents with "\n\n", tokenize the
//! full stream, split into consecutive fixed-length samples, then (with a
//! fixed random seed) select `n` samples uniformly. We reproduce exactly
//! that, plus train/eval splits for the training loop and perplexity
//! evaluation.

use crate::data::tokenizer::{ByteTokenizer, BOS, PAD};
use crate::tensor::ITensor;
use crate::util::rng::Pcg64;

/// A tokenized corpus split into fixed-length chunks.
#[derive(Clone, Debug)]
pub struct Split {
    pub chunks: Vec<Vec<i32>>,
    pub seq_len: usize,
}

impl Split {
    /// Appendix-B chunking: docs joined by "\n\n", byte-tokenized, cut into
    /// consecutive `seq_len`-token samples (remainder dropped).
    pub fn from_docs(docs: &[String], seq_len: usize) -> Split {
        let text = docs.join("\n\n");
        let stream = ByteTokenizer.encode(&text);
        let chunks = stream
            .chunks_exact(seq_len)
            .map(|c| c.to_vec())
            .collect();
        Split { chunks, seq_len }
    }

    pub fn n_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Fixed-seed random selection of `n` chunks (paper: random.seed(0),
    /// 128 samples). Errors if the corpus is too small.
    pub fn sample(&self, n: usize, seed: u64) -> Vec<Vec<i32>> {
        assert!(
            n <= self.chunks.len(),
            "requested {n} calibration samples from {} chunks",
            self.chunks.len()
        );
        let mut rng = Pcg64::with_stream(seed, 0xca11b);
        rng.choose_distinct(self.chunks.len(), n)
            .into_iter()
            .map(|i| self.chunks[i].clone())
            .collect()
    }

    /// Deterministic head/tail split for train vs held-out perplexity.
    pub fn train_eval(self, eval_frac: f64) -> (Split, Split) {
        let n_eval = ((self.chunks.len() as f64) * eval_frac).ceil() as usize;
        let n_train = self.chunks.len() - n_eval;
        let (train, eval) = {
            let mut c = self.chunks;
            let eval = c.split_off(n_train);
            (c, eval)
        };
        (
            Split { chunks: train, seq_len: self.seq_len },
            Split { chunks: eval, seq_len: self.seq_len },
        )
    }
}

/// Batches of (tokens, targets) for the train_step / calib / loss
/// artifacts. Targets are next-token; the final target of each chunk is PAD
/// (ignored by the loss). Short batches are padded with PAD rows.
pub struct CalibSampler;

impl CalibSampler {
    /// Pack `chunks[lo..hi]` into one (tokens, targets) pair of shape
    /// [batch, seq_len], padding missing rows entirely with PAD.
    pub fn pack(chunks: &[Vec<i32>], batch: usize, seq_len: usize) -> (ITensor, ITensor) {
        assert!(chunks.len() <= batch);
        let mut toks = vec![PAD; batch * seq_len];
        let mut tgts = vec![PAD; batch * seq_len];
        for (b, c) in chunks.iter().enumerate() {
            assert_eq!(c.len(), seq_len);
            // input: BOS + chunk[..-1]; target: chunk — next-token LM over
            // the chunk's own tokens.
            toks[b * seq_len] = BOS;
            toks[b * seq_len + 1..(b + 1) * seq_len].copy_from_slice(&c[..seq_len - 1]);
            tgts[b * seq_len..(b + 1) * seq_len].copy_from_slice(c);
        }
        (
            ITensor::from_vec(&[batch, seq_len], toks),
            ITensor::from_vec(&[batch, seq_len], tgts),
        )
    }

    /// All batches covering `chunks` in order.
    pub fn batches(chunks: &[Vec<i32>], batch: usize, seq_len: usize) -> Vec<(ITensor, ITensor)> {
        chunks
            .chunks(batch)
            .map(|group| Self::pack(group, batch, seq_len))
            .collect()
    }

    /// Random training batch.
    pub fn train_batch(
        split: &Split,
        batch: usize,
        rng: &mut Pcg64,
    ) -> (ITensor, ITensor) {
        let picks: Vec<Vec<i32>> = (0..batch.min(split.n_chunks()))
            .map(|_| split.chunks[rng.below(split.n_chunks())].clone())
            .collect();
        Self::pack(&picks, batch, split.seq_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Grammar;

    fn small_split() -> Split {
        let g = Grammar::standard();
        Split::from_docs(&g.corpus("wiki", 0, 50_000), 64)
    }

    #[test]
    fn chunking_is_exact() {
        let s = small_split();
        assert!(s.n_chunks() > 100);
        assert!(s.chunks.iter().all(|c| c.len() == 64));
    }

    #[test]
    fn sampling_is_seeded_and_distinct() {
        let s = small_split();
        let a = s.sample(16, 0);
        let b = s.sample(16, 0);
        assert_eq!(a, b);
        let c = s.sample(16, 1);
        assert_ne!(a, c);
    }

    #[test]
    fn pack_produces_shifted_targets() {
        let chunks = vec![(0..64).map(|x| x % 256).collect::<Vec<i32>>()];
        let (toks, tgts) = CalibSampler::pack(&chunks, 2, 64);
        assert_eq!(toks.shape(), &[2, 64]);
        assert_eq!(toks.data()[0], BOS);
        assert_eq!(toks.data()[1], 0);
        assert_eq!(tgts.data()[0], 0);
        assert_eq!(tgts.data()[63], 63);
        // padded second row
        assert!(toks.data()[64..].iter().all(|&t| t == PAD));
        assert!(tgts.data()[64..].iter().all(|&t| t == PAD));
    }

    #[test]
    fn batches_cover_all_chunks() {
        let s = small_split();
        let sample = s.sample(10, 0);
        let bs = CalibSampler::batches(&sample, 4, 64);
        assert_eq!(bs.len(), 3); // 4 + 4 + 2(padded)
    }

    #[test]
    fn train_eval_split_disjoint_sizes() {
        let s = small_split();
        let total = s.n_chunks();
        let (tr, ev) = s.train_eval(0.1);
        assert_eq!(tr.n_chunks() + ev.n_chunks(), total);
        assert!(ev.n_chunks() >= total / 20);
    }
}
