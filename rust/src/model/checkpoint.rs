//! Checkpoint format (safetensors-like, custom because the image has no
//! serde): magic + u32 header length + JSON header + raw little-endian f32
//! payloads, each tensor aligned to its header-declared offset.
//!
//! Stores full *or* pruned (ragged-width) models: the header carries every
//! tensor's shape plus the optional width profile, so a pruned checkpoint
//! is self-describing for the serving coordinator.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::model::store::ParamStore;
use crate::model::WidthProfile;
use crate::tensor::Tensor;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"HEAPRCK1";

/// f32s per serialization chunk in [`Checkpoint::save`] (64 KiB of
/// payload): large enough to amortize the `write_all` calls, small
/// enough that the staging buffer stays cache-friendly.
const CHUNK_FLOATS: usize = 16 * 1024;

pub struct Checkpoint {
    pub store: ParamStore,
    pub widths: Option<WidthProfile>,
    /// free-form metadata (training step, loss, preset name...)
    pub meta: Json,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut header_tensors = Vec::new();
        let mut offset = 0usize;
        for (name, t) in self.store.iter() {
            header_tensors.push(Json::obj(vec![
                ("name", Json::s(name.clone())),
                ("shape", Json::Arr(t.shape().iter().map(|&s| Json::n(s as f64)).collect())),
                ("offset", Json::n(offset as f64)),
            ]));
            offset += t.len() * 4;
        }
        let widths = match &self.widths {
            Some(w) => Json::Arr(
                w.widths
                    .iter()
                    .map(|l| Json::Arr(l.iter().map(|&x| Json::n(x as f64)).collect()))
                    .collect(),
            ),
            None => Json::Null,
        };
        let header = Json::obj(vec![
            ("tensors", Json::Arr(header_tensors)),
            ("widths", widths),
            ("meta", self.meta.clone()),
        ])
        .to_string();

        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        // Safe chunked serialization (replaced a raw byte transmute of the
        // f32 buffer): explicit to_le_bytes per value makes the payload
        // little-endian by construction on every host, with no alignment
        // or provenance hazards. One reused chunk buffer keeps it at a
        // handful of large write_all calls instead of 4-byte writes.
        let mut bytes = Vec::with_capacity(CHUNK_FLOATS * 4);
        for (_, t) in self.store.iter() {
            for chunk in t.data().chunks(CHUNK_FLOATS) {
                bytes.clear();
                for v in chunk {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                f.write_all(&bytes)?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).map_err(|e| anyhow!("open {path:?}: {e}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: bad magic {magic:?}");
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)?;

        let mut names = Vec::new();
        let mut tensors = Vec::new();
        let mut expected_offset = 0usize;
        for t in header.get("tensors")?.as_arr()? {
            let name = t.get("name")?.as_str()?.to_string();
            let shape = t.get("shape")?.usize_vec()?;
            let offset = t.get("offset")?.as_usize()?;
            if offset != expected_offset {
                bail!("checkpoint corrupt: offset {offset} != {expected_offset}");
            }
            let n: usize = shape.iter().product();
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            names.push(name);
            tensors.push(Tensor::from_vec(&shape, data));
            expected_offset += n * 4;
        }
        let widths = match header.get("widths")? {
            Json::Null => None,
            w => {
                let widths = w
                    .as_arr()?
                    .iter()
                    .map(|l| l.usize_vec())
                    .collect::<Result<Vec<_>>>()?;
                Some(WidthProfile { widths })
            }
        };
        Ok(Checkpoint {
            store: ParamStore::from_tensors(names, tensors),
            widths,
            meta: header.get("meta")?.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn temp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("heapr-test-{name}-{}", std::process::id()))
    }

    fn random_store() -> ParamStore {
        let mut rng = Pcg64::new(1);
        let shapes: Vec<(&str, Vec<usize>)> = vec![
            ("embed", vec![16, 8]),
            ("l0.wd", vec![4, 8, 6]),
            ("lnf", vec![8]),
        ];
        let names = shapes.iter().map(|(n, _)| n.to_string()).collect();
        let tensors = shapes
            .iter()
            .map(|(_, s)| {
                let n: usize = s.iter().product();
                Tensor::from_vec(s, (0..n).map(|_| rng.normal()).collect())
            })
            .collect();
        ParamStore::from_tensors(names, tensors)
    }

    #[test]
    fn roundtrip_full() {
        let path = temp("full");
        let ck = Checkpoint {
            store: random_store(),
            widths: None,
            meta: Json::obj(vec![("step", Json::n(42.0))]),
        };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        for (name, t) in ck.store.iter() {
            assert_eq!(back.store.get(name).unwrap(), t);
        }
        assert!(back.widths.is_none());
        assert_eq!(back.meta.get("step").unwrap().as_usize().unwrap(), 42);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn roundtrip_with_widths() {
        let path = temp("widths");
        let ck = Checkpoint {
            store: random_store(),
            widths: Some(WidthProfile { widths: vec![vec![8, 0], vec![16, 24]] }),
            meta: Json::Null,
        };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.widths.unwrap().widths, vec![vec![8, 0], vec![16, 24]]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corrupt_magic_rejected() {
        let path = temp("bad");
        std::fs::write(&path, b"NOTAHDR!....").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
