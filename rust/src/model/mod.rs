//! Model-side state: the flat parameter store, checkpoint IO, pruned-width
//! profiles, and FLOPs accounting.

pub mod store;
pub mod checkpoint;
pub mod flops;

pub use flops::{flops_per_token, FlopsBreakdown};
pub use store::ParamStore;

/// Per-(layer, expert) retained atomic-expert widths after pruning; the
/// serving coordinator rounds these up to width buckets when dispatching.
#[derive(Clone, Debug, PartialEq)]
pub struct WidthProfile {
    pub widths: Vec<Vec<usize>>, // [layer][expert]
}

impl WidthProfile {
    pub fn full(n_layers: usize, n_experts: usize, d_inter: usize) -> Self {
        WidthProfile { widths: vec![vec![d_inter; n_experts]; n_layers] }
    }

    pub fn total(&self) -> usize {
        self.widths.iter().flatten().sum()
    }

    /// Fraction of atomic experts retained.
    pub fn keep_ratio(&self, d_inter: usize) -> f64 {
        let full: usize = self.widths.iter().map(|l| l.len() * d_inter).sum();
        self.total() as f64 / full as f64
    }

    /// Per-layer keep ratios (Figures 5/6).
    pub fn per_layer_keep(&self, d_inter: usize) -> Vec<f64> {
        self.widths
            .iter()
            .map(|l| l.iter().sum::<usize>() as f64 / (l.len() * d_inter) as f64)
            .collect()
    }

    /// Round every width up to the nearest serving bucket (0 stays 0).
    pub fn bucketed(&self, blk: usize, d_inter: usize) -> WidthProfile {
        let widths = self
            .widths
            .iter()
            .map(|l| {
                l.iter()
                    .map(|&w| if w == 0 { 0 } else { (w.div_ceil(blk) * blk).min(d_inter) })
                    .collect()
            })
            .collect();
        WidthProfile { widths }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn width_profile_ratios() {
        let mut p = WidthProfile::full(2, 2, 32);
        assert_eq!(p.keep_ratio(32), 1.0);
        p.widths[0][0] = 16;
        p.widths[1][1] = 0;
        assert_eq!(p.total(), 16 + 32 + 32);
        let per = p.per_layer_keep(32);
        assert_eq!(per[0], 0.75);
        assert_eq!(per[1], 0.5);
    }

    #[test]
    fn bucketing_rounds_up() {
        let p = WidthProfile { widths: vec![vec![1, 8, 9, 0, 32]] };
        let b = p.bucketed(8, 32);
        assert_eq!(b.widths[0], vec![8, 8, 16, 0, 32]);
    }
}
