//! Analytic FLOPs accounting (per generated/processed token).
//!
//! Used for Table 3's "FLOPs rr." column, Figure 2's FLOPs-saving axis and
//! the Table 5 pruning-cost rows. Counts multiply-adds as 2 FLOPs,
//! matching how the paper reports FLOPs reduction.

use crate::config::ModelConfig;
use crate::model::WidthProfile;

#[derive(Clone, Debug)]
pub struct FlopsBreakdown {
    pub attention: f64,
    pub router: f64,
    pub experts: f64,
    pub head: f64,
}

impl FlopsBreakdown {
    pub fn total(&self) -> f64 {
        self.attention + self.router + self.experts + self.head
    }
}

/// Forward FLOPs per token under a width profile. Only the *activated*
/// (top-k routed) expert width matters at inference: the per-token expert
/// cost uses the mean retained width of the experts the token activates —
/// we report the expectation under uniform routing, which matches how the
/// paper computes FLOPs reduction from pruning ratios.
pub fn flops_per_token(cfg: &ModelConfig, widths: &WidthProfile) -> FlopsBreakdown {
    let d = cfg.d_model as f64;
    let t = cfg.seq_len as f64;
    let mut attention = 0.0;
    let mut router = 0.0;
    let mut experts = 0.0;
    for l in 0..cfg.n_layers {
        // qkv + output projections, plus score/value matmuls over seq_len
        // lint:allow(float-accum-order) analytic FLOP count accumulated in layer order; a reporting figure, not a pinned kernel
        attention += 2.0 * 4.0 * d * d + 2.0 * 2.0 * t * d;
        // lint:allow(float-accum-order) same analytic reporting count as `attention` above
        router += 2.0 * d * cfg.n_experts as f64;
        // mean width over this layer's experts = expected activated width
        let mean_w: f64 = widths.widths[l].iter().sum::<usize>() as f64
            / widths.widths[l].len() as f64;
        // lint:allow(float-accum-order) same analytic reporting count as `attention` above
        experts += cfg.top_k as f64 * 2.0 * 3.0 * d * mean_w;
    }
    let head = 2.0 * d * cfg.vocab as f64;
    FlopsBreakdown { attention, router, experts, head }
}

/// FLOPs reduction ratio of `pruned` relative to the full model.
pub fn flops_reduction(cfg: &ModelConfig, pruned: &WidthProfile) -> f64 {
    let full = WidthProfile::full(cfg.n_layers, cfg.n_experts, cfg.d_inter);
    let f0 = flops_per_token(cfg, &full).total();
    let f1 = flops_per_token(cfg, pruned).total();
    1.0 - f1 / f0
}

/// Reduction within the MoE-expert FLOPs alone. This is the number the
/// paper's "FLOPs rr." emphasises: in the paper's models MoE layers are
/// >97% of compute, so expert-FLOPs rr ≈ total rr there; in MiniMoE
/// attention/head are proportionally larger, so we report both.
pub fn expert_flops_reduction(cfg: &ModelConfig, pruned: &WidthProfile) -> f64 {
    let full = WidthProfile::full(cfg.n_layers, cfg.n_experts, cfg.d_inter);
    let f0 = flops_per_token(cfg, &full).experts;
    let f1 = flops_per_token(cfg, pruned).experts;
    1.0 - f1 / f0
}

/// Bytes one *dense* KV lane pins for the whole decode: a full
/// `[n_heads, capacity, d_head]` f32 rectangle for K and V in every
/// layer, regardless of how many rows the occupant ever writes.
pub fn kv_lane_bytes(cfg: &ModelConfig, capacity: usize) -> usize {
    cfg.n_layers * 2 * cfg.n_heads * capacity * cfg.d_head * 4
}

/// Bytes a *paged* lane holding `rows` written positions pins under page
/// size `page`: `ceil(rows/page)` pages per (layer, K|V) table. This is
/// the quantity the block allocator actually charges — unwritten tail
/// capacity costs nothing.
pub fn kv_paged_lane_bytes(cfg: &ModelConfig, page: usize, rows: usize) -> usize {
    let pages = rows.div_ceil(page.max(1));
    cfg.n_layers * 2 * pages * cfg.n_heads * page.max(1) * cfg.d_head * 4
}

/// Concurrent lanes a KV byte budget seats: dense lanes pay
/// [`kv_lane_bytes`] at full `capacity`; paged lanes pay
/// [`kv_paged_lane_bytes`] for the rows they hold. The paged count is
/// what the `bench_serve` lanes-per-GB figure reports.
pub fn kv_lanes_per_budget(budget_bytes: usize, lane_bytes: usize) -> usize {
    budget_bytes / lane_bytes.max(1)
}

/// Total forward+backward FLOPs of a calibration run over `n_tokens`
/// (backward ≈ 2× forward), for Table 5's TFLOPs column.
pub fn calib_flops(cfg: &ModelConfig, n_tokens: usize, passes_fwd: f64, passes_bwd: f64) -> f64 {
    let full = WidthProfile::full(cfg.n_layers, cfg.n_experts, cfg.d_inter);
    // calibration computes all experts densely
    let mut per_tok = flops_per_token(cfg, &full);
    per_tok.experts *= cfg.n_experts as f64 / cfg.top_k as f64;
    per_tok.total() * n_tokens as f64 * (passes_fwd + 2.0 * passes_bwd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::util::json::Json;

    fn cfg() -> ModelConfig {
        ModelConfig::from_json(
            &Json::parse(
                r#"{"name":"tiny","vocab":260,"d_model":64,"n_layers":2,
            "n_heads":2,"d_head":32,"n_experts":4,"top_k":2,"d_inter":32,
            "seq_len":64,"batch":4,"blk_n":16,"blk_i":8,
            "serve_batches":[1,4],"token_buckets":[8,32],
            "width_buckets":[8,16,24,32],"max_decode_len":96}"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn full_profile_zero_reduction() {
        let c = cfg();
        let full = WidthProfile::full(c.n_layers, c.n_experts, c.d_inter);
        assert!(flops_reduction(&c, &full).abs() < 1e-12);
    }

    #[test]
    fn half_width_reduces_expert_flops_half() {
        let c = cfg();
        let half = WidthProfile { widths: vec![vec![16; 4]; 2] };
        let f_full = flops_per_token(&c, &WidthProfile::full(2, 4, 32));
        let f_half = flops_per_token(&c, &half);
        assert!((f_half.experts / f_full.experts - 0.5).abs() < 1e-12);
        assert_eq!(f_half.attention, f_full.attention);
        let rr = flops_reduction(&c, &half);
        assert!(rr > 0.0 && rr < 0.5);
    }

    #[test]
    fn paged_lane_sizing_beats_dense_for_short_occupants() {
        let c = cfg(); // n_layers 2, n_heads 2, d_head 32
        let dense = kv_lane_bytes(&c, 64);
        assert_eq!(dense, 2 * 2 * 2 * 64 * 32 * 4);
        // an 8-row occupant under page 16 pins one page per table
        let paged = kv_paged_lane_bytes(&c, 16, 8);
        assert_eq!(paged, 2 * 2 * 2 * 16 * 32 * 4);
        assert!(paged < dense);
        // full occupancy converges to the dense rectangle
        assert_eq!(kv_paged_lane_bytes(&c, 16, 64), dense);
        let budget = 8 * dense;
        assert_eq!(kv_lanes_per_budget(budget, dense), 8);
        assert_eq!(kv_lanes_per_budget(budget, paged), 32);
        assert_eq!(kv_lanes_per_budget(budget, 0), budget); // guard, no div-by-zero
    }

    #[test]
    fn calib_flops_positive_and_scales() {
        let c = cfg();
        let a = calib_flops(&c, 1000, 2.0, 1.0);
        let b = calib_flops(&c, 2000, 2.0, 1.0);
        assert!(a > 0.0);
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
