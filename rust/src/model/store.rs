//! ParamStore: the ordered flat parameter list shared with the artifacts.
//!
//! Order and shapes come from the manifest (which mirrors
//! `python/compile/model.py::param_specs`); marshalling params into an
//! artifact call is `store.values()`, and a train_step's returned params
//! re-enter via `set_all`.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

// lint:allow(layering) structural: ParamStore is defined by the manifest contract (ARCHITECTURE §2) and Manifest/Value are data-only types
use crate::runtime::{Manifest, Value};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct ParamStore {
    names: Vec<String>,
    index: HashMap<String, usize>,
    tensors: Vec<Tensor>,
}

impl ParamStore {
    pub fn from_tensors(names: Vec<String>, tensors: Vec<Tensor>) -> ParamStore {
        assert_eq!(names.len(), tensors.len());
        let index = names
            .iter()
            .enumerate()
            .map(|(i, n)| (n.clone(), i))
            .collect();
        ParamStore { names, index, tensors }
    }

    /// Zero-initialised store with manifest shapes (Adam moment buffers).
    pub fn zeros(manifest: &Manifest) -> ParamStore {
        let names = manifest.params.iter().map(|(n, _)| n.clone()).collect();
        let tensors = manifest
            .params
            .iter()
            .map(|(_, s)| Tensor::zeros(s))
            .collect();
        ParamStore::from_tensors(names, tensors)
    }

    /// Random init mirroring `model.py::init_params`: RMSNorm scales = 1,
    /// embeddings ~ N(0, 0.02), projections ~ N(0, fan_in^-1/2).
    pub fn init(manifest: &Manifest, seed: u64) -> ParamStore {
        let mut rng = Pcg64::with_stream(seed, 0x1417);
        let names: Vec<String> = manifest.params.iter().map(|(n, _)| n.clone()).collect();
        let tensors = manifest
            .params
            .iter()
            .map(|(name, shape)| {
                if name.ends_with("ln1") || name.ends_with("ln2") || name == "lnf" {
                    Tensor::ones(shape)
                } else {
                    let fan_in = *shape.last().unwrap() as f32;
                    let scale = if name == "embed" || name == "pos" {
                        0.02
                    } else {
                        fan_in.powf(-0.5)
                    };
                    let n: usize = shape.iter().product();
                    Tensor::from_vec(
                        shape,
                        (0..n).map(|_| rng.normal() * scale).collect(),
                    )
                }
            })
            .collect();
        ParamStore::from_tensors(names, tensors)
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.index
            .get(name)
            .map(|&i| &self.tensors[i])
            .ok_or_else(|| anyhow!("no param {name:?}"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        let i = *self
            .index
            .get(name)
            .ok_or_else(|| anyhow!("no param {name:?}"))?;
        Ok(&mut self.tensors[i])
    }

    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        *self.get_mut(name)? = t;
        Ok(())
    }

    /// Marshal every parameter as artifact inputs (manifest order).
    pub fn values(&self) -> Vec<Value> {
        self.tensors.iter().map(|t| Value::F32(t.clone())).collect()
    }

    /// Replace all tensors from artifact outputs (manifest order).
    pub fn set_all(&mut self, values: Vec<Value>) -> Result<()> {
        if values.len() != self.tensors.len() {
            return Err(anyhow!(
                "set_all: {} values for {} params",
                values.len(),
                self.tensors.len()
            ));
        }
        for (slot, v) in self.tensors.iter_mut().zip(values) {
            *slot = v.f32()?;
        }
        Ok(())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.names.iter().zip(self.tensors.iter())
    }

    pub fn n_elems(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
          "preset": {"name":"tiny","vocab":260,"d_model":64,"n_layers":2,
            "n_heads":2,"d_head":32,"n_experts":4,"top_k":2,"d_inter":32,
            "seq_len":64,"batch":4,"blk_n":16,"blk_i":8,"aux_coef":0.01,
            "serve_batches":[1,4],"token_buckets":[8,32],
            "width_buckets":[8,16,24,32],"max_decode_len":96},
          "params": [{"name":"embed","shape":[260,64]},
                     {"name":"l0.ln1","shape":[64]},
                     {"name":"l0.wq","shape":[64,64]},
                     {"name":"lnf","shape":[64]}],
          "artifacts": {}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn init_follows_scheme() {
        let m = manifest();
        let s = ParamStore::init(&m, 0);
        assert_eq!(s.len(), 4);
        // rmsnorm scales exactly one
        assert!(s.get("l0.ln1").unwrap().data().iter().all(|&x| x == 1.0));
        assert!(s.get("lnf").unwrap().data().iter().all(|&x| x == 1.0));
        // embed small scale
        let emax = s.get("embed").unwrap().data().iter()
            .fold(0.0f32, |a, &b| a.max(b.abs()));
        assert!(emax < 0.15, "{emax}");
        // projections ~ fan_in^-1/2 = 0.125
        let wq = s.get("l0.wq").unwrap();
        let std = (wq.data().iter().map(|x| x * x).sum::<f32>()
            / wq.len() as f32).sqrt();
        assert!((std - 0.125).abs() < 0.01, "{std}");
    }

    #[test]
    fn init_deterministic() {
        let m = manifest();
        let a = ParamStore::init(&m, 7);
        let b = ParamStore::init(&m, 7);
        assert_eq!(a.get("embed").unwrap(), b.get("embed").unwrap());
        let c = ParamStore::init(&m, 8);
        assert_ne!(a.get("embed").unwrap(), c.get("embed").unwrap());
    }

    #[test]
    fn values_set_all_roundtrip() {
        let m = manifest();
        let mut s = ParamStore::init(&m, 0);
        let vals = s.values();
        let before = s.get("l0.wq").unwrap().clone();
        s.set_all(vals).unwrap();
        assert_eq!(s.get("l0.wq").unwrap(), &before);
    }
}
