//! Pruning plans: ranking, masks, and weight surgery.

use anyhow::Result;

use crate::model::store::ParamStore;
use crate::model::WidthProfile;
use crate::tensor::{argsort, gather0, gather_cols, Tensor};
use crate::util::cmp::f32_nan_last_desc;

/// Ranking scope (Table 2 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scope {
    /// HEAPr-G: one ranking across every layer/expert.
    Global,
    /// HEAPr-L / CAMERA-P style: rank within each MoE layer.
    Layerwise,
}

/// Which atomic experts to keep, per (layer, expert). Kept indices are
/// sorted ascending so sliced weights preserve column order.
#[derive(Clone, Debug, PartialEq)]
pub struct PrunePlan {
    pub keep: Vec<Vec<Vec<usize>>>, // [layer][expert] -> kept atomic indices
    pub d_inter: usize,
}

impl PrunePlan {
    /// Build a plan pruning the `ratio` lowest-scoring atomic experts.
    /// `scores` is [L, E, di]; lower = pruned first.
    pub fn from_scores(scores: &Tensor, ratio: f64, scope: Scope) -> PrunePlan {
        let &[l, e, di] = scores.shape() else {
            panic!("scores must be [L,E,di], got {:?}", scores.shape())
        };
        assert!((0.0..=1.0).contains(&ratio), "ratio {ratio}");
        let mut pruned = vec![vec![vec![false; di]; e]; l];
        match scope {
            Scope::Global => {
                let order = argsort(scores.data());
                let n_prune = ((l * e * di) as f64 * ratio).round() as usize;
                for &flat in order.iter().take(n_prune) {
                    let (li, rest) = (flat / (e * di), flat % (e * di));
                    pruned[li][rest / di][rest % di] = true;
                }
            }
            Scope::Layerwise => {
                let n_prune = ((e * di) as f64 * ratio).round() as usize;
                for li in 0..l {
                    let base = li * e * di;
                    let layer_scores = &scores.data()[base..base + e * di];
                    let order = argsort(layer_scores);
                    for &flat in order.iter().take(n_prune) {
                        pruned[li][flat / di][flat % di] = true;
                    }
                }
            }
        }
        let keep = pruned
            .into_iter()
            .map(|layer| {
                layer
                    .into_iter()
                    .map(|ex| {
                        (0..di).filter(|&k| !ex[k]).collect::<Vec<usize>>()
                    })
                    .collect()
            })
            .collect();
        PrunePlan { keep, d_inter: di }
    }

    /// Expert-level plan (Table 3): drop whole experts by summed score
    /// until at least `ratio` of atomic experts are removed.
    pub fn expert_level(expert_scores: &Tensor, ratio: f64, di: usize) -> PrunePlan {
        let &[l, e] = expert_scores.shape() else {
            panic!("expert scores must be [L,E]")
        };
        let order = argsort(expert_scores.data());
        let n_drop = ((l * e) as f64 * ratio).round() as usize;
        let mut keep = vec![vec![(0..di).collect::<Vec<usize>>(); e]; l];
        for &flat in order.iter().take(n_drop) {
            keep[flat / e][flat % e] = Vec::new();
        }
        PrunePlan { keep, d_inter: di }
    }

    pub fn n_layers(&self) -> usize {
        self.keep.len()
    }

    pub fn n_experts(&self) -> usize {
        self.keep[0].len()
    }

    /// Total pruned fraction.
    pub fn pruned_ratio(&self) -> f64 {
        let total = self.n_layers() * self.n_experts() * self.d_inter;
        let kept: usize = self.keep.iter().flatten().map(|k| k.len()).sum();
        1.0 - kept as f64 / total as f64
    }

    /// 0/1 keep-mask [L, E, di] for the masked-eval artifacts.
    pub fn mask(&self) -> Tensor {
        let (l, e, di) = (self.n_layers(), self.n_experts(), self.d_inter);
        let mut m = Tensor::zeros(&[l, e, di]);
        for li in 0..l {
            for ei in 0..e {
                for &k in &self.keep[li][ei] {
                    m.set(&[li, ei, k], 1.0);
                }
            }
        }
        m
    }

    pub fn widths(&self) -> WidthProfile {
        WidthProfile {
            widths: self
                .keep
                .iter()
                .map(|l| l.iter().map(|k| k.len()).collect())
                .collect(),
        }
    }

    /// Round the plan *up* to serving width buckets: per expert, re-add the
    /// highest-scoring pruned atomic experts until the kept width is a
    /// multiple of `blk`. Keeps masked-eval and serving numerics identical.
    pub fn bucket_aligned(&self, scores: &Tensor, blk: usize) -> PrunePlan {
        let (l, e, di) = (self.n_layers(), self.n_experts(), self.d_inter);
        let mut keep = self.keep.clone();
        for li in 0..l {
            for ei in 0..e {
                let k = &mut keep[li][ei];
                if k.is_empty() {
                    continue;
                }
                let target = (k.len().div_ceil(blk) * blk).min(di);
                if k.len() == target {
                    continue;
                }
                // candidates: currently pruned, best score first
                let kept: std::collections::HashSet<usize> = k.iter().copied().collect();
                let mut cand: Vec<usize> =
                    (0..di).filter(|x| !kept.contains(x)).collect();
                // best score first; NaN scores order last (never re-added
                // ahead of a real score) and cannot panic the ranking
                cand.sort_by(|&a, &b| {
                    f32_nan_last_desc(scores.at(&[li, ei, a]), scores.at(&[li, ei, b]))
                });
                k.extend(cand.into_iter().take(target - k.len()));
                k.sort_unstable();
            }
        }
        PrunePlan { keep, d_inter: di }
    }
}

/// Physically slice expert weights per plan. Produces a store where
/// `l{l}.wg/wu/wd` are replaced by per-expert `l{l}.e{e}.wg` ([w,d]),
/// `.wu` ([w,d]) and `.wd` ([d,w]); all other params pass through.
pub fn surgery(params: &ParamStore, plan: &PrunePlan) -> Result<ParamStore> {
    let mut names = Vec::new();
    let mut tensors = Vec::new();
    for (name, t) in params.iter() {
        let is_expert = name.ends_with(".wg") || name.ends_with(".wu") || name.ends_with(".wd");
        if !is_expert {
            names.push(name.clone());
            tensors.push(t.clone());
            continue;
        }
        let li: usize = name
            .strip_prefix('l')
            .and_then(|s| s.split('.').next())
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad expert param name {name:?}"))?;
        let kind = &name[name.len() - 2..];
        for (ei, keep) in plan.keep[li].iter().enumerate() {
            let full = t.index0(ei); // wg/wu: [di, d]; wd: [d, di]
            let sliced = if kind == "wd" {
                gather_cols(&full, keep)
            } else {
                gather0(&full, keep)
            };
            names.push(format!("l{li}.e{ei}.{kind}"));
            tensors.push(sliced);
        }
    }
    Ok(ParamStore::from_tensors(names, tensors))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Pcg64;

    fn scores(l: usize, e: usize, di: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::new(seed);
        Tensor::from_vec(
            &[l, e, di],
            (0..l * e * di).map(|_| rng.f32()).collect(),
        )
    }

    #[test]
    fn global_prunes_exact_count_of_lowest() {
        let s = scores(2, 3, 8, 1);
        let plan = PrunePlan::from_scores(&s, 0.25, Scope::Global);
        let total = 2 * 3 * 8;
        let kept: usize = plan.keep.iter().flatten().map(|k| k.len()).sum();
        assert_eq!(total - kept, total / 4);
        // every pruned score <= every kept score
        let mask = plan.mask();
        let pruned_max = s
            .data()
            .iter()
            .zip(mask.data())
            .filter(|(_, &m)| m == 0.0)
            .map(|(&v, _)| v)
            .fold(f32::NEG_INFINITY, f32::max);
        let kept_min = s
            .data()
            .iter()
            .zip(mask.data())
            .filter(|(_, &m)| m == 1.0)
            .map(|(&v, _)| v)
            .fold(f32::INFINITY, f32::min);
        assert!(pruned_max <= kept_min);
    }

    #[test]
    fn layerwise_prunes_per_layer() {
        let s = scores(3, 2, 8, 2);
        let plan = PrunePlan::from_scores(&s, 0.5, Scope::Layerwise);
        for l in 0..3 {
            let kept: usize = plan.keep[l].iter().map(|k| k.len()).sum();
            assert_eq!(kept, 8); // 16 per layer, half pruned
        }
    }

    #[test]
    fn mask_matches_keep_sets() {
        let s = scores(2, 2, 4, 3);
        let plan = PrunePlan::from_scores(&s, 0.5, Scope::Global);
        let m = plan.mask();
        for l in 0..2 {
            for e in 0..2 {
                for k in 0..4 {
                    let kept = plan.keep[l][e].contains(&k);
                    assert_eq!(m.at(&[l, e, k]) == 1.0, kept);
                }
            }
        }
    }

    #[test]
    fn expert_level_drops_whole_experts() {
        let es = Tensor::from_vec(&[2, 2], vec![3.0, 1.0, 2.0, 4.0]);
        let plan = PrunePlan::expert_level(&es, 0.5, 8);
        assert!(plan.keep[0][1].is_empty()); // score 1.0 dropped
        assert!(plan.keep[1][0].is_empty()); // score 2.0 dropped
        assert_eq!(plan.keep[0][0].len(), 8);
        assert_eq!(plan.keep[1][1].len(), 8);
    }

    #[test]
    fn bucket_aligned_rounds_up_with_best_scores() {
        let s = scores(1, 1, 16, 4);
        let plan = PrunePlan::from_scores(&s, 0.4, Scope::Global); // keep 10
        assert_eq!(plan.keep[0][0].len(), 10);
        let aligned = plan.bucket_aligned(&s, 8);
        assert_eq!(aligned.keep[0][0].len(), 16); // rounded to 16
        // the re-added ones are the best-scoring pruned units: the plan now
        // keeps everything, trivially satisfying that.
        let plan2 = PrunePlan::from_scores(&s, 0.75, Scope::Global); // keep 4
        let aligned2 = plan2.bucket_aligned(&s, 8); // -> 8
        assert_eq!(aligned2.keep[0][0].len(), 8);
        for k in &plan2.keep[0][0] {
            assert!(aligned2.keep[0][0].contains(k));
        }
    }

    #[test]
    fn nan_scores_rank_last_and_never_panic() {
        // a NaN importance score (upstream numerical accident) used to
        // panic the ranking via partial_cmp().unwrap(); now it orders
        // last everywhere: sorted after every number in the prune order
        // (so it is never pruned ahead of a real low score) and never
        // re-added by bucket alignment ahead of a real score
        let mut s = scores(1, 2, 8, 9);
        s.set(&[0, 0, 3], f32::NAN);
        s.set(&[0, 1, 5], f32::NAN);
        for scope in [Scope::Global, Scope::Layerwise] {
            let plan = PrunePlan::from_scores(&s, 0.25, scope);
            assert!(plan.keep[0][0].contains(&3), "NaN ordered last => kept");
            assert!(plan.keep[0][1].contains(&5), "NaN ordered last => kept");
        }
        let plan = PrunePlan::from_scores(&s, 0.5, Scope::Global);
        let aligned = plan.bucket_aligned(&s, 4); // must not panic
        assert!(aligned.pruned_ratio() <= plan.pruned_ratio());
    }

    #[test]
    fn prop_plan_invariants() {
        check("plan-invariants", 40,
              |g| {
                  let l = g.usize_in(1, 3);
                  let e = g.usize_in(1, 4);
                  let di = g.usize_in(2, 16);
                  let ratio = g.f32_in(0.0, 1.0) as f64;
                  let seed = g.rng.next_u64();
                  (l, e, di, ratio, seed)
              },
              |&(l, e, di, ratio, seed)| {
                  let s = scores(l, e, di, seed);
                  for scope in [Scope::Global, Scope::Layerwise] {
                      let plan = PrunePlan::from_scores(&s, ratio, scope);
                      // kept indices sorted & in range & distinct
                      for layer in &plan.keep {
                          for keep in layer {
                              if !keep.windows(2).all(|w| w[0] < w[1]) {
                                  return false;
                              }
                              if keep.iter().any(|&k| k >= di) {
                                  return false;
                              }
                          }
                      }
                      // pruned count correct (global: exact; layerwise: per layer)
                      let total = l * e * di;
                      let kept: usize =
                          plan.keep.iter().flatten().map(|k| k.len()).sum();
                      let expect = match scope {
                          Scope::Global => (total as f64 * ratio).round() as usize,
                          Scope::Layerwise =>
                              l * (((e * di) as f64 * ratio).round() as usize),
                      };
                      if total - kept != expect {
                          return false;
                      }
                  }
                  true
              });
    }

    #[test]
    fn surgery_slices_shapes() {
        // build a minimal 1-layer store with E=2, di=4, d=3
        let names = vec![
            "embed".to_string(),
            "l0.wg".to_string(),
            "l0.wu".to_string(),
            "l0.wd".to_string(),
        ];
        let mut rng = Pcg64::new(6);
        let mk = |shape: &[usize], rng: &mut Pcg64| {
            let n: usize = shape.iter().product();
            Tensor::from_vec(shape, (0..n).map(|_| rng.normal()).collect())
        };
        let tensors = vec![
            mk(&[5, 3], &mut rng),
            mk(&[2, 4, 3], &mut rng),
            mk(&[2, 4, 3], &mut rng),
            mk(&[2, 3, 4], &mut rng),
        ];
        let store = ParamStore::from_tensors(names, tensors);
        let plan = PrunePlan {
            keep: vec![vec![vec![0, 2], vec![1, 2, 3]]],
            d_inter: 4,
        };
        let pruned = surgery(&store, &plan).unwrap();
        assert_eq!(pruned.get("l0.e0.wg").unwrap().shape(), &[2, 3]);
        assert_eq!(pruned.get("l0.e1.wg").unwrap().shape(), &[3, 3]);
        assert_eq!(pruned.get("l0.e0.wd").unwrap().shape(), &[3, 2]);
        assert_eq!(pruned.get("embed").unwrap().shape(), &[5, 3]);
        // values come from the right columns
        let full_wd = store.get("l0.wd").unwrap().index0(0);
        let cut_wd = pruned.get("l0.e0.wd").unwrap();
        for r in 0..3 {
            assert_eq!(cut_wd.at(&[r, 1]), full_wd.at(&[r, 2]));
        }
    }
}
