//! Atomic-expert importance (eq. 13 via the output-space factorisation).
//!
//! s̄_{l,e,k} = ½ · q_k · mean_routed(h_k²),
//! q = diag(W_down^T Ḡ_{l,e} W_down)   (the Pallas `quadform` artifact).
//!
//! Scores are loss-calibrated (expected Δℓ of removing the atomic expert),
//! hence comparable across layers — this is what licenses HEAPr-G's global
//! ranking (paper §3.2).

use anyhow::Result;

use crate::heapr::calibrate::CalibStats;
use crate::model::store::ParamStore;
// lint:allow(layering) by design: importance scoring drives the engine as a client (ARCHITECTURE §2); it is not on the serve path
use crate::runtime::{Engine, Value};
use crate::tensor::Tensor;
#[cfg(not(feature = "pjrt"))]
use crate::util::pool;

/// Importance tensor [L, E, di]; smaller = prune first.
///
/// The L×E `quadform` + score loop fans out over the thread pool — each
/// (layer, expert) pair slices its own Ḡ, runs the quadform artifact and
/// produces its own [di] score row, so results are order-independent and
/// identical for every `HEAPR_THREADS`. The fan-out requires the engine to
/// be `Sync` (true of the host backend); pjrt builds compile the serial
/// loop instead (the PJRT engine holds raw FFI pointers).
pub fn importance_scores(
    engine: &Engine,
    params: &ParamStore,
    stats: &CalibStats,
) -> Result<Tensor> {
    let (l, e, _d, di) = stats.cfg_dims;
    // hoist the per-layer weight handles once (not once per (l, e) pair)
    let wd_alls: Vec<&Tensor> = (0..l)
        .map(|li| params.get(&format!("l{li}.wd"))) // [E, d, di]
        .collect::<Result<_>>()?;
    let score_pair = |pair: usize| -> Result<Option<Vec<f32>>> {
        let (li, ei) = (pair / e, pair % e);
        if stats.counts.at(&[li, ei]) == 0.0 {
            return Ok(None); // never-routed expert: importance stays 0
        }
        let wd = wd_alls[li].index0(ei); // [d, di]
        let gbar = stats.gbar_at(li, ei);
        let out = engine.run("quadform", &[Value::F32(wd), Value::F32(gbar)])?;
        let q = out.into_iter().next().unwrap().f32()?;
        let hsq = stats.hsq_at(li, ei);
        Ok(Some(
            (0..di).map(|k| 0.5 * q.data()[k] * hsq.data()[k]).collect(),
        ))
    };
    #[cfg(not(feature = "pjrt"))]
    let rows: Vec<Result<Option<Vec<f32>>>> = pool::par_map(l * e, score_pair);
    #[cfg(feature = "pjrt")]
    let rows: Vec<Result<Option<Vec<f32>>>> = (0..l * e).map(score_pair).collect();
    let mut scores = Tensor::zeros(&[l, e, di]);
    for (pair, row) in rows.into_iter().enumerate() {
        if let Some(vals) = row? {
            scores.data_mut()[pair * di..(pair + 1) * di].copy_from_slice(&vals);
        }
    }
    Ok(scores)
}

/// Expert-level importance = Σ_k atomic importance (Table 3 ablation; valid
/// because cross-atomic Hessian terms vanish, eq. 7/8).
pub fn expert_scores(scores: &Tensor) -> Tensor {
    let &[l, e, di] = scores.shape() else {
        panic!("scores must be [L,E,di]")
    };
    let mut out = Tensor::zeros(&[l, e]);
    for li in 0..l {
        for ei in 0..e {
            let mut s = 0.0;
            for k in 0..di {
                // lint:allow(float-accum-order) Eq. 8 expert aggregation: a ranking signal summed over <= d_i nonnegative atomic scores; no bitwise contract
                s += scores.at(&[li, ei, k]);
            }
            out.set(&[li, ei], s);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expert_scores_sum_atomics() {
        let s = Tensor::from_vec(&[1, 2, 3], vec![1., 2., 3., 10., 20., 30.]);
        let e = expert_scores(&s);
        assert_eq!(e.data(), &[6.0, 60.0]);
    }
}
