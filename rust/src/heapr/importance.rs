//! Atomic-expert importance (eq. 13 via the output-space factorisation).
//!
//! s̄_{l,e,k} = ½ · q_k · mean_routed(h_k²),
//! q = diag(W_down^T Ḡ_{l,e} W_down)   (the Pallas `quadform` artifact).
//!
//! Scores are loss-calibrated (expected Δℓ of removing the atomic expert),
//! hence comparable across layers — this is what licenses HEAPr-G's global
//! ranking (paper §3.2).

use anyhow::Result;

use crate::heapr::calibrate::CalibStats;
use crate::model::store::ParamStore;
use crate::runtime::{Engine, Value};
use crate::tensor::Tensor;

/// Importance tensor [L, E, di]; smaller = prune first.
pub fn importance_scores(
    engine: &Engine,
    params: &ParamStore,
    stats: &CalibStats,
) -> Result<Tensor> {
    let (l, e, _d, di) = stats.cfg_dims;
    let mut scores = Tensor::zeros(&[l, e, di]);
    for li in 0..l {
        let wd_all = params.get(&format!("l{li}.wd"))?; // [E, d, di]
        for ei in 0..e {
            if stats.counts.at(&[li, ei]) == 0.0 {
                continue; // never-routed expert: importance stays 0
            }
            let wd = wd_all.index0(ei); // [d, di]
            let gbar = stats.gbar_at(li, ei);
            let out = engine.run("quadform", &[Value::F32(wd), Value::F32(gbar)])?;
            let q = out.into_iter().next().unwrap().f32()?;
            let hsq = stats.hsq_at(li, ei);
            for k in 0..di {
                scores.set(&[li, ei, k], 0.5 * q.data()[k] * hsq.data()[k]);
            }
        }
    }
    Ok(scores)
}

/// Expert-level importance = Σ_k atomic importance (Table 3 ablation; valid
/// because cross-atomic Hessian terms vanish, eq. 7/8).
pub fn expert_scores(scores: &Tensor) -> Tensor {
    let &[l, e, di] = scores.shape() else {
        panic!("scores must be [L,E,di]")
    };
    let mut out = Tensor::zeros(&[l, e]);
    for li in 0..l {
        for ei in 0..e {
            let mut s = 0.0;
            for k in 0..di {
                s += scores.at(&[li, ei, k]);
            }
            out.set(&[li, ei], s);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expert_scores_sum_atomics() {
        let s = Tensor::from_vec(&[1, 2, 3], vec![1., 2., 3., 10., 20., 30.]);
        let e = expert_scores(&s);
        assert_eq!(e.data(), &[6.0, 60.0]);
    }
}
