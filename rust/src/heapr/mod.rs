//! HEAPr: Hessian-based Efficient Atomic Expert Pruning in Output Space.
//!
//! The paper's algorithm (Algorithm 1), end to end:
//!
//! 1. [`calibrate::Calibrator`] streams the calibration set through the
//!    `calib_pass1` (fwd+bwd) and `calib_pass2` (fwd) artifacts,
//!    accumulating per-expert gradient covariances Ḡ_{l,e} (eq. 15) and
//!    routed atomic-activation second moments (the sufficient statistic for
//!    eq. 16 under the rank-1 factorisation; see docs/ARCHITECTURE.md) — two forward
//!    passes + one backward pass total, O(d²) memory per expert.
//! 2. [`importance::importance_scores`] combines them through the Pallas
//!    `quadform` artifact: s̄_{l,e,k} = ½ · (w_down_k^T Ḡ w_down_k) ·
//!    mean_routed(h_k²).
//! 3. [`plan::PrunePlan`] ranks atomic experts globally (HEAPr-G) or per
//!    layer (HEAPr-L) and prunes the lowest r%.
//! 4. [`plan::surgery`] physically slices W_gate/W_up rows and W_down
//!    columns; [`plan::PrunePlan::mask`] produces the equivalent 0/1 mask
//!    for the masked-eval artifacts (the two are asserted equivalent in
//!    integration tests).

pub mod calibrate;
pub mod importance;
pub mod plan;

pub use calibrate::{CalibStats, Calibrator};
pub use importance::importance_scores;
pub use plan::{surgery, PrunePlan, Scope};

use anyhow::Result;

use crate::data::sampler::CalibSampler;
use crate::model::store::ParamStore;
// lint:allow(layering) by design: HEAPr calibration drives the engine as a client (ARCHITECTURE §2); it is not on the serve path
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// Convenience: run both calibration passes + importance over a sampled
/// calibration set (the paper's "two forward passes and one backward pass").
pub fn heapr_scores(
    engine: &Engine,
    params: &ParamStore,
    calib: &[Vec<i32>],
) -> Result<(Tensor, CalibStats)> {
    let cfg = engine.config().clone();
    let mut cal = Calibrator::new(&cfg);
    for (tokens, targets) in CalibSampler::batches(calib, cfg.batch, cfg.seq_len) {
        cal.accumulate_pass1(engine, params, &tokens, &targets)?;
        cal.accumulate_pass2(engine, params, &tokens)?;
    }
    let stats = cal.finish();
    let scores = importance_scores(engine, params, &stats)?;
    Ok((scores, stats))
}
