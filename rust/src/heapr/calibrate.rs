//! Calibration accumulators (HEAPr stage 1 + the pass-2 statistics).
//!
//! Streams batches: per batch, `calib_pass1` returns the *sums*
//! Σ g g^T per (layer, expert) and routed-token counts; `calib_pass2`
//! returns Σ h², max |h| and the same counts. The accumulator adds across
//! batches and normalises once in [`Calibrator::finish`] — numerically
//! identical to the paper's dataset-level means, while keeping rust-side
//! memory at O(L·E·d²) (the paper's headline complexity).

use anyhow::Result;

use crate::config::ModelConfig;
use crate::model::store::ParamStore;
// lint:allow(layering) by design: calibration drives the engine as a client (ARCHITECTURE §2); it is not on the serve path
use crate::runtime::{Engine, Value};
use crate::tensor::{ITensor, Tensor};

/// Final calibration statistics.
#[derive(Clone, Debug)]
pub struct CalibStats {
    pub cfg_dims: (usize, usize, usize, usize), // (L, E, d, di)
    /// Ḡ_{l,e} = Σ g g^T / |T_{l,e}|  — flattened [L, E, d, d].
    pub gbar: Tensor,
    /// mean_routed(h_k²) — [L, E, di].
    pub hsq_mean: Tensor,
    /// max_routed |h_k| — [L, E, di] (CAMERA-P baseline input).
    pub hmax: Tensor,
    /// routed-token counts |T_{l,e}| — [L, E].
    pub counts: Tensor,
    /// mean calibration CE loss across pass-1 batches.
    pub calib_ce: f32,
    /// number of sequences consumed.
    pub n_sequences: usize,
}

pub struct Calibrator {
    l: usize,
    e: usize,
    d: usize,
    di: usize,
    gsum: Tensor,
    hsq: Tensor,
    hmax: Tensor,
    counts1: Tensor,
    counts2: Tensor,
    ce_sum: f64,
    n_batches1: usize,
    n_batches2: usize,
    n_sequences: usize,
}

impl Calibrator {
    pub fn new(cfg: &ModelConfig) -> Calibrator {
        let (l, e, d, di) = (cfg.n_layers, cfg.n_experts, cfg.d_model, cfg.d_inter);
        Calibrator {
            l,
            e,
            d,
            di,
            gsum: Tensor::zeros(&[l, e, d, d]),
            hsq: Tensor::zeros(&[l, e, di]),
            hmax: Tensor::zeros(&[l, e, di]),
            counts1: Tensor::zeros(&[l, e]),
            counts2: Tensor::zeros(&[l, e]),
            ce_sum: 0.0,
            n_batches1: 0,
            n_batches2: 0,
            n_sequences: 0,
        }
    }

    /// Pass 1: forward+backward — accumulate Σ g g^T and counts.
    pub fn accumulate_pass1(
        &mut self,
        engine: &Engine,
        params: &ParamStore,
        tokens: &ITensor,
        targets: &ITensor,
    ) -> Result<()> {
        let mut inputs = params.values();
        inputs.push(Value::I32(tokens.clone()));
        inputs.push(Value::I32(targets.clone()));
        let out = engine.run("calib_pass1", &inputs)?;
        let [ce, gsum, counts]: [Value; 3] = out
            .try_into()
            .map_err(|_| anyhow::anyhow!("calib_pass1 output arity"))?;
        self.ce_sum += ce.f32()?.item() as f64;
        add_into(&mut self.gsum, &gsum.f32()?);
        add_into(&mut self.counts1, &counts.f32()?);
        self.n_batches1 += 1;
        self.n_sequences += tokens.shape()[0];
        Ok(())
    }

    /// Pass 2: forward — accumulate Σ h², max |h| and counts.
    pub fn accumulate_pass2(
        &mut self,
        engine: &Engine,
        params: &ParamStore,
        tokens: &ITensor,
    ) -> Result<()> {
        let mut inputs = params.values();
        inputs.push(Value::I32(tokens.clone()));
        let out = engine.run("calib_pass2", &inputs)?;
        let [hsq, hmax, counts, _probe]: [Value; 4] = out
            .try_into()
            .map_err(|_| anyhow::anyhow!("calib_pass2 output arity"))?;
        add_into(&mut self.hsq, &hsq.f32()?);
        max_into(&mut self.hmax, &hmax.f32()?);
        add_into(&mut self.counts2, &counts.f32()?);
        self.n_batches2 += 1;
        Ok(())
    }

    /// First (layer, expert) whose pass-1 and pass-2 routed counts differ.
    /// Both passes replay the same router on the same tokens, so any
    /// divergence means the passes saw different data (caller bug) or the
    /// routing drifted between passes (artifact bug).
    fn counts_divergence(&self) -> Option<(usize, usize, f32, f32)> {
        for li in 0..self.l {
            for ei in 0..self.e {
                let c1 = self.counts1.at(&[li, ei]);
                let c2 = self.counts2.at(&[li, ei]);
                if c1 != c2 {
                    return Some((li, ei, c1, c2));
                }
            }
        }
        None
    }

    /// Normalise sums into the dataset-level means of eqs. 15/16.
    pub fn finish(self) -> CalibStats {
        assert!(self.n_batches1 > 0, "no pass-1 batches accumulated");
        assert!(self.n_batches2 > 0, "no pass-2 batches accumulated");
        // Both passes see the same routed sets: pass-1 counts normalise Ḡ,
        // pass-2 counts normalise h². If they diverge the importance
        // scores mix statistics from different token sets — surface it
        // loudly instead of silently normalising past it.
        if let Some((li, ei, c1, c2)) = self.counts_divergence() {
            crate::warn!(
                "calibration counts diverged at layer {li} expert {ei}: \
                 pass1={c1} pass2={c2} — passes saw different batches?"
            );
            debug_assert!(
                false,
                "calibration count divergence: layer {li} expert {ei} \
                 pass1={c1} pass2={c2}"
            );
        }
        let (l, e, d, di) = (self.l, self.e, self.d, self.di);
        let mut gbar = self.gsum;
        let mut hsq_mean = self.hsq;
        for li in 0..l {
            for ei in 0..e {
                let c1 = self.counts1.at(&[li, ei]).max(1.0);
                let c2 = self.counts2.at(&[li, ei]).max(1.0);
                let base = (li * e + ei) * d * d;
                for x in &mut gbar.data_mut()[base..base + d * d] {
                    *x /= c1;
                }
                let hbase = (li * e + ei) * di;
                for x in &mut hsq_mean.data_mut()[hbase..hbase + di] {
                    *x /= c2;
                }
            }
        }
        CalibStats {
            cfg_dims: (l, e, d, di),
            gbar,
            hsq_mean,
            hmax: self.hmax,
            counts: self.counts1,
            calib_ce: (self.ce_sum / self.n_batches1 as f64) as f32,
            n_sequences: self.n_sequences,
        }
    }
}

fn add_into(acc: &mut Tensor, x: &Tensor) {
    assert_eq!(acc.shape(), x.shape());
    for (a, b) in acc.data_mut().iter_mut().zip(x.data()) {
        // lint:allow(float-accum-order) calibration moments accumulate batch-sequentially by definition (Ḡ += per-batch G); the loader seed pins batch order
        *a += *b;
    }
}

fn max_into(acc: &mut Tensor, x: &Tensor) {
    assert_eq!(acc.shape(), x.shape());
    for (a, b) in acc.data_mut().iter_mut().zip(x.data()) {
        *a = a.max(*b);
    }
}

impl CalibStats {
    /// Ḡ for one (layer, expert) as a [d, d] tensor.
    pub fn gbar_at(&self, l: usize, e: usize) -> Tensor {
        let (_, ne, d, _) = self.cfg_dims;
        let base = (l * ne + e) * d * d;
        Tensor::from_vec(&[d, d], self.gbar.data()[base..base + d * d].to_vec())
    }

    /// mean h² slice for one (layer, expert) as [di].
    pub fn hsq_at(&self, l: usize, e: usize) -> Tensor {
        let (_, ne, _, di) = self.cfg_dims;
        let base = (l * ne + e) * di;
        Tensor::from_vec(&[di], self.hsq_mean.data()[base..base + di].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_max_into() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        add_into(&mut a, &Tensor::from_vec(&[3], vec![1.0, -1.0, 0.5]));
        assert_eq!(a.data(), &[2.0, 1.0, 3.5]);
        max_into(&mut a, &Tensor::from_vec(&[3], vec![5.0, 0.0, 3.6]));
        assert_eq!(a.data(), &[5.0, 1.0, 3.6]);
    }

    fn manual_calibrator() -> Calibrator {
        let cfg = crate::runtime::preset::builtin("tiny").unwrap();
        let mut cal = Calibrator::new(&cfg);
        cal.n_batches1 = 1;
        cal.n_batches2 = 1;
        cal
    }

    #[test]
    fn equal_counts_pass_the_divergence_check() {
        let mut cal = manual_calibrator();
        cal.counts1.set(&[0, 0], 4.0);
        cal.counts2.set(&[0, 0], 4.0);
        assert!(cal.counts_divergence().is_none());
        let stats = cal.finish(); // must not assert
        assert_eq!(stats.counts.at(&[0, 0]), 4.0);
    }

    #[test]
    fn diverged_counts_are_detected() {
        let mut cal = manual_calibrator();
        cal.counts1.set(&[1, 2], 4.0);
        cal.counts2.set(&[1, 2], 5.0);
        assert_eq!(cal.counts_divergence(), Some((1, 2, 4.0, 5.0)));
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "calibration count divergence")]
    fn diverged_counts_trip_the_debug_assert_in_finish() {
        let mut cal = manual_calibrator();
        cal.counts1.set(&[0, 1], 3.0);
        cal.counts2.set(&[0, 1], 7.0);
        let _ = cal.finish();
    }

    #[test]
    fn stats_slicing() {
        let stats = CalibStats {
            cfg_dims: (1, 2, 2, 3),
            gbar: Tensor::from_vec(&[1, 2, 2, 2], (0..8).map(|x| x as f32).collect()),
            hsq_mean: Tensor::from_vec(&[1, 2, 3], (0..6).map(|x| x as f32).collect()),
            hmax: Tensor::zeros(&[1, 2, 3]),
            counts: Tensor::ones(&[1, 2]),
            calib_ce: 0.0,
            n_sequences: 0,
        };
        assert_eq!(stats.gbar_at(0, 1).data(), &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(stats.hsq_at(0, 0).data(), &[0.0, 1.0, 2.0]);
    }
}
