//! Continuous-batching scheduler: lane-granular decode with in-flight
//! admission.
//!
//! [`Server::serve_batch`] is batch-synchronous — one closed batch runs
//! to completion, so one long request holds every lane in its batch
//! hostage while queued requests wait for the slowest straggler. This
//! module replaces that loop for online serving: the scheduler owns a
//! set of decode **lanes** (one serve-batch bucket's worth of KV cache,
//! allocated once via [`Server::empty_state`]) and drives one decode
//! step across all occupied lanes at a time. When a lane's sequence
//! finishes — EOS, budget, or window — the lane is **retired**
//! individually ([`DecodeState::zero_lane`]) and refilled from the
//! queue **mid-decode**: the new request is prefilled solo, its KV rows
//! seated into the freed lane ([`DecodeState::write_lane`]), and the
//! next step advances old and new sequences together.
//!
//! ```text
//!  step:      1 2 3 4 5 6 7 8 9 …
//!  lane 0:    A A A A A A A A A     (long request, never blocked)
//!  lane 1:    B B B·C C C C·D D     (B retires at 3, C admitted in
//!  lane 2:    E E·F F F F F F·G      flight at 4; · = solo prefill)
//! ```
//!
//! # Equivalence
//!
//! Per-request token streams are **bitwise identical** to
//! [`Server::serve_batch`]'s, whatever the admission order, lane count,
//! thread count or residency — every per-row computation in the serving
//! composition (rmsnorm, gating, attention per (batch, head), the GEMM
//! accumulation contract, greedy argmax) depends only on that row, so a
//! sequence's logits do not care which lane it occupies or who its
//! neighbours are. The tier-1 `continuous_scheduler` tests assert this.
//!
//! # Streaming
//!
//! Tokens are emitted per request as they land ([`StreamEvent`] over an
//! mpsc sender) — index-ordered within a request, with `done` marking
//! the final token. [`Response`]s carry true per-request latency
//! (submission to retirement, queue wait included), which is what
//! `bench_serve`'s admission-policy axis reports as p50/p99.
//!
//! # Compaction
//!
//! Once the queue has drained for good, a wide state serving few
//! survivors wastes per-step work on empty lanes. The scheduler then
//! *compacts*: survivors' KV lanes are copied into a fresh state at the
//! smallest serve-batch bucket that fits them and decode continues
//! there — bitwise unchanged (lane values are lane-position and
//! bucket independent), just cheaper per step.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::batcher::{Batcher, Request, RequestId};
use crate::coordinator::prefix::PrefixIndex;
use crate::coordinator::serve::{argmax_row, lane_rows, DecodeState, Response, Server};
use crate::data::tokenizer::{EOS, PAD};
use crate::debug;

/// One token landing in one request's stream, emitted by the scheduler
/// the moment the token is committed (not when the request completes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamEvent {
    pub id: RequestId,
    /// 0-based index of this token within the request's generation.
    pub index: usize,
    pub token: i32,
    /// True on the request's final token.
    pub done: bool,
}

/// Cross-thread cancellation requests keyed by [`RequestId`]. The wire
/// layer ([`crate::coordinator::http`]) files a cancellation when a
/// client deadline expires or a connection dies mid-stream; the
/// scheduler consumes it at the lane's next token commit and retires
/// the lane through the normal path (KV zeroed, response recorded,
/// metrics updated), so a cancelled request can never leak lane state.
/// The steady state is empty: `commit` pays one atomic load per token
/// and touches the mutex only while a cancellation is actually pending.
#[derive(Debug, Default)]
pub struct CancelSet {
    pending: AtomicUsize,
    ids: Mutex<Vec<RequestId>>,
}

impl CancelSet {
    pub fn new() -> CancelSet {
        CancelSet::default()
    }

    /// File a cancellation for `id`. Filing twice is harmless: every
    /// copy is consumed by the retire-side sweep.
    pub fn request(&self, id: RequestId) {
        let mut ids = self.ids.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        ids.push(id);
        self.pending.store(ids.len(), Ordering::Release);
    }

    /// Consume any pending cancellation for `id`, returning whether one
    /// was filed. Fast path: a single atomic load while the set is
    /// empty, so an uncancelled serve loop never contends on the lock.
    fn take(&self, id: RequestId) -> bool {
        if self.pending.load(Ordering::Acquire) == 0 {
            return false;
        }
        let mut ids = self.ids.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut hit = false;
        let mut i = 0;
        while i < ids.len() {
            if ids[i] == id {
                ids.swap_remove(i);
                hit = true;
            } else {
                i += 1;
            }
        }
        self.pending.store(ids.len(), Ordering::Release);
        hit
    }
}

/// Continuous scheduler knobs. `Default` serves with the preset's widest
/// serve-batch bucket, no streaming sink, compaction on.
pub struct SchedulerOpts {
    /// Lane count; rounded up to a serve-batch bucket, clamped to the
    /// widest. `None` = the preset's widest bucket.
    pub lanes: Option<usize>,
    /// Per-token streaming sink. Send failures (a dropped receiver) are
    /// ignored — streaming is observability, not control flow.
    pub stream: Option<Sender<StreamEvent>>,
    /// Compact to a smaller bucket once the queue has drained for good.
    pub compact: bool,
    /// Shared-prefix page reuse at admission: a request whose prompt
    /// prefix is resident in a live lane seats by mapping the shared
    /// pages and replaying only the tail. Effective only under
    /// [`crate::coordinator::Residency::Paged`] with a b=1 decode
    /// artifact; bitwise-identical token streams either way.
    pub prefix_cache: bool,
    /// Cross-thread cancellation set, consumed at token commit: a
    /// request filed here retires at its next committed token, with
    /// `done` raised on that final stream event. `None` = no external
    /// cancellation (the in-process serving paths).
    pub cancel: Option<Arc<CancelSet>>,
    /// Scheduler-side deadline backstop: a lane whose request has been
    /// in flight (submission to now) at least this long is cancelled at
    /// its next commit. The HTTP layer enforces its own, strictly
    /// earlier, per-request deadline through `cancel`; this backstop
    /// catches requests whose wire handler is already gone.
    pub deadline: Option<Duration>,
}

impl Default for SchedulerOpts {
    fn default() -> Self {
        SchedulerOpts {
            lanes: None,
            stream: None,
            compact: true,
            prefix_cache: prefix_cache_enabled(),
            cancel: None,
            deadline: None,
        }
    }
}

/// `HEAPR_NO_PREFIX_CACHE=1` disables shared-prefix admission (pages and
/// token streams are unchanged — only the prefill-skip optimization is
/// off), the escape hatch mirroring `HEAPR_NO_BUFFER_CACHE`.
pub fn prefix_cache_enabled() -> bool {
    std::env::var("HEAPR_NO_PREFIX_CACHE").map(|v| v != "1").unwrap_or(true)
}

/// One occupied decode lane: the request plus exactly the per-sequence
/// state `serve_batch` keeps per batch row.
struct Lane {
    req: Request,
    /// Uncommitted next token (argmax of the latest logits).
    next: i32,
    /// Decode position of the next append = prompt len + committed
    /// tokens (mirrors `serve_batch`'s `positions[i]`).
    pos: usize,
    generated: Vec<i32>,
}

/// Continuous-batching serve loop over a [`Server`]. See the module
/// docs; most callers want [`serve_continuous`].
pub struct Scheduler<'s, 'e> {
    server: &'s mut Server<'e>,
    opts: SchedulerOpts,
}

/// Serve the batcher's queue to drain with continuous admission;
/// returns one [`Response`] per request, in completion order.
pub fn serve_continuous(
    server: &mut Server<'_>,
    batcher: &mut Batcher,
    opts: SchedulerOpts,
) -> Result<Vec<Response>> {
    Scheduler::new(server, opts).run(batcher)
}

impl<'s, 'e> Scheduler<'s, 'e> {
    pub fn new(server: &'s mut Server<'e>, opts: SchedulerOpts) -> Scheduler<'s, 'e> {
        Scheduler { server, opts }
    }

    /// Run the serve loop until the queue is drained (producer channel
    /// closed and every admitted request retired).
    pub fn run(&mut self, batcher: &mut Batcher) -> Result<Vec<Response>> {
        // lint:allow(hot-path-alloc) one-time setup before the serve loop: a small plain-old-data config copy, and `run` is entered once
        let cfg = self.server.engine().config().clone();
        let max_pos = cfg.seq_len.min(cfg.max_decode_len);
        let widest = *cfg.serve_batches.last().unwrap_or(&1);
        let want = self.opts.lanes.unwrap_or(widest).clamp(1, widest);
        let bb = cfg.serve_batches.iter().find(|&&b| b >= want).copied().unwrap_or(widest);

        // busy-time clock: paused across blocking waits for work, so
        // wall_s (and tok/s) measures serving, not producer idle, and
        // stays comparable with serve_batch's
        let mut t0 = Instant::now();
        // lint:allow(hot-path-alloc) one-time lane-table allocation before the loop
        let mut lanes: Vec<Option<Lane>> = (0..bb).map(|_| None).collect();
        // allocated lazily at first admission so an empty queue costs
        // nothing; released (or compacted + released) on the way out
        let mut state: Option<DecodeState<'e>> = None;
        // created alongside the state iff prefix reuse can apply: paged
        // residency (pages to share) and a b=1 decode artifact (to replay
        // prompt tails lane-solo)
        let mut pidx: Option<PrefixIndex> = None;
        let mut responses: Vec<Response> = Vec::new();
        // per-step token/position scratch, reused across every decode
        // iteration: the steady-state loop must not heap-allocate
        // (hot-path-alloc). `resize` only grows them once, to the lane
        // count; compaction shrinks `lanes`, never grows it.
        let mut next: Vec<i32> = Vec::new();
        let mut poss: Vec<usize> = Vec::new();

        loop {
            // -- admission: refill freed lanes from the queue. Each
            // admission commits its first (prefill) token right here, so
            // an instant-done request (EOS or budget on token one)
            // retires without ever occupying a decode step and its lane
            // is offered to the next queued request immediately — hence
            // the inner loop.
            loop {
                let n_free = lanes.iter().filter(|l| l.is_none()).count();
                if n_free == 0 {
                    break;
                }
                let idle = n_free == lanes.len();
                let ready = if idle {
                    // nothing mid-decode: block for work (or for the
                    // producer channel to close) with the busy clock
                    // paused — this wait is the producer's idle time
                    self.server.metrics.wall_s += t0.elapsed().as_secs_f64();
                    let ready = batcher.wait_ready(n_free);
                    t0 = Instant::now();
                    ready
                } else {
                    // lanes mid-decode: admission must never stall them
                    batcher.take_ready(n_free)
                };
                if ready.is_empty() {
                    break;
                }
                if state.is_none() {
                    let st = self.server.empty_state(lanes.len(), max_pos)?;
                    if self.opts.prefix_cache && cfg.serve_batches.contains(&1) {
                        if let Some(page) = st.kv_page() {
                            pidx = Some(PrefixIndex::new(page, lanes.len()));
                        }
                    }
                    state = Some(st);
                }
                let mut ready = ready.into_iter();
                for slot in 0..lanes.len() {
                    if lanes[slot].is_some() {
                        continue;
                    }
                    let Some(req) = ready.next() else { break };
                    let lane = self.admit(
                        req,
                        slot,
                        state.as_mut().context("scheduler state exists after admission")?,
                        pidx.as_mut(),
                    )?;
                    lanes[slot] = Some(lane);
                    self.commit(
                        &mut lanes,
                        slot,
                        max_pos,
                        state.as_mut(),
                        pidx.as_mut(),
                        &mut responses,
                    )?;
                }
            }
            if lanes.iter().all(|l| l.is_none()) {
                if batcher.drained() {
                    break; // queue drained for good
                }
                continue; // back to (blocking) admission
            }

            // -- compaction: shrink the drain tail ---------------------
            if self.opts.compact && batcher.drained() {
                self.compact(&mut lanes, &mut state, pidx.as_mut())?;
            }

            // -- one decode step across all lanes ----------------------
            let st = state.as_mut().context("occupied lanes have a state")?;
            next.clear();
            next.resize(lanes.len(), PAD);
            poss.clear();
            poss.resize(lanes.len(), 0);
            for (i, lane) in lanes.iter().enumerate() {
                if let Some(lane) = lane {
                    next[i] = lane.next;
                    poss[i] = lane.pos;
                }
            }
            let u0 = self.server.engine().upload_stats().1;
            let logits = self.server.decode_step(&next, &poss, st)?;
            let step_bytes = self.server.engine().upload_stats().1 - u0;
            self.server.metrics.decode_steps += 1;
            self.server.metrics.decode_upload_bytes += step_bytes;
            for (i, lane) in lanes.iter_mut().enumerate() {
                if let Some(lane) = lane {
                    lane.next = argmax_row(&logits, i);
                    lane.pos += 1;
                }
            }

            // -- commit: land every stepped lane's token. Lanes retired
            // here are refilled by the next iteration's admission pass
            // *before* the next decode step — no one-step bubble.
            for slot in 0..lanes.len() {
                if lanes[slot].is_some() {
                    self.commit(
                        &mut lanes,
                        slot,
                        max_pos,
                        state.as_mut(),
                        pidx.as_mut(),
                        &mut responses,
                    )?;
                }
            }
        }

        if let Some(st) = state.take() {
            self.server.absorb_kv_stats(&st);
            st.release();
        }
        self.server.metrics.wall_s += t0.elapsed().as_secs_f64();
        Ok(responses)
    }

    /// Land lane `slot`'s pending token: push it, emit the stream event,
    /// and — under exactly `serve_batch`'s completion conditions —
    /// retire the lane.
    fn commit(
        &mut self,
        lanes: &mut [Option<Lane>],
        slot: usize,
        max_pos: usize,
        state: Option<&mut DecodeState<'e>>,
        pidx: Option<&mut PrefixIndex>,
        responses: &mut Vec<Response>,
    ) -> Result<()> {
        let Some(lane) = &mut lanes[slot] else { return Ok(()) };
        lane.generated.push(lane.next);
        // exact mirror of serve_batch's completion conditions
        let natural = lane.next == EOS
            || lane.generated.len() >= lane.req.max_new_tokens
            || lane.pos + 1 >= max_pos;
        // cancellation (wire-filed or deadline backstop) only ever adds
        // a stop on a token that was not final anyway — a naturally
        // final token is never re-labelled — so uncancelled streams
        // stay bitwise identical to serve_batch's
        let cancelled = !natural
            && (self.opts.cancel.as_deref().is_some_and(|c| c.take(lane.req.id))
                || self.opts.deadline.is_some_and(|d| lane.req.submitted.elapsed() >= d));
        let done = natural || cancelled;
        if cancelled {
            self.server.metrics.cancelled_requests += 1;
            debug!("cancelled request {} after {} tokens", lane.req.id, lane.generated.len());
        }
        if let Some(tx) = &self.opts.stream {
            // lint:allow(swallowed-result) streaming is observability, not control flow: a dropped receiver must not fail the serve loop
            let _ = tx.send(StreamEvent {
                id: lane.req.id,
                index: lane.generated.len() - 1,
                token: lane.next,
                done,
            });
        }
        if done {
            self.retire(lanes, slot, state, pidx, responses)?;
        }
        Ok(())
    }

    /// In-flight admission: prefill `req` solo, seat its KV rows into
    /// the freed lane, and return the lane carrying the first
    /// (uncommitted) token — exactly the state `serve_batch` holds for
    /// a batch row after its batched prefill. With a [`PrefixIndex`], a
    /// prompt whose page-aligned prefix is resident in a live lane skips
    /// the solo prefill: the shared pages are mapped and only the tail
    /// replays ([`Scheduler::try_admit_prefix`]). Either way the prompt
    /// is then registered as a future donor.
    fn admit(
        &mut self,
        req: Request,
        slot: usize,
        state: &mut DecodeState<'e>,
        mut pidx: Option<&mut PrefixIndex>,
    ) -> Result<Lane> {
        let hit = match pidx.as_deref_mut() {
            Some(idx) => self.try_admit_prefix(&req, slot, state, idx)?,
            None => None,
        };
        let next = match hit {
            Some(next) => next,
            None => {
                // Solo prefill at the shared state's capacity: row values
                // are batch-composition independent, so the prompt's K/V
                // rows land exactly as a batched prefill would have
                // placed them. Only the prompt's rows are seated (see
                // `DecodeState::admit_lane`).
                let (logits, solo) = self
                    .server
                    .prefill_with_capacity(std::slice::from_ref(&req.prompt), state.capacity())?;
                state.admit_lane(slot, &solo, req.prompt.len())?;
                self.server.absorb_kv_stats(&solo);
                solo.release();
                debug!("admitted request {} into lane {slot}", req.id);
                argmax_row(&logits, 0)
            }
        };
        // either arm leaves the request owned here, so the `Lane` takes
        // it by move — admission never clones a prompt
        let pos = req.prompt.len();
        let lane = Lane { req, next, pos, generated: Vec::new() };
        if let Some(idx) = pidx {
            idx.register(slot, &lane.req.prompt);
        }
        Ok(lane)
    }

    /// Prefix-hit admission: if a live lane's prompt shares leading full
    /// pages with `req`'s (token-exact, page-aligned), map those pages
    /// into the freed lane — refcount bumps, zero bytes, zero prefill
    /// GEMMs — and replay only the prompt tail through b=1 lane decode
    /// steps. The result is bitwise identical to a cold solo prefill: a
    /// decode step at position `p` computes exactly row `p` of a masked
    /// prefill (see `attend_softmax_v` in `runtime/host.rs`), and the
    /// shared rows themselves are prefix-only functions of the prompt.
    /// Returns the first (uncommitted) token on a hit — the caller owns
    /// the request and builds the `Lane` by move — or `None` (cold
    /// path) when no donor qualifies.
    fn try_admit_prefix(
        &mut self,
        req: &Request,
        slot: usize,
        state: &mut DecodeState<'e>,
        pidx: &PrefixIndex,
    ) -> Result<Option<i32>> {
        let Some((src, npages)) = pidx.lookup(&req.prompt) else { return Ok(None) };
        if src == slot {
            // the freed slot was evicted at retirement; a self-hit would
            // mean a stale index — refuse rather than alias
            return Ok(None);
        }
        let shared_rows = npages * pidx.page();
        debug_assert!(shared_rows < req.prompt.len(), "lookup must leave a tail");
        let mapped = state.map_prefix(src, slot, npages)?;
        self.server.metrics.prefix_pages_reused += mapped as u64;
        self.server.metrics.prefill_rows_skipped += shared_rows as u64;
        // replay the tail; the last step's logits carry the first token
        let mut logits = None;
        for p in shared_rows..req.prompt.len() {
            logits = Some(self.server.decode_lane_step(req.prompt[p], p, state, slot)?);
        }
        let logits = logits.context("prefix-hit replay left no tail logits")?;
        let next = argmax_row(&logits, 0);
        debug!(
            "prefix-hit: request {} into lane {slot} ({npages} pages from lane {src})",
            req.id
        );
        Ok(Some(next))
    }

    /// Retire one finished lane: zero its KV rows (the next occupant —
    /// and any introspection — can never observe them), record the
    /// response with true per-request latency, free the slot.
    fn retire(
        &mut self,
        lanes: &mut [Option<Lane>],
        slot: usize,
        state: Option<&mut DecodeState<'e>>,
        pidx: Option<&mut PrefixIndex>,
        responses: &mut Vec<Response>,
    ) -> Result<()> {
        let lane = lanes[slot].take().context("retire called on an empty lane")?;
        if let Some(c) = self.opts.cancel.as_deref() {
            // purge a cancellation that raced natural completion, so the
            // set's commit-side fast path returns to its empty state
            c.take(lane.req.id);
        }
        if let Some(idx) = pidx {
            // the lane can no longer donate its prefix; pages it shared
            // stay alive through their refcounts, not through the index
            idx.evict(slot);
        }
        if let Some(state) = state {
            state.zero_lane(slot)?;
        }
        let latency_ms = lane.req.submitted.elapsed().as_secs_f64() * 1000.0;
        let m = &mut self.server.metrics;
        m.requests += 1;
        m.prompt_tokens += lane.req.prompt.len();
        m.generated_tokens += lane.generated.len();
        m.latencies_ms.push(latency_ms);
        debug!(
            "retired request {} from lane {slot} after {} tokens",
            lane.req.id,
            lane.generated.len()
        );
        responses.push(Response { id: lane.req.id, tokens: lane.generated, latency_ms });
        Ok(())
    }

    /// Drain-tail compaction: move the survivors into a state at the
    /// smallest serve-batch bucket that fits them. KV lane values are
    /// lane-position and bucket independent, so tokens are bitwise
    /// unchanged; each step just stops paying for empty lanes.
    fn compact(
        &mut self,
        lanes: &mut Vec<Option<Lane>>,
        state: &mut Option<DecodeState<'e>>,
        pidx: Option<&mut PrefixIndex>,
    ) -> Result<()> {
        let Some(old) = state.as_mut() else { return Ok(()) };
        let active: Vec<usize> = (0..lanes.len()).filter(|&i| lanes[i].is_some()).collect();
        if active.is_empty() {
            return Ok(());
        }
        let cfg = self.server.engine().config().clone();
        let target = cfg
            .serve_batches
            .iter()
            .find(|&&b| b >= active.len())
            .copied()
            .unwrap_or(old.bucket());
        if target >= old.bucket() {
            return Ok(());
        }
        debug!("compacting {} survivors from b{} to b{}", active.len(), old.bucket(), target);
        let mut fresh = self.server.empty_state(active.len(), old.capacity())?;
        for l in 0..old.n_layers() {
            let (k, v) = old.kv_cache(l)?;
            for (ni, &oi) in active.iter().enumerate() {
                // trim to the survivor's written rows: rows at and above
                // `pos` are zeros on every residency (seated prompts are
                // prompt-trimmed, retirement zeroes), so this is bitwise
                // free — and under paging the fresh lane maps only the
                // pages the survivor actually occupies
                let rows = lanes[oi]
                    .as_ref()
                    .map(|ln| ln.pos)
                    .unwrap_or(1)
                    .clamp(1, old.capacity());
                fresh.write_lane(l, ni, &lane_rows(&k, oi, rows), &lane_rows(&v, oi, rows))?;
            }
        }
        let mut packed: Vec<Option<Lane>> = (0..fresh.bucket()).map(|_| None).collect();
        for (ni, &oi) in active.iter().enumerate() {
            packed[ni] = lanes[oi].take();
        }
        *lanes = packed;
        if let Some(idx) = pidx {
            // lane numbering changed wholesale: rebuild the donor index
            // against the packed slots (the fresh state's pages are new,
            // but the resident prompt rows are unchanged)
            idx.clear();
            for (slot, lane) in lanes.iter().enumerate() {
                if let Some(l) = lane {
                    idx.register(slot, &l.req.prompt);
                }
            }
        }
        if let Some(old) = state.replace(fresh) {
            self.server.absorb_kv_stats(&old);
            old.release();
        }
        Ok(())
    }
}
