//! Dependency-free HTTP/1.1 serving front-end over the continuous
//! scheduler: the repo's wire layer.
//!
//! Everything here is hand-rolled on `std::net` + [`crate::util::pool`]
//! — no HTTP crate, same vendoring philosophy as the in-tree `anyhow`.
//! The pieces:
//!
//! - [`RequestParser`] — an incremental HTTP/1.1 request parser
//!   (request line, headers, `Content-Length` bodies). It accumulates
//!   bytes across reads and only ever interprets a *complete* head, so
//!   the parse is invariant under read segmentation by construction;
//!   the `http_serve` property suite feeds it every split point,
//!   pipelined requests and raw byte soup to prove it never panics and
//!   never hangs. Malformed input maps to `400`, an oversized head to
//!   `431`, an oversized body to `413`.
//! - [`HttpServer`] — accept loop, per-connection handlers and an SSE
//!   dispatcher around [`serve_continuous`]. `POST /generate` takes a
//!   JSON body ([`crate::util::json`]), maps it onto a
//!   [`Request`] and streams tokens back as Server-Sent
//!   Events over chunked transfer encoding, one event per committed
//!   token, driven straight off the scheduler's [`StreamEvent`] sink.
//!   Admission is load-shed via a bounded in-flight queue (`429` +
//!   `Retry-After`); per-request deadlines terminate a stream
//!   mid-flight through the scheduler's [`CancelSet`] so the lane is
//!   retired leak-free; raising the shutdown flag drains gracefully
//!   (stop accepting, finish in-flight lanes, exit).
//! - [`PoissonSchedule`] — the open-loop arrival clock used by
//!   `bench_load`: a pure function of the [`Pcg64`] seed, so offered
//!   load is reproducible across runs and thread counts.
//!
//! # Threads
//!
//! The scheduler runs on the *caller's* thread (it borrows the engine);
//! the wire side fans out through [`pool::spawn_named`]: one accept
//! thread owning the listener, one handler thread per connection, and
//! one dispatcher routing [`StreamEvent`]s to the handler that admitted
//! the request. Drain is free of deadlock by ownership: handlers hold
//! the request-channel senders, so the scheduler's queue closes exactly
//! when the last handler exits, and the event channel closes when the
//! scheduler returns — which is what unblocks any handler still
//! waiting on tokens.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::batcher::{Batcher, Request, RequestId};
use crate::coordinator::scheduler::{serve_continuous, CancelSet, SchedulerOpts, StreamEvent};
use crate::coordinator::serve::{Response, Server};
use crate::data::tokenizer::{ByteTokenizer, VOCAB};
use crate::debug;
use crate::util::json::Json;
use crate::util::pool;
use crate::util::rng::Pcg64;

/// Hard cap on a request head (request line + headers + separators);
/// beyond it the parser answers `431 Request Header Fields Too Large`.
pub const MAX_HEAD_BYTES: usize = 8 * 1024;
/// Hard cap on a declared `Content-Length`; beyond it the parser
/// answers `413 Content Too Large` without buffering the body.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Poll granularity for blocking waits that must observe the shutdown
/// flag or a deadline (connection reads, stream receives).
const TICK: Duration = Duration::from_millis(25);
/// Once drain starts, a connection caught mid-request gets this long to
/// finish sending before the socket is closed under it.
const DRAIN_GRACE: Duration = Duration::from_secs(2);
/// The scheduler-side deadline backstop trails the wire-side deadline
/// by this slack, so the handler's final error event is the normal
/// expiry path and the backstop only catches orphaned lanes.
const DEADLINE_BACKSTOP_SLACK: Duration = Duration::from_millis(250);

// ---------------------------------------------------------------------------
// Incremental request parser
// ---------------------------------------------------------------------------

/// One fully-parsed HTTP/1.1 request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// Whether the connection may carry another request after this one
    /// (HTTP/1.1 default, `Connection: close` and HTTP/1.0 semantics).
    pub keep_alive: bool,
}

/// Outcome of one [`RequestParser::poll`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Parse {
    /// Need more bytes.
    Pending,
    /// One complete request; consumed from the buffer (pipelined bytes
    /// behind it are retained for the next poll).
    Ready(HttpRequest),
    /// Protocol error: respond with this status + reason and close.
    /// Framing is unrecoverable, so the state is terminal — every later
    /// poll repeats it.
    Bad(u16, &'static str),
}

/// Incremental, segmentation-invariant HTTP/1.1 request parser. Feed it
/// bytes as they arrive ([`RequestParser::feed`]) and poll for complete
/// requests; it never interprets a partial head, so splitting the input
/// at any byte boundary cannot change the parse. Never panics on
/// arbitrary input, and its buffer is bounded by the head + body caps.
#[derive(Debug, Default)]
pub struct RequestParser {
    buf: Vec<u8>,
    /// Bytes already scanned for the head terminator, so repeated polls
    /// over a slowly-arriving head stay linear overall.
    scanned: usize,
    dead: Option<(u16, &'static str)>,
}

impl RequestParser {
    pub fn new() -> RequestParser {
        RequestParser::default()
    }

    /// Append newly-read bytes. After a fatal [`Parse::Bad`] the stream
    /// has lost framing and further input is discarded.
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.dead.is_none() {
            self.buf.extend_from_slice(bytes);
        }
    }

    /// True when no partial request is buffered — the safe moment to
    /// close a keep-alive connection during drain.
    pub fn is_idle(&self) -> bool {
        self.buf.is_empty() && self.dead.is_none()
    }

    /// Try to produce the next complete request from the buffered bytes.
    pub fn poll(&mut self) -> Parse {
        if let Some((status, reason)) = self.dead {
            return Parse::Bad(status, reason);
        }
        // the head terminator may straddle the previous scan boundary
        let from = self.scanned.saturating_sub(3);
        let head_end =
            self.buf[from..].windows(4).position(|w| w == b"\r\n\r\n").map(|p| from + p);
        let Some(head_end) = head_end else {
            self.scanned = self.buf.len();
            // up to 3 buffered bytes may be a partial terminator of a
            // head that is exactly at the cap, so the eager overflow
            // check carries that slack — otherwise a read cut inside
            // `\r\n\r\n` would 431 a head the whole-buffer parse accepts
            if self.buf.len() > MAX_HEAD_BYTES + 3 {
                return self.die(431, "request head too large");
            }
            return Parse::Pending;
        };
        if head_end > MAX_HEAD_BYTES {
            return self.die(431, "request head too large");
        }
        let head = match parse_head(&self.buf[..head_end]) {
            Ok(head) => head,
            Err((status, reason)) => return self.die(status, reason),
        };
        if head.content_length > MAX_BODY_BYTES {
            return self.die(413, "request body too large");
        }
        let total = head_end + 4 + head.content_length;
        if self.buf.len() < total {
            // body still arriving: park the scan cursor ON the head
            // terminator so the next poll re-finds it — advancing past
            // it would lose the head and hang the request forever
            self.scanned = head_end;
            return Parse::Pending;
        }
        let body = self.buf[head_end + 4..total].to_vec();
        self.buf.drain(..total);
        self.scanned = 0;
        Parse::Ready(HttpRequest {
            method: head.method,
            path: head.path,
            body,
            keep_alive: head.keep_alive,
        })
    }

    fn die(&mut self, status: u16, reason: &'static str) -> Parse {
        self.buf.clear();
        self.scanned = 0;
        self.dead = Some((status, reason));
        Parse::Bad(status, reason)
    }
}

struct Head {
    method: String,
    path: String,
    content_length: usize,
    keep_alive: bool,
}

/// Parse a complete request head (everything before the `\r\n\r\n`).
/// Strict by design: CRLF line endings only, single-space request line,
/// no whitespace before a header colon, `Transfer-Encoding` refused —
/// every reject is a deterministic status, never a panic.
fn parse_head(head: &[u8]) -> std::result::Result<Head, (u16, &'static str)> {
    let text = std::str::from_utf8(head).map_err(|_| (400u16, "request head is not valid UTF-8"))?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    if request_line.contains('\r') || request_line.contains('\n') {
        return Err((400, "bare CR or LF in request line"));
    }
    let mut parts = request_line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) => (m, p, v),
        _ => return Err((400, "malformed request line")),
    };
    if method.is_empty() || !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err((400, "malformed method"));
    }
    if !path.starts_with('/') {
        return Err((400, "request target must be origin-form"));
    }
    let mut keep_alive = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Err((505, "only HTTP/1.0 and HTTP/1.1 are supported")),
    };
    let mut content_length: Option<usize> = None;
    for line in lines {
        if line.contains('\r') || line.contains('\n') {
            return Err((400, "bare CR or LF in header"));
        }
        let Some(colon) = line.find(':') else {
            return Err((400, "malformed header line"));
        };
        let (name, rest) = line.split_at(colon);
        let value = rest[1..].trim();
        if name.is_empty() || name.bytes().any(|b| b.is_ascii_whitespace()) {
            return Err((400, "malformed header name"));
        }
        if name.eq_ignore_ascii_case("content-length") {
            let n: usize = value.parse().map_err(|_| (400u16, "bad Content-Length"))?;
            if content_length.is_some_and(|prev| prev != n) {
                return Err((400, "conflicting Content-Length"));
            }
            content_length = Some(n);
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            return Err((400, "chunked request bodies are not supported"));
        } else if name.eq_ignore_ascii_case("connection") {
            if value.eq_ignore_ascii_case("close") {
                keep_alive = false;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                keep_alive = true;
            }
        }
    }
    Ok(Head {
        method: method.to_string(),
        path: path.to_string(),
        content_length: content_length.unwrap_or(0),
        keep_alive,
    })
}

// ---------------------------------------------------------------------------
// Allocation-free SSE write path (hot-path-alloc entry points)
// ---------------------------------------------------------------------------

/// Render one [`StreamEvent`] as an SSE `data:` line into `out`
/// (cleared first). Steady-state per-token work: no heap allocation —
/// integers are formatted through stack digit buffers and the scratch
/// is reused across events (`hot-path-alloc` gates this via the
/// `write_event` lint entry point).
pub fn write_event(out: &mut Vec<u8>, ev: &StreamEvent) {
    out.clear();
    out.extend_from_slice(b"data: {\"id\":");
    push_u64(out, ev.id);
    out.extend_from_slice(b",\"index\":");
    push_u64(out, ev.index as u64);
    out.extend_from_slice(b",\"token\":");
    push_i64(out, ev.token as i64);
    out.extend_from_slice(b",\"done\":");
    out.extend_from_slice(if ev.done { b"true" } else { b"false" });
    out.extend_from_slice(b"}\n\n");
}

/// Render the terminal SSE error event (deadline expiry, server abort)
/// into `out`. `kind` must not contain JSON-significant characters.
pub fn write_error_event(out: &mut Vec<u8>, id: RequestId, kind: &str) {
    out.clear();
    out.extend_from_slice(b"data: {\"id\":");
    push_u64(out, id);
    out.extend_from_slice(b",\"error\":\"");
    out.extend_from_slice(kind.as_bytes());
    out.extend_from_slice(b"\",\"done\":true}\n\n");
}

/// Write one chunked-transfer-encoding chunk (`<hex len>\r\n<payload>\r\n`).
/// `head` is a reused scratch for the length line, so the per-token
/// write path stays allocation-free (`hot-path-alloc` entry point).
pub fn write_chunk<W: Write>(stream: &mut W, head: &mut Vec<u8>, payload: &[u8]) -> io::Result<()> {
    head.clear();
    push_hex(head, payload.len() as u64);
    head.extend_from_slice(b"\r\n");
    stream.write_all(head)?;
    stream.write_all(payload)?;
    stream.write_all(b"\r\n")
}

/// Terminal zero-length chunk closing a chunked response body.
fn end_chunks<W: Write>(stream: &mut W) -> io::Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()
}

fn push_u64(out: &mut Vec<u8>, mut v: u64) {
    let mut digits = [0u8; 20];
    let mut i = digits.len();
    loop {
        i -= 1;
        digits[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    out.extend_from_slice(&digits[i..]);
}

fn push_i64(out: &mut Vec<u8>, v: i64) {
    if v < 0 {
        out.push(b'-');
    }
    push_u64(out, v.unsigned_abs());
}

fn push_hex(out: &mut Vec<u8>, mut v: u64) {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut digits = [0u8; 16];
    let mut i = digits.len();
    loop {
        i -= 1;
        digits[i] = HEX[(v & 0xf) as usize];
        v >>= 4;
        if v == 0 {
            break;
        }
    }
    out.extend_from_slice(&digits[i..]);
}

// ---------------------------------------------------------------------------
// Simple (non-streaming) responses
// ---------------------------------------------------------------------------

/// Write a complete JSON response with `Content-Length` framing.
fn write_simple<W: Write>(
    stream: &mut W,
    status: u16,
    reason: &str,
    extra: &[(&str, &str)],
    body: &[u8],
) -> io::Result<()> {
    let mut head: Vec<u8> = Vec::with_capacity(160);
    head.extend_from_slice(b"HTTP/1.1 ");
    push_u64(&mut head, status as u64);
    head.push(b' ');
    head.extend_from_slice(reason.as_bytes());
    head.extend_from_slice(b"\r\nContent-Type: application/json\r\nContent-Length: ");
    push_u64(&mut head, body.len() as u64);
    head.extend_from_slice(b"\r\n");
    for (name, value) in extra {
        head.extend_from_slice(name.as_bytes());
        head.extend_from_slice(b": ");
        head.extend_from_slice(value.as_bytes());
        head.extend_from_slice(b"\r\n");
    }
    head.extend_from_slice(b"\r\n");
    stream.write_all(&head)?;
    stream.write_all(body)?;
    stream.flush()
}

/// `{"error":"<reason>"}` — `reason` must not contain `"` or `\`.
fn error_body(reason: &str) -> Vec<u8> {
    let mut body = Vec::with_capacity(reason.len() + 13);
    body.extend_from_slice(b"{\"error\":\"");
    body.extend_from_slice(reason.as_bytes());
    body.extend_from_slice(b"\"}");
    body
}

fn reason_phrase(status: u16) -> &'static str {
    match status {
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Content Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Error",
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Wire-layer knobs. [`HttpOpts::from_env`] reads the `HEAPR_*`
/// defaults; the `serve --http` flags override field-by-field.
#[derive(Clone, Debug)]
pub struct HttpOpts {
    /// Port to bind on 127.0.0.1; `0` asks the OS for an ephemeral port
    /// (read it back via [`HttpServer::local_addr`]).
    pub port: u16,
    /// Bounded admission queue: requests arriving while this many are
    /// in flight are shed with `429` + `Retry-After`. `0` = unbounded.
    pub max_queue: usize,
    /// Default per-request deadline; a request's `deadline_ms` JSON
    /// field overrides it. `None` = no deadline.
    pub deadline: Option<Duration>,
    /// Scheduler lane count (see [`SchedulerOpts::lanes`]).
    pub lanes: Option<usize>,
    /// Extent-grouped admission ([`Batcher::group_by_extent`]).
    pub group_extent: bool,
    /// Token budget for requests that do not send `max_new_tokens`.
    pub default_max_new_tokens: usize,
}

impl Default for HttpOpts {
    fn default() -> HttpOpts {
        HttpOpts {
            port: 0,
            max_queue: 64,
            deadline: None,
            lanes: None,
            group_extent: false,
            default_max_new_tokens: 16,
        }
    }
}

impl HttpOpts {
    /// Defaults from the environment: `HEAPR_HTTP_PORT` (default 8080),
    /// `HEAPR_MAX_QUEUE` (default 64; 0 = unbounded) and
    /// `HEAPR_DEADLINE_MS` (default unset = no deadline).
    pub fn from_env() -> HttpOpts {
        let port = std::env::var("HEAPR_HTTP_PORT")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(8080);
        let max_queue = std::env::var("HEAPR_MAX_QUEUE")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        let deadline = std::env::var("HEAPR_DEADLINE_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .filter(|&ms| ms > 0)
            .map(Duration::from_millis);
        HttpOpts { port, max_queue, deadline, ..HttpOpts::default() }
    }
}

/// What a completed [`HttpServer::serve`] run handled.
#[derive(Debug)]
pub struct HttpServeReport {
    /// One [`Response`] per retired request, in completion order —
    /// the same values the in-process serving paths return.
    pub responses: Vec<Response>,
    /// Requests admitted to the scheduler over the wire.
    pub admitted: usize,
    /// Requests refused with `429` by the bounded admission queue.
    pub shed: usize,
}

/// State shared between the accept loop, connection handlers and the
/// SSE dispatcher. All locks here are leaf locks: nothing is acquired
/// while one is held.
struct Wire {
    /// Per-request SSE routes: the dispatcher looks up the admitting
    /// handler's sender by request id and removes it on the final event.
    registry: Mutex<HashMap<RequestId, Sender<StreamEvent>>>,
    /// Admitted-but-not-retired count — the bounded queue's occupancy.
    in_flight: AtomicUsize,
    next_id: AtomicU64,
    admitted: AtomicU64,
    shed: AtomicU64,
    cancel: Arc<CancelSet>,
    shutdown: Arc<AtomicBool>,
    max_queue: usize,
    deadline: Option<Duration>,
    /// Longest admissible prompt: one decode position must remain.
    max_prompt: usize,
    default_budget: usize,
    max_budget: usize,
}

/// Poison-tolerant lock: a handler that panicked while holding the
/// registry must not wedge the rest of the wire layer.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A bound HTTP/1.1 front-end. [`HttpServer::bind`] grabs the port;
/// [`HttpServer::serve`] runs the accept loop + scheduler until the
/// shutdown flag ([`HttpServer::shutdown_handle`]) is raised, then
/// drains: new connections are refused, in-flight lanes run to
/// completion, and every wire thread is joined before returning.
pub struct HttpServer {
    listener: TcpListener,
    addr: SocketAddr,
    opts: HttpOpts,
    shutdown: Arc<AtomicBool>,
}

impl HttpServer {
    /// Bind `127.0.0.1:{opts.port}` (port 0 = OS-assigned).
    pub fn bind(opts: HttpOpts) -> Result<HttpServer> {
        let listener = TcpListener::bind(("127.0.0.1", opts.port))
            .with_context(|| format!("binding 127.0.0.1:{}", opts.port))?;
        let addr = listener.local_addr().context("listener local_addr")?;
        Ok(HttpServer { listener, addr, opts, shutdown: Arc::new(AtomicBool::new(false)) })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Raising this flag (from any thread) starts the graceful drain.
    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    /// Run the wire + scheduler until drained. The scheduler runs on
    /// the calling thread (it borrows the engine through `server`);
    /// accept/handler/dispatcher threads are joined before returning,
    /// so no wire thread outlives this call.
    pub fn serve(self, server: &mut Server<'_>) -> Result<HttpServeReport> {
        let cfg = server.engine().config().clone();
        let max_pos = cfg.seq_len.min(cfg.max_decode_len);
        let wire = Arc::new(Wire {
            registry: Mutex::new(HashMap::new()),
            in_flight: AtomicUsize::new(0),
            next_id: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            cancel: Arc::new(CancelSet::new()),
            shutdown: self.shutdown.clone(),
            max_queue: self.opts.max_queue,
            deadline: self.opts.deadline,
            max_prompt: max_pos.saturating_sub(1).max(1),
            default_budget: self.opts.default_max_new_tokens.max(1),
            max_budget: cfg.max_decode_len.max(1),
        });
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (ev_tx, ev_rx) = mpsc::channel::<StreamEvent>();

        let accept = {
            let wire = wire.clone();
            let listener = self.listener;
            pool::spawn_named("http-accept", move || accept_loop(listener, &wire, req_tx))
        };
        let dispatcher = {
            let wire = wire.clone();
            pool::spawn_named("http-dispatch", move || dispatch(ev_rx, &wire))
        };

        let mut batcher =
            Batcher::new(req_rx, cfg.serve_batches.clone(), Duration::from_millis(2))
                .group_by_extent(self.opts.group_extent);
        let opts = SchedulerOpts {
            lanes: self.opts.lanes,
            stream: Some(ev_tx),
            cancel: Some(wire.cancel.clone()),
            deadline: self.opts.deadline.map(|d| d + DEADLINE_BACKSTOP_SLACK),
            ..SchedulerOpts::default()
        };
        let outcome = serve_continuous(server, &mut batcher, opts);
        // whatever ended the serve loop — a drain or an engine error —
        // tear the wire down before reporting: raise the flag so accept
        // exits even on the error path (handlers then observe the
        // closed event channel and abort their streams)
        self.shutdown.store(true, Ordering::Release);
        accept.join().map_err(|_| anyhow!("http accept thread panicked"))?;
        dispatcher.join().map_err(|_| anyhow!("http dispatch thread panicked"))?;
        let responses = outcome?;
        Ok(HttpServeReport {
            responses,
            admitted: wire.admitted.load(Ordering::Relaxed) as usize,
            shed: wire.shed.load(Ordering::Relaxed) as usize,
        })
    }
}

/// Accept until shutdown; handlers are detached into their own threads
/// and joined here before the request channel closes, so the scheduler
/// only sees the queue end after every connection is done producing.
fn accept_loop(listener: TcpListener, wire: &Arc<Wire>, req_tx: Sender<Request>) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    loop {
        if wire.shutdown.load(Ordering::Acquire) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let wire = wire.clone();
                let tx = req_tx.clone();
                handlers.push(pool::spawn_named("http-conn", move || {
                    // lint:allow(swallowed-result) a torn connection fails only itself; the accept loop must outlive any one socket
                    let _ = handle_conn(stream, &wire, &tx);
                }));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
        // cap the handle table: completed connections are reaped as we go
        handlers.retain(|h| !h.is_finished());
    }
    // refuse new connections the moment drain starts…
    drop(listener);
    // …and only then wait out the in-flight ones; dropping `req_tx`
    // after this join is what lets the scheduler's queue drain
    for handle in handlers {
        // lint:allow(swallowed-result) a panicked handler already failed its own connection; drain must still complete
        let _ = handle.join();
    }
}

/// Route [`StreamEvent`]s to the handler that admitted each request.
/// On a final event the route is dropped and the in-flight count
/// decremented — whether or not a handler is still listening, so
/// abandoned streams (deadline, disconnect) cannot leak queue slots.
fn dispatch(ev_rx: Receiver<StreamEvent>, wire: &Wire) {
    for ev in ev_rx {
        let route = {
            let mut registry = lock(&wire.registry);
            if ev.done {
                registry.remove(&ev.id)
            } else {
                registry.get(&ev.id).cloned()
            }
        };
        if ev.done {
            wire.in_flight.fetch_sub(1, Ordering::AcqRel);
        }
        if let Some(tx) = route {
            // lint:allow(swallowed-result) the handler may have abandoned its stream (deadline expiry, client gone); orphaned events are dropped by design
            let _ = tx.send(ev);
        }
    }
    // the event channel closed: the scheduler has returned. Any route
    // still registered belongs to a stream that will never finish (the
    // engine-error path) — drop the senders so those handlers' receivers
    // disconnect and their connections abort instead of waiting forever.
    lock(&wire.registry).clear();
}

/// One connection: read → parse → respond, keep-alive until the peer
/// closes, a parse becomes fatal, or drain catches the socket idle.
fn handle_conn(mut stream: TcpStream, wire: &Wire, req_tx: &Sender<Request>) -> io::Result<()> {
    stream.set_read_timeout(Some(TICK))?;
    stream.set_nodelay(true)?;
    let mut parser = RequestParser::new();
    let mut rbuf = [0u8; 4096];
    // per-connection scratch reused across every streamed token
    let mut event_scratch: Vec<u8> = Vec::with_capacity(128);
    let mut chunk_scratch: Vec<u8> = Vec::with_capacity(32);
    let mut drain_seen: Option<Instant> = None;
    loop {
        // drain everything already buffered before reading again, so
        // pipelined requests are answered in order without more input
        loop {
            match parser.poll() {
                Parse::Pending => break,
                Parse::Bad(status, reason) => {
                    let body = error_body(reason);
                    write_simple(&mut stream, status, reason_phrase(status), &[], &body)?;
                    return Ok(());
                }
                Parse::Ready(req) => {
                    let keep = handle_request(
                        &mut stream,
                        wire,
                        req_tx,
                        &req,
                        &mut event_scratch,
                        &mut chunk_scratch,
                    )?;
                    if !keep {
                        return Ok(());
                    }
                }
            }
        }
        match stream.read(&mut rbuf) {
            Ok(0) => return Ok(()), // peer closed
            Ok(n) => parser.feed(&rbuf[..n]),
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut
                    || e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
        if wire.shutdown.load(Ordering::Acquire) {
            // drain: close idle keep-alive connections immediately; a
            // connection caught mid-request gets a bounded grace to
            // finish sending, then is closed under it
            let since = *drain_seen.get_or_insert_with(Instant::now);
            if parser.is_idle() || since.elapsed() >= DRAIN_GRACE {
                return Ok(());
            }
        }
    }
}

/// Dispatch one parsed request to its route.
fn handle_request(
    stream: &mut TcpStream,
    wire: &Wire,
    req_tx: &Sender<Request>,
    req: &HttpRequest,
    event_scratch: &mut Vec<u8>,
    chunk_scratch: &mut Vec<u8>,
) -> io::Result<bool> {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/generate") => {
            handle_generate(stream, wire, req_tx, req, event_scratch, chunk_scratch)
        }
        ("GET", "/healthz") => {
            let mut body = Vec::with_capacity(48);
            body.extend_from_slice(b"{\"status\":\"ok\",\"in_flight\":");
            push_u64(&mut body, wire.in_flight.load(Ordering::Acquire) as u64);
            body.extend_from_slice(b"}");
            write_simple(stream, 200, "OK", &[], &body)?;
            Ok(req.keep_alive)
        }
        (_, "/generate") => {
            write_simple(
                stream,
                405,
                reason_phrase(405),
                &[("Allow", "POST")],
                &error_body("use POST"),
            )?;
            Ok(req.keep_alive)
        }
        (_, "/healthz") => {
            write_simple(
                stream,
                405,
                reason_phrase(405),
                &[("Allow", "GET")],
                &error_body("use GET"),
            )?;
            Ok(req.keep_alive)
        }
        _ => {
            write_simple(stream, 404, reason_phrase(404), &[], &error_body("unknown path"))?;
            Ok(req.keep_alive)
        }
    }
}

/// A validated `/generate` body.
struct Generate {
    prompt: Vec<i32>,
    max_new_tokens: usize,
    deadline: Option<Duration>,
}

/// Decode + validate a `/generate` JSON body. Every reject is a `400`
/// message; nothing here can panic on arbitrary JSON.
fn parse_generate(body: &[u8], wire: &Wire) -> std::result::Result<Generate, String> {
    let text =
        std::str::from_utf8(body).map_err(|_| "request body is not valid UTF-8".to_string())?;
    let json = Json::parse(text).map_err(|e| format!("invalid JSON body: {e}"))?;
    let prompt: Vec<i32> = if let Some(tokens) = json.opt("prompt") {
        let items = tokens.as_arr().map_err(|_| "prompt must be an array".to_string())?;
        let mut prompt = Vec::with_capacity(items.len());
        for item in items {
            let v = item.as_f64().map_err(|_| "prompt tokens must be numbers".to_string())?;
            if v.fract() != 0.0 || v < 0.0 || v >= VOCAB as f64 {
                return Err(format!("prompt tokens must be integers in 0..{VOCAB}"));
            }
            prompt.push(v as i32);
        }
        prompt
    } else if let Some(text) = json.opt("text") {
        let s = text.as_str().map_err(|_| "text must be a string".to_string())?;
        ByteTokenizer.encode(s)
    } else {
        return Err("body needs a prompt (token array) or text (string)".to_string());
    };
    if prompt.is_empty() {
        return Err("prompt must be non-empty".to_string());
    }
    if prompt.len() > wire.max_prompt {
        return Err(format!("prompt too long: {} tokens (max {})", prompt.len(), wire.max_prompt));
    }
    let max_new_tokens = match json.opt("max_new_tokens") {
        Some(n) => n
            .as_usize()
            .map_err(|_| "max_new_tokens must be a non-negative integer".to_string())?,
        None => wire.default_budget,
    };
    let max_new_tokens = max_new_tokens.clamp(1, wire.max_budget);
    let deadline = match json.opt("deadline_ms") {
        Some(n) => {
            let ms =
                n.as_usize().map_err(|_| "deadline_ms must be a non-negative integer".to_string())?;
            (ms > 0).then(|| Duration::from_millis(ms as u64))
        }
        None => wire.deadline,
    };
    Ok(Generate { prompt, max_new_tokens, deadline })
}

const SSE_HEAD: &[u8] = b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nTransfer-Encoding: chunked\r\n\r\n";

/// `POST /generate`: shed or admit, then stream tokens as SSE events
/// over chunked transfer encoding until the final token, deadline
/// expiry, or a dead client.
fn handle_generate(
    stream: &mut TcpStream,
    wire: &Wire,
    req_tx: &Sender<Request>,
    req: &HttpRequest,
    event_scratch: &mut Vec<u8>,
    chunk_scratch: &mut Vec<u8>,
) -> io::Result<bool> {
    if wire.shutdown.load(Ordering::Acquire) {
        write_simple(stream, 503, reason_phrase(503), &[], &error_body("draining"))?;
        return Ok(false);
    }
    // load shedding before any parsing work: refusal must stay cheap
    if wire.max_queue > 0 && wire.in_flight.load(Ordering::Acquire) >= wire.max_queue {
        wire.shed.fetch_add(1, Ordering::Relaxed);
        write_simple(
            stream,
            429,
            reason_phrase(429),
            &[("Retry-After", "1")],
            &error_body("admission queue full"),
        )?;
        return Ok(req.keep_alive);
    }
    let spec = match parse_generate(&req.body, wire) {
        Ok(spec) => spec,
        Err(msg) => {
            write_simple(stream, 400, reason_phrase(400), &[], &error_body(&msg))?;
            return Ok(req.keep_alive);
        }
    };
    let id = wire.next_id.fetch_add(1, Ordering::Relaxed);
    let (tx, rx) = mpsc::channel::<StreamEvent>();
    // route first, then submit: the dispatcher must be able to deliver
    // the very first event
    lock(&wire.registry).insert(id, tx);
    wire.in_flight.fetch_add(1, Ordering::AcqRel);
    let submitted = Instant::now();
    if req_tx.send(Request::new(id, spec.prompt, spec.max_new_tokens)).is_err() {
        // the scheduler is gone (drain raced this admission): undo
        lock(&wire.registry).remove(&id);
        wire.in_flight.fetch_sub(1, Ordering::AcqRel);
        write_simple(stream, 503, reason_phrase(503), &[], &error_body("draining"))?;
        return Ok(false);
    }
    wire.admitted.fetch_add(1, Ordering::Relaxed);
    debug!("http: admitted request {id} ({} in flight)", wire.in_flight.load(Ordering::Acquire));
    stream.write_all(SSE_HEAD)?;
    stream_tokens(stream, &rx, wire, id, submitted, spec.deadline, event_scratch, chunk_scratch)?;
    Ok(req.keep_alive)
}

/// Pump one request's [`StreamEvent`]s to the client as SSE chunks.
/// Ends on the final token, on deadline expiry (final error event +
/// scheduler-side cancellation, so the lane retires leak-free), or on
/// a write failure (client gone — also cancels the lane).
#[allow(clippy::too_many_arguments)]
fn stream_tokens(
    stream: &mut TcpStream,
    rx: &Receiver<StreamEvent>,
    wire: &Wire,
    id: RequestId,
    submitted: Instant,
    deadline: Option<Duration>,
    event_scratch: &mut Vec<u8>,
    chunk_scratch: &mut Vec<u8>,
) -> io::Result<()> {
    loop {
        if deadline.is_some_and(|d| submitted.elapsed() >= d) {
            // terminate the stream mid-flight; the scheduler consumes
            // the cancellation at the lane's next commit and retires it
            wire.cancel.request(id);
            write_error_event(event_scratch, id, "deadline");
            write_chunk(stream, chunk_scratch, event_scratch)?;
            return end_chunks(stream);
        }
        let wait = match deadline {
            Some(d) => d.saturating_sub(submitted.elapsed()).min(TICK),
            None => TICK,
        };
        match rx.recv_timeout(wait) {
            Ok(ev) => {
                write_event(event_scratch, &ev);
                if let Err(e) = write_chunk(stream, chunk_scratch, event_scratch) {
                    // client went away mid-stream: stop decoding for it
                    wire.cancel.request(id);
                    return Err(e);
                }
                if ev.done {
                    return end_chunks(stream);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                // the scheduler ended without finishing this stream
                // (engine error path): tell the client, close cleanly
                write_error_event(event_scratch, id, "aborted");
                write_chunk(stream, chunk_scratch, event_scratch)?;
                return end_chunks(stream);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Open-loop load schedule
// ---------------------------------------------------------------------------

/// Deterministic open-loop Poisson arrival clock: yields cumulative
/// arrival offsets (seconds from t=0) with exponential inter-arrival
/// gaps at `qps`. A pure function of the seed — identical across runs,
/// machines and thread counts — so `bench_load`'s offered-load legs
/// are reproducible ([`Pcg64`] is the repo's only entropy source).
#[derive(Clone, Debug)]
pub struct PoissonSchedule {
    rng: Pcg64,
    mean_gap_s: f64,
    t_s: f64,
}

impl PoissonSchedule {
    /// `qps` is the offered arrival rate; clamped away from zero.
    pub fn new(seed: u64, qps: f64) -> PoissonSchedule {
        PoissonSchedule {
            // own stream constant: arrival times must not correlate
            // with any other consumer of the same seed
            rng: Pcg64::with_stream(seed, 0x4c4f_4144),
            mean_gap_s: 1.0 / qps.max(1e-9),
            t_s: 0.0,
        }
    }
}

impl Iterator for PoissonSchedule {
    type Item = f64;

    fn next(&mut self) -> Option<f64> {
        // inverse-CDF exponential gap; f64() < 1.0 so the log is finite
        let u = self.rng.f64();
        // lint:allow(float-accum-order) the arrival clock is a sequential running sum by definition — the order *is* the semantics, not a reduction choice
        self.t_s += -(1.0 - u).ln() * self.mean_gap_s;
        Some(self.t_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_all(bytes: &[u8]) -> Vec<Parse> {
        let mut p = RequestParser::new();
        p.feed(bytes);
        let mut out = Vec::new();
        loop {
            match p.poll() {
                Parse::Pending => break,
                done @ Parse::Bad(..) => {
                    out.push(done);
                    break;
                }
                ready => out.push(ready),
            }
        }
        out
    }

    #[test]
    fn parses_simple_post() {
        let raw = b"POST /generate HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi";
        let got = parse_all(raw);
        assert_eq!(got.len(), 1);
        let Parse::Ready(req) = &got[0] else { panic!("expected Ready, got {got:?}") };
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/generate");
        assert_eq!(req.body, b"hi");
        assert!(req.keep_alive);
    }

    #[test]
    fn pipelined_requests_parse_in_order() {
        let raw = b"GET /healthz HTTP/1.1\r\n\r\nPOST /generate HTTP/1.1\r\nContent-Length: 3\r\n\r\nabcGET /healthz HTTP/1.0\r\n\r\n";
        let got = parse_all(raw);
        assert_eq!(got.len(), 3, "{got:?}");
        let Parse::Ready(r1) = &got[1] else { panic!() };
        assert_eq!(r1.body, b"abc");
        let Parse::Ready(r2) = &got[2] else { panic!() };
        assert!(!r2.keep_alive, "HTTP/1.0 defaults to close");
    }

    #[test]
    fn segmentation_invariance_on_a_small_request() {
        let raw = b"POST /generate HTTP/1.1\r\nContent-Length: 5\r\nConnection: close\r\n\r\n[1,2]";
        let whole = parse_all(raw);
        for cut in 1..raw.len() {
            let mut p = RequestParser::new();
            p.feed(&raw[..cut]);
            let mut got = Vec::new();
            loop {
                match p.poll() {
                    Parse::Pending => break,
                    other => got.push(other),
                }
            }
            p.feed(&raw[cut..]);
            loop {
                match p.poll() {
                    Parse::Pending => break,
                    other => {
                        got.push(other);
                        break;
                    }
                }
            }
            assert_eq!(got, whole, "split at {cut}");
        }
    }

    #[test]
    fn rejects_garbage_with_400_family() {
        for raw in [
            &b"\x00\xff\xfe\r\n\r\n"[..],
            &b"GET\r\n\r\n"[..],
            &b"GET / HTTP/2.0\r\n\r\n"[..],
            &b"GET / HTTP/1.1\r\nBad Header: x\r\n\r\n"[..],
            &b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n"[..],
            &b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"[..],
        ] {
            let got = parse_all(raw);
            assert!(
                matches!(got.last(), Some(Parse::Bad(400..=505, _))),
                "{:?} -> {got:?}",
                String::from_utf8_lossy(raw)
            );
        }
    }

    #[test]
    fn oversized_head_is_431_even_without_terminator() {
        let mut p = RequestParser::new();
        // 3 trailing bytes could still be a partial terminator of a
        // cap-sized head, so this is (barely) pending…
        p.feed(&[b'A'; MAX_HEAD_BYTES + 3]);
        assert!(matches!(p.poll(), Parse::Pending));
        // …and one more byte proves the head cannot fit the cap
        p.feed(b"A");
        assert!(matches!(p.poll(), Parse::Bad(431, _)));
        // terminal: stays bad, discards further input
        p.feed(b"GET / HTTP/1.1\r\n\r\n");
        assert!(matches!(p.poll(), Parse::Bad(431, _)));
    }

    #[test]
    fn cap_sized_head_parses_even_when_cut_mid_terminator() {
        // head_end == MAX_HEAD_BYTES exactly: the largest legal head,
        // with the read boundary landing inside `\r\n\r\n`
        let mut raw = b"GET / HTTP/1.1\r\nX-Pad: ".to_vec();
        raw.resize(MAX_HEAD_BYTES, b'a');
        raw.extend_from_slice(b"\r\n\r\n");
        for cut in [MAX_HEAD_BYTES + 1, MAX_HEAD_BYTES + 2, MAX_HEAD_BYTES + 3] {
            let mut p = RequestParser::new();
            p.feed(&raw[..cut]);
            assert!(matches!(p.poll(), Parse::Pending), "cut at {cut}");
            p.feed(&raw[cut..]);
            assert!(matches!(p.poll(), Parse::Ready(_)), "cut at {cut}");
        }
    }

    #[test]
    fn oversized_declared_body_is_413() {
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        let got = parse_all(raw.as_bytes());
        assert!(matches!(got.last(), Some(Parse::Bad(413, _))), "{got:?}");
    }

    #[test]
    fn sse_event_bytes_are_exact() {
        let mut out = Vec::new();
        write_event(&mut out, &StreamEvent { id: 7, index: 0, token: -3, done: false });
        assert_eq!(out, b"data: {\"id\":7,\"index\":0,\"token\":-3,\"done\":false}\n\n");
        write_event(&mut out, &StreamEvent { id: 12, index: 41, token: 258, done: true });
        assert_eq!(out, b"data: {\"id\":12,\"index\":41,\"token\":258,\"done\":true}\n\n");
    }

    #[test]
    fn chunk_framing_is_exact() {
        let mut sink: Vec<u8> = Vec::new();
        let mut head = Vec::new();
        write_chunk(&mut sink, &mut head, b"0123456789abcdef").unwrap();
        assert_eq!(sink, b"10\r\n0123456789abcdef\r\n");
        end_chunks(&mut sink).unwrap();
        assert!(sink.ends_with(b"0\r\n\r\n"));
    }

    #[test]
    fn poisson_schedule_is_a_pure_function_of_the_seed() {
        let a: Vec<f64> = PoissonSchedule::new(9, 25.0).take(64).collect();
        let b: Vec<f64> = PoissonSchedule::new(9, 25.0).take(64).collect();
        assert_eq!(a, b);
        let c: Vec<f64> = PoissonSchedule::new(10, 25.0).take(64).collect();
        assert_ne!(a, c);
        assert!(a.windows(2).all(|w| w[1] >= w[0]), "arrival times are monotone");
        // mean gap converges on 1/qps (loose bound, 64 samples)
        let mean = a.last().unwrap() / a.len() as f64;
        assert!((0.2..5.0).contains(&(mean * 25.0)), "mean gap {mean} at 25 qps");
    }
}
