//! Serving coordinator: request queue + admission policy ([`batcher`]),
//! rust-side routing ([`router`]), the per-layer serving composition and
//! the batch-synchronous reference loop ([`serve`]), the shared-prefix
//! admission index ([`prefix`]), the continuous-batching scheduler
//! with in-flight admission and prefix-hit seating ([`scheduler`]),
//! and the dependency-free HTTP/1.1 wire layer with SSE token
//! streaming, load shedding and graceful drain ([`http`]).

pub mod batcher;
pub mod http;
pub mod prefix;
pub mod router;
pub mod scheduler;
pub mod serve;

pub use batcher::{AdmissionPolicy, Batcher, Request, RequestId};
pub use http::{HttpOpts, HttpServeReport, HttpServer, PoissonSchedule, RequestParser};
pub use prefix::PrefixIndex;
pub use router::Router;
pub use scheduler::{serve_continuous, CancelSet, Scheduler, SchedulerOpts, StreamEvent};
pub use serve::{DecodeState, Residency, Response, ServeMetrics, Server};
