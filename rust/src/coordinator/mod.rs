//! Serving coordinator: request queue + admission policy ([`batcher`]),
//! rust-side routing ([`router`]), the per-layer serving composition and
//! the batch-synchronous reference loop ([`serve`]), and the
//! continuous-batching scheduler with in-flight admission
//! ([`scheduler`]).

pub mod batcher;
pub mod router;
pub mod scheduler;
pub mod serve;

pub use batcher::{AdmissionPolicy, Batcher, Request, RequestId};
pub use router::Router;
pub use scheduler::{serve_continuous, Scheduler, SchedulerOpts, StreamEvent};
pub use serve::{DecodeState, Residency, Response, ServeMetrics, Server};
