//! Serving coordinator (filled in by `engine.rs`/`batcher.rs`/`router.rs`).

pub mod batcher;
pub mod router;
pub mod serve;

pub use batcher::{Batcher, Request, RequestId};
pub use router::Router;
pub use serve::{DecodeState, Residency, ServeMetrics, Server};
