//! Request queue + continuous batcher.
//!
//! Producer threads submit [`Request`]s over an mpsc channel; the serving
//! loop drains the queue into the largest serve-batch bucket that fits,
//! waiting up to `max_wait` for stragglers — the standard continuous-
//! batching trade-off between latency and occupancy.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

pub type RequestId = u64;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub submitted: Instant,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request { id, prompt, max_new_tokens, submitted: Instant::now() }
    }

    /// Worst-case sequence extent: prompt plus full generation budget.
    /// This is what sizes a batch's resident KV capacity (the serving
    /// session allocates `max` extent over the batch).
    pub fn extent(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }
}

pub struct Batcher {
    rx: Receiver<Request>,
    pending: VecDeque<Request>,
    /// serve-batch buckets, ascending (from the manifest preset).
    buckets: Vec<usize>,
    pub max_wait: Duration,
    group_by_extent: bool,
}

impl Batcher {
    /// A misconfigured empty bucket list is *defaulted* to `[1]` (with a
    /// warning) rather than asserted on: the failure used to surface as
    /// a `buckets.last().unwrap()` panic in the middle of
    /// [`Batcher::next_batch`], taking the serving loop down long after
    /// the bad config was accepted. Serving degraded (batch size 1)
    /// beats serving down.
    pub fn new(rx: Receiver<Request>, mut buckets: Vec<usize>, max_wait: Duration) -> Batcher {
        buckets.sort_unstable();
        if buckets.is_empty() {
            crate::warn!("Batcher built with an empty bucket list; defaulting to [1]");
            buckets.push(1);
        }
        Batcher {
            rx,
            pending: VecDeque::new(),
            buckets,
            max_wait,
            group_by_extent: false,
        }
    }

    /// Opt into extent grouping: when more requests are pending than fit
    /// one bucket, pick the window of most-similar [`Request::extent`]s
    /// instead of strict FIFO, so the batch's resident KV capacity (its
    /// max extent) wastes the least memory and stragglers don't pin short
    /// requests to long decode loops. Trades global FIFO order (still
    /// lossless, still FIFO within a batch) for occupancy; leave off when
    /// arrival order must be preserved across batches.
    pub fn group_by_extent(mut self, on: bool) -> Batcher {
        self.group_by_extent = on;
        self
    }

    /// Largest bucket <= n, or the smallest bucket when n > 0 (padding).
    pub fn bucket_for(&self, n: usize) -> usize {
        assert!(n > 0);
        self.buckets
            .iter()
            .rev()
            .find(|&&b| b <= n)
            .copied()
            .unwrap_or(self.buckets[0])
    }

    fn drain_channel(&mut self) {
        while let Ok(r) = self.rx.try_recv() {
            self.pending.push_back(r);
        }
    }

    /// Block for the next batch; returns None when the channel closed and
    /// the queue is empty. Never drops or duplicates a request; order is
    /// FIFO within the queue (globally FIFO unless
    /// [`Batcher::group_by_extent`] is on, in which case only the order
    /// within a batch is arrival order).
    pub fn next_batch(&mut self) -> Option<Vec<Request>> {
        self.drain_channel();
        if self.pending.is_empty() {
            match self.rx.recv() {
                Ok(r) => self.pending.push_back(r),
                Err(_) => return None,
            }
            self.drain_channel();
        }
        // wait briefly for a fuller bucket (buckets is non-empty by
        // construction — see `new` — so `last` cannot fail mid-serve)
        let largest = self.buckets.last().copied().unwrap_or(1);
        let deadline = Instant::now() + self.max_wait;
        while self.pending.len() < largest {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(r) => self.pending.push_back(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
            self.drain_channel();
        }
        let take = self.bucket_for(self.pending.len()).min(self.pending.len());
        if !self.group_by_extent || take == self.pending.len() {
            return Some(self.pending.drain(..take).collect());
        }
        // extent grouping: scan extent-sorted windows of width `take` for
        // the smallest extent spread; ties keep the lowest-extent window
        // (short requests drain first). Within a window, the stable sort
        // preserves arrival order among equal extents.
        let mut order: Vec<usize> = (0..self.pending.len()).collect();
        order.sort_by_key(|&i| self.pending[i].extent());
        let mut best = 0usize;
        let mut best_spread = usize::MAX;
        for w in 0..=order.len() - take {
            let spread = self.pending[order[w + take - 1]].extent()
                - self.pending[order[w]].extent();
            if spread < best_spread {
                best_spread = spread;
                best = w;
            }
        }
        let mut picked: Vec<usize> = order[best..best + take].to_vec();
        picked.sort_unstable(); // arrival order within the batch
        let mut batch = Vec::with_capacity(take);
        for &i in picked.iter().rev() {
            batch.push(self.pending.remove(i).unwrap());
        }
        batch.reverse();
        Some(batch)
    }

    pub fn queue_len(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn mk(buckets: Vec<usize>) -> (std::sync::mpsc::Sender<Request>, Batcher) {
        let (tx, rx) = channel();
        let b = Batcher::new(rx, buckets, Duration::from_millis(5));
        (tx, b)
    }

    #[test]
    fn bucket_selection() {
        let (_tx, b) = mk(vec![1, 4, 8]);
        assert_eq!(b.bucket_for(1), 1);
        assert_eq!(b.bucket_for(3), 1);
        assert_eq!(b.bucket_for(4), 4);
        assert_eq!(b.bucket_for(7), 4);
        assert_eq!(b.bucket_for(100), 8);
    }

    #[test]
    fn batches_are_fifo_and_lossless() {
        let (tx, mut b) = mk(vec![1, 4]);
        for i in 0..6 {
            tx.send(Request::new(i, vec![1], 4)).unwrap();
        }
        drop(tx);
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() == 1 || batch.len() == 4);
            seen.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_bucket_list_defaults_instead_of_panicking() {
        // regression: an empty bucket config used to blow up in
        // next_batch (buckets.last().unwrap()) mid-serve; it now degrades
        // to batch-size-1 service at construction
        let (tx, mut b) = mk(vec![]);
        assert_eq!(b.bucket_for(1), 1);
        assert_eq!(b.bucket_for(100), 1);
        for i in 0..3 {
            tx.send(Request::new(i, vec![1], 1)).unwrap();
        }
        drop(tx);
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert_eq!(batch.len(), 1);
            seen.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(seen, vec![0, 1, 2]);
    }

    #[test]
    fn closed_empty_returns_none() {
        let (tx, mut b) = mk(vec![1]);
        drop(tx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn fifo_per_producer_under_concurrent_producers() {
        // Several producer threads share the channel. Global arrival order
        // is scheduler-dependent, but the batcher must (a) never drop or
        // duplicate, and (b) preserve each producer's submission order
        // (mpsc is per-sender FIFO; draining must keep it that way).
        const PRODUCERS: u64 = 4;
        const PER_PRODUCER: u64 = 50;
        let (tx, rx) = channel();
        let mut b = Batcher::new(rx, vec![1, 4, 8], Duration::from_millis(1));
        let mut handles = Vec::new();
        for p in 0..PRODUCERS {
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || {
                for j in 0..PER_PRODUCER {
                    tx.send(Request::new(p * 1000 + j, vec![1], 1)).unwrap();
                }
            }));
        }
        drop(tx);
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            seen.extend(batch.iter().map(|r| r.id));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(seen.len() as u64, PRODUCERS * PER_PRODUCER);
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len() as u64, PRODUCERS * PER_PRODUCER, "duplicates");
        for p in 0..PRODUCERS {
            let mine: Vec<u64> = seen.iter().copied()
                .filter(|id| id / 1000 == p)
                .collect();
            assert!(
                mine.windows(2).all(|w| w[0] < w[1]),
                "producer {p} order violated: {mine:?}"
            );
        }
    }

    #[test]
    fn extent_grouping_packs_similar_requests() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(rx, vec![2], Duration::from_millis(0)).group_by_extent(true);
        // two long and two short requests, interleaved by arrival
        tx.send(Request::new(0, vec![1; 40], 40)).unwrap(); // extent 80
        tx.send(Request::new(1, vec![1; 4], 4)).unwrap(); // extent 8
        tx.send(Request::new(2, vec![1; 42], 40)).unwrap(); // extent 82
        tx.send(Request::new(3, vec![1; 6], 4)).unwrap(); // extent 10
        drop(tx);
        let first = b.next_batch().unwrap();
        let second = b.next_batch().unwrap();
        assert!(b.next_batch().is_none());
        let ids = |v: &[Request]| v.iter().map(|r| r.id).collect::<Vec<_>>();
        // lossless, and each batch holds the similar-extent pair, in
        // arrival order within the batch
        assert_eq!(ids(&first), vec![1, 3]);
        assert_eq!(ids(&second), vec![0, 2]);
    }

    #[test]
    fn extent_grouping_off_preserves_fifo() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(rx, vec![2], Duration::from_millis(0));
        tx.send(Request::new(0, vec![1; 40], 40)).unwrap();
        tx.send(Request::new(1, vec![1; 4], 4)).unwrap();
        tx.send(Request::new(2, vec![1; 42], 40)).unwrap();
        drop(tx);
        let first = b.next_batch().unwrap();
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn request_extent_is_prompt_plus_budget() {
        assert_eq!(Request::new(0, vec![1; 7], 5).extent(), 12);
    }

    #[test]
    fn prop_batcher_never_drops() {
        use crate::util::prop::check;
        check("batcher-lossless", 20,
              |g| {
                  let n = g.usize_in(1, 40);
                  let buckets = match g.usize_in(0, 2) {
                      0 => vec![1],
                      1 => vec![1, 4],
                      _ => vec![2, 8],
                  };
                  (n, buckets)
              },
              |&(n, ref buckets)| {
                  let (tx, rx) = channel();
                  let mut b = Batcher::new(rx, buckets.clone(),
                                           Duration::from_millis(0));
                  for i in 0..n as u64 {
                      tx.send(Request::new(i, vec![1], 1)).unwrap();
                  }
                  drop(tx);
                  let mut ids = Vec::new();
                  while let Some(batch) = b.next_batch() {
                      ids.extend(batch.iter().map(|r| r.id));
                  }
                  ids.len() == n && ids.windows(2).all(|w| w[0] < w[1])
              });
    }
}
