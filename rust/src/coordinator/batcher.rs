//! Request queue + admission policy.
//!
//! Producer threads submit [`Request`]s over an mpsc channel. Two serve
//! loops consume the queue:
//!
//! * **batch-at-once** ([`Batcher::next_batch`]) — drain into the
//!   largest serve-batch bucket that fits, waiting up to `max_wait` for
//!   stragglers, and hand the closed batch to `Server::serve_batch`.
//! * **continuous** ([`Batcher::take_ready`] / [`Batcher::wait_ready`])
//!   — the scheduler asks for "up to `k` requests for the lanes that
//!   just freed", non-blocking while other lanes are mid-decode so the
//!   queue can never stall a running step.
//!
//! Both paths pick requests through one [`AdmissionPolicy`]: strict
//! FIFO, or extent grouping (pack requests of similar
//! `prompt + max_new_tokens` so a batch's resident KV capacity wastes
//! the least memory). Extent grouping is bounded by an anti-starvation
//! override: the request at the head of the queue can be passed over at
//! most [`Batcher::max_skip_rounds`] consecutive picks before admission
//! falls back to strict FIFO — so a lone large-extent request cannot be
//! deferred indefinitely by a stream of small ones (regression-tested
//! below). Since every starving request eventually reaches the head as
//! the requests ahead of it drain, its total wait is bounded too.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

pub type RequestId = u64;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub submitted: Instant,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request { id, prompt, max_new_tokens, submitted: Instant::now() }
    }

    /// Worst-case sequence extent: prompt plus full generation budget.
    /// This is what sizes a batch's resident KV capacity (the serving
    /// session allocates `max` extent over the batch).
    pub fn extent(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }

    /// [`Request::extent`] as a page budget: the page-table length that
    /// covers this request's worst case under paged KV residency. Unlike
    /// a dense lane, this is a *bound*, not an allocation — pages
    /// materialize only as rows are written.
    pub fn page_budget(&self, page: usize) -> usize {
        self.extent().div_ceil(page.max(1))
    }
}

/// How pending requests are picked when more are queued than fit the
/// batch (or the free lanes) at hand.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Strict arrival order, across batches and within them.
    Fifo,
    /// Pick the window of most-similar [`Request::extent`]s, so the
    /// resident KV capacity (the batch's max extent) wastes the least
    /// memory and stragglers don't pin short requests to long decode
    /// loops. Arrival order is preserved *within* a pick, and the
    /// anti-starvation override (see the module docs) bounds how long
    /// the queue head can be passed over.
    GroupExtent,
}

pub struct Batcher {
    rx: Receiver<Request>,
    pending: VecDeque<Request>,
    /// serve-batch buckets, ascending (from the manifest preset).
    buckets: Vec<usize>,
    pub max_wait: Duration,
    policy: AdmissionPolicy,
    /// True once the producer channel disconnected (observed by any
    /// receive); with `pending` empty this means the queue is drained
    /// for good.
    closed: bool,
    /// Anti-starvation bound: how many consecutive picks may pass over
    /// the request at the head of the queue before admission falls back
    /// to strict FIFO. Only consulted under
    /// [`AdmissionPolicy::GroupExtent`].
    pub max_skip_rounds: usize,
    /// (head request id, times passed over) for the starvation bound.
    starve: Option<(RequestId, usize)>,
}

impl Batcher {
    /// A misconfigured empty bucket list is *defaulted* to `[1]` (with a
    /// warning) rather than asserted on: the failure used to surface as
    /// a `buckets.last().unwrap()` panic in the middle of
    /// [`Batcher::next_batch`], taking the serving loop down long after
    /// the bad config was accepted. Serving degraded (batch size 1)
    /// beats serving down.
    pub fn new(rx: Receiver<Request>, mut buckets: Vec<usize>, max_wait: Duration) -> Batcher {
        buckets.sort_unstable();
        if buckets.is_empty() {
            crate::warn!("Batcher built with an empty bucket list; defaulting to [1]");
            buckets.push(1);
        }
        Batcher {
            rx,
            pending: VecDeque::new(),
            buckets,
            max_wait,
            policy: AdmissionPolicy::Fifo,
            closed: false,
            max_skip_rounds: 4,
            starve: None,
        }
    }

    /// Select the admission policy (builder-style).
    pub fn admission(mut self, policy: AdmissionPolicy) -> Batcher {
        self.policy = policy;
        self
    }

    pub fn policy(&self) -> AdmissionPolicy {
        self.policy
    }

    /// Opt into extent grouping — sugar for
    /// [`Batcher::admission`]`(`[`AdmissionPolicy::GroupExtent`]`)`.
    /// Trades global FIFO order (still lossless, still FIFO within a
    /// batch, starvation-bounded — see the module docs) for occupancy;
    /// leave off when arrival order must be preserved across batches.
    pub fn group_by_extent(self, on: bool) -> Batcher {
        self.admission(if on { AdmissionPolicy::GroupExtent } else { AdmissionPolicy::Fifo })
    }

    /// Largest bucket <= n, or the smallest bucket when n > 0 (padding).
    pub fn bucket_for(&self, n: usize) -> usize {
        assert!(n > 0);
        self.buckets
            .iter()
            .rev()
            .find(|&&b| b <= n)
            .copied()
            .unwrap_or(self.buckets[0])
    }

    fn drain_channel(&mut self) {
        loop {
            match self.rx.try_recv() {
                Ok(r) => self.pending.push_back(r),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    self.closed = true;
                    break;
                }
            }
        }
    }

    /// Block for the next batch; returns None when the channel closed and
    /// the queue is empty. Never drops or duplicates a request; order is
    /// FIFO within the queue (globally FIFO under
    /// [`AdmissionPolicy::Fifo`]; under [`AdmissionPolicy::GroupExtent`]
    /// only the order within a batch is arrival order).
    pub fn next_batch(&mut self) -> Option<Vec<Request>> {
        self.drain_channel();
        if self.pending.is_empty() {
            if self.closed {
                return None;
            }
            match self.rx.recv() {
                Ok(r) => self.pending.push_back(r),
                Err(_) => {
                    self.closed = true;
                    return None;
                }
            }
            self.drain_channel();
        }
        // wait briefly for a fuller bucket (buckets is non-empty by
        // construction — see `new` — so `last` cannot fail mid-serve)
        let largest = self.buckets.last().copied().unwrap_or(1);
        self.fill_until(largest);
        let take = self.bucket_for(self.pending.len()).min(self.pending.len());
        Some(self.pick(take))
    }

    /// Linger up to `max_wait` for the queue to reach `want` requests.
    fn fill_until(&mut self, want: usize) {
        let deadline = Instant::now() + self.max_wait;
        while self.pending.len() < want && !self.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(r) => self.pending.push_back(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    self.closed = true;
                    break;
                }
            }
            self.drain_channel();
        }
    }

    /// Non-blocking admission feed: up to `max` requests per the
    /// admission policy, empty when nothing is pending. The continuous
    /// scheduler calls this while other lanes are mid-decode, so it must
    /// never wait on the channel.
    pub fn take_ready(&mut self, max: usize) -> Vec<Request> {
        self.drain_channel();
        let take = max.min(self.pending.len());
        self.pick(take)
    }

    /// Blocking admission feed for an idle scheduler: wait for at least
    /// one pending request (or channel close), linger up to `max_wait`
    /// for up to `max` of them (the same latency/occupancy trade-off as
    /// [`Batcher::next_batch`]), then pick per the admission policy.
    /// An empty result means the queue is drained for good.
    pub fn wait_ready(&mut self, max: usize) -> Vec<Request> {
        self.drain_channel();
        if self.pending.is_empty() {
            if self.closed {
                return Vec::new();
            }
            match self.rx.recv() {
                Ok(r) => self.pending.push_back(r),
                Err(_) => {
                    self.closed = true;
                    return Vec::new();
                }
            }
            self.drain_channel();
        }
        self.fill_until(max);
        let take = max.min(self.pending.len());
        self.pick(take)
    }

    /// True once the producer channel closed and every request was taken.
    pub fn drained(&mut self) -> bool {
        self.drain_channel();
        self.closed && self.pending.is_empty()
    }

    /// Take `take` pending requests per the admission policy. FIFO (and
    /// extent grouping asked for the whole queue) drain in arrival
    /// order; extent grouping scans extent-sorted windows of width
    /// `take` for the smallest extent spread — ties keep the
    /// lowest-extent window (short requests drain first), the stable
    /// sort preserves arrival order among equal extents, and the pick is
    /// returned in arrival order. The anti-starvation override forces a
    /// strict-FIFO pick once the queue head has been passed over
    /// [`Batcher::max_skip_rounds`] times in a row.
    fn pick(&mut self, take: usize) -> Vec<Request> {
        let take = take.min(self.pending.len());
        if take == 0 {
            return Vec::new();
        }
        if self.policy == AdmissionPolicy::Fifo || take == self.pending.len() {
            self.starve = None;
            return self.pending.drain(..take).collect();
        }
        let head_id = self.pending[0].id;
        let skipped = match self.starve {
            Some((id, rounds)) if id == head_id => rounds,
            _ => 0,
        };
        if skipped >= self.max_skip_rounds {
            // age-based override: the head request has been passed over
            // its full allowance (`max_skip_rounds = 0` disables
            // grouping past the head entirely) — this pick is strict
            // FIFO, grouping resumes after
            self.starve = None;
            return self.pending.drain(..take).collect();
        }
        let mut order: Vec<usize> = (0..self.pending.len()).collect();
        order.sort_by_key(|&i| self.pending[i].extent());
        let mut best = 0usize;
        let mut best_spread = usize::MAX;
        for w in 0..=order.len() - take {
            let spread = self.pending[order[w + take - 1]].extent()
                - self.pending[order[w]].extent();
            if spread < best_spread {
                best_spread = spread;
                best = w;
            }
        }
        let mut picked: Vec<usize> = order[best..best + take].to_vec();
        picked.sort_unstable(); // arrival order within the batch
        self.starve = if picked[0] == 0 {
            None // the head request is served; nothing is starving
        } else {
            Some(match self.starve {
                Some((id, rounds)) if id == head_id => (id, rounds + 1),
                _ => (head_id, 1),
            })
        };
        let mut batch = Vec::with_capacity(take);
        for &i in picked.iter().rev() {
            batch.push(self.pending.remove(i).unwrap());
        }
        batch.reverse();
        batch
    }

    pub fn queue_len(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool;
    use std::sync::mpsc::channel;

    fn mk(buckets: Vec<usize>) -> (std::sync::mpsc::Sender<Request>, Batcher) {
        let (tx, rx) = channel();
        let b = Batcher::new(rx, buckets, Duration::from_millis(5));
        (tx, b)
    }

    #[test]
    fn bucket_selection() {
        let (_tx, b) = mk(vec![1, 4, 8]);
        assert_eq!(b.bucket_for(1), 1);
        assert_eq!(b.bucket_for(3), 1);
        assert_eq!(b.bucket_for(4), 4);
        assert_eq!(b.bucket_for(7), 4);
        assert_eq!(b.bucket_for(100), 8);
    }

    #[test]
    fn batches_are_fifo_and_lossless() {
        let (tx, mut b) = mk(vec![1, 4]);
        for i in 0..6 {
            tx.send(Request::new(i, vec![1], 4)).unwrap();
        }
        drop(tx);
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() == 1 || batch.len() == 4);
            seen.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_bucket_list_defaults_instead_of_panicking() {
        // regression: an empty bucket config used to blow up in
        // next_batch (buckets.last().unwrap()) mid-serve; it now degrades
        // to batch-size-1 service at construction
        let (tx, mut b) = mk(vec![]);
        assert_eq!(b.bucket_for(1), 1);
        assert_eq!(b.bucket_for(100), 1);
        for i in 0..3 {
            tx.send(Request::new(i, vec![1], 1)).unwrap();
        }
        drop(tx);
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert_eq!(batch.len(), 1);
            seen.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(seen, vec![0, 1, 2]);
    }

    #[test]
    fn closed_empty_returns_none() {
        let (tx, mut b) = mk(vec![1]);
        drop(tx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn fifo_per_producer_under_concurrent_producers() {
        // Several producer threads share the channel. Global arrival order
        // is scheduler-dependent, but the batcher must (a) never drop or
        // duplicate, and (b) preserve each producer's submission order
        // (mpsc is per-sender FIFO; draining must keep it that way).
        const PRODUCERS: u64 = 4;
        const PER_PRODUCER: u64 = 50;
        let (tx, rx) = channel();
        let mut b = Batcher::new(rx, vec![1, 4, 8], Duration::from_millis(1));
        let mut handles = Vec::new();
        for p in 0..PRODUCERS {
            let tx = tx.clone();
            handles.push(pool::spawn_named("producer", move || {
                for j in 0..PER_PRODUCER {
                    tx.send(Request::new(p * 1000 + j, vec![1], 1)).unwrap();
                }
            }));
        }
        drop(tx);
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            seen.extend(batch.iter().map(|r| r.id));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(seen.len() as u64, PRODUCERS * PER_PRODUCER);
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len() as u64, PRODUCERS * PER_PRODUCER, "duplicates");
        for p in 0..PRODUCERS {
            let mine: Vec<u64> = seen.iter().copied()
                .filter(|id| id / 1000 == p)
                .collect();
            assert!(
                mine.windows(2).all(|w| w[0] < w[1]),
                "producer {p} order violated: {mine:?}"
            );
        }
    }

    #[test]
    fn extent_grouping_packs_similar_requests() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(rx, vec![2], Duration::from_millis(0)).group_by_extent(true);
        // two long and two short requests, interleaved by arrival
        tx.send(Request::new(0, vec![1; 40], 40)).unwrap(); // extent 80
        tx.send(Request::new(1, vec![1; 4], 4)).unwrap(); // extent 8
        tx.send(Request::new(2, vec![1; 42], 40)).unwrap(); // extent 82
        tx.send(Request::new(3, vec![1; 6], 4)).unwrap(); // extent 10
        drop(tx);
        let first = b.next_batch().unwrap();
        let second = b.next_batch().unwrap();
        assert!(b.next_batch().is_none());
        let ids = |v: &[Request]| v.iter().map(|r| r.id).collect::<Vec<_>>();
        // lossless, and each batch holds the similar-extent pair, in
        // arrival order within the batch
        assert_eq!(ids(&first), vec![1, 3]);
        assert_eq!(ids(&second), vec![0, 2]);
    }

    #[test]
    fn extent_grouping_off_preserves_fifo() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(rx, vec![2], Duration::from_millis(0));
        tx.send(Request::new(0, vec![1; 40], 40)).unwrap();
        tx.send(Request::new(1, vec![1; 4], 4)).unwrap();
        tx.send(Request::new(2, vec![1; 42], 40)).unwrap();
        drop(tx);
        let first = b.next_batch().unwrap();
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn request_extent_is_prompt_plus_budget() {
        assert_eq!(Request::new(0, vec![1; 7], 5).extent(), 12);
    }

    #[test]
    fn request_page_budget_rounds_up() {
        let r = Request::new(0, vec![1; 7], 5); // extent 12
        assert_eq!(r.page_budget(16), 1);
        assert_eq!(r.page_budget(4), 3);
        assert_eq!(r.page_budget(5), 3);
        assert_eq!(r.page_budget(0), 12, "page 0 degrades to 1 position/page");
    }

    #[test]
    fn extent_grouping_cannot_starve_the_queue_head() {
        // regression: a lone large-extent request at the head of the
        // queue, facing an endless stream of similar small requests,
        // used to be passed over on every pick (the small pairs always
        // have the smaller spread). The anti-starvation override bounds
        // the head's wait to max_skip_rounds consecutive picks.
        let (tx, rx) = channel();
        let mut b = Batcher::new(rx, vec![2], Duration::from_millis(0))
            .admission(AdmissionPolicy::GroupExtent);
        tx.send(Request::new(0, vec![1; 60], 60)).unwrap(); // extent 120, head
        for i in 1..=20 {
            tx.send(Request::new(i, vec![1; 4], 4)).unwrap(); // extent 8
        }
        drop(tx);
        let mut batches_until_served = None;
        for round in 1..=10 {
            let batch = b.next_batch().unwrap();
            assert_eq!(batch.len(), 2);
            if batch.iter().any(|r| r.id == 0) {
                batches_until_served = Some(round);
                break;
            }
        }
        let served = batches_until_served.expect("request 0 starved for 10 batches");
        // skipped exactly max_skip_rounds times, forced on the next pick
        assert_eq!(served, b.max_skip_rounds + 1, "override must fire at the bound");
    }

    #[test]
    fn starvation_override_resets_once_head_is_served() {
        // after a forced FIFO pick the policy returns to extent grouping
        let (tx, rx) = channel();
        let mut b = Batcher::new(rx, vec![2], Duration::from_millis(0))
            .admission(AdmissionPolicy::GroupExtent);
        b.max_skip_rounds = 1;
        tx.send(Request::new(0, vec![1; 60], 60)).unwrap();
        for i in 1..=6 {
            tx.send(Request::new(i, vec![1; 4], 4)).unwrap();
        }
        drop(tx);
        let first = b.next_batch().unwrap(); // grouping skips the head once
        assert!(!first.iter().any(|r| r.id == 0));
        let second = b.next_batch().unwrap(); // forced FIFO: head + next
        assert!(second.iter().any(|r| r.id == 0), "override did not fire");
        let third = b.next_batch().unwrap(); // grouping again, no head left
        assert_eq!(third.len(), 2);
    }

    #[test]
    fn take_ready_is_nonblocking_and_policy_driven() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(rx, vec![1, 4], Duration::from_millis(50));
        // nothing pending: immediately empty, no blocking on the channel
        assert!(b.take_ready(4).is_empty());
        for i in 0..3 {
            tx.send(Request::new(i, vec![1], 1)).unwrap();
        }
        // partial feed: two free lanes take the two oldest
        let got = b.take_ready(2);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.queue_len(), 1);
        assert!(!b.drained());
        drop(tx);
        let got = b.take_ready(2);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        assert!(b.drained());
        assert!(b.take_ready(2).is_empty());
    }

    #[test]
    fn wait_ready_blocks_for_work_and_ends_on_close() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(rx, vec![1, 4], Duration::from_millis(1));
        let feeder = pool::spawn_named("feeder", move || {
            tx.send(Request::new(7, vec![1], 1)).unwrap();
            // tx drops here: channel closes after one request
        });
        let got = b.wait_ready(4);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 7);
        feeder.join().unwrap();
        // drained queue + closed channel: empty result, not a hang
        assert!(b.wait_ready(4).is_empty());
        assert!(b.drained());
    }

    #[test]
    fn take_ready_groups_by_extent_under_pressure() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(rx, vec![8], Duration::from_millis(0))
            .admission(AdmissionPolicy::GroupExtent);
        tx.send(Request::new(0, vec![1; 40], 40)).unwrap(); // extent 80
        tx.send(Request::new(1, vec![1; 4], 4)).unwrap(); // extent 8
        tx.send(Request::new(2, vec![1; 42], 40)).unwrap(); // extent 82
        tx.send(Request::new(3, vec![1; 6], 4)).unwrap(); // extent 10
        drop(tx);
        // two free lanes: the similar-extent small pair goes first
        let got = b.take_ready(2);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        let rest = b.take_ready(4);
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
    }

    #[test]
    fn prop_batcher_never_drops() {
        use crate::util::prop::check;
        check("batcher-lossless", 20,
              |g| {
                  let n = g.usize_in(1, 40);
                  let buckets = match g.usize_in(0, 2) {
                      0 => vec![1],
                      1 => vec![1, 4],
                      _ => vec![2, 8],
                  };
                  (n, buckets)
              },
              |&(n, ref buckets)| {
                  let (tx, rx) = channel();
                  let mut b = Batcher::new(rx, buckets.clone(),
                                           Duration::from_millis(0));
                  for i in 0..n as u64 {
                      tx.send(Request::new(i, vec![1], 1)).unwrap();
                  }
                  drop(tx);
                  let mut ids = Vec::new();
                  while let Some(batch) = b.next_batch() {
                      ids.extend(batch.iter().map(|r| r.id));
                  }
                  ids.len() == n && ids.windows(2).all(|w| w[0] < w[1])
              });
    }
}
