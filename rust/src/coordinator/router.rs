//! Expert router: turns dense top-k gate rows into per-expert token groups
//! for width-bucketed dispatch.
//!
//! The `moe_gate_n*` artifact returns gates [N, E] with exact zeros outside
//! each token's top-k. The router inverts that map: for every expert, the
//! (token index, gate weight) list of tokens routed to it — the unit of
//! work the serving loop feeds to `expert_n{N}_w{W}` executables.

use crate::tensor::Tensor;

#[derive(Clone, Debug, Default)]
pub struct ExpertGroup {
    pub token_idx: Vec<usize>,
    pub weights: Vec<f32>,
}

pub struct Router;

impl Router {
    /// gates: [N, E] dense top-k weights. Returns E groups.
    pub fn group(gates: &Tensor) -> Vec<ExpertGroup> {
        let mut groups = Vec::new();
        Self::group_into(gates, &mut groups);
        groups
    }

    /// [`group`], but reusing caller-owned scratch: `groups` is resized
    /// to E and each group's index/weight vectors are cleared in place,
    /// so a steady-state decode loop re-fills warm capacity instead of
    /// allocating E fresh groups per step.
    pub fn group_into(gates: &Tensor, groups: &mut Vec<ExpertGroup>) {
        let &[n, e] = gates.shape() else {
            panic!("gates must be [N,E], got {:?}", gates.shape())
        };
        groups.resize_with(e, ExpertGroup::default);
        for g in groups.iter_mut() {
            g.token_idx.clear();
            g.weights.clear();
        }
        for t in 0..n {
            for x in 0..e {
                let w = gates.at(&[t, x]);
                if w > 0.0 {
                    groups[x].token_idx.push(t);
                    groups[x].weights.push(w);
                }
            }
        }
    }

    /// Smallest bucket >= n from `buckets` (ascending); None if n == 0.
    /// Falls back to chunks of the largest bucket when n exceeds it (the
    /// caller loops).
    pub fn token_bucket(buckets: &[usize], n: usize) -> Option<usize> {
        if n == 0 {
            return None;
        }
        buckets.iter().find(|&&b| b >= n).copied().or(buckets.last().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn groups_invert_gates() {
        // 3 tokens, 2 experts
        let gates = Tensor::from_vec(&[3, 2], vec![0.7, 0.3, 0.0, 1.0, 0.5, 0.5]);
        let g = Router::group(&gates);
        assert_eq!(g[0].token_idx, vec![0, 2]);
        assert_eq!(g[0].weights, vec![0.7, 0.5]);
        assert_eq!(g[1].token_idx, vec![0, 1, 2]);
    }

    #[test]
    fn bucket_choice() {
        let b = vec![8, 32, 128];
        assert_eq!(Router::token_bucket(&b, 0), None);
        assert_eq!(Router::token_bucket(&b, 1), Some(8));
        assert_eq!(Router::token_bucket(&b, 9), Some(32));
        assert_eq!(Router::token_bucket(&b, 1000), Some(128));
    }

    #[test]
    fn prop_grouping_preserves_mass() {
        check("router-mass", 30,
              |g| {
                  let n = g.usize_in(1, 20);
                  let e = g.usize_in(1, 6);
                  let k = g.usize_in(1, e);
                  let mut data = vec![0.0f32; n * e];
                  for t in 0..n {
                      let picks = g.rng.choose_distinct(e, k);
                      for &p in &picks {
                          data[t * e + p] = 0.01 + g.rng.f32();
                      }
                  }
                  (n, e, k, data)
              },
              |&(n, e, k, ref data)| {
                  let gates = Tensor::from_vec(&[n, e], data.clone());
                  let groups = Router::group(&gates);
                  // every token appears exactly k times across groups
                  let mut count = vec![0usize; n];
                  let mut mass = vec![0.0f32; n];
                  for (ei, g) in groups.iter().enumerate() {
                      for (i, &t) in g.token_idx.iter().enumerate() {
                          count[t] += 1;
                          mass[t] += g.weights[i];
                          if (gates.at(&[t, ei]) - g.weights[i]).abs() > 1e-6 {
                              return false;
                          }
                      }
                  }
                  count.iter().all(|&c| c == k)
                      && mass.iter().enumerate().all(|(t, &m)| {
                          let want: f32 = (0..e).map(|x| gates.at(&[t, x])).sum();
                          (m - want).abs() < 1e-5
                      })
              });
    }
}
