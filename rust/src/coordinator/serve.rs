//! The serving engine: composes per-layer artifacts with rust-side routing,
//! width-bucketed expert dispatch and KV-cache decode.
//!
//! This is where HEAPr's atomic pruning turns into real latency: pruned
//! experts carry physically sliced weights whose retained width W selects a
//! smaller `expert_n{N}_w{W}` executable — fewer Pallas grid steps, fewer
//! FLOPs, measured end to end by `benches/bench_serve.rs`.
//!
//! Layer composition per token batch (python never runs):
//!   embed+pos (rust) → [attn_prefill | attn_decode] → moe_gate →
//!   router groups (rust) → expert_n{N}_w{W} per routed expert →
//!   weighted scatter-add + residual (rust) → … → lm_head → greedy sample.
//!
//! Two serve loops share this machinery:
//!
//! * [`Server::serve_batch`] — batch-synchronous: one closed batch is
//!   prefetched, decoded to completion, released. The reference loop:
//!   every per-request token stream is defined by it.
//! * [`crate::coordinator::scheduler`] — continuous batching over the
//!   same [`DecodeState`], made lane-granular here: [`Server::empty_state`]
//!   allocates KV lanes without a prefill, [`DecodeState::write_lane`]
//!   admits a new sequence into a freed lane mid-decode, and
//!   [`DecodeState::zero_lane`] retires lanes one at a time. Per-request
//!   outputs are bitwise identical between the two loops (tier-1
//!   `continuous_scheduler` tests) because every per-row computation in
//!   the layer composition is independent of batch composition.

use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::batcher::Request;
use crate::coordinator::router::{ExpertGroup, Router};
use crate::data::tokenizer::{EOS, PAD};
use crate::heapr::plan::{surgery, PrunePlan};
use crate::model::store::ParamStore;
use crate::model::WidthProfile;
use crate::runtime::{DeviceTensor, Engine, SArg, Session, Value};
use crate::tensor::{ITensor, Tensor};
use crate::util::pool;
use crate::util::pool::RowsPtr;

/// Host-side gather/scatter chunks smaller than this stay serial — pool
/// dispatch would dominate. Engine (device) calls are always serialized on
/// the caller thread; only the host-side copies fan out.
const PAR_MIN_ELEMS: usize = 1 << 13;

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub requests: usize,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub latencies_ms: Vec<f64>,
    pub expert_tokens: Vec<usize>, // routed token count per (layer*E + e)
    pub wall_s: f64,
    /// Batched decode iterations (one per generated position per batch).
    pub decode_steps: usize,
    /// Host->device bytes moved during decode ([`Engine::upload_stats`]
    /// deltas around the decode loop): the number the session refactor
    /// drives toward "one token embedding per step".
    pub decode_upload_bytes: u64,
    /// Subset of `decode_upload_bytes` spent re-uploading KV caches —
    /// exactly zero on the session path (asserted by tests).
    pub decode_kv_upload_bytes: u64,
    /// Physical KV pages allocated over the serve loop's lifetime
    /// (cumulative across decode states; paged residency only).
    pub kv_pages_allocated: u64,
    /// High-water mark of simultaneously live KV pages in any one state.
    pub kv_pages_peak: usize,
    /// Page mappings added by prefix-hit admissions: each is one shared
    /// page (refcount++), zero bytes moved, zero prefill GEMMs.
    pub prefix_pages_reused: u64,
    /// Prompt rows whose prefill compute was skipped because a resident
    /// prefix already held their K/V pages.
    pub prefill_rows_skipped: u64,
    /// Requests retired early by a [`crate::coordinator::CancelSet`]
    /// filing or the scheduler's deadline backstop — each one still
    /// passes through the normal retire path (counted in `requests`,
    /// KV zeroed), so `requests - cancelled_requests` is the number
    /// that ran to natural completion.
    pub cancelled_requests: usize,
}

impl ServeMetrics {
    pub fn throughput_tps(&self) -> f64 {
        if self.wall_s == 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.wall_s
    }

    /// Mean host->device traffic per decode step.
    pub fn upload_bytes_per_step(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.decode_upload_bytes as f64 / self.decode_steps as f64
    }

    /// Fraction of admitted prompt rows served from resident prefix
    /// pages instead of prefill compute.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prompt_tokens == 0 {
            return 0.0;
        }
        self.prefill_rows_skipped as f64 / self.prompt_tokens as f64
    }
}

/// Where decode state lives between steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    /// KV caches are engine residents ([`Session`]), sized to the batch's
    /// actual decode extent and appended to in place; per-step uploads
    /// shrink to the [bb, d] hidden-state vector and positions per layer —
    /// zero KV-cache bytes.
    Resident,
    /// Like [`Residency::Resident`], but lane rectangles are virtual:
    /// each lane owns a page table over a refcounted pool of fixed-size
    /// pages ([`crate::runtime::PagedKv`]). Allocation is lazy (a lane
    /// maps pages as rows are written or appended, never for its whole
    /// capacity up front) and prefix-hit admissions map shared pages
    /// instead of re-prefilling. Bitwise-identical token streams to
    /// `Resident` (tier-1 `continuous_scheduler` gate).
    Paged,
    /// PR-1 behavior: caches held host-side at the compiled maximum and
    /// re-uploaded (plus re-downloaded) every step. Kept selectable for
    /// the §Perf before/after measurement.
    Legacy,
}

impl Residency {
    /// `HEAPR_NO_BUFFER_CACHE=1` selects the legacy path, same switch as
    /// the weight-pinning fallback. Otherwise `HEAPR_KV_PAGE` picks the
    /// paged pool's page size (default 16 positions); `HEAPR_KV_PAGE=0`
    /// disables paging and keeps dense resident rectangles.
    pub fn from_env() -> Residency {
        if !buffer_cache_enabled() {
            Residency::Legacy
        } else if kv_page_from_env() == 0 {
            Residency::Resident
        } else {
            Residency::Paged
        }
    }
}

/// `HEAPR_KV_PAGE`: positions per KV page under paged residency
/// (default 16). `0` turns paging off (see [`Residency::from_env`]).
pub fn kv_page_from_env() -> usize {
    std::env::var("HEAPR_KV_PAGE").ok().and_then(|v| v.parse().ok()).unwrap_or(16)
}

/// Page size used when a paged state is constructed while the env says
/// "paging off": fall back to the default so a forced
/// [`Server::set_residency`]`(Paged)` still works.
fn effective_kv_page() -> usize {
    match kv_page_from_env() {
        0 => 16,
        p => p,
    }
}

/// Per-batch decode state returned by [`Server::prefill`] and advanced by
/// [`Server::decode_step`]; release (or drop) it at end of sequence.
pub struct DecodeState<'e> {
    kind: StateKind<'e>,
    /// KV capacity along the sequence axis.
    capacity: usize,
    /// Batch bucket the state was allocated for.
    bb: usize,
    /// KV layer count (fixed at construction).
    layers: usize,
}

enum StateKind<'e> {
    Resident(Session<'e>),
    Legacy(Vec<(Tensor, Tensor)>),
}

impl DecodeState<'_> {
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn bucket(&self) -> usize {
        self.bb
    }

    pub fn residency(&self) -> Residency {
        match &self.kind {
            StateKind::Resident(sess) if sess.is_paged() => Residency::Paged,
            StateKind::Resident(_) => Residency::Resident,
            StateKind::Legacy(_) => Residency::Legacy,
        }
    }

    /// Page size of the paged pool backing this state (`None` when the
    /// state is dense-resident or legacy).
    pub fn kv_page(&self) -> Option<usize> {
        match &self.kind {
            StateKind::Resident(sess) => sess.paged().map(|pk| pk.page_size()),
            StateKind::Legacy(_) => None,
        }
    }

    /// `(live, peak, total_allocated)` page counters of the paged pool.
    pub fn page_stats(&self) -> Option<(usize, usize, u64)> {
        match &self.kind {
            StateKind::Resident(sess) => sess
                .paged()
                .map(|pk| (pk.live_pages(), pk.peak_pages(), pk.pages_allocated_total())),
            StateKind::Legacy(_) => None,
        }
    }

    /// Map the first `npages` prompt-prefix pages of lane `src` into lane
    /// `dst` across every layer's K and V tables (paged residency only).
    /// Pure refcount bumps — zero bytes move, zero prefill compute — and
    /// the shared pages become immutable until one side retires. Returns
    /// the number of physical page mappings added.
    pub fn map_prefix(&mut self, src: usize, dst: usize, npages: usize) -> Result<usize> {
        match &mut self.kind {
            StateKind::Resident(sess) if sess.is_paged() => sess.map_prefix(src, dst, npages),
            _ => bail!("map_prefix requires paged residency"),
        }
    }

    /// Host copies of layer `l`'s (K, V) caches (tests / introspection).
    pub fn kv_cache(&self, l: usize) -> Result<(Tensor, Tensor)> {
        match &self.kind {
            StateKind::Resident(sess) => Ok((
                sess.download(&format!("kc{l}"))?.f32()?,
                sess.download(&format!("vc{l}"))?.f32()?,
            )),
            StateKind::Legacy(caches) => caches
                .get(l)
                .cloned()
                .ok_or_else(|| anyhow!("no cache for layer {l}")),
        }
    }

    /// KV layer count held by this state.
    pub fn n_layers(&self) -> usize {
        self.layers
    }

    /// Re-seat batch lane `lane` of layer `l`'s caches with a solo
    /// sequence's `[1, h, s, hd]` caches — the admission half of lane
    /// recycling. The lane is zeroed before the copy (the previous
    /// occupant's rows can never survive) and a source at a different
    /// capacity is truncated / zero-extended like `fit_cache` re-seats
    /// a prefill cache.
    pub fn write_lane(&mut self, l: usize, lane: usize, k: &Tensor, v: &Tensor) -> Result<()> {
        match &mut self.kind {
            StateKind::Resident(sess) => {
                sess.write_lane(&format!("kc{l}"), lane, k)?;
                sess.write_lane(&format!("vc{l}"), lane, v)
            }
            StateKind::Legacy(caches) => {
                let (kc, vc) =
                    caches.get_mut(l).ok_or_else(|| anyhow!("no cache for layer {l}"))?;
                crate::runtime::write_lane_f32(kc, lane, k)?;
                crate::runtime::write_lane_f32(vc, lane, v)
            }
        }
    }

    /// Seat a solo-prefilled sequence into batch lane `lane`: for every
    /// layer, the first `rows` cache rows of `solo` are copied in and
    /// the rest of the lane is zeroed.
    ///
    /// `rows` is the prompt length: a prefill computes K/V for the full
    /// compiled window, so rows past the prompt hold PAD-derived values
    /// a decode never reads (position `p` attends to rows `0..=p`, and
    /// rows from the prompt upward are appended by decode steps before
    /// they are ever attended). Dropping them costs nothing bitwise and
    /// is what makes the no-leak guarantee total: after admission the
    /// lane holds the new occupant's prompt rows and zeros — nothing of
    /// the previous occupant, and nothing of the solo state's padding.
    pub fn admit_lane(&mut self, lane: usize, solo: &DecodeState<'_>, rows: usize) -> Result<()> {
        let rows = rows.clamp(1, self.capacity());
        for l in 0..self.n_layers() {
            let (k, v) = solo.kv_cache(l)?;
            // a 1-prompt prefill still pads to the smallest serve-batch
            // bucket, which nothing guarantees is 1: take its lane 0,
            // trimmed to the prompt's rows, in one pass
            self.write_lane(l, lane, &lane_rows(&k, 0, rows), &lane_rows(&v, 0, rows))?;
        }
        Ok(())
    }

    /// Zero batch lane `lane` in every layer's caches — the retirement
    /// half of lane recycling: the sequence is finished, the lane is
    /// free, and whatever it held is gone *now*, not when the whole
    /// batch drains.
    pub fn zero_lane(&mut self, lane: usize) -> Result<()> {
        let n = self.n_layers();
        match &mut self.kind {
            StateKind::Resident(sess) => {
                for l in 0..n {
                    sess.zero_lane(&format!("kc{l}"), lane)?;
                    sess.zero_lane(&format!("vc{l}"), lane)?;
                }
                Ok(())
            }
            StateKind::Legacy(caches) => {
                for (kc, vc) in caches.iter_mut() {
                    crate::runtime::zero_lane_f32(kc, lane)?;
                    crate::runtime::zero_lane_f32(vc, lane)?;
                }
                Ok(())
            }
        }
    }

    /// End of sequence: free the engine residents. Dropping the state is
    /// equivalent; this spells out the prefill -> decode -> release
    /// lifecycle at call sites.
    pub fn release(mut self) {
        if let StateKind::Resident(sess) = &mut self.kind {
            sess.clear();
        }
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub latency_ms: f64,
}

struct ExpertWeights {
    /// device-resident weight buffers [wg, wu, wd] (§Perf: uploaded once at
    /// server build; per-call uploads are activations only)
    bufs: [DeviceTensor; 3],
    /// host copies for the legacy literal path (HEAPR_NO_BUFFER_CACHE=1,
    /// kept for the §Perf before/after measurement)
    host: [Tensor; 3],
    width: usize,
}

/// §Perf before/after switch: set HEAPR_NO_BUFFER_CACHE=1 to re-measure the
/// pre-optimization path (every input marshalled host->literal per call).
fn buffer_cache_enabled() -> bool {
    std::env::var("HEAPR_NO_BUFFER_CACHE").map(|v| v != "1").unwrap_or(true)
}

/// Per-layer device-resident static weights.
struct LayerBuffers {
    attn: [DeviceTensor; 5], // ln1, wq, wk, wv, wo
    ln2: DeviceTensor,
    router: DeviceTensor,
}

/// Every kernel / resident name the decode hot path ever asks for,
/// rendered once at server build: per-step `format!` calls are heap
/// allocations, and the steady-state decode loop must not allocate
/// (`hot-path-alloc`). Lookups are linear scans over a handful of
/// entries — allocation-free and cache-resident.
struct Names {
    /// `("kc{l}", "vc{l}")` per layer.
    kv: Vec<(String, String)>,
    /// `attn_decode_b{bb}` per serve-batch bucket.
    attn_decode: Vec<(usize, String)>,
    /// `moe_gate_n{nb}` per token bucket.
    moe_gate: Vec<(usize, String)>,
    /// `lm_head_n{nb}` per token bucket.
    lm_head: Vec<(usize, String)>,
    /// `expert_n{nb}_w{w}` per (token bucket, retained width) pair
    /// actually present in the served plan.
    expert: Vec<(usize, usize, String)>,
}

impl Names {
    fn build(cfg: &crate::config::ModelConfig, experts: &[Vec<ExpertWeights>]) -> Names {
        let mut widths: Vec<usize> =
            experts.iter().flatten().map(|e| e.width).filter(|&w| w > 0).collect();
        widths.sort_unstable();
        widths.dedup();
        Names {
            kv: (0..cfg.n_layers).map(|l| (format!("kc{l}"), format!("vc{l}"))).collect(),
            attn_decode: cfg
                .serve_batches
                .iter()
                .map(|&bb| (bb, format!("attn_decode_b{bb}")))
                .collect(),
            moe_gate: cfg
                .token_buckets
                .iter()
                .map(|&nb| (nb, format!("moe_gate_n{nb}")))
                .collect(),
            lm_head: cfg
                .token_buckets
                .iter()
                .map(|&nb| (nb, format!("lm_head_n{nb}")))
                .collect(),
            expert: cfg
                .token_buckets
                .iter()
                .flat_map(|&nb| {
                    widths.iter().map(move |&w| (nb, w, format!("expert_n{nb}_w{w}")))
                })
                .collect(),
        }
    }

    fn kv_names(&self, l: usize) -> Result<(&str, &str)> {
        self.kv
            .get(l)
            .map(|(k, v)| (k.as_str(), v.as_str()))
            .ok_or_else(|| anyhow!("no KV names for layer {l}"))
    }

    fn attn_name(&self, bb: usize) -> Result<&str> {
        self.attn_decode
            .iter()
            .find(|&&(b, _)| b == bb)
            .map(|(_, n)| n.as_str())
            .ok_or_else(|| anyhow!("no attn_decode artifact for bucket {bb}"))
    }

    fn gate_name(&self, nb: usize) -> Result<&str> {
        self.moe_gate
            .iter()
            .find(|&&(b, _)| b == nb)
            .map(|(_, n)| n.as_str())
            .ok_or_else(|| anyhow!("no moe_gate artifact for bucket {nb}"))
    }

    fn head_name(&self, nb: usize) -> Result<&str> {
        self.lm_head
            .iter()
            .find(|&&(b, _)| b == nb)
            .map(|(_, n)| n.as_str())
            .ok_or_else(|| anyhow!("no lm_head artifact for bucket {nb}"))
    }

    fn expert_name(&self, nb: usize, w: usize) -> Result<&str> {
        self.expert
            .iter()
            .find(|&&(b, ew, _)| b == nb && ew == w)
            .map(|(_, _, n)| n.as_str())
            .ok_or_else(|| anyhow!("no expert artifact for bucket {nb} width {w}"))
    }
}

pub struct Server<'e> {
    engine: &'e Engine,
    base: ParamStore,
    experts: Vec<Vec<ExpertWeights>>, // [layer][expert]
    layers: Vec<LayerBuffers>,
    lnf_buf: DeviceTensor,
    embed_buf: DeviceTensor,
    residency: Residency,
    kv_page: Option<usize>, // per-server page-size override (benchmarks)
    /// Precomputed hot-path kernel / resident names (see [`Names`]).
    names: Names,
    /// Decode-step scratch, reused across steps so the steady-state
    /// loop never heap-allocates: padded token / position rows, the
    /// per-group routed (token, weight) pairs, and the per-expert
    /// token groups the router re-fills each chunk.
    scratch_toks: Vec<i32>,
    scratch_poss: Vec<usize>,
    scratch_pairs: Vec<(usize, f32)>,
    scratch_groups: Vec<ExpertGroup>,
    pub widths: WidthProfile,
    pub metrics: ServeMetrics,
}

impl<'e> Server<'e> {
    /// Build from a full checkpoint and an optional (bucket-aligned!)
    /// pruning plan. With a plan, expert weights are physically sliced.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use heapr::coordinator::{Request, Server};
    /// use heapr::model::store::ParamStore;
    /// use heapr::runtime::Engine;
    ///
    /// let engine = Engine::open("artifacts/tiny").unwrap();
    /// let params = ParamStore::init(&engine.manifest, 0);
    /// let mut server = Server::new(&engine, &params, None).unwrap();
    /// let responses = server
    ///     .serve_batch(&[Request::new(0, vec![7, 8, 9], 4)])
    ///     .unwrap();
    /// assert_eq!(responses[0].id, 0);
    /// ```
    pub fn new(
        engine: &'e Engine,
        store: &ParamStore,
        plan: Option<&PrunePlan>,
    ) -> Result<Server<'e>> {
        let cfg = engine.config().clone();
        let full_plan;
        let plan = match plan {
            Some(p) => p,
            None => {
                full_plan = PrunePlan {
                    keep: vec![
                        vec![(0..cfg.d_inter).collect(); cfg.n_experts];
                        cfg.n_layers
                    ],
                    d_inter: cfg.d_inter,
                };
                &full_plan
            }
        };
        for layer in &plan.keep {
            for keep in layer {
                if keep.len() % cfg.blk_i != 0 {
                    return Err(anyhow!(
                        "plan width {} not a multiple of blk_i {} — call \
                         bucket_aligned() first",
                        keep.len(),
                        cfg.blk_i
                    ));
                }
            }
        }
        let sliced = surgery(store, plan)?;
        let up = |t: &Tensor| engine.upload(Value::F32(t.clone()));
        // Host-side weight prep (per-expert tensor clones — the dominant
        // build cost at scale) fans out across layers on the pool; engine
        // uploads stay serialized below per the engine discipline.
        let prepped: Vec<Result<Vec<([Tensor; 3], usize)>>> =
            pool::par_map(cfg.n_layers, |l| {
                (0..cfg.n_experts)
                    .map(|e| -> Result<([Tensor; 3], usize)> {
                        let wg = sliced.get(&format!("l{l}.e{e}.wg"))?;
                        let wu = sliced.get(&format!("l{l}.e{e}.wu"))?;
                        let wd = sliced.get(&format!("l{l}.e{e}.wd"))?;
                        let width = wg.shape()[0];
                        Ok(([wg.clone(), wu.clone(), wd.clone()], width))
                    })
                    .collect()
            });
        let mut experts = Vec::with_capacity(cfg.n_layers);
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for (l, row_prep) in prepped.into_iter().enumerate() {
            let mut row = Vec::with_capacity(cfg.n_experts);
            for ([wg, wu, wd], width) in row_prep? {
                // width-0 experts never execute; upload a 1-element dummy
                let bufs = if width == 0 {
                    let dummy = Tensor::zeros(&[1]);
                    [up(&dummy)?, up(&dummy)?, up(&dummy)?]
                } else {
                    [up(&wg)?, up(&wu)?, up(&wd)?]
                };
                row.push(ExpertWeights { bufs, host: [wg, wu, wd], width });
            }
            experts.push(row);
            layers.push(LayerBuffers {
                attn: [
                    up(store.get(&format!("l{l}.ln1"))?)?,
                    up(store.get(&format!("l{l}.wq"))?)?,
                    up(store.get(&format!("l{l}.wk"))?)?,
                    up(store.get(&format!("l{l}.wv"))?)?,
                    up(store.get(&format!("l{l}.wo"))?)?,
                ],
                ln2: up(store.get(&format!("l{l}.ln2"))?)?,
                router: up(store.get(&format!("l{l}.router"))?)?,
            });
        }
        let lnf_buf = up(store.get("lnf")?)?;
        let embed_buf = up(store.get("embed")?)?;
        let names = Names::build(&cfg, &experts);
        Ok(Server {
            engine,
            base: store.clone(),
            widths: plan.widths(),
            experts,
            layers,
            lnf_buf,
            embed_buf,
            residency: Residency::from_env(),
            kv_page: None,
            names,
            scratch_toks: Vec::new(),
            scratch_poss: Vec::new(),
            scratch_pairs: Vec::new(),
            scratch_groups: Vec::new(),
            metrics: ServeMetrics {
                expert_tokens: vec![0; cfg.n_layers * cfg.n_experts],
                ..Default::default()
            },
        })
    }

    /// Override the env-selected decode residency (tests, benchmarks).
    pub fn set_residency(&mut self, r: Residency) {
        self.residency = r;
    }

    /// Override the `HEAPR_KV_PAGE` page size for states this server
    /// builds (benchmark page-size sweeps; env mutation is unsafe once
    /// the worker pool is up). Ignored unless the residency is paged.
    pub fn set_kv_page(&mut self, page: usize) {
        self.kv_page = Some(page.max(1));
    }

    fn page_size(&self) -> usize {
        self.kv_page.unwrap_or_else(effective_kv_page)
    }

    /// The engine this server executes on (upload accounting, config).
    pub fn engine(&self) -> &'e Engine {
        self.engine
    }

    pub fn residency(&self) -> Residency {
        self.residency
    }

    /// The engine's model config, by reference: `cfg()` sits on every
    /// decode-hot call path, so it must not clone (`hot-path-alloc`).
    /// The `'e` lifetime means the borrow is independent of `self` —
    /// callers can hold it across `&mut self` calls.
    fn cfg(&self) -> &'e crate::config::ModelConfig {
        self.engine.config()
    }

    /// embed lookup + positional embedding; pad id embeds position anyway.
    fn embed(&self, tokens: &[i32], positions: &[usize]) -> Result<Tensor> {
        let cfg = self.cfg();
        let embed = self.base.get("embed")?;
        let pos = self.base.get("pos")?;
        let d = cfg.d_model;
        // lint:allow(hot-path-alloc) embed output is consumed by the value-ABI `Tensor::from_vec` below; no scratch row can back it
        let mut out = vec![0.0f32; tokens.len() * d];
        for (i, (&t, &p)) in tokens.iter().zip(positions).enumerate() {
            let trow = &embed.data()[(t as usize) * d..(t as usize + 1) * d];
            let prow = &pos.data()[p * d..(p + 1) * d];
            for j in 0..d {
                out[i * d + j] = trow[j] + prow[j];
            }
        }
        Ok(Tensor::from_vec(&[tokens.len(), d], out))
    }

    /// MoE layer over a flat token matrix [N, d]; returns x + moe(x).
    fn moe_layer(&mut self, l: usize, x: Tensor) -> Result<Tensor> {
        let cfg = self.cfg();
        let d = cfg.d_model;
        let n = x.shape()[0];
        let buckets = &cfg.token_buckets;
        let max_bucket = *buckets.last().context("token_buckets is non-empty")?;
        // lint:allow(hot-path-alloc) the residual accumulator must own a copy: experts scatter-add into `y` while `x` is still read for gathers
        let mut y = x.clone(); // residual accumulates expert outputs

        let mut start = 0usize;
        while start < n {
            let take = (n - start).min(max_bucket);
            let nb = Router::token_bucket(buckets, take)
                .context("chunk size fits the largest token bucket")?;
            // pad chunk to bucket
            // lint:allow(hot-path-alloc) chunk buffer is consumed by the value-ABI `Tensor::from_vec`; ownership moves into the engine call
            let mut chunk = vec![0.0f32; nb * d];
            chunk[..take * d]
                .copy_from_slice(&x.data()[start * d..(start + take) * d]);
            let chunk_t = Tensor::from_vec(&[nb, d], chunk);
            let out = if buffer_cache_enabled() {
                let chunk_b = self.engine.upload(Value::F32(chunk_t))?;
                self.engine.run_b(
                    self.names.gate_name(nb)?,
                    &[&chunk_b.buf, &self.layers[l].ln2.buf, &self.layers[l].router.buf],
                )?
            } else {
                self.run_moe_gate_legacy(l, nb, chunk_t)?
            };
            let mut out = out.into_iter();
            let xn = out.next().context("moe_gate returns (xn, gates)")?.f32()?;
            let gates = out.next().context("moe_gate returns (xn, gates)")?.f32()?;
            // per-expert groups reuse server-owned scratch: `group_into`
            // clears and re-fills warm Vec capacity instead of building
            // E fresh groups per chunk
            let mut groups = std::mem::take(&mut self.scratch_groups);
            Router::group_into(&gates, &mut groups);

            for (e, group) in groups.iter().enumerate() {
                // drop padding rows from the group; the pair list reuses
                // server-owned scratch (grown once, to the largest routed
                // group) so steady-state routing never heap-allocates
                let mut pairs = std::mem::take(&mut self.scratch_pairs);
                pairs.clear();
                pairs.extend(
                    group
                        .token_idx
                        .iter()
                        .zip(&group.weights)
                        .filter(|(&t, _)| t < take)
                        .map(|(&t, &w)| (t, w)),
                );
                if pairs.is_empty() {
                    self.scratch_pairs = pairs;
                    continue;
                }
                let ew = &self.experts[l][e];
                self.metrics.expert_tokens[l * cfg.n_experts + e] += pairs.len();
                if ew.width == 0 {
                    // fully pruned expert contributes nothing
                    self.scratch_pairs = pairs;
                    continue;
                }
                let ew_width = ew.width;
                let mut gstart = 0usize;
                while gstart < pairs.len() {
                    let gtake = (pairs.len() - gstart).min(max_bucket);
                    let gb = Router::token_bucket(buckets, gtake)
                        .context("group size fits the largest token bucket")?;
                    // lint:allow(hot-path-alloc) gather buffer is consumed by the value-ABI `Tensor::from_vec`; ownership moves into the engine call
                    let mut xs = vec![0.0f32; gb * d];
                    let gather = |i: usize, dst: &mut [f32]| {
                        let (t, _) = pairs[gstart + i];
                        dst.copy_from_slice(&xn.data()[t * d..(t + 1) * d]);
                    };
                    if gtake * d < PAR_MIN_ELEMS {
                        for i in 0..gtake {
                            gather(i, &mut xs[i * d..(i + 1) * d]);
                        }
                    } else {
                        // parallel gather: lane i fills row i only
                        // lint:allow(sendptr-confinement) disjoint-row gather; see SAFETY at the use site below
                        let ptr = RowsPtr::new(&mut xs);
                        pool::par_for(gtake, |i| {
                            // SAFETY: lane i writes only row i of xs —
                            // [i*d, (i+1)*d) ranges are disjoint across
                            // lanes, in bounds (xs is gb*d, gtake <= gb),
                            // and xs outlives the par_for.
                            gather(i, unsafe { ptr.slice(i * d, d) });
                        });
                    }
                    let xs_t = Tensor::from_vec(&[gb, d], xs);
                    let res = if buffer_cache_enabled() {
                        let xs_b = self.engine.upload(Value::F32(xs_t))?;
                        self.engine.run_b(
                            self.names.expert_name(gb, ew_width)?,
                            &[&xs_b.buf, &ew.bufs[0].buf, &ew.bufs[1].buf, &ew.bufs[2].buf],
                        )?
                    } else {
                        self.run_expert_legacy(l, e, gb, xs_t)?
                    };
                    let ys = res
                        .into_iter()
                        .next()
                        .context("expert kernel returns one output")?
                        .f32()?;
                    let scatter = |i: usize, dst: &mut [f32]| {
                        let (_, w) = pairs[gstart + i];
                        let src = &ys.data()[i * d..(i + 1) * d];
                        for j in 0..d {
                            dst[j] += w * src[j];
                        }
                    };
                    if gtake * d < PAR_MIN_ELEMS {
                        for i in 0..gtake {
                            let (t, _) = pairs[gstart + i];
                            let dst = (start + t) * d;
                            scatter(i, &mut y.data_mut()[dst..dst + d]);
                        }
                    } else {
                        // parallel scatter-add: token indices are unique
                        // within a group, so destination rows are disjoint
                        // lint:allow(sendptr-confinement) disjoint-row scatter; see SAFETY at the use site below
                        let ptr = RowsPtr::new(y.data_mut());
                        pool::par_for(gtake, |i| {
                            let (t, _) = pairs[gstart + i];
                            // SAFETY: token indices t are unique within
                            // the group, so lanes update disjoint rows
                            // [(start+t)*d, (start+t+1)*d) of y, all in
                            // bounds; y outlives the par_for.
                            scatter(i, unsafe { ptr.slice((start + t) * d, d) });
                        });
                    }
                    gstart += gtake;
                }
                self.scratch_pairs = pairs;
            }
            self.scratch_groups = groups;
            start += take;
        }
        Ok(y)
    }

    /// Legacy-path (`HEAPR_NO_BUFFER_CACHE=1`) MoE gate dispatch: the
    /// layer-norm and router weights round-trip by value on every call.
    /// Split out of [`Server::moe_layer`] as a declared cold boundary —
    /// the steady-state decode loop never takes this path, so its
    /// by-value clones stay out of the hot set.
    fn run_moe_gate_legacy(&self, l: usize, nb: usize, chunk_t: Tensor) -> Result<Vec<Value>> {
        self.engine.run(
            &format!("moe_gate_n{nb}"),
            &[
                Value::F32(chunk_t),
                Value::F32(self.base.get(&format!("l{l}.ln2"))?.clone()),
                Value::F32(self.base.get(&format!("l{l}.router"))?.clone()),
            ],
        )
    }

    /// Legacy-path expert dispatch for expert `e` of layer `l`: all
    /// three weight tensors round-trip by value. A declared cold
    /// boundary for the same reason as [`Server::run_moe_gate_legacy`].
    fn run_expert_legacy(&self, l: usize, e: usize, gb: usize, xs_t: Tensor) -> Result<Vec<Value>> {
        let ew = &self.experts[l][e];
        self.engine.run(
            &format!("expert_n{gb}_w{}", ew.width),
            &[
                Value::F32(xs_t),
                Value::F32(ew.host[0].clone()),
                Value::F32(ew.host[1].clone()),
                Value::F32(ew.host[2].clone()),
            ],
        )
    }

    /// Last-position logits for a set of row states [B, d].
    fn lm_head(&self, states: Tensor) -> Result<Tensor> {
        let cfg = self.cfg();
        let b = states.shape()[0];
        let d = cfg.d_model;
        let nb = Router::token_bucket(&cfg.token_buckets, b)
            .context("batch size fits the largest token bucket")?;
        // lint:allow(hot-path-alloc) padded lm_head input is consumed by the value-ABI `Tensor::from_vec`; ownership moves into the engine call
        let mut xs = vec![0.0f32; nb * d];
        xs[..b * d].copy_from_slice(states.data());
        let xs_t = Tensor::from_vec(&[nb, d], xs);
        let out = if buffer_cache_enabled() {
            let xs_b = self.engine.upload(Value::F32(xs_t))?;
            self.engine.run_b(
                self.names.head_name(nb)?,
                &[&xs_b.buf, &self.lnf_buf.buf, &self.embed_buf.buf],
            )?
        } else {
            self.run_lm_head_legacy(nb, xs_t)?
        };
        let logits = out
            .into_iter()
            .next()
            .context("lm_head kernel returns one output")?
            .f32()?;
        Ok(logits.slice0(0, b))
    }

    /// Legacy-path (`HEAPR_NO_BUFFER_CACHE=1`) LM-head dispatch: the
    /// final layer norm and the tied embedding matrix round-trip by
    /// value. A declared cold boundary for the same reason as
    /// [`Server::run_moe_gate_legacy`].
    fn run_lm_head_legacy(&self, nb: usize, xs_t: Tensor) -> Result<Vec<Value>> {
        self.engine.run(
            &format!("lm_head_n{nb}"),
            &[
                Value::F32(xs_t),
                Value::F32(self.base.get("lnf")?.clone()),
                Value::F32(self.base.get("embed")?.clone()),
            ],
        )
    }

    /// Full-batch prefill; returns per-seq last-position logits [B, V]
    /// and the decode state holding every layer's KV cache.
    ///
    /// On the [`Residency::Resident`] path the caches become session
    /// residents sized `max_i(prompt_i + max_new_tokens)` (clamped to the
    /// decode window) — short requests stop paying for `max_decode_len`
    /// rows. The legacy path keeps full-size host caches, matching the
    /// compiled artifact shapes it re-uploads each step.
    pub fn prefill(
        &mut self,
        prompts: &[Vec<i32>],
        max_new_tokens: usize,
    ) -> Result<(Tensor, DecodeState<'e>)> {
        let max_pos = self.cfg().seq_len.min(self.cfg().max_decode_len);
        let capacity = prompts
            .iter()
            .map(|p| (p.len() + max_new_tokens).min(max_pos))
            .max()
            .unwrap_or(max_pos);
        self.prefill_with_capacity(prompts, capacity)
    }

    /// [`Server::prefill`] with an explicit resident KV capacity —
    /// `serve_batch` sizes it per request ([`Request::extent`] clamped to
    /// the decode window), so one small-budget long prompt plus one
    /// large-budget short prompt does not allocate their sum. The value
    /// is clamped to `[longest prompt, decode window]`.
    pub fn prefill_with_capacity(
        &mut self,
        prompts: &[Vec<i32>],
        capacity: usize,
    ) -> Result<(Tensor, DecodeState<'e>)> {
        let cfg = self.cfg();
        let (t, d) = (cfg.seq_len, cfg.d_model);
        let bb = cfg
            .serve_batches
            .iter()
            .find(|&&b| b >= prompts.len())
            .copied()
            .ok_or_else(|| anyhow!("batch {} exceeds buckets", prompts.len()))?;
        let max_pos = cfg.seq_len.min(cfg.max_decode_len);
        let min_cap = prompts
            .iter()
            .map(|p| p.len())
            .max()
            .unwrap_or(1)
            .max(1)
            .min(max_pos);
        let capacity = capacity.clamp(min_cap, max_pos);
        let mut state = match self.residency {
            Residency::Resident => DecodeState {
                kind: StateKind::Resident(self.engine.session()),
                capacity,
                bb,
                layers: cfg.n_layers,
            },
            Residency::Paged => {
                let mut sess = self.engine.session();
                sess.alloc_paged(self.page_size(), cfg.n_heads, cfg.d_head, None)?;
                DecodeState {
                    kind: StateKind::Resident(sess),
                    capacity,
                    bb,
                    layers: cfg.n_layers,
                }
            }
            Residency::Legacy => DecodeState {
                kind: StateKind::Legacy(Vec::with_capacity(cfg.n_layers)),
                capacity: cfg.max_decode_len,
                bb,
                layers: cfg.n_layers,
            },
        };

        let mut tokens = vec![PAD; bb * t];
        let mut lmask = vec![0.0f32; bb * t];
        for (i, p) in prompts.iter().enumerate() {
            assert!(p.len() <= t, "prompt longer than seq_len");
            tokens[i * t..i * t + p.len()].copy_from_slice(p);
            for j in 0..p.len() {
                lmask[i * t + j] = 1.0;
            }
        }
        let positions: Vec<usize> = (0..bb * t).map(|i| i % t).collect();
        let x0 = self.embed(&tokens, &positions)?;
        let mut x = x0.reshape(&[bb, t, d])?;
        let lmask_t = Tensor::from_vec(&[bb, t], lmask);

        let lmask_b = self.engine.upload(Value::F32(lmask_t.clone()))?;
        for l in 0..cfg.n_layers {
            let out = if buffer_cache_enabled() {
                let x_b = self.engine.upload(Value::F32(x.clone()))?;
                let a = &self.layers[l].attn;
                self.engine.run_b(
                    &format!("attn_prefill_b{bb}"),
                    &[
                        &x_b.buf, &a[0].buf, &a[1].buf, &a[2].buf, &a[3].buf,
                        &a[4].buf, &lmask_b.buf,
                    ],
                )?
            } else {
                self.engine.run(
                    &format!("attn_prefill_b{bb}"),
                    &[
                        Value::F32(x.clone()),
                        Value::F32(self.base.get(&format!("l{l}.ln1"))?.clone()),
                        Value::F32(self.base.get(&format!("l{l}.wq"))?.clone()),
                        Value::F32(self.base.get(&format!("l{l}.wk"))?.clone()),
                        Value::F32(self.base.get(&format!("l{l}.wv"))?.clone()),
                        Value::F32(self.base.get(&format!("l{l}.wo"))?.clone()),
                        Value::F32(lmask_t.clone()),
                    ],
                )?
            };
            let [y, k, v]: [Value; 3] = out
                .try_into()
                .map_err(|_| anyhow!("attn_prefill output arity"))?;
            // place prefill K/V into decode caches (allocated once here)
            let (kt, vt) = (k.f32()?, v.f32()?);
            match &mut state.kind {
                StateKind::Resident(sess) if sess.is_paged() => {
                    // exact mirror of the dense resident below: every
                    // bucket lane (pad lanes included) seats its first
                    // min(t, capacity) prefill rows, so paged and dense
                    // caches download bit-identically; rows past t stay
                    // unmapped and read as the zeros fit_cache would
                    // have stored
                    let rows = t.min(state.capacity);
                    sess.alloc_paged_resident(format!("kc{l}"), bb, state.capacity)?;
                    sess.alloc_paged_resident(format!("vc{l}"), bb, state.capacity)?;
                    for lane in 0..bb {
                        sess.write_lane(&format!("kc{l}"), lane, &lane_rows(&kt, lane, rows))?;
                        sess.write_lane(&format!("vc{l}"), lane, &lane_rows(&vt, lane, rows))?;
                    }
                }
                StateKind::Resident(sess) => {
                    sess.alloc_resident(
                        format!("kc{l}"),
                        Value::F32(fit_cache(&kt, state.capacity)),
                    );
                    sess.alloc_resident(
                        format!("vc{l}"),
                        Value::F32(fit_cache(&vt, state.capacity)),
                    );
                }
                StateKind::Legacy(caches) => {
                    caches.push((
                        fit_cache(&kt, cfg.max_decode_len),
                        fit_cache(&vt, cfg.max_decode_len),
                    ));
                }
            }
            let flat = y.f32()?.reshape(&[bb * t, d])?;
            let merged = self.moe_layer(l, flat)?;
            x = merged.reshape(&[bb, t, d])?;
        }
        // last valid position per sequence
        let xf = x.reshape(&[bb * t, d])?;
        let mut states = vec![0.0f32; prompts.len() * d];
        for (i, p) in prompts.iter().enumerate() {
            let pos = i * t + p.len() - 1;
            states[i * d..(i + 1) * d]
                .copy_from_slice(&xf.data()[pos * d..(pos + 1) * d]);
        }
        let logits = self.lm_head(Tensor::from_vec(&[prompts.len(), d], states))?;
        Ok((logits, state))
    }

    /// Allocate a decode state of `lanes` zeroed KV lanes (rounded up to
    /// a serve-batch bucket) at sequence capacity `capacity` (clamped to
    /// the decode window), without running a prefill pass.
    ///
    /// This is the continuous scheduler's entry point: where
    /// [`Server::prefill`] sizes one state for one closed batch, an
    /// empty state outlives any single request — lanes are populated at
    /// admission ([`DecodeState::write_lane`]) and cleared at retirement
    /// ([`DecodeState::zero_lane`]) while the other lanes keep decoding.
    /// On the [`Residency::Legacy`] path capacity is pinned to the
    /// compiled `max_decode_len`, matching the artifact shapes that path
    /// re-uploads each step.
    pub fn empty_state(&mut self, lanes: usize, capacity: usize) -> Result<DecodeState<'e>> {
        let cfg = self.cfg();
        let bb = cfg
            .serve_batches
            .iter()
            .find(|&&b| b >= lanes)
            .copied()
            .ok_or_else(|| anyhow!("batch {} exceeds buckets", lanes))?;
        let max_pos = cfg.seq_len.min(cfg.max_decode_len);
        let capacity = capacity.clamp(1, max_pos);
        let (h, hd) = (cfg.n_heads, cfg.d_head);
        match self.residency {
            Residency::Resident => {
                let mut sess = self.engine.session();
                for l in 0..cfg.n_layers {
                    sess.alloc_resident(
                        format!("kc{l}"),
                        Value::F32(Tensor::zeros(&[bb, h, capacity, hd])),
                    );
                    sess.alloc_resident(
                        format!("vc{l}"),
                        Value::F32(Tensor::zeros(&[bb, h, capacity, hd])),
                    );
                }
                Ok(DecodeState {
                    kind: StateKind::Resident(sess),
                    capacity,
                    bb,
                    layers: cfg.n_layers,
                })
            }
            Residency::Paged => {
                // the per-lane capacity tier: `capacity` is only a page
                // table length here — no lane allocates a rectangle up
                // front, so an empty paged state holds zero KV bytes and
                // each lane's footprint tracks what it actually wrote
                let mut sess = self.engine.session();
                sess.alloc_paged(self.page_size(), h, hd, None)?;
                for l in 0..cfg.n_layers {
                    sess.alloc_paged_resident(format!("kc{l}"), bb, capacity)?;
                    sess.alloc_paged_resident(format!("vc{l}"), bb, capacity)?;
                }
                Ok(DecodeState {
                    kind: StateKind::Resident(sess),
                    capacity,
                    bb,
                    layers: cfg.n_layers,
                })
            }
            Residency::Legacy => {
                let caches = (0..cfg.n_layers)
                    .map(|_| {
                        (
                            Tensor::zeros(&[bb, h, cfg.max_decode_len, hd]),
                            Tensor::zeros(&[bb, h, cfg.max_decode_len, hd]),
                        )
                    })
                    .collect();
                Ok(DecodeState {
                    kind: StateKind::Legacy(caches),
                    capacity: cfg.max_decode_len,
                    bb,
                    layers: cfg.n_layers,
                })
            }
        }
    }

    /// One greedy decode step for `batch` sequences at `positions`
    /// (each must be below `state.capacity()`).
    ///
    /// Resident path: each layer appends one position into its KV
    /// residents via [`Session::run_s`]; per-step uploads are one
    /// [bb, d] hidden-state vector and the positions per layer (the
    /// token embedding at layer 0, intermediate activations after) —
    /// zero KV-cache bytes. Legacy path: both cache tensors round-trip
    /// through the engine every layer, every step.
    pub fn decode_step(
        &mut self,
        next_tokens: &[i32],
        positions: &[usize],
        state: &mut DecodeState<'e>,
    ) -> Result<Tensor> {
        let cfg = self.cfg();
        let d = cfg.d_model;
        let bb = state.bb;
        let b = next_tokens.len();
        assert!(b <= bb);
        // padded token/position rows live in server-owned scratch: the
        // steady-state decode loop allocates nothing per step
        let mut toks = std::mem::take(&mut self.scratch_toks);
        toks.clear();
        toks.resize(bb, PAD);
        toks[..b].copy_from_slice(next_tokens);
        let mut poss = std::mem::take(&mut self.scratch_poss);
        poss.clear();
        poss.resize(bb, 0);
        poss[..b].copy_from_slice(positions);
        let mut x = self.embed(&toks, &poss)?.reshape(&[bb, 1, d])?;

        // lint:allow(hot-path-alloc) the [bb] i32 position tensor is the designed per-step upload; `from_vec` consumes its Vec, so no scratch can back it
        let pos_t = ITensor::from_vec(&[bb], poss.iter().map(|&p| p as i32).collect());
        self.scratch_toks = toks;
        self.scratch_poss = poss;
        // lint:allow(hot-path-alloc) [bb]-element clone into the argument value wrapper — per-step position traffic, not a cache copy
        let pos_val = Value::I32(pos_t.clone());
        let pos_b = match &state.kind {
            StateKind::Legacy(_) if buffer_cache_enabled() => {
                // lint:allow(hot-path-alloc) legacy-path-only clone of the [bb] position tensor
                Some(self.engine.upload(Value::I32(pos_t.clone()))?)
            }
            _ => None,
        };
        for l in 0..cfg.n_layers {
            let flat = match &mut state.kind {
                StateKind::Resident(sess) => {
                    // the hidden state moves into the argument value — no
                    // per-layer clone; it is rebuilt from the MoE output below
                    let x_val = Value::F32(x);
                    let (kn, vn) = self.names.kv_names(l)?;
                    let a = &self.layers[l].attn;
                    let out = sess.run_s(
                        self.names.attn_name(bb)?,
                        &[
                            SArg::Val(&x_val),
                            SArg::Buf(&a[0].buf),
                            SArg::Buf(&a[1].buf),
                            SArg::Buf(&a[2].buf),
                            SArg::Buf(&a[3].buf),
                            SArg::Buf(&a[4].buf),
                            SArg::Res(kn),
                            SArg::Res(vn),
                            SArg::Val(&pos_val),
                        ],
                    )?;
                    let y = out
                        .into_iter()
                        .next()
                        .ok_or_else(|| anyhow!("attn_decode output arity"))?;
                    y.f32()?.reshape(&[bb, d])?
                }
                StateKind::Legacy(caches) => {
                    self.legacy_decode_attn(l, &x, bb, d, &pos_t, pos_b.as_ref(), caches)?
                }
            };
            let merged = self.moe_layer(l, flat)?;
            x = merged.reshape(&[bb, 1, d])?;
        }
        self.lm_head(x.reshape(&[bb, d])?.slice0(0, b))
    }

    /// One legacy-path decode attention step for layer `l`: both cache
    /// tensors round-trip through the engine by value (and re-upload
    /// under the buffer cache). Split out of [`Server::decode_step`] as
    /// a declared cold boundary — the resident path never enters it, so
    /// its per-step clones stay out of the hot set.
    #[allow(clippy::too_many_arguments)]
    fn legacy_decode_attn(
        &mut self,
        l: usize,
        x: &Tensor,
        bb: usize,
        d: usize,
        pos_t: &ITensor,
        pos_b: Option<&DeviceTensor>,
        caches: &mut [(Tensor, Tensor)],
    ) -> Result<Tensor> {
        let a = &self.layers[l].attn;
        let kv_bytes = ((caches[l].0.len() + caches[l].1.len()) * 4) as u64;
        let out = if buffer_cache_enabled() {
            let x_b = self.engine.upload(Value::F32(x.clone()))?;
            let kc_b = self.engine.upload(Value::F32(caches[l].0.clone()))?;
            let vc_b = self.engine.upload(Value::F32(caches[l].1.clone()))?;
            let pos_b =
                pos_b.context("pos buffer is uploaded when the buffer cache is on")?;
            self.engine.run_b(
                &format!("attn_decode_b{bb}"),
                &[
                    &x_b.buf, &a[0].buf, &a[1].buf, &a[2].buf,
                    &a[3].buf, &a[4].buf, &kc_b.buf, &vc_b.buf,
                    &pos_b.buf,
                ],
            )?
        } else {
            self.engine.run(
                &format!("attn_decode_b{bb}"),
                &[
                    Value::F32(x.clone()),
                    Value::F32(self.base.get(&format!("l{l}.ln1"))?.clone()),
                    Value::F32(self.base.get(&format!("l{l}.wq"))?.clone()),
                    Value::F32(self.base.get(&format!("l{l}.wk"))?.clone()),
                    Value::F32(self.base.get(&format!("l{l}.wv"))?.clone()),
                    Value::F32(self.base.get(&format!("l{l}.wo"))?.clone()),
                    Value::F32(caches[l].0.clone()),
                    Value::F32(caches[l].1.clone()),
                    Value::I32(pos_t.clone()),
                ],
            )?
        };
        self.metrics.decode_kv_upload_bytes += kv_bytes;
        let [y, kc, vc]: [Value; 3] = out
            .try_into()
            .map_err(|_| anyhow!("attn_decode output arity"))?;
        caches[l] = (kc.f32()?, vc.f32()?);
        y.f32()?.reshape(&[bb, d])
    }

    /// One greedy decode step for a *single lane* of a paged state — the
    /// tail prefill of a prefix-hit admission. Token `token` is embedded
    /// at `position`, appended into lane `lane`'s page tables and attended
    /// through a batch-1 decode artifact bound with [`SArg::ResLane`],
    /// leaving every other lane's caches untouched. Because a decode step
    /// at position `p` is bitwise identical to row `p` of a masked prefill
    /// (see `attend_softmax_v` in `runtime/host.rs`), replaying a prompt's
    /// tail through this method reproduces a cold prefill's cache rows and
    /// logits exactly.
    pub fn decode_lane_step(
        &mut self,
        token: i32,
        position: usize,
        state: &mut DecodeState<'e>,
        lane: usize,
    ) -> Result<Tensor> {
        let cfg = self.cfg();
        let d = cfg.d_model;
        if position >= state.capacity() {
            bail!("decode_lane_step: position {position} outside capacity {}", state.capacity());
        }
        if !cfg.serve_batches.contains(&1) {
            bail!(
                "decode_lane_step needs a b=1 decode artifact (serve_batches {:?})",
                cfg.serve_batches
            );
        }
        let mut x = self.embed(&[token], &[position])?.reshape(&[1, 1, d])?;
        // lint:allow(hot-path-alloc) single-element position tensor for the b=1 lane replay; `from_vec` consumes its Vec
        let pos_val = Value::I32(ITensor::from_vec(&[1], vec![position as i32]));
        for l in 0..cfg.n_layers {
            let StateKind::Resident(sess) = &mut state.kind else {
                bail!("decode_lane_step requires session residency");
            };
            let a = &self.layers[l].attn;
            // the hidden state moves into the argument value — no
            // per-layer clone; it is rebuilt from the MoE output below
            let x_val = Value::F32(x);
            let (kn, vn) = self.names.kv_names(l)?;
            let out = sess.run_s(
                "attn_decode_b1",
                &[
                    SArg::Val(&x_val),
                    SArg::Buf(&a[0].buf),
                    SArg::Buf(&a[1].buf),
                    SArg::Buf(&a[2].buf),
                    SArg::Buf(&a[3].buf),
                    SArg::Buf(&a[4].buf),
                    SArg::ResLane(kn, lane),
                    SArg::ResLane(vn, lane),
                    SArg::Val(&pos_val),
                ],
            )?;
            let y = out
                .into_iter()
                .next()
                .ok_or_else(|| anyhow!("attn_decode output arity"))?;
            let flat = y.f32()?.reshape(&[1, d])?;
            let merged = self.moe_layer(l, flat)?;
            x = merged.reshape(&[1, 1, d])?;
        }
        self.lm_head(x.reshape(&[1, d])?)
    }

    /// Fold a (paged) state's pool counters into the serve metrics. Call
    /// once per state lifetime, before [`DecodeState::release`] — the
    /// counters are cumulative within a pool, so absorbing twice would
    /// double-count. No-op for dense / legacy states.
    pub fn absorb_kv_stats(&mut self, state: &DecodeState<'_>) {
        if let Some((_live, peak, total)) = state.page_stats() {
            self.metrics.kv_pages_allocated += total;
            self.metrics.kv_pages_peak = self.metrics.kv_pages_peak.max(peak);
        }
    }

    /// Serve a batch of requests to completion (greedy decoding).
    pub fn serve_batch(&mut self, requests: &[Request]) -> Result<Vec<Response>> {
        let cfg = self.cfg();
        let t0 = Instant::now();
        let prompts: Vec<Vec<i32>> = requests.iter().map(|r| r.prompt.clone()).collect();
        let max_pos = cfg.seq_len.min(cfg.max_decode_len);
        // per-request extents, not prompt-max + budget-max: a long prompt
        // with a tiny budget must not inflate every lane's cache
        let capacity = requests
            .iter()
            .map(|r| r.extent().min(max_pos))
            .max()
            .unwrap_or(max_pos);
        let (logits, mut state) = self.prefill_with_capacity(&prompts, capacity)?;
        let b = prompts.len();

        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); b];
        let mut done = vec![false; b];
        let mut next: Vec<i32> = (0..b).map(|i| argmax_row(&logits, i)).collect();
        let mut positions: Vec<usize> = prompts.iter().map(|p| p.len()).collect();

        let upload0 = self.engine.upload_stats().1;
        loop {
            let mut active = false;
            for i in 0..b {
                if done[i] {
                    continue;
                }
                generated[i].push(next[i]);
                if next[i] == EOS
                    || generated[i].len() >= requests[i].max_new_tokens
                    || positions[i] + 1 >= max_pos
                {
                    done[i] = true;
                } else {
                    active = true;
                }
            }
            if !active {
                break;
            }
            // done lanes carry a stale position that can sit AT the
            // right-sized resident capacity (e.g. a full-window prompt
            // finishing on the first token); clamp them into range — their
            // cache rows and logits are never read again, and active
            // lanes always sit strictly below capacity, so generated
            // tokens are unaffected on both residency paths.
            let step_positions: Vec<usize> = positions
                .iter()
                .zip(&done)
                .map(|(&p, &d)| if d { p.min(state.capacity() - 1) } else { p })
                .collect();
            let logits = self.decode_step(&next, &step_positions, &mut state)?;
            self.metrics.decode_steps += 1;
            for i in 0..b {
                if !done[i] {
                    next[i] = argmax_row(&logits, i);
                    positions[i] += 1;
                }
            }
        }
        self.metrics.decode_upload_bytes += self.engine.upload_stats().1 - upload0;
        self.absorb_kv_stats(&state);
        state.release();
        let latency = t0.elapsed().as_secs_f64() * 1000.0;
        self.metrics.requests += b;
        self.metrics.prompt_tokens += prompts.iter().map(|p| p.len()).sum::<usize>();
        self.metrics.generated_tokens +=
            generated.iter().map(|g| g.len()).sum::<usize>();
        self.metrics.wall_s += latency / 1000.0;
        Ok(requests
            .iter()
            .zip(generated)
            .map(|(r, tokens)| {
                self.metrics.latencies_ms.push(latency);
                Response { id: r.id, tokens, latency_ms: latency }
            })
            .collect())
    }
}

/// Greedy token pick. Total and panic-free on NaN logits: a NaN never
/// beats a finite logit, so one poisoned lane cannot take down the
/// serving process (regression-tested below). Shared with the continuous
/// scheduler so both serve loops sample identically.
pub(crate) fn argmax_row(logits: &Tensor, row: usize) -> i32 {
    let v = logits.shape()[1];
    let xs = &logits.data()[row * v..(row + 1) * v];
    xs.iter()
        .enumerate()
        .max_by(|a, b| crate::util::cmp::f32_nan_first(*a.1, *b.1))
        .map_or(0, |(i, _)| i as i32)
}

/// Re-seat a [B, H, T, hd] prefill cache in a [B, H, S, hd] decode cache
/// of any capacity S: the first min(T, S) positions are copied, the rest
/// (if growing) zeroed. Runs once per sequence at prefill — per-step cache
/// movement is gone; the resident path appends in place instead.
fn fit_cache(kv: &Tensor, s: usize) -> Tensor {
    // lint:allow(panic-free-serve) shape invariant: prefill caches are always [B,H,T,hd] from the attn kernels
    let &[b, h, t, hd] = kv.shape() else { panic!("bad cache shape") };
    let keep = t.min(s);
    let mut out = Tensor::zeros(&[b, h, s, hd]);
    for bi in 0..b {
        for hi in 0..h {
            let src = ((bi * h) + hi) * t * hd;
            let dst = ((bi * h) + hi) * s * hd;
            out.data_mut()[dst..dst + keep * hd]
                .copy_from_slice(&kv.data()[src..src + keep * hd]);
        }
    }
    out
}

/// Extract one batch lane of a `[b, h, t, hd]` cache as `[1, h, rows, hd]`,
/// trimming (or zero-extending) the sequence axis to `rows` — the
/// admission copy, in a single pass. Shared with the scheduler's
/// compaction, which trims survivors to their written rows.
pub(crate) fn lane_rows(kv: &Tensor, lane: usize, rows: usize) -> Tensor {
    // lint:allow(panic-free-serve) shape invariant: decode caches are always [B,H,S,hd] from fit_cache / the KV pool
    let &[_b, h, t, hd] = kv.shape() else { panic!("bad cache shape") };
    let keep = t.min(rows);
    let mut out = Tensor::zeros(&[1, h, rows, hd]);
    for hi in 0..h {
        let src = ((lane * h) + hi) * t * hd;
        let dst = hi * rows * hd;
        out.data_mut()[dst..dst + keep * hd]
            .copy_from_slice(&kv.data()[src..src + keep * hd]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_row_picks_max() {
        let t = Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.2, 5.0, -1.0, 2.0]);
        assert_eq!(argmax_row(&t, 0), 1);
        assert_eq!(argmax_row(&t, 1), 0);
    }

    #[test]
    fn argmax_row_survives_nan_logits() {
        // regression: a single NaN logit used to panic the serving loop
        let t = Tensor::from_vec(&[2, 3], vec![f32::NAN, 0.9, 0.2, f32::NAN, f32::NAN, f32::NAN]);
        assert_eq!(argmax_row(&t, 0), 1, "NaN must not beat a number");
        let all_nan = argmax_row(&t, 1); // still a valid index, no panic
        assert!((0..3).contains(&all_nan));
    }

    #[test]
    fn fit_cache_grows_with_zeroed_tail() {
        let kv = Tensor::from_vec(&[1, 2, 2, 2], (0..8).map(|x| x as f32).collect());
        let g = fit_cache(&kv, 4);
        assert_eq!(g.shape(), &[1, 2, 4, 2]);
        assert_eq!(g.at(&[0, 0, 1, 1]), 3.0);
        assert_eq!(g.at(&[0, 1, 0, 0]), 4.0);
        assert_eq!(g.at(&[0, 0, 2, 0]), 0.0); // grown region zeroed
    }

    #[test]
    fn lane_rows_extracts_one_trimmed_lane() {
        // kv [2, 2, 2, 1]: lane 1 holds heads [[4, 5], [6, 7]]
        let kv = Tensor::from_vec(&[2, 2, 2, 1], (0..8).map(|x| x as f32).collect());
        let r = lane_rows(&kv, 1, 3);
        assert_eq!(r.shape(), &[1, 2, 3, 1]);
        assert_eq!(r.data(), &[4.0, 5.0, 0.0, 6.0, 7.0, 0.0]);
        // trimming below the source keeps the prefix
        let r = lane_rows(&kv, 0, 1);
        assert_eq!(r.shape(), &[1, 2, 1, 1]);
        assert_eq!(r.data(), &[0.0, 2.0]);
    }

    #[test]
    fn fit_cache_shrinks_to_capacity() {
        // resident caches are sized prompt+max_new < T: keep the prefix
        let kv = Tensor::from_vec(&[1, 2, 4, 1], (0..8).map(|x| x as f32).collect());
        let s = fit_cache(&kv, 2);
        assert_eq!(s.shape(), &[1, 2, 2, 1]);
        assert_eq!(s.data(), &[0.0, 1.0, 4.0, 5.0]);
    }
}
