//! Shared-prefix index for prefix-hit admission.
//!
//! The continuous scheduler registers every admitted prompt here. A later
//! request whose prompt shares leading **full pages** (page size =
//! `HEAPR_KV_PAGE` positions) with a live lane's prompt can seat by
//! mapping those pages (refcount++, zero bytes, zero GEMMs) and
//! prefilling only the tail — the shared-system-prompt pattern that
//! dominates chat traffic.
//!
//! The index is a chained page hash: for a registered prompt, page `k`'s
//! key is `H(H(...H(seed, page 0)..., page k-1), page k)`, so one map
//! lookup per candidate length finds every lane holding that exact
//! page-aligned prefix chain. Hashes only nominate; every hit is verified
//! token-exact against the lane's stored prompt before any page is
//! mapped, so a hash collision can cost a scan, never a wrong mapping.
//!
//! Sharing is capped at `(prompt.len() - 1) / page` pages for the
//! incoming request — at least one tail token always replays through the
//! lane-decode path so admission produces first-token logits — and at
//! `stored.len() / page` for the donor, so a donor's in-flight decode
//! appends (positions `>= stored.len()`) can never land in a page it
//! shared.

use std::collections::HashMap;

/// FNV-1a offset basis / prime (64-bit).
const FNV_SEED: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Extend a chain hash by one page of token ids.
fn chain_hash(seed: u64, page: &[i32]) -> u64 {
    let mut h = seed;
    for &t in page {
        for byte in t.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Index over the page-aligned prompt prefixes resident in live lanes.
pub struct PrefixIndex {
    page: usize,
    /// chain hash of pages `0..=k` of a registered prompt → lanes whose
    /// prompt covers that chain
    by_hash: HashMap<u64, Vec<usize>>,
    /// lane → registered prompt (token-exact verification + eviction);
    /// grown on demand so compaction-resized lane sets just work
    prompts: Vec<Option<Vec<i32>>>,
}

impl PrefixIndex {
    pub fn new(page: usize, lanes: usize) -> PrefixIndex {
        assert!(page > 0, "page size must be nonzero");
        PrefixIndex { page, by_hash: HashMap::new(), prompts: vec![None; lanes] }
    }

    /// Positions per page.
    pub fn page(&self) -> usize {
        self.page
    }

    /// Number of lanes currently registered.
    pub fn registered(&self) -> usize {
        self.prompts.iter().filter(|p| p.is_some()).count()
    }

    /// Register `lane` as holding `prompt`'s K/V rows. Replaces any
    /// previous registration for the lane.
    pub fn register(&mut self, lane: usize, prompt: &[i32]) {
        self.evict(lane);
        if lane >= self.prompts.len() {
            self.prompts.resize(lane + 1, None);
        }
        let mut h = FNV_SEED;
        for k in 0..prompt.len() / self.page {
            h = chain_hash(h, &prompt[k * self.page..(k + 1) * self.page]);
            self.by_hash.entry(h).or_default().push(lane);
        }
        self.prompts[lane] = Some(prompt.to_vec());
    }

    /// Drop `lane`'s registration (lane retired, or about to be reused).
    pub fn evict(&mut self, lane: usize) {
        let Some(prompt) = self.prompts.get_mut(lane).and_then(Option::take) else {
            return;
        };
        let mut h = FNV_SEED;
        for k in 0..prompt.len() / self.page {
            h = chain_hash(h, &prompt[k * self.page..(k + 1) * self.page]);
            if let Some(lanes) = self.by_hash.get_mut(&h) {
                lanes.retain(|&l| l != lane);
                if lanes.is_empty() {
                    self.by_hash.remove(&h);
                }
            }
        }
    }

    /// Forget everything (lane numbering changed, e.g. compaction).
    pub fn clear(&mut self) {
        self.by_hash.clear();
        self.prompts.iter_mut().for_each(|p| *p = None);
    }

    /// Best donor for `prompt`: the lane sharing the longest page-aligned
    /// token-exact prefix. Returns `(lane, npages)` with `npages >= 1`
    /// and `npages * page <= prompt.len() - 1` (a non-empty tail always
    /// remains to replay), or `None` when no full page matches.
    pub fn lookup(&self, prompt: &[i32]) -> Option<(usize, usize)> {
        let cap = prompt.len().saturating_sub(1) / self.page;
        let mut hashes = Vec::with_capacity(cap);
        let mut h = FNV_SEED;
        for k in 0..cap {
            h = chain_hash(h, &prompt[k * self.page..(k + 1) * self.page]);
            hashes.push(h);
        }
        for k in (1..=cap).rev() {
            let Some(lanes) = self.by_hash.get(&hashes[k - 1]) else { continue };
            for &lane in lanes {
                let Some(stored) = self.prompts.get(lane).and_then(Option::as_ref) else {
                    continue;
                };
                // token-exact verification: hashes nominate, never decide
                let n = k * self.page;
                if stored.len() >= n && stored[..n] == prompt[..n] {
                    return Some((lane, k));
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_finds_longest_page_aligned_prefix() {
        let mut idx = PrefixIndex::new(4, 2);
        idx.register(0, &[1, 2, 3, 4, 5, 6, 7, 8, 9]); // 2 full pages
        // identical first 8 tokens, then diverges: 2 shared pages, but the
        // incoming prompt of length 9 caps at (9-1)/4 = 2
        assert_eq!(idx.lookup(&[1, 2, 3, 4, 5, 6, 7, 8, 99]), Some((0, 2)));
        // only the first page matches
        assert_eq!(idx.lookup(&[1, 2, 3, 4, 99, 6, 7, 8, 9]), Some((0, 1)));
        // first page diverges: no hit
        assert_eq!(idx.lookup(&[9, 2, 3, 4, 5, 6, 7, 8, 9]), None);
    }

    #[test]
    fn lookup_always_leaves_a_tail_token() {
        let mut idx = PrefixIndex::new(4, 1);
        idx.register(0, &[1, 2, 3, 4, 5, 6, 7, 8]);
        // exact 8-token re-ask: only 1 page shareable, position 4..8 replay
        assert_eq!(idx.lookup(&[1, 2, 3, 4, 5, 6, 7, 8]), Some((0, 1)));
        // a prompt shorter than one page + 1 can never hit
        assert_eq!(idx.lookup(&[1, 2, 3, 4]), None);
    }

    #[test]
    fn donor_cap_respects_stored_full_pages() {
        let mut idx = PrefixIndex::new(4, 1);
        idx.register(0, &[1, 2, 3, 4, 5, 6]); // one full page only
        // 12-token prompt matching all 6 stored tokens: donor holds just
        // one full page, so only one page is shareable
        assert_eq!(idx.lookup(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]), Some((0, 1)));
    }

    #[test]
    fn evict_and_clear_forget_lanes() {
        let mut idx = PrefixIndex::new(2, 2);
        idx.register(0, &[1, 2, 3, 4]);
        idx.register(1, &[1, 2, 9, 9]);
        idx.evict(0);
        // lane 1 still serves the shared first page
        assert_eq!(idx.lookup(&[1, 2, 3, 4, 5]), Some((1, 1)));
        idx.clear();
        assert_eq!(idx.lookup(&[1, 2, 3, 4, 5]), None);
        assert_eq!(idx.registered(), 0);
    }

    #[test]
    fn register_replaces_previous_occupant() {
        let mut idx = PrefixIndex::new(2, 1);
        idx.register(0, &[1, 2, 3, 4]);
        idx.register(0, &[5, 6, 7, 8]);
        assert_eq!(idx.lookup(&[1, 2, 3, 4, 5]), None);
        assert_eq!(idx.lookup(&[5, 6, 7, 8, 9]), Some((0, 2)));
    }
}
